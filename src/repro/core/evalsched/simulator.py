"""Continuous-time cluster simulator for evaluation scheduling.

A minimal max-min fair-share engine: active tasks progress at rates that may
depend on global state (remote-storage loads share a per-node NIC, Fig. 16
left); fixed-duration stages progress at rate 1. The engine repeatedly
advances to the earliest completion, fires its callback (which mutates
scheduler state: frees a GPU, enqueues the next stage, ...), and recomputes
rates. Exact for piecewise-constant rates, which is all we need.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Optional

EPS = 1e-9


@dataclasses.dataclass
class Task:
    tid: int
    kind: str                     # "load" | "work"
    remaining: float              # bytes for loads, minutes for work
    node: Optional[int]           # loads: which node's NIC it uses
    on_done: Callable[["Engine"], None]
    tag: str = ""


class Engine:
    def __init__(self):
        self.t = 0.0
        self.tasks: dict[int, Task] = {}
        self._ids = itertools.count()
        self.rate_fn: Optional[Callable[[Task, "Engine"], float]] = None
        self.trace: list[tuple[float, str]] = []
        self.completed = 0        # total task completions (throughput probe)

    # -- task management ------------------------------------------------------

    def add(self, kind: str, amount: float, on_done, *, node=None,
            tag: str = "") -> int:
        tid = next(self._ids)
        self.tasks[tid] = Task(tid, kind, max(amount, 0.0), node, on_done, tag)
        return tid

    def loads_on_node(self, node: int) -> int:
        return sum(1 for t in self.tasks.values()
                   if t.kind == "load" and t.node == node)

    # -- main loop -------------------------------------------------------------

    def run(self, max_events: int = 1_000_000) -> float:
        for _ in range(max_events):
            if not self.tasks:
                return self.t
            rates = {tid: max(self.rate_fn(t, self), EPS)
                     for tid, t in self.tasks.items()}
            dt = min(t.remaining / rates[tid]
                     for tid, t in self.tasks.items())
            dt = max(dt, 0.0)
            self.t += dt
            done = []
            for tid, t in self.tasks.items():
                t.remaining -= rates[tid] * dt
                if t.remaining <= EPS:
                    done.append(tid)
            for tid in done:
                t = self.tasks.pop(tid)
                self.completed += 1
                if t.tag:
                    self.trace.append((self.t, t.tag))
                t.on_done(self)
        raise RuntimeError("simulator exceeded max_events")


@dataclasses.dataclass
class SimResult:
    makespan: float               # minutes
    gpu_busy_minutes: float       # GPU actually computing (inference)
    gpu_held_minutes: float       # GPU allocated to a trial (incl. idle)
    n_gpus: int
    trace: list[tuple[float, str]]
    n_events: int = 0             # engine task completions (throughput probe)

    @property
    def gpu_utilization(self) -> float:
        """Busy fraction of the allocation — the paper's 'GPU idle' lens."""
        if self.gpu_held_minutes <= 0:
            return 0.0
        return self.gpu_busy_minutes / self.gpu_held_minutes

    @property
    def gpu_occupancy(self) -> float:
        """Busy fraction of the whole (makespan x fleet) area."""
        area = self.makespan * self.n_gpus
        return self.gpu_busy_minutes / area if area else 0.0
