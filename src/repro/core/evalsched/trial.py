"""Evaluation trials: datasets, runtime priors, and the coordinator's
decomposition step (split / merge / sort by prior knowledge).

Paper §6.2: "our prior knowledge regarding the approximate trial runtime
for each evaluation dataset is quite robust. Furthermore, these datasets are
flexible, allowing us to batch multiple sets into one trial to circumvent
model loading. We can also break down large datasets and decouple metric
computation."
"""
from __future__ import annotations

import dataclasses
import random
from typing import Optional


@dataclasses.dataclass(frozen=True)
class EvalDataset:
    """One benchmark dataset with its runtime priors (minutes, 1 GPU)."""
    name: str
    n_samples: int
    gpu_minutes: float            # inference time for the full set
    cpu_metric_minutes: float     # post-inference CPU-only metric time
    preprocess_minutes: float     # tokenization / few-shot prompt build
    splittable: bool = True

    @property
    def total_minutes(self) -> float:
        return self.gpu_minutes + self.cpu_metric_minutes + self.preprocess_minutes


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """The evaluation slice of the cluster + its storage model (Fig. 16 left).

    The paper's nodes have a 25 Gb/s storage NIC; loading collapses as
    concurrent single-GPU trials per node grow 1 -> 8, then stabilizes —
    i.e. the per-node NIC is the bottleneck, fairly shared among streams,
    with a per-stream ceiling below the NIC line rate.
    """
    n_nodes: int
    gpus_per_node: int = 8
    storage_nic_gbps: float = 25.0      # Gb/s per node, shared by loads
    stream_gbps: float = 12.0           # single remote-read stream ceiling
    pcie_gbps: float = 128.0            # shm -> GPU staging (decoupled path)
    model_bytes: float = 14e9           # 7B model, bf16
    cpu_slots: int = 128                # per node, for decoupled metric jobs
    dump_minutes: float = 0.02          # writing generations to files

    @property
    def n_gpus(self) -> int:
        return self.n_nodes * self.gpus_per_node

    def load_minutes_shared(self, concurrent: int) -> float:
        """Remote load time with ``concurrent`` streams on one node."""
        per_stream = min(self.stream_gbps, self.storage_nic_gbps / max(concurrent, 1))
        return self.model_bytes * 8 / (per_stream * 1e9) / 60.0

    @property
    def shm_load_minutes(self) -> float:
        return self.model_bytes * 8 / (self.pcie_gbps * 1e9) / 60.0


# The canonical storage-model-only spec for ``TrialBorrower``: the borrower
# prices NIC-shared loads purely from a spec's storage rates
# (``load_minutes_shared``); node *topology* comes from the replay engine's
# ``NodeLedger``, so the node count here is irrelevant.
STORAGE_SPEC = ClusterSpec(n_nodes=1)


# ---------------------------------------------------------------------------
# the 63-dataset suite (synthetic but shaped like the paper's: OpenCompass-
# style mixture — a few code sets with long CPU tails, several large
# knowledge sets, a tail of small fast sets)
# ---------------------------------------------------------------------------

_CODE = [("humaneval", 164, 2.0, 1.0), ("mbpp", 500, 4.5, 3.5),
         ("humaneval_cn", 164, 2.1, 1.0), ("mbpp_cn", 500, 4.6, 3.6),
         ("ds1000", 1000, 7.0, 6.0), ("apps", 700, 9.0, 14.0)]
_LARGE = [("mmlu", 14042, 22.0, 0.4), ("ceval", 12342, 19.0, 0.4),
          ("cmmlu", 11528, 18.0, 0.4), ("agieval", 8062, 15.0, 0.3),
          ("bbh", 6511, 17.0, 0.5), ("flores", 8000, 16.0, 0.6)]


def standard_suite(n: int = 63, seed: int = 0) -> list[EvalDataset]:
    """A deterministic suite of ``n`` datasets matching the paper's shape."""
    rng = random.Random(seed)
    out: list[EvalDataset] = []
    for name, ns, g, c in _CODE:
        out.append(EvalDataset(name, ns, g, c, preprocess_minutes=0.4))
    for name, ns, g, c in _LARGE:
        out.append(EvalDataset(name, ns, g, c, preprocess_minutes=0.9))
    i = 0
    while len(out) < n:
        ns = rng.randint(200, 3000)
        g = round(rng.uniform(0.8, 8.0), 2)
        c = round(rng.choices([rng.uniform(0.02, 0.3), rng.uniform(1.0, 6.0)],
                              weights=[0.8, 0.2])[0], 2)
        out.append(EvalDataset(f"task{i:02d}", ns, g, c,
                               preprocess_minutes=round(rng.uniform(0.1, 0.6), 2),
                               splittable=rng.random() < 0.8))
        i += 1
    return out[:n]


# ---------------------------------------------------------------------------
# decomposition: split large sets, merge small ones, sort by priors
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WorkItem:
    """A schedulable unit: one shard of one dataset, or a merged bundle."""
    name: str
    gpu_minutes: float
    cpu_metric_minutes: float
    preprocess_minutes: float
    datasets: tuple[str, ...]     # provenance


def plan_work_items(datasets: list[EvalDataset], n_gpus: int, *,
                    split_target_minutes: Optional[float] = None,
                    merge_below_minutes: float = 2.0) -> list[WorkItem]:
    """The coordinator's prior-based decomposition.

    * split: any splittable dataset whose GPU time exceeds the target shard
      length is cut into equal shards (metric time splits pro rata);
    * merge: runt datasets are bundled (greedy) so per-trial overhead
      amortizes;
    * sort: longest-processing-time first, with long CPU tails boosted so
      their metric computation overlaps the remaining GPU work.
    """
    total_gpu = sum(d.gpu_minutes for d in datasets)
    if split_target_minutes is None:
        # aim for ~4 shards per GPU wave, bounded to something sensible
        split_target_minutes = max(2.0, total_gpu / max(n_gpus, 1) / 4)

    items: list[WorkItem] = []
    runts: list[EvalDataset] = []
    for d in datasets:
        if d.splittable and d.gpu_minutes > split_target_minutes * 1.5:
            shards = int(-(-d.gpu_minutes // split_target_minutes))
            for s in range(shards):
                items.append(WorkItem(
                    f"{d.name}[{s}/{shards}]",
                    d.gpu_minutes / shards,
                    d.cpu_metric_minutes / shards,
                    d.preprocess_minutes / shards,
                    (d.name,)))
        elif d.total_minutes < merge_below_minutes:
            runts.append(d)
        else:
            items.append(WorkItem(d.name, d.gpu_minutes,
                                  d.cpu_metric_minutes,
                                  d.preprocess_minutes, (d.name,)))
    # greedy bundle of runts up to the shard target
    runts.sort(key=lambda d: -d.total_minutes)
    bundle: list[EvalDataset] = []
    acc = 0.0
    for d in runts:
        if bundle and acc + d.gpu_minutes > split_target_minutes:
            items.append(_bundle(bundle))
            bundle, acc = [], 0.0
        bundle.append(d)
        acc += d.gpu_minutes
    if bundle:
        items.append(_bundle(bundle))

    # sorted queue: long CPU tails first (they must start early to overlap),
    # then LPT on GPU time
    items.sort(key=lambda w: (-w.cpu_metric_minutes, -w.gpu_minutes))
    return items


def _bundle(ds: list[EvalDataset]) -> WorkItem:
    return WorkItem(
        "+".join(d.name for d in ds),
        sum(d.gpu_minutes for d in ds),
        sum(d.cpu_metric_minutes for d in ds),
        sum(d.preprocess_minutes for d in ds),
        tuple(d.name for d in ds))


# ---------------------------------------------------------------------------
# borrowed-capacity trials: single-GPU shards leased from the replay free
# pool (the §6.2 side of the elastic capacity pool)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BorrowItem:
    """One preemptible single-GPU trial shard for the borrowing bridge.

    ``remaining_min`` is mutable execution state: it starts at the shard's
    GPU minutes, has the decomposed-trial (re)start cost added on every
    lease (the model must be re-staged from node shm and the prompt cache
    rebuilt), and ticks down while the shard holds a leased GPU. Progress
    is *kept* across preemptions — decoupled trials dump outputs
    incrementally (§6.2), so a revoked lease costs only the restart
    overhead, not the shard's in-flight work.
    """
    name: str
    work_min: float               # nominal single-GPU inference minutes
    remaining_min: float = 0.0    # work (+ charged overheads) still to run
    leases: int = 0               # times this shard acquired a GPU
    overhead_min: float = 0.0     # total (re)start cost charged so far

    def __post_init__(self):
        if self.remaining_min == 0.0:
            self.remaining_min = self.work_min


def plan_borrow_items(datasets: list[EvalDataset], *, repeat: int = 1,
                      shard_target_minutes: float = 4.0) -> list:
    """Decompose ``datasets`` into preemptible single-GPU shards for
    :class:`~repro.core.evalsched.coordinator.TrialBorrower`.

    Reuses the coordinator's prior-based split/merge planning (so shard
    sizes bound the work a preemption can ever strand) and repeats the
    suite ``repeat`` times — one copy per tracked checkpoint, matching the
    paper's per-checkpoint evaluation batches."""
    items: list[BorrowItem] = []
    planned = plan_work_items(datasets, n_gpus=1,
                              split_target_minutes=shard_target_minutes)
    for rep in range(max(repeat, 1)):
        for w in planned:
            name = w.name if repeat <= 1 else f"ckpt{rep}:{w.name}"
            items.append(BorrowItem(name, w.gpu_minutes))
    return items
