"""A *real* (non-simulated) decoupled mini-evaluation on CPU.

Validates the §6.2 design with actual execution: a small JAX model performs
genuine batched inference; model "loading" reads a serialized checkpoint
from a bandwidth-throttled "remote" file; metric computation emulates the
paper's subprocess-based program-correctness tests (external processes, so a
sleep is the honest model of the GPU-side cost). Baseline holds a worker
through load+infer+metric; the decoupled runner stages the model once,
frees workers after inference, and runs metrics on a separate CPU pool.
"""
from __future__ import annotations

import dataclasses
import os
import pickle
import tempfile
import threading
import time
from concurrent.futures import ThreadPoolExecutor, wait
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model


@dataclasses.dataclass(frozen=True)
class MiniDataset:
    name: str
    prompts: np.ndarray            # (n, seq) int32
    metric_seconds: float          # external correctness-test time


@dataclasses.dataclass
class MiniEvalResult:
    makespan_s: float
    n_inferences: int
    per_stage: dict


def make_suite(model: Model, *, n_datasets: int = 8, n_prompts: int = 4,
               seq: int = 16, seed: int = 0,
               heavy_tail: float = 1.2) -> list[MiniDataset]:
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n_datasets):
        prompts = rng.integers(0, model.cfg.vocab_size,
                               size=(n_prompts, seq)).astype(np.int32)
        metric = heavy_tail if i == 0 else 0.05 + 0.1 * rng.random()
        out.append(MiniDataset(f"mini{i}", prompts, metric))
    return out


class RemoteStore:
    """Checkpoint file + bandwidth-throttled reads (the contended PFS)."""

    def __init__(self, params, bandwidth_mbps: float = 400.0):
        self.bandwidth = bandwidth_mbps * 1e6
        self._lock = threading.Lock()
        self._readers = 0
        fd, self.path = tempfile.mkstemp(suffix=".ckpt")
        with os.fdopen(fd, "wb") as f:
            pickle.dump(jax.tree_util.tree_map(np.asarray, params), f)
        self.size = os.path.getsize(self.path)

    def load(self):
        """Fair-share read: concurrent readers split the bandwidth."""
        with self._lock:
            self._readers += 1
            readers = self._readers
        t = self.size / (self.bandwidth / max(readers, 1))
        time.sleep(t)
        with open(self.path, "rb") as f:
            params = pickle.load(f)
        with self._lock:
            self._readers -= 1
        return jax.tree_util.tree_map(jnp.asarray, params)

    def close(self):
        os.unlink(self.path)


def _make_infer(model: Model, warm_params, example: MiniDataset):
    """jit'd inference fn, compiled (warm) before any timing starts."""
    fn = jax.jit(lambda p, toks: jnp.argmax(
        model.forward_logits(p, {"tokens": toks}), axis=-1))
    fn(warm_params, jnp.asarray(example.prompts)).block_until_ready()

    def infer(params, ds: MiniDataset) -> np.ndarray:
        return np.asarray(fn(params, jnp.asarray(ds.prompts)))
    return infer


def _metric(ds: MiniDataset, outputs: np.ndarray) -> float:
    time.sleep(ds.metric_seconds)       # external program-correctness tests
    return float(np.mean(outputs % 7 == 0))


def run_baseline(model: Model, store: RemoteStore,
                 datasets: list[MiniDataset], *,
                 n_workers: int = 2,
                 warm_params=None) -> MiniEvalResult:
    stages = {"load": 0.0, "infer": 0.0, "metric": 0.0}
    lock = threading.Lock()
    infer = _make_infer(model, warm_params, datasets[0])

    def trial(ds: MiniDataset):
        t0 = time.perf_counter()
        params = store.load()               # re-loaded per trial (contended)
        t1 = time.perf_counter()
        outs = infer(params, ds)
        t2 = time.perf_counter()
        _metric(ds, outs)                   # worker held while GPU idles
        t3 = time.perf_counter()
        with lock:
            stages["load"] += t1 - t0
            stages["infer"] += t2 - t1
            stages["metric"] += t3 - t2

    t0 = time.perf_counter()
    with ThreadPoolExecutor(n_workers) as ex:
        wait([ex.submit(trial, d) for d in datasets])
    return MiniEvalResult(time.perf_counter() - t0, len(datasets), stages)


def run_decoupled(model: Model, store: RemoteStore,
                  datasets: list[MiniDataset], *, n_workers: int = 2,
                  n_cpu: int = 8, warm_params=None) -> MiniEvalResult:
    stages = {"load": 0.0, "infer": 0.0, "metric": 0.0}
    lock = threading.Lock()
    infer = _make_infer(model, warm_params, datasets[0])

    t0 = time.perf_counter()
    params = store.load()                   # precursor: staged once
    stages["load"] = time.perf_counter() - t0

    # sorted queue: long metric tails first so they overlap remaining work
    queue = sorted(datasets, key=lambda d: -d.metric_seconds)
    metric_pool = ThreadPoolExecutor(n_cpu)
    metric_futs = []

    def trial(ds: MiniDataset):
        t1 = time.perf_counter()
        outs = infer(params, ds)
        with lock:
            stages["infer"] += time.perf_counter() - t1
        metric_futs.append(metric_pool.submit(_metric, ds, outs))

    with ThreadPoolExecutor(n_workers) as ex:
        wait([ex.submit(trial, d) for d in queue])
    wait(metric_futs)
    metric_pool.shutdown()
    return MiniEvalResult(time.perf_counter() - t0, len(datasets), stages)
