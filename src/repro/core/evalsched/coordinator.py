"""The trial coordinator: baseline vs decoupled evaluation scheduling.

Baseline (paper Fig. 16 right (a)): every dataset is one monolithic trial —
the GPU is held through remote model load (contending for the node storage
NIC), preprocessing, inference, and CPU-only metric computation.

Decoupled (Fig. 16 right (b), our system):
  1. precursor jobs stage the model once per node into shared memory;
     eval trials then load over PCIe instead of the remote PFS;
  2. after inference the outputs are dumped to files and the GPU is freed;
     metric computation runs in separate CPU jobs;
  3. prior-based elastic scheduling: large datasets are split, runts are
     merged, and the queue is sorted so long-CPU-tail items start first
     (their metric jobs overlap remaining GPU work).

Borrowed capacity (§6.1 x §6.2, the elastic capacity pool): decomposed
trials are flexible enough to run on *revocable* GPUs, so
:class:`TrialBorrower` leases idle-fragment and shrunken-job capacity from
the replay engine's free-GPU ledger (``repro.cluster.replay``). Leases are
instantly revocable — the lender cluster preempts them the moment a queued
job dispatches or a shrunken job regrows — and a preempted shard pays only
the decomposed-trial restart cost, because its outputs were dumped
incrementally. See ``ReplayConfig.borrower``.
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Optional

from repro.core.evalsched.simulator import Engine, SimResult
from repro.core.evalsched.trial import (BorrowItem, ClusterSpec, EvalDataset,
                                        WorkItem, plan_borrow_items,
                                        plan_work_items, standard_suite)


# ---------------------------------------------------------------------------
# shared bits
# ---------------------------------------------------------------------------

def _load_rate_fn(spec: ClusterSpec):
    """bytes/minute for 'load' tasks (per-node fair share); 1.0 for 'work'."""
    def rate(task, eng: Engine) -> float:
        if task.kind != "load":
            return 1.0
        k = eng.loads_on_node(task.node)
        gbps = min(spec.stream_gbps, spec.storage_nic_gbps / max(k, 1))
        return gbps * 1e9 / 8 * 60.0
    return rate


@dataclasses.dataclass
class _Gpu:
    node: int
    busy: bool = False


class _Accounting:
    def __init__(self):
        self.busy = 0.0     # inference minutes
        self.held = 0.0     # allocation minutes (incl. idle stages)


# ---------------------------------------------------------------------------
# baseline: one monolithic trial per dataset
# ---------------------------------------------------------------------------

def schedule_baseline(datasets: list[EvalDataset],
                      spec: ClusterSpec) -> SimResult:
    eng = Engine()
    eng.rate_fn = _load_rate_fn(spec)
    gpus = [_Gpu(node=i // spec.gpus_per_node) for i in range(spec.n_gpus)]
    queue = list(datasets)          # batch-submitted, arbitrary order
    acct = _Accounting()

    def try_dispatch(eng: Engine) -> None:
        for g in gpus:
            if g.busy and queue:
                continue
            if not queue:
                break
            if g.busy:
                continue
            d = queue.pop(0)
            g.busy = True
            start = eng.t
            # stage 1: remote model load over the node NIC (contended)
            def after_load(eng, d=d, g=g, start=start):
                # stage 2: preprocess, 3: inference, 4: metric — all hold GPU
                def after_pre(eng, d=d, g=g, start=start):
                    def after_infer(eng, d=d, g=g, start=start):
                        acct.busy += d.gpu_minutes
                        def after_metric(eng, d=d, g=g, start=start):
                            acct.held += eng.t - start
                            g.busy = False
                            try_dispatch(eng)
                        eng.add("work", d.cpu_metric_minutes, after_metric,
                                tag=f"metric:{d.name}")
                    eng.add("work", d.gpu_minutes, after_infer,
                            tag=f"infer:{d.name}")
                eng.add("work", d.preprocess_minutes, after_pre)
            eng.add("load", spec.model_bytes, after_load, node=g.node,
                    tag=f"load:{d.name}")

    try_dispatch(eng)
    makespan = eng.run()
    return SimResult(makespan, acct.busy, acct.held, spec.n_gpus, eng.trace,
                     eng.completed)


# ---------------------------------------------------------------------------
# decoupled: precursor loads + split/merge/sorted queue + CPU metric jobs
# ---------------------------------------------------------------------------

def schedule_decoupled(datasets: list[EvalDataset], spec: ClusterSpec, *,
                       items: Optional[list[WorkItem]] = None) -> SimResult:
    eng = Engine()
    eng.rate_fn = _load_rate_fn(spec)
    gpus = [_Gpu(node=i // spec.gpus_per_node) for i in range(spec.n_gpus)]
    queue = items if items is not None else plan_work_items(
        datasets, spec.n_gpus)
    queue = list(queue)
    acct = _Accounting()
    shm_ready = [False] * spec.n_nodes
    cpu_free = [spec.cpu_slots] * spec.n_nodes
    cpu_backlog: list[tuple[int, WorkItem]] = []
    # tokenized-data cache (paper §4.2: "cache the tokenized data"):
    # preprocessing runs as CPU jobs concurrent with the precursor loads;
    # an item is dispatchable once all its source datasets are tokenized.
    tokenized: set[str] = set()
    by_name = {d.name: d for d in datasets}

    def submit_metric(eng: Engine, node: int, w: WorkItem) -> None:
        if cpu_free[node] <= 0:
            cpu_backlog.append((node, w))
            return
        cpu_free[node] -= 1
        def done(eng, node=node):
            cpu_free[node] += 1
            if cpu_backlog:
                n2, w2 = cpu_backlog.pop(0)
                submit_metric(eng, n2, w2)
        eng.add("work", w.cpu_metric_minutes, done, tag=f"metric:{w.name}")

    def ready(w: WorkItem) -> bool:
        return all(name in tokenized or name not in by_name
                   for name in w.datasets)

    def try_dispatch(eng: Engine) -> None:
        for g in gpus:
            if g.busy or not shm_ready[g.node]:
                continue
            idx = next((i for i, w in enumerate(queue) if ready(w)), None)
            if idx is None:
                break
            w = queue.pop(idx)
            g.busy = True
            start = eng.t
            # stage 1: stage weights from node shm over PCIe (fast)
            def after_shm(eng, w=w, g=g, start=start):
                def after_infer(eng, w=w, g=g, start=start):
                    acct.busy += w.gpu_minutes
                    def after_dump(eng, w=w, g=g, start=start):
                        acct.held += eng.t - start
                        g.busy = False
                        # metric decoupled to a CPU job; GPU moves on
                        submit_metric(eng, g.node, w)
                        try_dispatch(eng)
                    eng.add("work", spec.dump_minutes, after_dump)
                eng.add("work", w.gpu_minutes, after_infer,
                        tag=f"infer:{w.name}")
            eng.add("work", spec.shm_load_minutes, after_shm)

    # CPU tokenization jobs for every dataset, submitted at t=0
    for d in datasets:
        def tok_done(eng, d=d):
            tokenized.add(d.name)
            try_dispatch(eng)
        eng.add("work", d.preprocess_minutes, tok_done,
                tag=f"tokenize:{d.name}")

    # precursor jobs: one remote load per node, in parallel
    for node in range(spec.n_nodes):
        def precursor_done(eng, node=node):
            shm_ready[node] = True
            try_dispatch(eng)
        eng.add("load", spec.model_bytes, precursor_done, node=node,
                tag=f"precursor:node{node}")

    makespan = eng.run()
    return SimResult(makespan, acct.busy, acct.held, spec.n_gpus, eng.trace,
                     eng.completed)


# ---------------------------------------------------------------------------
# borrowing bridge: trials leasing replay free-pool GPUs (§6.1 x §6.2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Lease:
    """One GPU leased from the replay free pool, running ``item``."""
    item: BorrowItem
    t0: float                     # lease acquisition time
    t_fold: float                 # progress folded up to here
    done_at: float                # scheduled completion of the current item
    node: int = -1                # concrete NodeLedger node (placement mode)
    load_end: float = 0.0         # model load finishes (NIC contention span)


class TrialBorrower:
    """Runs decomposed eval shards on revocable GPUs leased from the replay
    engine's free pool.

    The replay engine drives this object through two calls (the borrower
    protocol expected by ``ReplayConfig.borrower``):

      ``reconcile(now, free, nodes=None)``  called after every capacity
          event, with the scheduler's current total free GPUs. The borrower
          folds lease progress (shards that finished chain into the next
          pending shard in the same slot), *revokes* newest-first whenever
          its lease count exceeds ``free`` — leases are strictly lower
          priority than every queued job, every regrowing shrunken job and
          every best-effort lease — and leases additional free GPUs (one
          shard each, up to ``max_leases``) when capacity is idle. Returns
          the number of active leases. With ``ReplayConfig.placement`` the
          engine passes its ``NodeLedger`` as ``nodes``: each lease then
          lands on a concrete node with genuinely idle GPUs, the shard's
          model load pays that node's shared storage-NIC time
          (``ClusterSpec.load_minutes_shared`` over the loads concurrently
          in flight there — the Fig. 16 collapse, snapshot-priced at
          acquisition), and leases on nodes whose free count dropped are
          revoked node-locally (newest-first) even when total free
          capacity still covers the lease count.
      ``close(now)``            end of replay: folds and releases all
          leases without counting preemptions.

    Progress accounting is exact and lazy: each slot knows its current
    shard's completion time, so a reconcile pass is O(1) unless a
    completion or a revocation actually lands in the elapsed window. A
    preempted shard keeps its progress (decoupled trials dump outputs
    incrementally) but pays ``restart_cost_min`` — plus the NIC-contended
    reload in placement mode — again on its next lease, the §6.2
    decomposed-trial restart cost. A shard chaining into the next pending
    one on the *same* leased GPU pays no reload: the model is already
    resident in node shared memory (the decoupled precursor design).

    Invariant (property-tested): ``borrowed_gpu_min`` equals the summed
    per-shard consumption ``work_min + overhead_min - remaining_min``
    over every shard, leased or not.
    """

    def __init__(self, items: list, *, restart_cost_min: float = 0.5,
                 max_leases: int = 32, record_leases: bool = False,
                 spec: Optional[ClusterSpec] = None):
        self.pending: collections.deque = collections.deque(items)
        self.items: tuple = tuple(items)
        self.restart_cost_min = restart_cost_min
        self.max_leases = max_leases
        self.spec = spec              # storage model for node-local loads
        self.active: list[_Lease] = []
        self.completed: list[str] = []
        self.borrowed_gpu_min = 0.0   # GPU-minutes held (always working)
        self.overhead_min = 0.0       # (re)start cost charged across leases
        self.lease_count = 0
        self.preemptions = 0
        # placement mode: live lease cover per node + realized load-time
        # bins keyed by NIC concurrency at acquisition (the Fig. 16 curve)
        self.leases_by_node: dict = {}
        # node -> list of in-flight model-load end times (same membership
        # as scanning ``active`` for that node; keeps the NIC-concurrency
        # snapshot O(node's leases) instead of O(active) per acquisition)
        self._load_ends: dict = {}
        self.load_bins: dict = {}
        # (t_lease, t_release) spans, 1 GPU each, for conservation tests
        self.lease_records: Optional[list] = [] if record_leases else None
        self._min_done = math.inf

    @classmethod
    def from_suite(cls, n_datasets: int = 63, *, repeat: int = 1, seed: int = 0,
                   shard_target_minutes: float = 4.0,
                   **kwargs) -> "TrialBorrower":
        """Borrower over ``repeat`` copies of the standard eval suite (one
        per tracked checkpoint)."""
        return cls(plan_borrow_items(standard_suite(n_datasets, seed=seed),
                                     repeat=repeat,
                                     shard_target_minutes=shard_target_minutes),
                   **kwargs)

    # -- internals ----------------------------------------------------------

    def _charge(self, item: BorrowItem, extra: float = 0.0) -> None:
        """One lease acquisition: charge the decomposed-trial (re)start
        cost — plus ``extra`` NIC-contended model-load minutes in
        placement mode — and bump the lease counters (kept in one place so
        the borrowed == work + overhead - remaining invariant has a single
        accounting site)."""
        c = self.restart_cost_min + extra
        item.remaining_min += c
        item.overhead_min += c
        item.leases += 1
        self.overhead_min += c
        self.lease_count += 1

    def _drop_node(self, lease: _Lease) -> None:
        node = lease.node
        if node >= 0:
            left = self.leases_by_node[node] - 1
            if left:
                self.leases_by_node[node] = left
            else:
                del self.leases_by_node[node]
            ends = self._load_ends.get(node)
            if ends is not None:
                try:
                    ends.remove(lease.load_end)
                except ValueError:
                    pass            # already pruned by a later acquisition
                if not ends:
                    del self._load_ends[node]

    def _lease(self, now: float, nodes=None) -> bool:
        """Acquire one free GPU for the next pending shard; returns False
        when placement found no concrete node to put it on."""
        node = -1
        load = 0.0
        if nodes is not None:
            node = nodes.lease_node(self.leases_by_node)
            if node < 0:
                return False           # only unplaced capacity is left
            # snapshot-priced NIC share: loads already in flight on this
            # node at acquisition (the §6.2 fair-share collapse; rates are
            # not re-divided mid-load, unlike the evalsched Engine). The
            # in-flight set is read off the per-node load-end list — the
            # same membership a scan over ``active`` would count, expired
            # entries pruned as they are passed (event time is monotonic)
            ends = self._load_ends.get(node)
            if ends is None:
                ends = self._load_ends[node] = []
            elif ends:
                live = [t for t in ends if t > now + 1e-12]
                if len(live) != len(ends):
                    ends[:] = live
            k = 1 + len(ends)
            if self.spec is not None:
                load = self.spec.load_minutes_shared(k)
            b = self.load_bins.setdefault(k, [0, 0.0])
            b[0] += 1
            b[1] += load
            self.leases_by_node[node] = self.leases_by_node.get(node, 0) + 1
            ends.append(now + self.restart_cost_min + load)
        item = self.pending.popleft()
        self._charge(item, load)
        lease = _Lease(item, now, now, now + item.remaining_min, node,
                       now + self.restart_cost_min + load)
        self.active.append(lease)
        if lease.done_at < self._min_done:
            self._min_done = lease.done_at
        return True

    def _fold(self, lease: _Lease, now: float) -> bool:
        """Advance ``lease`` to ``now``, chaining completed shards into the
        next pending one. Returns False when the slot ran out of work and
        released its GPU (mid-window, at the final completion time)."""
        while True:
            if now < lease.done_at - 1e-12:
                step = max(now - lease.t_fold, 0.0)
                lease.item.remaining_min -= step
                self.borrowed_gpu_min += step
                lease.t_fold = now
                return True
            t_done = lease.done_at
            self.borrowed_gpu_min += max(t_done - lease.t_fold, 0.0)
            lease.item.remaining_min = 0.0
            self.completed.append(lease.item.name)
            if self.pending:
                item = self.pending.popleft()
                # same GPU, model already in node shm: no NIC reload
                self._charge(item)
                lease.item = item
                lease.t0 = t_done        # new lease span, same GPU
                lease.t_fold = t_done
                lease.done_at = t_done + item.remaining_min
                continue
            if self.lease_records is not None:
                self.lease_records.append((lease.t0, t_done))
            self._drop_node(lease)
            return False

    def _revoke(self, lease: _Lease, now: float) -> None:
        """The pool reclaimed this lease's GPU (already popped from
        ``active``): keep the shard's progress, requeue it first."""
        if not self._fold(lease, now):
            return                    # ran dry before the revocation landed
        self.preemptions += 1
        self.pending.appendleft(lease.item)
        if self.lease_records is not None:
            self.lease_records.append((lease.t0, now))
        self._drop_node(lease)

    # -- the borrower protocol ---------------------------------------------

    def reconcile(self, now: float, free: int, nodes=None) -> int:
        active = self.active
        if active and now >= self._min_done - 1e-12:
            active = self.active = [l for l in active if self._fold(l, now)]
            self._min_done = min((l.done_at for l in active),
                                 default=math.inf)
        dropped = False
        if len(active) > free:
            while len(active) > free:
                self._revoke(active.pop(), now)
            dropped = True
        if nodes is not None and nodes.dirty:
            # node-local reclamation: a node whose free count fell below
            # its lease cover revokes its newest leases — the global pass
            # above cannot see *where* the capacity disappeared
            if self.leases_by_node:
                for nd in nodes.dirty:
                    while self.leases_by_node.get(nd, 0) > nodes.free[nd]:
                        i = next(i for i in range(len(active) - 1, -1, -1)
                                 if active[i].node == nd)
                        self._revoke(active.pop(i), now)
                        dropped = True
            nodes.dirty.clear()
        if dropped:
            self._min_done = min((l.done_at for l in active),
                                 default=math.inf)
        n = len(active)
        if n < free and self.pending and n < self.max_leases:
            take = min(free - n, self.max_leases - n, len(self.pending))
            for _ in range(take):
                if not self._lease(now, nodes):
                    break
                n += 1
        return n

    def close(self, now: float) -> None:
        """Fold and release every lease (end of replay); unfinished shards
        return to the pending queue without counting a preemption."""
        for lease in self.active:
            if self._fold(lease, now):
                self.pending.appendleft(lease.item)
                if self.lease_records is not None:
                    self.lease_records.append((lease.t0, now))
                self._drop_node(lease)
        self.active = []
        self._min_done = math.inf

    def stats(self) -> dict:
        """JSON-ready borrowing stats for ``ReplayResult.summary()``."""
        out = {
            "borrowed_gpu_min": self.borrowed_gpu_min,
            "borrowed_gpu_hours": self.borrowed_gpu_min / 60.0,
            "leases": self.lease_count,
            "preemptions": self.preemptions,
            "restart_overhead_min": self.overhead_min,
            "shards_completed": len(self.completed),
            "shards_pending": len(self.pending) + len(self.active),
        }
        if self.load_bins:
            # realized NIC-contended load minutes per concurrency level —
            # the Fig. 16 collapse curve, consumed by
            # ``repro.cluster.analysis.placement_stats``
            out["placement"] = {
                "load_by_concurrency": {
                    k: {"n": b[0], "mean_load_min": b[1] / b[0]}
                    for k, b in sorted(self.load_bins.items())},
                "max_concurrency": max(self.load_bins),
            }
        return out


# ---------------------------------------------------------------------------
# Fig. 16 (left): loading-speed collapse vs concurrent trials
# ---------------------------------------------------------------------------

def loading_speed_curve(spec: ClusterSpec,
                        trial_counts: list[int]) -> list[tuple[int, float]]:
    """(n_trials, per-trial load speed GB/s) across a node-count sweep.

    Mirrors the paper's stress test: 1..8 trials land on one node (speed
    divides by the NIC share); beyond 8, extra trials land on other nodes so
    per-trial speed stabilizes.
    """
    out = []
    for n in trial_counts:
        per_node = min(n, spec.gpus_per_node)
        gbps = min(spec.stream_gbps, spec.storage_nic_gbps / per_node)
        out.append((n, gbps / 8.0))
    return out
