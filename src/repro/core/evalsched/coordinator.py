"""The trial coordinator: baseline vs decoupled evaluation scheduling.

Baseline (paper Fig. 16 right (a)): every dataset is one monolithic trial —
the GPU is held through remote model load (contending for the node storage
NIC), preprocessing, inference, and CPU-only metric computation.

Decoupled (Fig. 16 right (b), our system):
  1. precursor jobs stage the model once per node into shared memory;
     eval trials then load over PCIe instead of the remote PFS;
  2. after inference the outputs are dumped to files and the GPU is freed;
     metric computation runs in separate CPU jobs;
  3. prior-based elastic scheduling: large datasets are split, runts are
     merged, and the queue is sorted so long-CPU-tail items start first
     (their metric jobs overlap remaining GPU work).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.evalsched.simulator import Engine, SimResult
from repro.core.evalsched.trial import (ClusterSpec, EvalDataset, WorkItem,
                                        plan_work_items)


# ---------------------------------------------------------------------------
# shared bits
# ---------------------------------------------------------------------------

def _load_rate_fn(spec: ClusterSpec):
    """bytes/minute for 'load' tasks (per-node fair share); 1.0 for 'work'."""
    def rate(task, eng: Engine) -> float:
        if task.kind != "load":
            return 1.0
        k = eng.loads_on_node(task.node)
        gbps = min(spec.stream_gbps, spec.storage_nic_gbps / max(k, 1))
        return gbps * 1e9 / 8 * 60.0
    return rate


@dataclasses.dataclass
class _Gpu:
    node: int
    busy: bool = False


class _Accounting:
    def __init__(self):
        self.busy = 0.0     # inference minutes
        self.held = 0.0     # allocation minutes (incl. idle stages)


# ---------------------------------------------------------------------------
# baseline: one monolithic trial per dataset
# ---------------------------------------------------------------------------

def schedule_baseline(datasets: list[EvalDataset],
                      spec: ClusterSpec) -> SimResult:
    eng = Engine()
    eng.rate_fn = _load_rate_fn(spec)
    gpus = [_Gpu(node=i // spec.gpus_per_node) for i in range(spec.n_gpus)]
    queue = list(datasets)          # batch-submitted, arbitrary order
    acct = _Accounting()

    def try_dispatch(eng: Engine) -> None:
        for g in gpus:
            if g.busy and queue:
                continue
            if not queue:
                break
            if g.busy:
                continue
            d = queue.pop(0)
            g.busy = True
            start = eng.t
            # stage 1: remote model load over the node NIC (contended)
            def after_load(eng, d=d, g=g, start=start):
                # stage 2: preprocess, 3: inference, 4: metric — all hold GPU
                def after_pre(eng, d=d, g=g, start=start):
                    def after_infer(eng, d=d, g=g, start=start):
                        acct.busy += d.gpu_minutes
                        def after_metric(eng, d=d, g=g, start=start):
                            acct.held += eng.t - start
                            g.busy = False
                            try_dispatch(eng)
                        eng.add("work", d.cpu_metric_minutes, after_metric,
                                tag=f"metric:{d.name}")
                    eng.add("work", d.gpu_minutes, after_infer,
                            tag=f"infer:{d.name}")
                eng.add("work", d.preprocess_minutes, after_pre)
            eng.add("load", spec.model_bytes, after_load, node=g.node,
                    tag=f"load:{d.name}")

    try_dispatch(eng)
    makespan = eng.run()
    return SimResult(makespan, acct.busy, acct.held, spec.n_gpus, eng.trace)


# ---------------------------------------------------------------------------
# decoupled: precursor loads + split/merge/sorted queue + CPU metric jobs
# ---------------------------------------------------------------------------

def schedule_decoupled(datasets: list[EvalDataset], spec: ClusterSpec, *,
                       items: Optional[list[WorkItem]] = None) -> SimResult:
    eng = Engine()
    eng.rate_fn = _load_rate_fn(spec)
    gpus = [_Gpu(node=i // spec.gpus_per_node) for i in range(spec.n_gpus)]
    queue = items if items is not None else plan_work_items(
        datasets, spec.n_gpus)
    queue = list(queue)
    acct = _Accounting()
    shm_ready = [False] * spec.n_nodes
    cpu_free = [spec.cpu_slots] * spec.n_nodes
    cpu_backlog: list[tuple[int, WorkItem]] = []
    # tokenized-data cache (paper §4.2: "cache the tokenized data"):
    # preprocessing runs as CPU jobs concurrent with the precursor loads;
    # an item is dispatchable once all its source datasets are tokenized.
    tokenized: set[str] = set()
    by_name = {d.name: d for d in datasets}

    def submit_metric(eng: Engine, node: int, w: WorkItem) -> None:
        if cpu_free[node] <= 0:
            cpu_backlog.append((node, w))
            return
        cpu_free[node] -= 1
        def done(eng, node=node):
            cpu_free[node] += 1
            if cpu_backlog:
                n2, w2 = cpu_backlog.pop(0)
                submit_metric(eng, n2, w2)
        eng.add("work", w.cpu_metric_minutes, done, tag=f"metric:{w.name}")

    def ready(w: WorkItem) -> bool:
        return all(name in tokenized or name not in by_name
                   for name in w.datasets)

    def try_dispatch(eng: Engine) -> None:
        for g in gpus:
            if g.busy or not shm_ready[g.node]:
                continue
            idx = next((i for i, w in enumerate(queue) if ready(w)), None)
            if idx is None:
                break
            w = queue.pop(idx)
            g.busy = True
            start = eng.t
            # stage 1: stage weights from node shm over PCIe (fast)
            def after_shm(eng, w=w, g=g, start=start):
                def after_infer(eng, w=w, g=g, start=start):
                    acct.busy += w.gpu_minutes
                    def after_dump(eng, w=w, g=g, start=start):
                        acct.held += eng.t - start
                        g.busy = False
                        # metric decoupled to a CPU job; GPU moves on
                        submit_metric(eng, g.node, w)
                        try_dispatch(eng)
                    eng.add("work", spec.dump_minutes, after_dump)
                eng.add("work", w.gpu_minutes, after_infer,
                        tag=f"infer:{w.name}")
            eng.add("work", spec.shm_load_minutes, after_shm)

    # CPU tokenization jobs for every dataset, submitted at t=0
    for d in datasets:
        def tok_done(eng, d=d):
            tokenized.add(d.name)
            try_dispatch(eng)
        eng.add("work", d.preprocess_minutes, tok_done,
                tag=f"tokenize:{d.name}")

    # precursor jobs: one remote load per node, in parallel
    for node in range(spec.n_nodes):
        def precursor_done(eng, node=node):
            shm_ready[node] = True
            try_dispatch(eng)
        eng.add("load", spec.model_bytes, precursor_done, node=node,
                tag=f"precursor:node{node}")

    makespan = eng.run()
    return SimResult(makespan, acct.busy, acct.held, spec.n_gpus, eng.trace)


# ---------------------------------------------------------------------------
# Fig. 16 (left): loading-speed collapse vs concurrent trials
# ---------------------------------------------------------------------------

def loading_speed_curve(spec: ClusterSpec,
                        trial_counts: list[int]) -> list[tuple[int, float]]:
    """(n_trials, per-trial load speed GB/s) across a node-count sweep.

    Mirrors the paper's stress test: 1..8 trials land on one node (speed
    divides by the NIC share); beyond 8, extra trials land on other nodes so
    per-trial speed stabilizes.
    """
    out = []
    for n in trial_counts:
        per_node = min(n, spec.gpus_per_node)
        gbps = min(spec.stream_gbps, spec.storage_nic_gbps / per_node)
        out.append((n, gbps / 8.0))
    return out
