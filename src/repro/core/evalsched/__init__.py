"""Decoupled scheduling for evaluation (paper §6.2)."""
from repro.core.evalsched.trial import (STORAGE_SPEC, BorrowItem,
                                        ClusterSpec, EvalDataset, WorkItem,
                                        plan_borrow_items, plan_work_items,
                                        standard_suite)
from repro.core.evalsched.simulator import SimResult
from repro.core.evalsched.coordinator import (TrialBorrower,
                                              schedule_baseline,
                                              schedule_decoupled)

__all__ = [
    "ClusterSpec", "STORAGE_SPEC", "EvalDataset", "WorkItem",
    "plan_work_items", "standard_suite", "SimResult", "schedule_baseline",
    "schedule_decoupled", "BorrowItem", "plan_borrow_items", "TrialBorrower",
]
