"""Loss-spike detection and rollback policy (paper §5.3 / §6.1).

Paper: "A 'loss spike' refers to a sudden increase in the loss that was
previously decreasing normally, and does not recover over a certain period.
... if the failure is attributed to a sudden increase in loss, we opt to an
earlier healthy restart checkpoint and bypass subsequent data batches."

Detector: rolling median + MAD (robust to the heavy-tailed LM loss curve).
A step is *spiking* when loss > median + z_threshold * (1.4826 * MAD).
A spike *event* fires only after ``patience`` consecutive spiking steps
(transient single-step spikes recover on their own and are ignored, matching
the paper's "does not recover over a certain period").

The policy names the rollback checkpoint (the newest checkpoint at or before
the spike onset minus ``margin`` steps — "an *earlier healthy* checkpoint",
not merely the latest, which may already be poisoned) and the data range to
skip (onset .. detection, padded by ``skip_margin``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class SpikeEvent:
    onset_step: int           # first spiking step
    detect_step: int          # step at which patience ran out
    rollback_step: int        # checkpoint step to resume from
    skip_range: tuple[int, int]   # data steps [lo, hi) to bypass
    baseline: float
    peak: float


class SpikeDetector:
    def __init__(self, *, window: int = 64, z_threshold: float = 6.0,
                 patience: int = 4, min_history: int = 16,
                 skip_margin: int = 8, ckpt_margin: int = 0):
        self.window = window
        self.z_threshold = z_threshold
        self.patience = patience
        self.min_history = min_history
        self.skip_margin = skip_margin
        self.ckpt_margin = ckpt_margin
        self._hist: list[tuple[int, float]] = []   # healthy (step, loss)
        self._spiking: list[tuple[int, float]] = []  # consecutive spike steps

    def _threshold(self) -> Optional[float]:
        if len(self._hist) < self.min_history:
            return None
        vals = np.array([l for _, l in self._hist[-self.window:]])
        med = float(np.median(vals))
        mad = float(np.median(np.abs(vals - med)))
        sigma = 1.4826 * mad if mad > 0 else max(1e-3, 0.05 * abs(med))
        return med + self.z_threshold * sigma

    def update(self, step: int, loss: float,
               available_ckpts: Sequence[int] = ()) -> Optional[SpikeEvent]:
        """Feed one (step, loss); returns a SpikeEvent when one is confirmed."""
        if not np.isfinite(loss):
            loss = float("inf")
        thr = self._threshold()
        if thr is not None and loss > thr:
            self._spiking.append((step, loss))
            if len(self._spiking) >= self.patience:
                onset = self._spiking[0][0]
                peak = max(l for _, l in self._spiking)
                target = onset - self.ckpt_margin
                older = [c for c in available_ckpts if c <= target]
                rollback = max(older) if older else (
                    min(available_ckpts) if available_ckpts else 0)
                event = SpikeEvent(
                    onset_step=onset, detect_step=step,
                    rollback_step=rollback,
                    skip_range=(max(rollback, onset - self.skip_margin),
                                step + self.skip_margin),
                    baseline=float(np.median(
                        [l for _, l in self._hist[-self.window:]])),
                    peak=peak)
                self._spiking.clear()
                return event
        else:
            self._spiking.clear()
            self._hist.append((step, loss))
            if len(self._hist) > 4 * self.window:
                del self._hist[: 2 * self.window]
        return None

    def reset_after_rollback(self, resume_step: int) -> None:
        """Drop history newer than the rollback point."""
        self._hist = [(s, l) for s, l in self._hist if s <= resume_step]
        self._spiking.clear()
