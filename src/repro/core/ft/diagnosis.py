"""Failure diagnosis system (paper §6.1 Fig. 15).

Pipeline:
  1. Real-time log compression — evolving regex *Filter Rules*, maintained by
     the LLM Log Agent (self-consistency voted); repeated/similar jobs reuse
     the accumulated rules, so filtering gets cheaper over time.
  2. Rule-based diagnosis — regexes learned from previously diagnosed
     incidents, tried first.
  3. On miss: the compressed log is embedded (hashed bag-of-words) into a
     vector store; the Failure Agent retrieves similar past incidents and
     diagnoses the root cause via the LLM; the result is written back as a
     new rule (continuous learning) and a new vector-store entry.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import re
from typing import Optional

import numpy as np

from repro.core.ft.events import BY_NAME, FailureType
from repro.core.ft.log_agent import (FAILURE_AGENT_PROMPT, LOG_AGENT_PROMPT,
                                     LLMClient, OfflineLLM, looks_like_error,
                                     self_consistent, template_of,
                                     template_to_regex)


# ---------------------------------------------------------------------------
# 1. log compression
# ---------------------------------------------------------------------------

class LogCompressor:
    """Filter-rule based compressor; rules evolve via the Log Agent."""

    def __init__(self, client: Optional[LLMClient] = None,
                 segment_lines: int = 200, samples: int = 3):
        self.client = client or OfflineLLM()
        self.rules: list[re.Pattern] = []
        self.segment_lines = segment_lines
        self.samples = samples
        self.stats = {"in_lines": 0, "out_lines": 0, "agent_calls": 0}

    def add_rule(self, regex: str) -> None:
        try:
            pat = re.compile(regex)
        except re.error:
            return
        if pat.pattern not in {r.pattern for r in self.rules}:
            self.rules.append(pat)

    def _filter(self, lines: list[str]) -> list[str]:
        out = []
        for line in lines:
            if any(r.search(line) for r in self.rules):
                continue
            out.append(line)
        return out

    def compress(self, lines: list[str]) -> list[str]:
        """Stream segments through the rules; ask the Log Agent to mine new
        rules for whatever survives; keep error-looking lines."""
        kept: list[str] = []
        self.stats["in_lines"] += len(lines)
        for i in range(0, len(lines), self.segment_lines):
            seg = self._filter(lines[i:i + self.segment_lines])
            if not seg:
                continue
            # if the segment still contains many non-error lines, mine rules
            non_err = [l for l in seg if not looks_like_error(l)]
            if len(non_err) >= 3:
                prompt = LOG_AGENT_PROMPT.format(segment="\n".join(seg))
                reply = self_consistent(self.client, prompt,
                                        samples=self.samples,
                                        key="filter_regexes")
                self.stats["agent_calls"] += 1
                for rx in reply.get("filter_regexes", []) or []:
                    self.add_rule(rx)
                seg = self._filter(seg)
            kept.extend(seg)
        self.stats["out_lines"] += len(kept)
        return kept

    @property
    def compression_ratio(self) -> float:
        if self.stats["out_lines"] == 0:
            return float("inf")
        return self.stats["in_lines"] / self.stats["out_lines"]


# ---------------------------------------------------------------------------
# 2. rule-based diagnosis (learned over time)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Rule:
    pattern: re.Pattern
    failure: str
    priority: int


@dataclasses.dataclass
class Diagnosis:
    failure: str
    category: str
    confidence: float
    source: str              # "rule" | "agent" | "unknown"
    mitigation: str = ""
    root_cause_line: str = ""
    needs_node_cordon: bool = False
    auto_recoverable: bool = True

    @classmethod
    def from_failure_type(cls, ft: FailureType, source: str,
                          confidence: float, line: str = "",
                          mitigation: str = "") -> "Diagnosis":
        return cls(failure=ft.name, category=ft.category,
                   confidence=confidence, source=source,
                   mitigation=mitigation, root_cause_line=line,
                   needs_node_cordon=ft.needs_node_cordon,
                   auto_recoverable=ft.auto_recoverable)


class RuleBasedDiagnoser:
    """Ordered regex rules; highest-priority match wins (root-cause logic:
    a CUDA/NVLink rule outranks the NCCL-timeout symptom it causes)."""

    def __init__(self, seed_rules: Optional[list[tuple[str, str]]] = None):
        self.rules: list[Rule] = []
        for failure, rx in seed_rules or []:
            self.add_rule(failure, rx)

    def add_rule(self, failure: str, regex: str) -> None:
        ft = BY_NAME.get(failure)
        if ft is None:
            return
        try:
            pat = re.compile(regex, re.IGNORECASE)
        except re.error:
            return
        if any(r.pattern.pattern == pat.pattern for r in self.rules):
            return
        self.rules.append(Rule(pat, failure, ft.priority))
        self.rules.sort(key=lambda r: -r.priority)

    def diagnose(self, lines: list[str]) -> Optional[Diagnosis]:
        for rule in self.rules:                      # priority order
            for line in lines:
                if rule.pattern.search(line):
                    ft = BY_NAME[rule.failure]
                    return Diagnosis.from_failure_type(
                        ft, "rule", 0.95, line,
                        mitigation="(cached rule)")
        return None


# -- recovery-policy taxonomy (consumed by repro.cluster.replay) ------------
#
# The replay engine collapses a fine-grained Diagnosis onto three recovery
# verdicts: a node is at fault (cordon it, or shrink the job elastically off
# it), the fault is transient (restart in place from the last checkpoint
# without giving up the allocation), or a human must fix something (the job
# is resubmitted).
VERDICT_HARDWARE, VERDICT_TRANSIENT, VERDICT_USER = \
    "hardware", "transient", "user"


def verdict_class(diag: Diagnosis) -> str:
    """Map a :class:`Diagnosis` onto the replay recovery taxonomy."""
    if diag.needs_node_cordon:
        return VERDICT_HARDWARE
    if diag.auto_recoverable and diag.failure != "Unknown":
        return VERDICT_TRANSIENT
    return VERDICT_USER


DEFAULT_SEED_RULES: list[tuple[str, str]] = [
    ("OutOfMemoryError", r"OutOfMemoryError|RESOURCE_EXHAUSTED"),
    ("FileNotFoundError", r"FileNotFoundError"),
    ("ImportError", r"ImportError: cannot import"),
]

_INFRA_HINTS = ("nvlink", "cuda error", "ecc", "nccl", "infiniband",
                "ibv_", "rdma", "xid ", "slurmstepd", "kubelet",
                "unexpectedly rebooted", "notready")


def _infra_signature(lines: list[str]) -> bool:
    return any(h in l.lower() for l in lines for h in _INFRA_HINTS)


# ---------------------------------------------------------------------------
# 3. vector store + failure agent
# ---------------------------------------------------------------------------

def embed(lines: list[str], dim: int = 512) -> np.ndarray:
    """Hashed bag-of-words embedding of a compressed log (unit norm)."""
    v = np.zeros(dim, np.float32)
    for line in lines:
        for tok in re.split(r"[^A-Za-z_]+", template_of(line).lower()):
            if len(tok) < 3:
                continue
            h = int(hashlib.md5(tok.encode()).hexdigest()[:8], 16)
            v[h % dim] += 1.0
    n = np.linalg.norm(v)
    return v / n if n else v


class VectorStore:
    def __init__(self, dim: int = 512):
        self.dim = dim
        self.vectors: list[np.ndarray] = []
        self.payloads: list[dict] = []

    def add(self, lines: list[str], payload: dict) -> None:
        self.vectors.append(embed(lines, self.dim))
        self.payloads.append(payload)

    def query(self, lines: list[str], k: int = 3) -> list[tuple[float, dict]]:
        if not self.vectors:
            return []
        q = embed(lines, self.dim)
        sims = np.stack(self.vectors) @ q
        idx = np.argsort(-sims)[:k]
        return [(float(sims[i]), self.payloads[i]) for i in idx]


class FailureDiagnosisSystem:
    """The full Fig.-15 pipeline; the framework entry point."""

    def __init__(self, client: Optional[LLMClient] = None,
                 seed_rules: Optional[list[tuple[str, str]]] = None,
                 samples: int = 3):
        self.client = client or OfflineLLM()
        self.compressor = LogCompressor(self.client)
        self.rules = RuleBasedDiagnoser(
            DEFAULT_SEED_RULES if seed_rules is None else seed_rules)
        self.store = VectorStore()
        self.samples = samples
        self.stats = {"rule_hits": 0, "agent_hits": 0, "unknown": 0}

    def diagnose(self, raw_lines: list[str]) -> Diagnosis:
        compressed = self.compressor.compress(raw_lines)
        error_lines = [l for l in compressed if looks_like_error(l)] or compressed
        hit = self.rules.diagnose(error_lines)
        if hit is not None:
            # Cascade guard (the paper's motivating case): a learned
            # low-priority framework/script rule can match a *symptom* line
            # while the root cause is an infrastructure fault. If the log
            # carries infra signatures the low-priority rule can't explain,
            # defer to the agent.
            ft = BY_NAME[hit.failure]
            if ft.priority >= 50 or not _infra_signature(error_lines):
                self.stats["rule_hits"] += 1
                return hit
        # agent path: retrieve similar incidents, prompt, vote
        retrieved = self.store.query(error_lines, k=3)
        taxonomy = ", ".join(f"{f.name}: {f.category}"
                             for f in BY_NAME.values())
        prompt = FAILURE_AGENT_PROMPT.format(
            taxonomy=taxonomy,
            retrieved=json.dumps([p for _, p in retrieved]),
            log="\n".join(error_lines[-120:]))
        reply = self_consistent(self.client, prompt, samples=self.samples,
                                key="failure")
        name = reply.get("failure", "Unknown")
        ft = BY_NAME.get(name)
        if ft is None:
            self.stats["unknown"] += 1
            return Diagnosis("Unknown", "Unknown", 0.0, "unknown",
                             mitigation="escalate to on-call",
                             auto_recoverable=False)
        self.stats["agent_hits"] += 1
        diag = Diagnosis.from_failure_type(
            ft, "agent", float(reply.get("confidence", 0.5)),
            reply.get("root_cause_line", ""),
            reply.get("mitigation", ""))
        # continuous learning: write back a rule + a vector-store entry
        line = diag.root_cause_line
        if line:
            self.rules.add_rule(name, template_to_regex(template_of(line)))
        self.store.add(error_lines, {"failure": name,
                                     "mitigation": diag.mitigation})
        return diag
