"""LLM-involved log analysis (paper §6.1, design 2 — Log Agent).

Architecture is faithful to the paper: agents talk to an ``LLMClient``
through *prompts* and parse structured JSON replies, with self-consistency
voting across multiple samples. The repo ships an offline deterministic
client (``OfflineLLM``) implementing the same contract with a Drain-style
log-template miner + keyword scorer, so everything runs hermetically; a real
GPT-4/InternLM endpoint can be dropped in by implementing ``complete()``.
"""
from __future__ import annotations

import collections
import json
import random
import re
from typing import Optional, Protocol

# ---------------------------------------------------------------------------
# LLM client interface
# ---------------------------------------------------------------------------


class LLMClient(Protocol):
    def complete(self, prompt: str, *, seed: int = 0) -> str:
        """Return the model's reply for ``prompt`` (JSON per our prompts)."""
        ...


LOG_AGENT_PROMPT = """You are a Log Agent for LLM pretraining jobs.
Given the log segment below, identify lines that follow fixed, repeating
patterns (metric records, init banners, debug output) and propose regular
expressions that match ONLY those regular lines so they can be filtered out.
Also list any lines that look like errors. Reply with JSON:
{{"filter_regexes": [...], "error_lines": [...]}}

LOG SEGMENT:
{segment}
"""

FAILURE_AGENT_PROMPT = """You are a Failure Agent diagnosing an LLM
pretraining job interruption. Candidate failure types (name: category):
{taxonomy}

Similar past incidents (may be empty):
{retrieved}

Compressed error log:
{log}

Identify the single ROOT CAUSE (secondary symptoms like NCCL timeouts often
follow a GPU/NVLink fault). Reply with JSON:
{{"failure": "<name>", "category": "<Infrastructure|Framework|Script>",
  "confidence": <0..1>, "root_cause_line": "<line>",
  "mitigation": "<one sentence>"}}
"""


# ---------------------------------------------------------------------------
# offline deterministic "LLM": template miner + keyword scorer
# ---------------------------------------------------------------------------

_NUM = re.compile(r"(?<![\w.])\d[\d.]*")
_HEX = re.compile(r"0x[0-9a-fA-F]+")
_PATH = re.compile(r"(/[\w.\-]+)+")
_ERROR_HINTS = ("error", "exception", "traceback", "failed", "fatal", "killed",
                "timeout", "assert", "notready", "refused", "denied",
                "exceeded", "not defined", "no such file", "out of memory",
                "invalid", "unable")


def template_of(line: str) -> str:
    """Drain-lite: normalize volatile fields to wildcards."""
    t = _HEX.sub("<*>", line)
    t = _PATH.sub("<P>", t)
    t = _NUM.sub("<#>", t)
    return t.strip()


def looks_like_error(line: str) -> bool:
    low = line.lower()
    return any(h in low for h in _ERROR_HINTS)


def template_to_regex(template: str) -> str:
    parts = re.split(r"(<\*>|<#>|<P>)", template)
    out = []
    for p in parts:
        if p == "<#>":
            out.append(r"\d[\d.]*")
        elif p == "<*>":
            out.append(r"0x[0-9a-fA-F]+")
        elif p == "<P>":
            out.append(r"(?:/[\w.\-]+)+")
        else:
            out.append(re.escape(p))
    return "".join(out)


class OfflineLLM:
    """Deterministic stand-in honoring the LLMClient prompt/JSON contract."""

    def __init__(self, min_template_count: int = 3):
        self.min_template_count = min_template_count

    def complete(self, prompt: str, *, seed: int = 0) -> str:
        if prompt.startswith("You are a Log Agent"):
            return self._log_agent(prompt, seed)
        if prompt.startswith("You are a Failure Agent"):
            return self._failure_agent(prompt, seed)
        return "{}"

    # -- log agent: mine repeating templates -------------------------------

    def _log_agent(self, prompt: str, seed: int) -> str:
        segment = prompt.split("LOG SEGMENT:\n", 1)[1]
        lines = [l for l in segment.splitlines() if l.strip()]
        counts: dict[str, int] = collections.Counter()
        errors = []
        for line in lines:
            if looks_like_error(line):
                errors.append(line)
            else:
                counts[template_of(line)] += 1
        regexes = [template_to_regex(t) for t, c in counts.items()
                   if c >= self.min_template_count]
        # emulate sampling temperature: a seed-dependent subset ordering
        rng = random.Random(seed)
        rng.shuffle(regexes)
        return json.dumps({"filter_regexes": regexes,
                           "error_lines": errors[:50]})

    # -- failure agent: score taxonomy keywords against the log ------------

    def _failure_agent(self, prompt: str, seed: int) -> str:
        from repro.core.ft.events import TABLE3
        log = prompt.split("Compressed error log:\n", 1)[1]
        log = log.split("Identify the single ROOT CAUSE", 1)[0]
        lines = [l for l in log.splitlines() if l.strip()]
        # the scoring scan is the §6.1 pipeline's hot loop: lowercase each
        # line once (it used to be lowered per signature token) and read
        # template signatures from the module cache — the scores are
        # unchanged, just not recomputed per (template, line, token)
        lower = [l.lower() for l in lines]
        best, best_score, best_line = None, -1.0, ""
        for ft in TABLE3:
            score, line_hit = 0.0, ""
            for tmpl in ft.templates:
                sig = _signature(tmpl)
                n_sig = max(len(sig), 1)
                for line, ll in zip(lines, lower):
                    hit = sum(1 for s in sig if s in ll)
                    frac = hit / n_sig
                    if frac >= 0.6:
                        sc = frac * (1.0 + ft.priority / 100.0)
                        if sc > score:
                            score, line_hit = sc, line
            # tiny seed jitter models LLM sampling variance
            score += _jitter(seed, ft.name)
            if score > best_score:
                best, best_score, best_line = ft, score, line_hit
        if best is None or best_score < 0.3:
            return json.dumps({"failure": "Unknown", "category": "Unknown",
                               "confidence": 0.0, "root_cause_line": "",
                               "mitigation": "escalate to on-call"})
        return json.dumps({
            "failure": best.name, "category": best.category,
            "confidence": min(1.0, best_score / 2.0 + 0.5),
            "root_cause_line": best_line,
            "mitigation": _mitigation(best),
        })


_SIG_CACHE: dict = {}
_JITTER_CACHE: dict = {}


def _signature(template: str) -> list[str]:
    """Distinctive lowercase keywords of a failure template (memoized —
    the agent re-scores the same fixed taxonomy on every call)."""
    sig = _SIG_CACHE.get(template)
    if sig is None:
        t = template.replace("{d}", " ").replace("{w}", " ").lower()
        sig = _SIG_CACHE[template] = \
            [w for w in re.split(r"[^a-z_]+", t) if len(w) >= 4][:8]
    return sig


def _jitter(seed: int, name: str) -> float:
    """The agent's deterministic per-(seed, failure-type) sampling jitter;
    memoized because seeding a fresh ``random.Random`` per score is ~100x
    the cost of the draw it produces."""
    key = (seed, name)
    v = _JITTER_CACHE.get(key)
    if v is None:
        v = _JITTER_CACHE[key] = \
            random.Random(f"{seed}:{name}").random() * 0.01
    return v


def _mitigation(ft) -> str:
    if ft.needs_node_cordon:
        return ("run two-round NCCL sweep, cordon faulty node(s), "
                "auto-restart from last checkpoint")
    if ft.category == "Infrastructure":
        return "retry with backoff; check auxiliary service endpoints"
    if ft.auto_recoverable:
        return "auto-restart from last checkpoint"
    return "surface to user: fix configuration/script and resubmit"


# ---------------------------------------------------------------------------
# self-consistency voting (paper: process segments multiple times + vote)
# ---------------------------------------------------------------------------

def self_consistent(client: LLMClient, prompt: str, *, samples: int = 3,
                    key: str) -> dict:
    """Sample ``complete`` several times; majority-vote on ``key``."""
    replies = []
    for s in range(samples):
        try:
            replies.append(json.loads(client.complete(prompt, seed=s)))
        except (json.JSONDecodeError, KeyError):
            continue
    if not replies:
        return {}
    votes = collections.Counter()
    for r in replies:
        v = r.get(key)
        votes[json.dumps(v, sort_keys=True) if isinstance(v, (list, dict))
              else v] += 1
    winner, _ = votes.most_common(1)[0]
    for r in replies:
        v = r.get(key)
        v_norm = (json.dumps(v, sort_keys=True)
                  if isinstance(v, (list, dict)) else v)
        if v_norm == winner:
            return r
    return replies[0]
