"""Auto-restart supervisor: run -> fail -> diagnose -> detect -> recover.

This is the orchestrator that stitches the paper's three §6.1 modules into
the pretraining loop:

    job body raises      ->  FailureDiagnosisSystem (rules + agent)
    infra failure        ->  two-round allgather sweep -> cordon nodes
    loss spike           ->  rollback to an *earlier* checkpoint + skip batches
    recoverable          ->  restore last good checkpoint, restart
    non-recoverable      ->  surface to user (counted as manual intervention)

The job body is any callable ``job_fn(ctx) -> final_step`` that raises
``JobFailure`` (with its runtime log) or ``SpikeInterrupt`` (with the
detector's event). ``ctx`` exposes the start step, the skip ranges for the
data loader, and the cordoned-node count so an elastic job can shrink its
mesh. The same supervisor drives both the simulated failure benchmarks and
the real CPU training example.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

from repro.core.ft.checkpoint import CheckpointManager
from repro.core.ft.detection import (DetectionResult, SimulatedFleet,
                                     two_round_detection)
from repro.core.ft.diagnosis import Diagnosis, FailureDiagnosisSystem
from repro.core.ft.spike import SpikeEvent
from repro.utils import logger


class JobFailure(Exception):
    def __init__(self, step: int, log_lines: list[str],
                 truth: Optional[str] = None):
        super().__init__(f"job failed at step {step}")
        self.step = step
        self.log_lines = log_lines
        self.truth = truth            # ground-truth failure name (evaluation)


class SpikeInterrupt(Exception):
    def __init__(self, event: SpikeEvent):
        super().__init__(f"loss spike at step {event.onset_step}")
        self.event = event


@dataclasses.dataclass
class JobContext:
    start_step: int
    attempt: int
    skip_ranges: list[tuple[int, int]]
    healthy_nodes: int
    resume_extra: dict


@dataclasses.dataclass
class RecoveryEvent:
    attempt: int
    kind: str                      # "failure" | "spike" | "done"
    step: int
    diagnosis: Optional[Diagnosis] = None
    detection: Optional[DetectionResult] = None
    resumed_from: Optional[int] = None
    lost_steps: int = 0
    manual: bool = False
    truth: Optional[str] = None


@dataclasses.dataclass
class SupervisorReport:
    completed: bool
    final_step: int
    attempts: int
    events: list[RecoveryEvent]

    @property
    def auto_recoveries(self) -> int:
        return sum(1 for e in self.events
                   if e.kind in ("failure", "spike") and not e.manual)

    @property
    def manual_interventions(self) -> int:
        return sum(1 for e in self.events if e.manual)

    @property
    def lost_steps(self) -> int:
        return sum(e.lost_steps for e in self.events)

    @property
    def diagnosis_accuracy(self) -> float:
        """Fraction of failures whose diagnosed type matches ground truth."""
        scored = [e for e in self.events
                  if e.kind == "failure" and e.truth is not None]
        if not scored:
            return 1.0
        ok = sum(1 for e in scored
                 if e.diagnosis and e.diagnosis.failure == e.truth)
        return ok / len(scored)


class Supervisor:
    """Automatic failure handling around a restartable job body."""

    def __init__(self, ckpt: CheckpointManager,
                 diagnosis: Optional[FailureDiagnosisSystem] = None,
                 fleet: Optional[SimulatedFleet] = None, *,
                 max_attempts: int = 16,
                 on_manual: Optional[Callable[[Diagnosis], None]] = None):
        self.ckpt = ckpt
        self.diagnosis = diagnosis or FailureDiagnosisSystem()
        self.fleet = fleet
        self.max_attempts = max_attempts
        self.on_manual = on_manual     # called when a human must step in

    def run(self, job_fn: Callable[[JobContext], int], *,
            start_step: int = 0) -> SupervisorReport:
        events: list[RecoveryEvent] = []
        skip_ranges: list[tuple[int, int]] = []
        resume_step = start_step
        resume_extra: dict = {}

        for attempt in range(self.max_attempts):
            ctx = JobContext(
                start_step=resume_step, attempt=attempt,
                skip_ranges=list(skip_ranges),
                healthy_nodes=(len(self.fleet.healthy_nodes())
                               if self.fleet else 1),
                resume_extra=dict(resume_extra))
            try:
                final = job_fn(ctx)
                events.append(RecoveryEvent(attempt, "done", final))
                return SupervisorReport(True, final, attempt + 1, events)

            except SpikeInterrupt as s:
                ev = s.event
                resume_step = ev.rollback_step
                skip_ranges.append(ev.skip_range)
                # the job restarts from the *rollback* checkpoint, so its
                # extra state (loader position, scaler, ...) must come from
                # that checkpoint too — not linger from the previous attempt
                resume_extra = self._peek_extra(ev.rollback_step)
                events.append(RecoveryEvent(
                    attempt, "spike", ev.detect_step,
                    resumed_from=ev.rollback_step,
                    lost_steps=max(0, ev.detect_step - ev.rollback_step)))
                logger.info("spike at %d: rollback to %d, skipping data %s",
                            ev.onset_step, ev.rollback_step, ev.skip_range)

            except JobFailure as f:
                diag = self.diagnosis.diagnose(f.log_lines)
                detection = None
                if diag.needs_node_cordon and self.fleet is not None:
                    detection = two_round_detection(
                        self.fleet.healthy_nodes(), self.fleet)
                    self.fleet.cordon(detection.faulty)
                    # once cordoned, the fault no longer fires probes/errors
                    for n in detection.faulty:
                        self.fleet.faulty.discard(n)
                    logger.info("detection: %d probes, faulty=%s",
                                detection.probes, detection.faulty)
                manual = not diag.auto_recoverable
                if manual and self.on_manual is not None:
                    self.on_manual(diag)
                # node loss invalidates that node's RAM cache; a process-level
                # failure can restart from the in-RAM snapshot (fast path)
                if diag.needs_node_cordon:
                    # surviving hosts finish their in-flight background
                    # persists before the restart point is chosen — without
                    # this drain, a snapshot taken just before the failure
                    # may not have landed on disk yet and the job resumes
                    # from a much older step (or from scratch)
                    try:
                        self.ckpt.wait(timeout=60.0)
                    except TimeoutError:
                        logger.warning("persist queue did not drain before "
                                       "restart; resuming from what is on disk")
                    last = self.ckpt.latest_step()
                else:
                    last = self.ckpt.latest_restorable()
                resumed = last if last is not None else start_step
                events.append(RecoveryEvent(
                    attempt, "failure", f.step, diagnosis=diag,
                    detection=detection, resumed_from=resumed,
                    lost_steps=max(0, f.step - resumed), manual=manual,
                    truth=f.truth))
                resume_step = resumed
                if last is not None:
                    resume_extra = self._peek_extra(last)
                logger.info("failure at %d diagnosed %s (%s, manual=%s); "
                            "resume from %d", f.step, diag.failure,
                            diag.source, manual, resumed)

        return SupervisorReport(False, resume_step, self.max_attempts, events)

    def _peek_extra(self, step: int) -> dict:
        if step in self.ckpt.ram_cache:
            return dict(self.ckpt.ram_cache[step][1])
        import json
        import os
        path = os.path.join(self.ckpt.dir, f"step_{step:08d}",
                            "manifest.json")
        try:
            with open(path) as fh:
                return dict(json.load(fh).get("extra", {}))
        except OSError:
            return {}
