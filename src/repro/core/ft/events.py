"""Failure taxonomy from paper Table 3 + synthetic runtime-log generation.

Every failure type carries the paper's measured statistics (occurrences,
GPU demand, time-to-failure, restart cost, % of lost GPU time) and realistic
log templates. The generator emits *cascades* — a root cause plus secondary
symptom errors (the paper: "a job might fail with messages that include
NCCLTimeoutError, CUDAError and multiple kinds of RuntimeError, whereas the
root cause is CUDAError") — which is exactly what makes naive rule matching
inaccurate and motivates the agent-based diagnosis.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Optional

INFRA, FRAMEWORK, SCRIPT = "Infrastructure", "Framework", "Script"


@dataclasses.dataclass(frozen=True)
class FailureType:
    name: str
    category: str
    # paper Table 3 statistics
    num: int
    gpu_demand_avg: float
    ttf_avg_mins: float        # time to failure
    ttf_median_mins: float
    gpu_time_pct: float        # share of lost GPU time
    restart_avg_mins: float
    # diagnosis machinery
    templates: tuple[str, ...] # root-cause log lines ({} slots randomized)
    secondary: tuple[str, ...] = ()  # cascade symptom names
    needs_node_cordon: bool = False  # triggers the two-round NCCL test
    auto_recoverable: bool = True    # restart-from-ckpt fixes it
    priority: int = 0                # higher wins when multiple errors coexist


TABLE3: tuple[FailureType, ...] = (
    # --- Infrastructure ----------------------------------------------------
    FailureType("NVLinkError", INFRA, 54, 800, 868.1, 155.3, 30.25, 95.6,
                ("NVLink Error: fatal error detected on link {d} (GPU {d})",
                 "torch.distributed: NCCL watchdog caught NVLink failure on rank {d}"),
                secondary=("NCCLTimeoutError", "RuntimeError"),
                needs_node_cordon=True, priority=90),
    FailureType("CUDAError", INFRA, 21, 847, 923.2, 586.0, 15.77, 78.3,
                ("CUDA error: an illegal memory access was encountered at device {d}",
                 "CUDA error: uncorrectable ECC error encountered (kernel launch)",
                 "RuntimeError: CUDA error: device-side assert triggered on rank {d}"),
                secondary=("NCCLTimeoutError", "RuntimeError"),
                needs_node_cordon=True, priority=85),
    FailureType("NodeFailure", INFRA, 16, 712, 1288.8, 535.8, 14.30, 102.8,
                ("slurmstepd: error: Node node-{d} unexpectedly rebooted",
                 "kubelet: node node-{d} became NotReady: heartbeat lost"),
                secondary=("ConnectionError",),
                needs_node_cordon=True, priority=80),
    FailureType("ECCError", INFRA, 12, 680, 1303.4, 1192.3, 11.00, 2.8,
                ("GPU {d}: double-bit ECC error detected, row remapping pending",
                 "XID 48: GPU {d} DBE (double bit error) occurred"),
                secondary=("CUDAError",),
                needs_node_cordon=True, priority=88),
    FailureType("NetworkError", INFRA, 12, 758, 549.6, 310.1, 4.53, 592.1,
                ("ibv_poll_cq failed: transport retry counter exceeded on mlx5_{d}",
                 "RDMA read error: remote access error qp={d}"),
                secondary=("NCCLTimeoutError", "ConnectionError"),
                needs_node_cordon=True, priority=75),
    FailureType("ConnectionError", INFRA, 147, 29, 51.9, 0.5, 3.44, 0.8,
                ("ConnectionError: [Errno 111] Connection refused: metrics.acme.lab:{d}",
                 "requests.exceptions.ConnectionError: HTTPSConnectionPool host='wandb-proxy'"),
                priority=30),
    FailureType("S3StorageError", INFRA, 10, 422, 2317.8, 202.2, 2.12, 6.2,
                ("botocore.exceptions.EndpointConnectionError: Could not connect to s3://ckpt-bucket/{d}",
                 "S3 upload failed after {d} retries: SlowDown"),
                priority=60),
    FailureType("NCCLTimeoutError", INFRA, 6, 596, 159.7, 48.1, 0.50, 66.7,
                ("NCCL watchdog: collective operation timed out after 1800000ms rank {d}",
                 "torch.distributed.DistBackendError: NCCL timeout in allreduce"),
                needs_node_cordon=True, priority=70),
    FailureType("NCCLRemoteError", INFRA, 3, 1152, 50.5, 22.6, 0.15, 0.0,
                ("NCCL error: remote process exited or there was a network error, rank {d}",),
                needs_node_cordon=True, priority=72),
    # --- Framework ----------------------------------------------------------
    FailureType("DataloaderKilled", FRAMEWORK, 6, 445, 1580.6, 961.4, 4.38, 115.1,
                ("RuntimeError: DataLoader worker (pid {d}) is killed by signal: Killed",
                 "dataloader worker exited unexpectedly, OOM-killer score {d}"),
                priority=55),
    FailureType("AttributeError", FRAMEWORK, 67, 228, 67.8, 1.2, 3.90, 2.4,
                ("AttributeError: 'NoneType' object has no attribute '{w}'",
                 "AttributeError: module 'internevo.model' has no attribute '{w}'"),
                auto_recoverable=False, priority=20),
    FailureType("OutOfMemoryError", FRAMEWORK, 14, 572, 323.8, 14.5, 3.28, 122.7,
                ("torch.cuda.OutOfMemoryError: Tried to allocate {d} GiB (GPU {d}; 79.35 GiB total)",
                 "RESOURCE_EXHAUSTED: Out of memory while trying to allocate {d} bytes"),
                auto_recoverable=False, priority=65),
    FailureType("RuntimeError", FRAMEWORK, 65, 441, 66.4, 3.9, 1.72, 10.9,
                ("RuntimeError: The size of tensor a ({d}) must match the size of tensor b ({d})",
                 "RuntimeError: expected scalar type BFloat16 but found Float"),
                auto_recoverable=False, priority=15),
    FailureType("AssertionError", FRAMEWORK, 105, 413, 41.7, 3.0, 1.24, 185.9,
                ("AssertionError: micro_num % pipeline_parallel_size == 0",
                 "AssertionError: expected checkpoint step {d}, got {d}"),
                auto_recoverable=False, priority=14),
    FailureType("ValueError", FRAMEWORK, 33, 387, 9.9, 3.7, 0.16, 27.4,
                ("ValueError: could not broadcast input array from shape ({d},) into ({d},)",),
                auto_recoverable=False, priority=13),
    FailureType("ZeroDivisionError", FRAMEWORK, 5, 499, 14.5, 15.6, 0.03, 2.5,
                ("ZeroDivisionError: division by zero in loss scaling",),
                auto_recoverable=False, priority=12),
    FailureType("ModelLoadingError", FRAMEWORK, 104, 8, 2.6, 2.6, 0.00, 0.0,
                ("OSError: Unable to load weights from checkpoint {w}.bin: invalid header",),
                auto_recoverable=False, priority=25),
    FailureType("DatasetLoadingError", FRAMEWORK, 5, 1, 1.6, 1.6, 0.00, 0.0,
                ("DatasetGenerationError: failed to parse shard {w}.jsonl line {d}",),
                auto_recoverable=False, priority=24),
    # --- Script -------------------------------------------------------------
    FailureType("FileNotFoundError", SCRIPT, 568, 21, 14.2, 0.4, 2.83, 0.4,
                ("FileNotFoundError: [Errno 2] No such file or directory: '{w}.json'",),
                auto_recoverable=False, priority=10),
    FailureType("OSError", SCRIPT, 266, 8, 9.6, 0.8, 0.28, 0.3,
                ("OSError: [Errno 122] Disk quota exceeded: '{w}.log'",),
                auto_recoverable=False, priority=9),
    FailureType("TypeError", SCRIPT, 620, 18, 0.9, 0.3, 0.06, 0.2,
                ("TypeError: unsupported operand type(s) for +: 'int' and 'str'",
                 "TypeError: {w}() got an unexpected keyword argument '{w}'"),
                auto_recoverable=False, priority=8),
    FailureType("NameError", SCRIPT, 18, 247, 3.2, 0.5, 0.02, 2.9,
                ("NameError: name '{w}' is not defined",),
                auto_recoverable=False, priority=7),
    FailureType("PermissionError", SCRIPT, 7, 438, 4.3, 0.8, 0.01, 2.4,
                ("PermissionError: [Errno 13] Permission denied: '/mnt/petrel/{w}'",),
                auto_recoverable=False, priority=6),
    FailureType("ImportError", SCRIPT, 111, 93, 1.1, 0.4, 0.01, 0.7,
                ("ImportError: cannot import name '{w}' from 'internevo.{w}'",),
                auto_recoverable=False, priority=5),
    FailureType("KeyError", SCRIPT, 260, 7, 3.0, 1.6, 0.01, 0.1,
                ("KeyError: '{w}'",),
                auto_recoverable=False, priority=4),
    FailureType("SyntaxError", SCRIPT, 10, 391, 0.7, 0.6, 0.00, 1.7,
                ("SyntaxError: invalid syntax ({w}.py, line {d})",),
                auto_recoverable=False, priority=3),
    FailureType("ArgumentError", SCRIPT, 3, 344, 0.7, 0.7, 0.00, 2.7,
                ("argparse.ArgumentError: argument --{w}: invalid int value: '{w}'",),
                auto_recoverable=False, priority=2),
    FailureType("CalledProcessError", SCRIPT, 4, 256, 0.2, 0.2, 0.00, 11.7,
                ("subprocess.CalledProcessError: Command '{w}' returned non-zero exit status {d}",),
                auto_recoverable=False, priority=2),
    FailureType("IndexError", SCRIPT, 23, 6, 1.6, 0.9, 0.00, 0.8,
                ("IndexError: list index out of range",),
                auto_recoverable=False, priority=1),
)

BY_NAME: dict[str, FailureType] = {f.name: f for f in TABLE3}

# the Table-3 types whose root cause lives in a node (GPU/NVLink/ECC/...):
# these are what the replay engine's ``hardware`` interruption class
# synthesizes logs from, and what must come back ``needs_node_cordon`` for
# the diagnosis-in-the-loop recovery to pick the cordon/elastic policies
CORDON_TYPES: tuple[str, ...] = tuple(
    f.name for f in TABLE3 if f.needs_node_cordon)


def types_in_category(category: str) -> tuple[FailureType, ...]:
    """All Table-3 failure types of one paper category
    (Infrastructure/Framework/Script)."""
    return tuple(f for f in TABLE3 if f.category == category)

_WORDS = ("config", "scheduler", "tokenizer", "embedding", "optimizer",
          "sampler", "rotary", "partition", "gateway", "collector")

_NORMAL_LINES = (
    "INFO [trainer] step={step} loss={loss:.4f} lr={lr:.2e} grad_norm={gn:.3f} tgs={tgs:.1f}",
    "INFO [trainer] step={step} consumed_tokens={tok} tflops={tf:.1f}",
    "DEBUG [mem] step={step} allocated={mem:.1f}GB reserved={mem2:.1f}GB",
    "INFO [ckpt] async snapshot step={step} stall={ms:.1f}ms",
    "INFO [data] shard rotation: now reading shard {shard}",
)

_INIT_LINES = (
    "INFO [launch] world_size=1024 tp=8 pp=4 dp=32 micro_batch=4",
    "INFO [launch] NCCL version 2.18.3+cuda12.1",
    "INFO [model] InternLM 123B: layers=96 hidden=10240 heads=80",
    "INFO [data] tokenizer loaded: vocab=103168 model=v7_sft.model",
    "INFO [ckpt] resuming from step 41200 (s3://ckpt-bucket/run-17/)",
)

# serving-flavored spam for the serving replay's injected incidents: same
# format keys as the trainer lines (the generate_log loop passes one kwarg
# set either way, so both flavors consume the RNG identically), but shaped
# like an inference engine's heartbeat — batch occupancy, TTFT, KV paging,
# admission — so the diagnosis pipeline sees a serving log, not a trainer's.
_SERVE_NORMAL_LINES = (
    "INFO [serve] step={step} batch_occupancy={gn:.3f} decode_tps={tgs:.1f}",
    "INFO [serve] step={step} kv_pages_resident={tok} prefill_tflops={tf:.1f}",
    "DEBUG [kv] step={step} allocated={mem:.1f}GB paged={mem2:.1f}GB",
    "INFO [admit] ttft_p50={ms:.1f}ms queue_lambda={loss:.4f}",
    "INFO [route] request shard {shard} admitted to decode instance",
)

_SERVE_INIT_LINES = (
    "INFO [serve] disaggregated fleet up: prefill=4 decode=16 gpus/inst=8",
    "INFO [serve] NCCL version 2.18.3+cuda12.1",
    "INFO [model] InternLM 7B serving: layers=32 hidden=4096 heads=32",
    "INFO [kv] paged KV cache: page=16 tokens, 4096 pages/instance",
    "INFO [serve] continuous batching enabled: max_batch=64",
)


def fill_template(template: str, rng: random.Random) -> str:
    """Randomize a log template's ``{d}``/``{w}`` slots."""
    out = template
    while "{d}" in out:
        out = out.replace("{d}", str(rng.randint(0, 4096)), 1)
    while "{w}" in out:
        out = out.replace("{w}", rng.choice(_WORDS), 1)
    return out


_fill = fill_template


def generate_log(failure: Optional[FailureType], *, seed: int = 0,
                 n_normal: int = 400, start_step: int = 41200,
                 cascade: bool = True, flavor: str = "train") -> list[str]:
    """Synthesize a runtime log: init banner + metric spam [+ failure tail].

    With ``cascade=True`` the root cause is buried among secondary symptom
    errors and repeated watchdog spam, mimicking real multi-error logs.
    ``flavor="serve"`` swaps the trainer banner/heartbeat for an inference
    engine's (same failure-tail templates — the §5 hazards are identical);
    both flavors consume the RNG identically, so a given seed yields the
    same root cause and tail ordering either way.
    """
    rng = random.Random(seed)
    init, normal = ((_SERVE_INIT_LINES, _SERVE_NORMAL_LINES)
                    if flavor == "serve" else (_INIT_LINES, _NORMAL_LINES))
    lines = list(init)
    loss = 2.31
    for i in range(n_normal):
        loss = max(1.2, loss - rng.random() * 1e-3)
        t = rng.choice(normal)
        lines.append(t.format(step=start_step + i, loss=loss,
                              lr=2.4e-5, gn=rng.random() * 2,
                              tgs=3900 + rng.random() * 200,
                              tok=(start_step + i) * 4_194_304,
                              tf=180 + rng.random() * 10,
                              mem=62 + rng.random() * 4,
                              mem2=72 + rng.random() * 4,
                              ms=210 + rng.random() * 40,
                              shard=rng.randint(0, 800)))
    if failure is None:
        return lines
    # failure tail: secondaries first (often what floods the log), root
    # cause in the middle, then more secondary spam — worst case for rules.
    tail: list[str] = []
    if cascade:
        for sec_name in failure.secondary:
            sec = BY_NAME.get(sec_name)
            if sec:
                for _ in range(rng.randint(1, 3)):
                    tail.append("ERROR " + _fill(rng.choice(sec.templates), rng))
    tail.append("ERROR " + _fill(rng.choice(failure.templates), rng))
    if cascade:
        for _ in range(rng.randint(2, 6)):
            tail.append("ERROR Traceback (most recent call last):")
            tail.append('ERROR   File "train.py", line %d, in <module>'
                        % rng.randint(100, 900))
        for sec_name in failure.secondary:
            sec = BY_NAME.get(sec_name)
            if sec:
                tail.append("ERROR " + _fill(rng.choice(sec.templates), rng))
    rng.shuffle(tail)  # interleaving across ranks scrambles ordering
    return lines + tail


def sample_failure(rng: random.Random,
                   category: Optional[str] = None) -> FailureType:
    """Draw a failure type with probability proportional to Table 3 counts."""
    pool = [f for f in TABLE3 if category is None or f.category == category]
    weights = [f.num for f in pool]
    return rng.choices(pool, weights=weights, k=1)[0]
