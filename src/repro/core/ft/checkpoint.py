"""Asynchronous checkpointing (paper §6.1, design 1).

The paper's observation: host memory is abundant (<50% used, Fig. 7b) while
TB-scale synchronous checkpoints stall training up to 43%. The fix: snapshot
device state into host RAM (the only part that blocks the training loop),
then persist to (remote) storage from a background thread.

This module implements:
  * ``CheckpointManager.save_async``  — blocking cost = device->host snapshot
  * ``CheckpointManager.save_sync``   — baseline: snapshot + serialize + write
  * in-RAM checkpoint cache (Gemini-style fast restore path)
  * atomic on-disk commit (tmp dir + rename; manifest written last)
  * mesh-agnostic restore: leaves are logical global arrays, re-sharded on
    load via ``jax.device_put`` — this is what makes restarts *elastic*
    (save on mesh A, resume on mesh B with fewer/more healthy nodes)
  * optional storage-bandwidth throttle modelling a contended remote PFS
    (the paper's all-NVMe shared parallel FS with a 25 Gb/s storage NIC)

State layout on disk::

    <dir>/step_00001230/
        manifest.json     # leaf count, shapes/dtypes, extra state, committed
        leaf_000000.npy ...

Tree *structure* comes from code (model.specs() + optimizer template), only
leaf data lives in storage — standard production practice.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.utils import logger

Params = Any


def _snapshot(tree: Params) -> list[np.ndarray]:
    """Device -> host copy of all leaves. This is the only training stall.

    ``copy=True`` forces a real materialized copy even on the CPU backend
    (where device_get would alias) — the honest stand-in for the D2H DMA."""
    leaves = jax.tree_util.tree_leaves(tree)
    return [np.array(jax.device_get(l), copy=True) for l in leaves]


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 ram_cache_slots: int = 2,
                 storage_bandwidth_gbps: Optional[float] = None):
        self.dir = directory
        self.keep = keep
        self.ram_cache_slots = ram_cache_slots
        self.bw = storage_bandwidth_gbps          # None = unthrottled
        self.ram_cache: dict[int, tuple[list[np.ndarray], dict]] = {}
        self._q: "queue.Queue[Optional[tuple]]" = queue.Queue()
        self._worker = threading.Thread(target=self._persist_loop, daemon=True)
        self._worker.start()
        self._inflight = 0
        self._lock = threading.Lock()
        self._errors: list[str] = []
        self._malformed_warned: set[str] = set()
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save_async(self, step: int, state: Params,
                   extra: Optional[dict] = None) -> float:
        """Snapshot to host RAM and return; persistence happens in the
        background. Returns the blocking (stall) time in seconds."""
        t0 = time.perf_counter()
        leaves = _snapshot(state)
        stall = time.perf_counter() - t0
        extra = dict(extra or {})
        self._cache_put(step, leaves, extra)
        with self._lock:
            self._inflight += 1
        self._q.put((step, leaves, extra))
        return stall

    def save_sync(self, step: int, state: Params,
                  extra: Optional[dict] = None) -> float:
        """Baseline synchronous checkpoint. Returns total blocking time."""
        t0 = time.perf_counter()
        leaves = _snapshot(state)
        self._cache_put(step, leaves, dict(extra or {}))
        self._write(step, leaves, dict(extra or {}))
        return time.perf_counter() - t0

    def wait(self, timeout: float = 300.0) -> None:
        """Drain in-flight background persists."""
        deadline = time.time() + timeout
        while time.time() < deadline:
            with self._lock:
                if self._inflight == 0:
                    return
            time.sleep(0.005)
        raise TimeoutError("checkpoint persist queue did not drain")

    # -- restore ------------------------------------------------------------

    def latest_step(self) -> Optional[int]:
        steps = self.available_steps()
        return steps[-1] if steps else None

    def latest_restorable(self) -> Optional[int]:
        """Newest step restorable from RAM cache *or* disk.

        The RAM cache is the Gemini-style fast path: a snapshot that has not
        finished persisting yet is still perfectly good for an in-place
        restart (process survived, node didn't fail)."""
        steps = set(self.available_steps()) | set(self.ram_cache)
        return max(steps) if steps else None

    def available_steps(self) -> list[int]:
        if not os.path.isdir(self.dir):
            return []
        out = []
        for name in os.listdir(self.dir):
            if not name.startswith("step_") or name.endswith(".tmp"):
                continue
            # stray entries ("step_final", "step_12_copy", editor litter)
            # must not poison the scan — skip anything whose suffix is not
            # a plain integer step number
            suffix = name[len("step_"):]
            if not suffix.isdigit():
                if name not in self._malformed_warned:
                    self._malformed_warned.add(name)
                    logger.warning("ignoring malformed checkpoint entry %r",
                                   name)
                continue
            manifest = os.path.join(self.dir, name, "manifest.json")
            if os.path.exists(manifest):
                out.append(int(suffix))
        return sorted(out)

    def restore(self, step: int, template: Params,
                shardings: Optional[Params] = None) -> tuple[Params, dict]:
        """Load leaves (RAM cache first, then disk) into ``template``'s
        structure; re-shard when ``shardings`` given (elastic restart)."""
        if step in self.ram_cache:
            leaves, extra = self.ram_cache[step]
            logger.info("checkpoint %d restored from RAM cache", step)
        else:
            path = self._step_dir(step)
            with open(os.path.join(path, "manifest.json")) as f:
                manifest = json.load(f)
            leaves = [np.load(os.path.join(path, f"leaf_{i:06d}.npy"))
                      for i in range(manifest["num_leaves"])]
            extra = manifest.get("extra", {})
        treedef = jax.tree_util.tree_structure(template)
        flat_t = jax.tree_util.tree_leaves(template)
        assert len(flat_t) == len(leaves), \
            f"leaf count mismatch: template {len(flat_t)} vs ckpt {len(leaves)}"
        if shardings is not None:
            flat_s = treedef.flatten_up_to(shardings)
            leaves = [jax.device_put(l.astype(t.dtype), s)
                      for l, t, s in zip(leaves, flat_t, flat_s)]
        else:
            leaves = [jax.numpy.asarray(l.astype(t.dtype))
                      for l, t in zip(leaves, flat_t)]
        return jax.tree_util.tree_unflatten(treedef, leaves), extra

    # -- internals ----------------------------------------------------------

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def _cache_put(self, step: int, leaves, extra) -> None:
        self.ram_cache[step] = (leaves, extra)
        while len(self.ram_cache) > self.ram_cache_slots:
            del self.ram_cache[min(self.ram_cache)]

    def _persist_loop(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            step, leaves, extra = item
            try:
                self._write(step, leaves, extra)
            except Exception as e:  # noqa: BLE001 — background thread
                self._errors.append(f"step {step}: {e!r}")
                logger.error("checkpoint persist failed: %s", e)
            finally:
                with self._lock:
                    self._inflight -= 1

    def _write(self, step: int, leaves: list[np.ndarray],
               extra: dict) -> None:
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.isdir(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp, exist_ok=True)
        total = 0
        t0 = time.perf_counter()
        for i, leaf in enumerate(leaves):
            np.save(os.path.join(tmp, f"leaf_{i:06d}.npy"), leaf)
            total += leaf.nbytes
        if self.bw is not None:
            # model a contended remote PFS: bytes / (Gb/s -> B/s)
            want = total / (self.bw * 1e9 / 8)
            slept = want - (time.perf_counter() - t0)
            if slept > 0:
                time.sleep(slept)
        manifest = {
            "step": step,
            "num_leaves": len(leaves),
            "total_bytes": total,
            "shapes": [list(l.shape) for l in leaves],
            "dtypes": [str(l.dtype) for l in leaves],
            "extra": extra,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.isdir(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.available_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def close(self) -> None:
        self._q.put(None)
        self._worker.join(timeout=10)
