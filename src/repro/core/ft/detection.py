"""Fast fault detection (paper §6.1, design 3).

Two-round pairwise-allgather sweep (the DLRover-style screen the paper
adopts):

  Round 1: divide all nodes into 2-node worlds (one world of 3 when the
           count is odd) and run an allgather health probe in each. A failed
           world marks *all* its members as suspects.
  Round 2: pair every suspect with a known-good node and probe again; the
           worlds that fail pinpoint the faulty nodes, which are cordoned.

The probe is abstract (``NodeProbe``): the simulated fleet flips health bits;
a real deployment implements it with a small allgather over
``jax.experimental.multihost_utils`` on the candidate hosts.

Also includes the straggler monitor: per-host step wall-times -> robust
z-score (median/MAD) -> slow hosts feed the same cordon list, so persistent
stragglers are removed at the next elastic restart.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Protocol, Sequence

import numpy as np


class NodeProbe(Protocol):
    def allgather_ok(self, world: Sequence[int]) -> bool:
        """Run an allgather across ``world`` node ids; True iff it passes."""
        ...


# ---------------------------------------------------------------------------
# simulated fleet (the container has no multi-host hardware)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SimulatedFleet:
    """A fleet of nodes with hidden health state and a probe counter."""
    num_nodes: int
    faulty: set[int] = dataclasses.field(default_factory=set)
    cordoned: set[int] = dataclasses.field(default_factory=set)
    probes_run: int = 0

    def healthy_nodes(self) -> list[int]:
        return [n for n in range(self.num_nodes)
                if n not in self.cordoned]

    def allgather_ok(self, world: Sequence[int]) -> bool:
        self.probes_run += 1
        return not any(n in self.faulty for n in world)

    def fail(self, nodes: Iterable[int]) -> None:
        self.faulty.update(nodes)

    def repair(self, nodes: Iterable[int]) -> None:
        for n in nodes:
            self.faulty.discard(n)
            self.cordoned.discard(n)

    def cordon(self, nodes: Iterable[int]) -> None:
        self.cordoned.update(nodes)


# ---------------------------------------------------------------------------
# two-round localization
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DetectionResult:
    faulty: tuple[int, ...]
    suspects_round1: tuple[int, ...]
    probes: int
    rounds: int


def _pair_up(nodes: list[int]) -> list[list[int]]:
    """2-node worlds; odd count -> one world of 3 (paper's rule)."""
    worlds = [list(nodes[i:i + 2]) for i in range(0, len(nodes) - (len(nodes) % 2), 2)]
    if len(nodes) % 2:
        if worlds:
            worlds[-1].append(nodes[-1])
        else:
            worlds = [[nodes[-1]]]
    return worlds


def two_round_detection(nodes: Sequence[int],
                        probe: NodeProbe) -> DetectionResult:
    """Locate faulty nodes with two rounds of pairwise allgather probes."""
    nodes = list(nodes)
    probes = 0

    # round 1: pairwise sweep
    suspects: list[int] = []
    cleared: list[int] = []
    for world in _pair_up(nodes):
        probes += 1
        if probe.allgather_ok(world):
            cleared.extend(world)
        else:
            suspects.extend(world)

    if not suspects:
        return DetectionResult((), (), probes, 1)

    # round 2: re-pair each suspect with a known-good node
    faulty: list[int] = []
    if not cleared:
        # degenerate fleet (everything suspect): probe each node "alone";
        # a single-node allgather still exercises its NIC/GPU path.
        for s in suspects:
            probes += 1
            if not probe.allgather_ok([s]):
                faulty.append(s)
        return DetectionResult(tuple(faulty), tuple(suspects), probes, 2)

    good_cycle = 0
    for s in suspects:
        buddy = cleared[good_cycle % len(cleared)]
        good_cycle += 1
        probes += 1
        if not probe.allgather_ok([s, buddy]):
            faulty.append(s)
    return DetectionResult(tuple(faulty), tuple(suspects), probes, 2)


# ---------------------------------------------------------------------------
# straggler monitor
# ---------------------------------------------------------------------------

class StragglerMonitor:
    """Per-host step-time ring buffers -> robust z-score slow-host flags.

    A host is a straggler when its median step time exceeds the fleet
    median by ``z_threshold`` robust z-scores (MAD-based) for at least
    ``min_samples`` observed steps.
    """

    def __init__(self, hosts: Sequence[int], *, window: int = 32,
                 z_threshold: float = 6.0, min_samples: int = 8):
        self.window = window
        self.z_threshold = z_threshold
        self.min_samples = min_samples
        self.times: dict[int, list[float]] = {h: [] for h in hosts}

    def record(self, host: int, step_time: float) -> None:
        buf = self.times.setdefault(host, [])
        buf.append(step_time)
        if len(buf) > self.window:
            del buf[0]

    def stragglers(self) -> list[int]:
        meds = {h: float(np.median(t)) for h, t in self.times.items()
                if len(t) >= self.min_samples}
        if len(meds) < 3:
            return []
        vals = np.array(list(meds.values()))
        med = float(np.median(vals))
        mad = float(np.median(np.abs(vals - med))) or 1e-9
        out = []
        for h, v in meds.items():
            z = 0.6745 * (v - med) / mad
            if z > self.z_threshold:
                out.append(h)
        return sorted(out)
