"""Configuration system.

Frozen dataclasses describing the model, parallelism and run; a registry that
maps ``--arch <id>`` names to config builders (populated by repro.configs.*).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.utils import round_up

# ---------------------------------------------------------------------------
# model configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    kind: str = "gqa"                # "gqa" | "mla"
    num_heads: int = 8
    num_kv_heads: int = 8
    head_dim: int = 64
    rope_theta: float = 10000.0
    use_rope: bool = True            # whisper uses learned/sinusoidal positions
    sliding_window: int = 0          # 0 = full attention; >0 = SWA window
    # local:global interleave (gemma3): every `global_every`-th layer is
    # global, others use `local_window` sliding window. 0 disables.
    global_every: int = 0
    local_window: int = 1024
    # MLA (deepseek-v2) parameters
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    logit_softcap: float = 0.0

    @property
    def q_dim(self) -> int:
        if self.kind == "mla":
            return self.num_heads * (self.qk_nope_dim + self.qk_rope_dim)
        return self.num_heads * self.head_dim

    @property
    def o_in_dim(self) -> int:
        if self.kind == "mla":
            return self.num_heads * self.v_head_dim
        return self.num_heads * self.head_dim

    def layer_window(self, layer_idx: int) -> int:
        """Effective attention window for a layer. 0 means full/global."""
        if self.global_every > 0:
            is_global = (layer_idx + 1) % self.global_every == 0
            return 0 if is_global else self.local_window
        return self.sliding_window


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0             # routed experts; 0 = dense model
    num_shared_experts: int = 0
    top_k: int = 2
    expert_ff: int = 0               # per-expert hidden dim
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_every: int = 1               # MoE on layers where idx % moe_every == moe_offset
    moe_offset: int = 0
    first_k_dense: int = 0           # leading dense layers (deepseek-v2)
    first_dense_ff: int = 0          # d_ff of those dense layers (0 -> model d_ff)

    def is_moe_layer(self, layer_idx: int) -> bool:
        if self.num_experts == 0 or layer_idx < self.first_k_dense:
            return False
        return (layer_idx % self.moe_every) == self.moe_offset


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128             # N
    head_dim: int = 64               # P
    expand: int = 2                  # d_inner = expand * d_model
    n_groups: int = 1                # B/C groups (G)
    conv_width: int = 4
    chunk_size: int = 256            # SSD chunk length
    head_block: int = 16             # heads per jnp-oracle SSD block (memory)

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_ssm_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"            # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int = 2
    d_model: int = 128
    d_ff: int = 512
    vocab_size: int = 1024
    max_seq_len: int = 4096
    attention: Optional[AttentionConfig] = None
    moe: MoEConfig = MoEConfig()
    ssm: Optional[SSMConfig] = None
    mlp_act: str = "silu_glu"        # silu_glu | gelu_glu | relu2 | gelu
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    vocab_pad_multiple: int = 128
    # hybrid (jamba): within each block of `attn_every` layers, layer at index
    # `attn_index` is attention and the rest are mamba. attn_every==1 -> all attn.
    attn_every: int = 1
    attn_index: int = 0
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0             # fixed encoder frames (whisper: 1500)
    # modality frontend stubs supply precomputed embeddings via input_specs()
    frontend: str = "none"           # none | audio_stub | patch_stub
    num_patches: int = 0             # vlm: patch embeddings prepended to text
    dtype: str = "bfloat16"
    # which attention implementation the jnp path uses for long sequences
    attn_block_q: int = 512
    attn_block_kv: int = 512

    @property
    def padded_vocab(self) -> int:
        return round_up(self.vocab_size, self.vocab_pad_multiple)

    def is_attn_layer(self, layer_idx: int) -> bool:
        if self.family == "ssm":
            return False
        if self.attn_every <= 1:
            return True
        return (layer_idx % self.attn_every) == self.attn_index

    def validate(self) -> None:
        if self.family != "ssm" and self.attention is None:
            raise ValueError(f"{self.name}: non-ssm model needs attention config")
        if self.family in ("ssm", "hybrid") and self.ssm is None:
            raise ValueError(f"{self.name}: ssm/hybrid model needs ssm config")
        if self.moe.num_experts and not self.moe.expert_ff:
            raise ValueError(f"{self.name}: moe needs expert_ff")
        if self.family == "audio" and self.encoder_layers <= 0:
            raise ValueError(f"{self.name}: audio model needs encoder layers")


# ---------------------------------------------------------------------------
# parallelism / run configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """How logical axes map onto the device mesh.

    Mesh axes are (pod, data, model) in multi-pod mode or (data, model) in
    single-pod mode. ``zero`` selects the redundancy-sharding mode:
      - "none":  params replicated over data axes (plain DP)
      - "zero1": optimizer state sharded over data axes, params replicated
      - "zero3": params + optimizer state sharded over data axes (FSDP)
      - "zero3_hier": params sharded over the *pod-local* data axis only
        (paper's hierarchical ZeRO: bound gather groups to a pod)
    """
    zero: str = "zero3"
    shard_model_axes: bool = True    # tensor parallelism over the "model" axis
    sequence_parallel: bool = True   # shard long activations over "model"
    expert_parallel: bool = True     # shard experts over "model" when divisible
    remat: str = "dots"              # none | full | dots
    scan_layers: bool = True
    # "float32": grads flow/reduce in fp32 (paper-faithful baseline).
    # "bfloat16": differentiate w.r.t. a bf16 view of the params so every
    # gradient tensor — including its cross-device reduction — is bf16
    # (halves the dominant collective bytes; fp32 master stays in AdamW).
    grad_dtype: str = "float32"
    moe_impl: str = "gshard"         # gshard (shard_map a2a) | dense (all experts)
    decode_moe_impl: str = "dense"   # dense | gather (top-k weight gather, small batch)
    use_pallas: bool = False         # TPU-only fast path; CPU dry-run uses jnp


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    global_batch: int = 8
    seq_len: int = 512
    microbatches: int = 1            # gradient accumulation steps
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    adam_eps: float = 1e-8
    z_loss: float = 1e-4
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class RunConfig:
    model: ModelConfig = ModelConfig()
    parallel: ParallelConfig = ParallelConfig()
    train: TrainConfig = TrainConfig()


# ---------------------------------------------------------------------------
# architecture registry (populated by repro.configs)
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}
_SMOKE: dict[str, Callable[[], ModelConfig]] = {}


def register_arch(name: str, smoke: Optional[Callable[[], ModelConfig]] = None
                  ) -> Callable:
    def deco(fn: Callable[[], ModelConfig]) -> Callable[[], ModelConfig]:
        _REGISTRY[name] = fn
        if smoke is not None:
            _SMOKE[name] = smoke
        return fn
    return deco


def register_smoke(name: str) -> Callable:
    def deco(fn: Callable[[], ModelConfig]) -> Callable[[], ModelConfig]:
        _SMOKE[name] = fn
        return fn
    return deco


def get_arch(name: str) -> ModelConfig:
    import repro.configs  # noqa: F401  (triggers registration)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    cfg = _REGISTRY[name]()
    cfg.validate()
    return cfg


def get_smoke(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    import repro.configs  # noqa: F401
    if name not in _SMOKE:
        raise KeyError(f"no smoke config for {name!r}")
    cfg = _SMOKE[name]()
    cfg.validate()
    return cfg


def list_archs() -> list[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)
