"""Mamba2 block via SSD (state-space duality, arXiv:2405.21060).

The chunked SSD algorithm: intra-chunk attention-like quadratic term +
inter-chunk linear state recurrence (log-depth via associative scan). This
pure-jnp implementation is also the oracle for the Pallas SSD kernel in
``repro.kernels.ssd``. Decode is the O(1)-per-token state recurrence.

Projections are kept as separate matrices (z/x/B/C/dt) instead of one fused
in_proj so each piece carries a clean sharding axis (inner dims TP-sharded
over ``model``, B/C groups replicated) — the TPU-native layout.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.config import SSMConfig
from repro.models.layers import rmsnorm
from repro.models.spec import ParamSpec

Params = Any


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------

def mamba_specs(cfg: SSMConfig, d_model: int) -> dict:
    d_in = cfg.d_inner(d_model)
    H = cfg.num_ssm_heads(d_model)
    GN = cfg.n_groups * cfg.state_dim
    s = d_model ** -0.5
    w = cfg.conv_width
    return {
        "in_z": ParamSpec((d_model, d_in), ("embed", "ssm_inner"), stddev=s),
        "in_x": ParamSpec((d_model, d_in), ("embed", "ssm_inner"), stddev=s),
        "in_B": ParamSpec((d_model, GN), ("embed", None), stddev=s),
        "in_C": ParamSpec((d_model, GN), ("embed", None), stddev=s),
        "in_dt": ParamSpec((d_model, H), ("embed", "ssm_heads"), stddev=s),
        "conv_x": ParamSpec((w, d_in), (None, "ssm_inner"), stddev=w ** -0.5),
        "conv_x_b": ParamSpec((d_in,), ("ssm_inner",), init="zeros"),
        "conv_B": ParamSpec((w, GN), (None, None), stddev=w ** -0.5),
        "conv_B_b": ParamSpec((GN,), (None,), init="zeros"),
        "conv_C": ParamSpec((w, GN), (None, None), stddev=w ** -0.5),
        "conv_C_b": ParamSpec((GN,), (None,), init="zeros"),
        "A_log": ParamSpec((H,), ("ssm_heads",), init="a_log"),
        "dt_bias": ParamSpec((H,), ("ssm_heads",), init="zeros"),
        "D": ParamSpec((H,), ("ssm_heads",), init="ones"),
        "norm": ParamSpec((d_in,), ("ssm_inner",), init="ones"),
        "out": ParamSpec((d_in, d_model), ("ssm_inner", "embed"),
                         stddev=d_in ** -0.5),
    }


# ---------------------------------------------------------------------------
# causal depthwise conv (width-4: unrolled shifts — cheap and shardable)
# ---------------------------------------------------------------------------

def causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (B, L, C); w: (W, C) -> (B, L, C), causal."""
    W = w.shape[0]
    out = x * w[-1]
    for i in range(1, W):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i or None][:, :x.shape[1]]
        out = out + shifted * w[-1 - i]
    return out + b


def causal_conv_step(x_t: jax.Array, state: jax.Array, w: jax.Array,
                     b: jax.Array) -> tuple[jax.Array, jax.Array]:
    """One-token conv. x_t: (B, C); state: (B, W-1, C) holds prior inputs."""
    full = jnp.concatenate([state, x_t[:, None, :]], axis=1)   # (B, W, C)
    out = jnp.einsum("bwc,wc->bc", full, w) + b
    return out, full[:, 1:]


# ---------------------------------------------------------------------------
# SSD core (chunked) — the jnp oracle
# ---------------------------------------------------------------------------

def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                C: jax.Array, *, chunk: int,
                initial_state: jax.Array | None = None,
                return_final_state: bool = False,
                head_block: int = 0):
    """SSD scan over chunks.

    x: (b, L, H, P); dt: (b, L, H) (already softplus'd, >=0);
    A: (H,) negative; B, C: (b, L, G, N). Returns y (b, L, H, P)
    [+ final state (b, H, P, N)]. L is padded to a chunk multiple internally.

    ``head_block`` > 0 processes group-aligned head blocks under vmap-of-map
    so the intra-chunk (cl, cl, Hb) decay tensors stay bounded — the jnp
    analogue of the Pallas kernel's per-head grid.
    """
    b, L, H, P = x.shape
    G, N0 = B.shape[-2:]
    rep0 = max(H // G, 1)
    if head_block and H > head_block:
        gb = max(head_block // rep0, 1)       # whole groups per block
        nb = G // gb
        if nb > 1 and G % gb == 0:
            # (b, L, nb, Hb/P...) blocked views; scan over nb blocks
            hb = gb * rep0                    # heads per block
            xb = x.reshape(b, L, nb, hb, P)
            dtb = dt.reshape(b, L, nb, hb)
            Ab = A.reshape(nb, hb)
            Bb = B.reshape(b, L, nb, gb, N0)
            Cb = C.reshape(b, L, nb, gb, N0)

            def one(i):
                return ssd_chunked(
                    xb[:, :, i], dtb[:, :, i], Ab[i], Bb[:, :, i],
                    Cb[:, :, i], chunk=chunk,
                    initial_state=(initial_state.reshape(
                        b, nb, hb, P, N0)[:, i]
                        if initial_state is not None else None),
                    return_final_state=True)

            ys, states = jax.lax.map(one, jnp.arange(nb))
            y = jnp.moveaxis(ys, 0, 2).reshape(b, L if L % chunk == 0 else L,
                                               H, P)
            y = y[:, :L]
            if return_final_state:
                state = jnp.moveaxis(states, 0, 1).reshape(b, H, P, N0)
                return y, state
            return y
    G, N = B.shape[-2:]
    rep = H // G
    cl = min(chunk, L)
    nc = -(-L // cl)
    pad = nc * cl - L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))   # dt=0 -> no-op steps
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))

    f32 = jnp.float32
    xc = x.reshape(b, nc, cl, H, P)
    dtc = dt.reshape(b, nc, cl, H).astype(f32)
    Bc = B.reshape(b, nc, cl, G, N)
    Cc = C.reshape(b, nc, cl, G, N)

    dA = dtc * A.astype(f32)                           # (b,nc,cl,H), <= 0
    cum = jnp.cumsum(dA, axis=2)                       # within-chunk cumsum
    # intra-chunk: decay from step j to step i (i >= j). Mask INSIDE the
    # exp: above the diagonal seg > 0 can overflow, and where(tri, exp, 0)
    # would leak NaN through the backward pass (inf * 0 cotangent).
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]        # (b,nc,i,j,H)
    tri = jnp.tril(jnp.ones((cl, cl), bool))
    Lmat = jnp.exp(jnp.where(tri[None, None, :, :, None], seg, -jnp.inf))
    scores = jnp.einsum("bcign,bcjgn->bcijg", Cc.astype(f32), Bc.astype(f32))
    scores = jnp.repeat(scores, rep, axis=-1)                  # g -> h
    W = scores * Lmat * dtc[:, :, None, :, :]                  # (b,nc,i,j,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", W, xc.astype(f32))

    # chunk-boundary states: (b, nc, H, P, N)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)            # (b,nc,j,H)
    Bh = jnp.repeat(Bc, rep, axis=3).astype(f32)               # (b,nc,cl,H,N)
    S = jnp.einsum("bcjh,bcjhn,bcjhp->bchpn",
                   decay_to_end * dtc, Bh, xc.astype(f32))

    # inter-chunk recurrence T_n = a_n * T_{n-1} + S_n (assoc. scan)
    a = jnp.exp(cum[:, :, -1, :])                              # (b,nc,H)

    def combine(e1, e2):
        a1, s1 = e1
        a2, s2 = e2
        return a1 * a2, a2[..., None, None] * s1 + s2

    T_a, T_s = jax.lax.associative_scan(combine, (a, S), axis=1)
    if initial_state is not None:
        # fold the initial state through each prefix decay
        T_s = T_s + (T_a[..., None, None] * initial_state[:, None].astype(f32))
    # state entering chunk n = T_{n-1} (zeros/init for n=0)
    init = (initial_state[:, None].astype(f32) if initial_state is not None
            else jnp.zeros_like(T_s[:, :1]))
    R = jnp.concatenate([init, T_s[:, :-1]], axis=1)           # (b,nc,H,P,N)

    Ch = jnp.repeat(Cc, rep, axis=3).astype(f32)               # (b,nc,cl,H,N)
    y_inter = jnp.einsum("bcihn,bcih,bchpn->bcihp",
                         Ch, jnp.exp(cum), R)
    y = (y_intra + y_inter).reshape(b, nc * cl, H, P)[:, :L]
    y = y.astype(x.dtype)
    if return_final_state:
        return y, T_s[:, -1]                                   # (b,H,P,N)
    return y


def ssd_decode_step(state: jax.Array, x_t: jax.Array, dt_t: jax.Array,
                    A: jax.Array, B_t: jax.Array, C_t: jax.Array):
    """One-token SSD. state: (b,H,P,N); x_t: (b,H,P); dt_t: (b,H);
    B_t, C_t: (b,G,N). Returns (y_t (b,H,P), new_state)."""
    b, H, P, N = state.shape
    G = B_t.shape[1]
    rep = H // G
    f32 = jnp.float32
    Bh = jnp.repeat(B_t, rep, axis=1).astype(f32)              # (b,H,N)
    Ch = jnp.repeat(C_t, rep, axis=1).astype(f32)
    decay = jnp.exp(dt_t.astype(f32) * A.astype(f32))          # (b,H)
    upd = (dt_t.astype(f32)[..., None, None] * x_t.astype(f32)[..., None]
           * Bh[:, :, None, :])                                # (b,H,P,N)
    new_state = decay[..., None, None] * state.astype(f32) + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    return y.astype(x_t.dtype), new_state


# ---------------------------------------------------------------------------
# full mamba2 block
# ---------------------------------------------------------------------------

def _project(params: Params, x: jax.Array, cfg: SSMConfig, d_model: int,
             dtype) -> tuple:
    z = x @ params["in_z"].astype(dtype)
    xi = x @ params["in_x"].astype(dtype)
    Bi = x @ params["in_B"].astype(dtype)
    Ci = x @ params["in_C"].astype(dtype)
    dt = x @ params["in_dt"].astype(dtype)
    return z, xi, Bi, Ci, dt


def mamba_forward(params: Params, cfg: SSMConfig, x: jax.Array, *,
                  d_model: int, dtype, norm_eps: float = 1e-5,
                  return_state: bool = False):
    """Full-sequence mamba2 block. x: (B, L, d_model)."""
    b, L, _ = x.shape
    H = cfg.num_ssm_heads(d_model)
    P = cfg.head_dim
    G, N = cfg.n_groups, cfg.state_dim
    z, xi, Bi, Ci, dt = _project(params, x, cfg, d_model, dtype)
    xi = jax.nn.silu(causal_conv(xi, params["conv_x"].astype(dtype),
                                 params["conv_x_b"].astype(dtype)))
    Bi = jax.nn.silu(causal_conv(Bi, params["conv_B"].astype(dtype),
                                 params["conv_B_b"].astype(dtype)))
    Ci = jax.nn.silu(causal_conv(Ci, params["conv_C"].astype(dtype),
                                 params["conv_C_b"].astype(dtype)))
    xh = xi.reshape(b, L, H, P)
    dt_sp = jax.nn.softplus(dt.astype(jnp.float32)
                            + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    from repro.kernels import runtime
    if runtime.STATE.use_pallas:
        from repro.kernels.ssd import ssd as ssd_kernel
        y, final_state = ssd_kernel(xh, dt_sp, A, Bi.reshape(b, L, G, N),
                                    Ci.reshape(b, L, G, N),
                                    chunk=cfg.chunk_size,
                                    interpret=runtime.STATE.interpret)
        if not return_state:
            final_state = None
    else:
        out = ssd_chunked(xh, dt_sp, A, Bi.reshape(b, L, G, N),
                          Ci.reshape(b, L, G, N), chunk=cfg.chunk_size,
                          return_final_state=return_state,
                          head_block=cfg.head_block)
        y, final_state = out if return_state else (out, None)
    y = y + params["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(b, L, H * P)
    y = rmsnorm({"scale": params["norm"]}, y * jax.nn.silu(z), norm_eps)
    y = y @ params["out"].astype(dtype)
    if return_state:
        # conv tail states: the last (W-1) *pre-conv* channel inputs
        W = cfg.conv_width
        def tail(v):
            return jnp.pad(v, ((0, 0), (max(W - 1 - L, 0), 0), (0, 0)))[:, -(W - 1):]
        _, xi_raw, Bi_raw, Ci_raw, _ = _project(params, x, cfg, d_model, dtype)
        cache = {
            "ssm": final_state,
            "conv_x": tail(xi_raw), "conv_B": tail(Bi_raw),
            "conv_C": tail(Ci_raw),
        }
        return y, cache
    return y


def mamba_cache_init(cfg: SSMConfig, batch: int, d_model: int, dtype) -> dict:
    H = cfg.num_ssm_heads(d_model)
    d_in = cfg.d_inner(d_model)
    GN = cfg.n_groups * cfg.state_dim
    W = cfg.conv_width
    return {
        "ssm": jnp.zeros((batch, H, cfg.head_dim, cfg.state_dim), jnp.float32),
        "conv_x": jnp.zeros((batch, W - 1, d_in), dtype),
        "conv_B": jnp.zeros((batch, W - 1, GN), dtype),
        "conv_C": jnp.zeros((batch, W - 1, GN), dtype),
    }


def mamba_decode(params: Params, cfg: SSMConfig, x: jax.Array, cache: dict, *,
                 d_model: int, dtype, norm_eps: float = 1e-5):
    """One-token decode. x: (B, 1, d_model)."""
    b = x.shape[0]
    H = cfg.num_ssm_heads(d_model)
    P = cfg.head_dim
    G, N = cfg.n_groups, cfg.state_dim
    z, xi, Bi, Ci, dt = _project(params, x[:, 0], cfg, d_model, dtype)
    xi, conv_x = causal_conv_step(xi, cache["conv_x"],
                                  params["conv_x"].astype(dtype),
                                  params["conv_x_b"].astype(dtype))
    Bi, conv_B = causal_conv_step(Bi, cache["conv_B"],
                                  params["conv_B"].astype(dtype),
                                  params["conv_B_b"].astype(dtype))
    Ci, conv_C = causal_conv_step(Ci, cache["conv_C"],
                                  params["conv_C"].astype(dtype),
                                  params["conv_C_b"].astype(dtype))
    xi, Bi, Ci = jax.nn.silu(xi), jax.nn.silu(Bi), jax.nn.silu(Ci)
    dt_sp = jax.nn.softplus(dt.astype(jnp.float32)
                            + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    y, new_state = ssd_decode_step(cache["ssm"], xi.reshape(b, H, P), dt_sp,
                                   A, Bi.reshape(b, G, N), Ci.reshape(b, G, N))
    y = y + params["D"].astype(y.dtype)[None, :, None] * xi.reshape(b, H, P)
    y = y.reshape(b, 1, H * P)
    y = rmsnorm({"scale": params["norm"]}, y * jax.nn.silu(z[:, None, :]),
                norm_eps)
    y = y @ params["out"].astype(dtype)
    new_cache = {"ssm": new_state, "conv_x": conv_x, "conv_B": conv_B,
                 "conv_C": conv_C}
    return y, new_cache
