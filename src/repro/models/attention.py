"""Attention: GQA (w/ sliding-window + local:global) and MLA (DeepSeek-v2).

The trainable path uses a blockwise online-softmax implementation in pure jnp
(`flash_attention_jnp`) so 32k-token prefill never materializes an (S, S)
score matrix; it is also the oracle for the Pallas TPU kernel in
``repro.kernels.flash_attention``. Decode uses ring-buffer KV caches whose
slots carry absolute positions, which makes full, sliding-window and
local:global layers uniform (validity is just a predicate on slot position).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.config import AttentionConfig
from repro.models.layers import apply_rope
from repro.models.spec import ParamSpec

Params = Any
NEG_INF = -2.0 ** 30  # large-but-finite; avoids NaNs for fully-masked rows


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------

def attn_specs(cfg: AttentionConfig, d_model: int) -> dict:
    s = d_model ** -0.5
    if cfg.kind == "mla":
        qk = cfg.qk_nope_dim + cfg.qk_rope_dim
        specs = {
            "w_dkv": ParamSpec((d_model, cfg.kv_lora_rank + cfg.qk_rope_dim),
                               ("embed", None), stddev=s),
            "kv_norm": ParamSpec((cfg.kv_lora_rank,), (None,), init="ones"),
            "w_uk": ParamSpec((cfg.kv_lora_rank, cfg.num_heads, cfg.qk_nope_dim),
                              (None, "heads", None),
                              stddev=cfg.kv_lora_rank ** -0.5),
            "w_uv": ParamSpec((cfg.kv_lora_rank, cfg.num_heads, cfg.v_head_dim),
                              (None, "heads", None),
                              stddev=cfg.kv_lora_rank ** -0.5),
            "wo": ParamSpec((cfg.num_heads, cfg.v_head_dim, d_model),
                            ("heads", None, "embed"),
                            stddev=(cfg.num_heads * cfg.v_head_dim) ** -0.5),
        }
        if cfg.q_lora_rank:
            specs["w_dq"] = ParamSpec((d_model, cfg.q_lora_rank),
                                      ("embed", None), stddev=s)
            specs["q_norm"] = ParamSpec((cfg.q_lora_rank,), (None,), init="ones")
            specs["w_uq"] = ParamSpec((cfg.q_lora_rank, cfg.num_heads, qk),
                                      (None, "heads", None),
                                      stddev=cfg.q_lora_rank ** -0.5)
        else:
            specs["wq"] = ParamSpec((d_model, cfg.num_heads, qk),
                                    ("embed", "heads", None), stddev=s)
        return specs
    return {
        "wq": ParamSpec((d_model, cfg.num_heads, cfg.head_dim),
                        ("embed", "heads", None), stddev=s),
        "wk": ParamSpec((d_model, cfg.num_kv_heads, cfg.head_dim),
                        ("embed", "kv_heads", None), stddev=s),
        "wv": ParamSpec((d_model, cfg.num_kv_heads, cfg.head_dim),
                        ("embed", "kv_heads", None), stddev=s),
        "wo": ParamSpec((cfg.num_heads, cfg.head_dim, d_model),
                        ("heads", None, "embed"),
                        stddev=(cfg.num_heads * cfg.head_dim) ** -0.5),
    }


# ---------------------------------------------------------------------------
# blockwise online-softmax attention (pure jnp; Pallas oracle)
# ---------------------------------------------------------------------------

def _mask(q_pos, k_pos, window, causal):
    """Validity of (q, k) pairs. Positions < 0 are empty slots."""
    valid = k_pos >= 0
    if causal:
        valid &= k_pos <= q_pos
    valid &= jnp.where(window > 0, q_pos - k_pos < window, True)
    return valid


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    q_positions: jax.Array, kv_positions: jax.Array,
                    window: jax.Array | int = 0, causal: bool = True,
                    block_kv: int = 512, softcap: float = 0.0) -> jax.Array:
    """Dispatch: Pallas TPU kernel when enabled, else the jnp oracle path."""
    from repro.kernels import runtime
    if runtime.STATE.use_pallas and isinstance(window, int):
        from repro.kernels.flash_attention import flash_attention as fa
        return fa(q, k, v, q_positions, kv_positions, causal=causal,
                  window=window, softcap=softcap,
                  interpret=runtime.STATE.interpret)
    return flash_attention_jnp(q, k, v, q_positions=q_positions,
                               kv_positions=kv_positions, window=window,
                               causal=causal, block_kv=block_kv,
                               softcap=softcap)


def flash_attention_jnp(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        q_positions: jax.Array, kv_positions: jax.Array,
                        window: jax.Array | int = 0, causal: bool = True,
                        block_kv: int = 512,
                        softcap: float = 0.0) -> jax.Array:
    """Memory-O(S·block) attention via a scan over KV blocks.

    q: (B, Sq, H, D); k, v: (B, Skv, KV, D) with H % KV == 0 (GQA).
    q_positions: (Sq,) or (B, Sq); kv_positions: (Skv,) or (B, Skv).
    """
    B, Sq, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = D ** -0.5
    qb = q.reshape(B, Sq, KV, G, D)
    q_pos = jnp.broadcast_to(jnp.asarray(q_positions), (B, Sq))
    kv_pos = jnp.broadcast_to(jnp.asarray(kv_positions), (B, Skv))

    # pad Skv to a block multiple; padded slots get position -1 (masked out)
    nb = -(-Skv // block_kv)
    pad = nb * block_kv - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)

    k_blk = k.reshape(B, nb, block_kv, KV, D).transpose(1, 0, 2, 3, 4)
    v_blk = v.reshape(B, nb, block_kv, KV, D).transpose(1, 0, 2, 3, 4)
    p_blk = kv_pos.reshape(B, nb, block_kv).transpose(1, 0, 2)

    def body(carry, xs):
        m, l, acc = carry                      # (B,KV,G,Sq), ..., (B,KV,G,Sq,D)
        kb, vb, pb = xs                        # (B,bk,KV,D), ..., (B,bk)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qb, kb,
                       preferred_element_type=jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        ok = _mask(q_pos[:, None, None, :, None],
                   pb[:, None, None, None, :], window, causal)
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.where(ok, jnp.exp(s - m_new[..., None]), 0.0)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(v.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (k_blk, v_blk, p_blk))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D).astype(q.dtype)


def attention_ref(q, k, v, *, q_positions, kv_positions, window=0,
                  causal=True, softcap: float = 0.0) -> jax.Array:
    """O(S^2)-memory reference used in unit tests for small shapes."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qb = q.reshape(B, Sq, KV, G, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qb, k,
                   preferred_element_type=jnp.float32) * (D ** -0.5)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    q_pos = jnp.broadcast_to(jnp.asarray(q_positions), (B, Sq))
    kv_pos = jnp.broadcast_to(jnp.asarray(kv_positions), (B, k.shape[1]))
    ok = _mask(q_pos[:, None, None, :, None], kv_pos[:, None, None, None, :],
               window, causal)
    s = jnp.where(ok, s, NEG_INF)
    p = jnp.where(ok, jax.nn.softmax(s, axis=-1), 0.0)
    out = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(v.dtype), v)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA module
# ---------------------------------------------------------------------------

def gqa_forward(params: Params, cfg: AttentionConfig, x: jax.Array,
                positions: jax.Array, *, window: jax.Array | int,
                dtype: Any, block_kv: int = 512,
                kv_override: Optional[tuple] = None,
                causal: bool = True) -> jax.Array:
    """Full-sequence attention (training / prefill). x: (B, S, d)."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
    if kv_override is None:
        k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dtype))
        v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dtype))
        kv_positions = positions
    else:
        k, v, kv_positions = kv_override  # cross-attention (whisper decoder)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        if kv_override is None:
            k = apply_rope(k, positions, cfg.rope_theta)
    out = flash_attention(q, k, v, q_positions=positions,
                          kv_positions=kv_positions, window=window,
                          causal=causal, block_kv=block_kv,
                          softcap=cfg.logit_softcap)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype))


def gqa_kv(params: Params, cfg: AttentionConfig, x: jax.Array,
           positions: jax.Array, dtype: Any) -> tuple[jax.Array, jax.Array]:
    """K/V projection only (cross-attention memo for enc-dec)."""
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dtype))
    if cfg.use_rope:
        k = apply_rope(k, positions, cfg.rope_theta)
    return k, v


# --- KV cache (ring buffer with absolute slot positions) -------------------

def gqa_cache_shape(cfg: AttentionConfig, batch: int, cache_len: int,
                    dtype: Any) -> dict:
    return {
        "k": jax.ShapeDtypeStruct((batch, cache_len, cfg.num_kv_heads,
                                   cfg.head_dim), dtype),
        "v": jax.ShapeDtypeStruct((batch, cache_len, cfg.num_kv_heads,
                                   cfg.head_dim), dtype),
        "pos": jax.ShapeDtypeStruct((batch, cache_len), jnp.int32),
    }


def gqa_cache_init(cfg: AttentionConfig, batch: int, cache_len: int,
                   dtype: Any) -> dict:
    return {
        "k": jnp.zeros((batch, cache_len, cfg.num_kv_heads, cfg.head_dim),
                       dtype),
        "v": jnp.zeros((batch, cache_len, cfg.num_kv_heads, cfg.head_dim),
                       dtype),
        "pos": jnp.full((batch, cache_len), -1, jnp.int32),
    }


def gqa_prefill_cache(params: Params, cfg: AttentionConfig, x: jax.Array,
                      positions: jax.Array, cache_len: int,
                      dtype: Any) -> dict:
    """Build a cache from a prompt of static length S (ring-rotated if S>len)."""
    B, S, _ = x.shape
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dtype))
    if cfg.use_rope:
        k = apply_rope(k, positions, cfg.rope_theta)
    pos = jnp.broadcast_to(jnp.asarray(positions), (B, S)).astype(jnp.int32)
    if S >= cache_len:
        k, v, pos = k[:, -cache_len:], v[:, -cache_len:], pos[:, -cache_len:]
        shift = S % cache_len
        k = jnp.roll(k, shift, axis=1)
        v = jnp.roll(v, shift, axis=1)
        pos = jnp.roll(pos, shift, axis=1)
        return {"k": k, "v": v, "pos": pos}
    cache = gqa_cache_init(cfg, B, cache_len, dtype)
    return {
        "k": jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0)),
        "pos": jax.lax.dynamic_update_slice(cache["pos"], pos, (0, 0)),
    }


def gqa_decode(params: Params, cfg: AttentionConfig, x: jax.Array,
               cache: dict, cur_index: jax.Array, *,
               window: jax.Array | int, dtype: Any) -> tuple[jax.Array, dict]:
    """One-token decode. x: (B, 1, d); cur_index: scalar absolute position."""
    B = x.shape[0]
    cache_len = cache["k"].shape[1]
    pos = jnp.full((B, 1), cur_index, jnp.int32)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dtype))
    if cfg.use_rope:
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    slot = jnp.mod(cur_index, cache_len)
    new_cache = {
        "k": jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, slot, 0, 0)),
        "v": jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, slot, 0, 0)),
        "pos": jax.lax.dynamic_update_slice(cache["pos"], pos, (0, slot)),
    }
    out = attention_ref(q, new_cache["k"].astype(dtype),
                        new_cache["v"].astype(dtype),
                        q_positions=pos, kv_positions=new_cache["pos"],
                        window=window, causal=True,
                        softcap=cfg.logit_softcap)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype))
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, DeepSeek-v2)
# ---------------------------------------------------------------------------

def _mla_q(params: Params, cfg: AttentionConfig, x: jax.Array, positions,
           dtype: Any) -> tuple[jax.Array, jax.Array]:
    from repro.models.layers import rmsnorm
    if cfg.q_lora_rank:
        cq = x @ params["w_dq"].astype(dtype)
        cq = rmsnorm({"scale": params["q_norm"]}, cq)
        q = jnp.einsum("bsr,rhk->bshk", cq, params["w_uq"].astype(dtype))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
    q_nope = q[..., :cfg.qk_nope_dim]
    q_rope = apply_rope(q[..., cfg.qk_nope_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(params: Params, cfg: AttentionConfig, x: jax.Array, positions,
                dtype: Any) -> tuple[jax.Array, jax.Array]:
    from repro.models.layers import rmsnorm
    dkv = x @ params["w_dkv"].astype(dtype)
    ckv = rmsnorm({"scale": params["kv_norm"]}, dkv[..., :cfg.kv_lora_rank])
    k_rope = dkv[..., None, cfg.kv_lora_rank:]        # (B,S,1,rope)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[..., 0, :]
    return ckv, k_rope


def mla_forward(params: Params, cfg: AttentionConfig, x: jax.Array,
                positions: jax.Array, *, dtype: Any,
                block_kv: int = 512) -> jax.Array:
    """Training/prefill MLA: decompress latent to per-head K/V, flash attend."""
    B, S, _ = x.shape
    q_nope, q_rope = _mla_q(params, cfg, x, positions, dtype)
    ckv, k_rope = _mla_latent(params, cfg, x, positions, dtype)
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv, params["w_uk"].astype(dtype))
    v = jnp.einsum("bsr,rhv->bshv", ckv, params["w_uv"].astype(dtype))
    H = cfg.num_heads
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, H, cfg.qk_rope_dim))], axis=-1)
    # pad v to qk dim so flash kernel sees one head_dim; slice after
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk - cfg.v_head_dim)))
    out = flash_attention(q, k, v_p, q_positions=positions,
                          kv_positions=positions, window=0, causal=True,
                          block_kv=block_kv)
    out = out[..., :cfg.v_head_dim]
    return jnp.einsum("bshv,hvd->bsd", out, params["wo"].astype(dtype))


def mla_cache_init(cfg: AttentionConfig, batch: int, cache_len: int,
                   dtype: Any) -> dict:
    return {
        "ckv": jnp.zeros((batch, cache_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, cache_len, cfg.qk_rope_dim), dtype),
        "pos": jnp.full((batch, cache_len), -1, jnp.int32),
    }


def mla_prefill_cache(params: Params, cfg: AttentionConfig, x: jax.Array,
                      positions: jax.Array, cache_len: int,
                      dtype: Any) -> dict:
    B, S, _ = x.shape
    ckv, k_rope = _mla_latent(params, cfg, x, positions, dtype)
    pos = jnp.broadcast_to(jnp.asarray(positions), (B, S)).astype(jnp.int32)
    cache = mla_cache_init(cfg, B, cache_len, dtype)
    n = min(S, cache_len)
    return {
        "ckv": jax.lax.dynamic_update_slice(cache["ckv"], ckv[:, -n:],
                                            (0, 0, 0)),
        "k_rope": jax.lax.dynamic_update_slice(cache["k_rope"],
                                               k_rope[:, -n:], (0, 0, 0)),
        "pos": jax.lax.dynamic_update_slice(cache["pos"], pos[:, -n:], (0, 0)),
    }


def mla_decode(params: Params, cfg: AttentionConfig, x: jax.Array,
               cache: dict, cur_index: jax.Array, *,
               dtype: Any) -> tuple[jax.Array, dict]:
    """Absorbed-weight decode: attend in the 512-d latent space directly —
    the compressed-KV insight of MLA; no per-head K/V is ever materialized."""
    B = x.shape[0]
    cache_len = cache["ckv"].shape[1]
    pos = jnp.full((B, 1), cur_index, jnp.int32)
    q_nope, q_rope = _mla_q(params, cfg, x, pos, dtype)          # (B,1,H,*)
    ckv_new, k_rope_new = _mla_latent(params, cfg, x, pos, dtype)
    slot = jnp.mod(cur_index, cache_len)
    cache = {
        "ckv": jax.lax.dynamic_update_slice(
            cache["ckv"], ckv_new.astype(cache["ckv"].dtype), (0, slot, 0)),
        "k_rope": jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype),
            (0, slot, 0)),
        "pos": jax.lax.dynamic_update_slice(cache["pos"], pos, (0, slot)),
    }
    # absorb w_uk into the query: q_lat[b,1,h,r]
    q_lat = jnp.einsum("bqhk,rhk->bqhr", q_nope, params["w_uk"].astype(dtype))
    s = (jnp.einsum("bqhr,bsr->bhqs", q_lat, cache["ckv"].astype(dtype),
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bqhk,bsk->bhqs", q_rope,
                      cache["k_rope"].astype(dtype),
                      preferred_element_type=jnp.float32))
    s = s * ((cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5)
    kv_pos = cache["pos"][:, None, None, :]                  # (B,1,1,S)
    ok = (kv_pos >= 0) & (kv_pos <= cur_index)
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhqs,bsr->bqhr", p.astype(dtype),
                     cache["ckv"].astype(dtype))
    out = jnp.einsum("bqhr,rhv->bqhv", ctx, params["w_uv"].astype(dtype))
    y = jnp.einsum("bqhv,hvd->bqd", out, params["wo"].astype(dtype))
    return y, cache
