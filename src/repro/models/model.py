"""Model builder: composes layers into scanned segments for every family.

A model is decomposed into *segments*: maximal runs of a repeating layer
pattern. Uniform models (llama-style) are one segment with a period-1
pattern scanned ``num_layers`` times; gemma3's 5 local : 1 global becomes a
period-6 pattern; jamba's (7 mamba + 1 attn) x (dense|moe alternation)
becomes a period-8 pattern; deepseek-v2's leading dense layer is its own
single-layer segment. Scanning keeps HLO size (and hence compile time for
512-device dry-runs) independent of depth, exactly like MaxText.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ParallelConfig
from repro.models import attention as attn_lib
from repro.models import layers as L
from repro.models import mamba as mamba_lib
from repro.models import moe as moe_lib
from repro.models.spec import ParamSpec, abstract_params, init_params, stack_specs
from repro.sharding import Rules, constrain

Params = Any


# ---------------------------------------------------------------------------
# layer plans & segmentation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerPlan:
    mixer: str            # "attn" | "mamba"
    mlp: str              # "dense" | "moe"
    window: int           # 0 = full attention
    d_ff: int
    cross_attn: bool = False


def layer_plans(cfg: ModelConfig, *, decoder: bool = True) -> list[LayerPlan]:
    plans = []
    n = cfg.num_layers
    for i in range(n):
        mixer = "attn" if cfg.is_attn_layer(i) else "mamba"
        is_moe = cfg.moe.is_moe_layer(i)
        if mixer == "attn" and cfg.attention is not None:
            window = cfg.attention.layer_window(i)
        else:
            window = 0
        d_ff = cfg.d_ff
        if (not is_moe and cfg.moe.num_experts and i < cfg.moe.first_k_dense
                and cfg.moe.first_dense_ff):
            d_ff = cfg.moe.first_dense_ff
        mlp = "moe" if is_moe else ("dense" if d_ff > 0 else "none")
        plans.append(LayerPlan(mixer=mixer, mlp=mlp,
                               window=window, d_ff=d_ff,
                               cross_attn=decoder and cfg.family == "audio"))
    return plans


@dataclasses.dataclass(frozen=True)
class Segment:
    pattern: tuple[LayerPlan, ...]
    repeat: int


def segment_plans(plans: list[LayerPlan], max_period: int = 12) -> list[Segment]:
    segs: list[Segment] = []
    i, n = 0, len(plans)
    while i < n:
        best_p, best_r = 1, 1
        for p in range(1, min(max_period, n - i) + 1):
            r = 1
            while (i + (r + 1) * p <= n
                   and plans[i + r * p: i + (r + 1) * p] == plans[i: i + p]):
                r += 1
            if r > 1 and r * p > best_p * best_r:
                best_p, best_r = p, r
        segs.append(Segment(tuple(plans[i: i + best_p]), best_r))
        i += best_p * best_r
    return segs


# ---------------------------------------------------------------------------
# per-layer specs / apply
# ---------------------------------------------------------------------------

def _layer_specs(cfg: ModelConfig, plan: LayerPlan) -> dict:
    d = cfg.d_model
    specs: dict = {"ln1": L.rmsnorm_specs(d)}
    if plan.mixer == "attn":
        specs["attn"] = attn_lib.attn_specs(cfg.attention, d)
    else:
        specs["mamba"] = mamba_lib.mamba_specs(cfg.ssm, d)
    if plan.cross_attn:
        specs["ln_cross"] = L.rmsnorm_specs(d)
        specs["cross"] = attn_lib.attn_specs(
            dataclasses.replace(cfg.attention, use_rope=False), d)
    if plan.mlp != "none":
        specs["ln2"] = L.rmsnorm_specs(d)
    if plan.mlp == "moe":
        specs["moe"] = moe_lib.moe_specs(d, cfg.moe, cfg.mlp_act)
    elif plan.mlp == "dense":
        specs["mlp"] = L.mlp_specs(d, plan.d_ff, cfg.mlp_act)
    return specs


def _apply_layer(cfg: ModelConfig, parallel: Optional[ParallelConfig],
                 rules: Optional[Rules], plan: LayerPlan, params: Params,
                 h: jax.Array, *, positions, dtype, mode: str,
                 cache: Optional[dict], cur_index, enc_out, enc_positions,
                 causal: bool = True, max_cache_len: int = 0):
    """Returns (h, new_cache, aux)."""
    aux = jnp.float32(0.0)
    new_cache: dict = {}

    def _seq_shard(y):
        # Megatron-SP: pin sub-block outputs back to (batch, seq) sharding
        # so XLA lowers the TP partial-sum as reduce-scatter instead of
        # all-reduce + re-slice (halves activation collective bytes).
        if rules is not None and mode != "decode":
            return constrain(y, rules, "batch", "seq", None)
        return y

    x = L.rmsnorm(params["ln1"], h, cfg.norm_eps)
    if plan.mixer == "attn":
        acfg = cfg.attention
        if mode == "decode":
            if acfg.kind == "mla":
                y, kv = attn_lib.mla_decode(params["attn"], acfg, x,
                                            cache["kv"], cur_index,
                                            dtype=dtype)
            else:
                y, kv = attn_lib.gqa_decode(params["attn"], acfg, x,
                                            cache["kv"], cur_index,
                                            window=plan.window, dtype=dtype)
            new_cache["kv"] = kv
        else:
            if acfg.kind == "mla":
                y = attn_lib.mla_forward(params["attn"], acfg, x, positions,
                                         dtype=dtype, block_kv=cfg.attn_block_kv)
            else:
                y = attn_lib.gqa_forward(params["attn"], acfg, x, positions,
                                         window=plan.window, dtype=dtype,
                                         block_kv=cfg.attn_block_kv,
                                         causal=causal)
            if mode == "prefill":
                # ring-buffer length: the window (SWA) or the decode horizon
                # (defaults to the model max; serving passes the actual
                # horizon so a 32k prefill doesn't allocate a 512k cache)
                horizon = max_cache_len or cfg.max_seq_len
                cache_len = min(_cache_len(cfg, plan),
                                max(horizon, x.shape[1]))
                if acfg.kind == "mla":
                    new_cache["kv"] = attn_lib.mla_prefill_cache(
                        params["attn"], acfg, x, positions, cache_len, dtype)
                else:
                    new_cache["kv"] = attn_lib.gqa_prefill_cache(
                        params["attn"], acfg, x, positions, cache_len, dtype)
    else:
        if mode == "decode":
            y, ssm_cache = mamba_lib.mamba_decode(
                params["mamba"], cfg.ssm, x, cache["ssm"], d_model=cfg.d_model,
                dtype=dtype, norm_eps=cfg.norm_eps)
            new_cache["ssm"] = ssm_cache
        elif mode == "prefill":
            y, ssm_cache = mamba_lib.mamba_forward(
                params["mamba"], cfg.ssm, x, d_model=cfg.d_model, dtype=dtype,
                norm_eps=cfg.norm_eps, return_state=True)
            new_cache["ssm"] = ssm_cache
        else:
            y = mamba_lib.mamba_forward(params["mamba"], cfg.ssm, x,
                                        d_model=cfg.d_model, dtype=dtype,
                                        norm_eps=cfg.norm_eps)
    h = h + _seq_shard(y)

    if plan.cross_attn:
        xq = L.rmsnorm(params["ln_cross"], h, cfg.norm_eps)
        acfg = dataclasses.replace(cfg.attention, use_rope=False)
        if mode == "decode":
            k, v = cache["cross_k"], cache["cross_v"]
            q = jnp.einsum("bsd,dhk->bshk", xq, params["cross"]["wq"].astype(dtype))
            o = attn_lib.attention_ref(
                q, k.astype(dtype), v.astype(dtype),
                q_positions=jnp.zeros((xq.shape[0], 1), jnp.int32),
                kv_positions=jnp.zeros((k.shape[0], k.shape[1]), jnp.int32),
                causal=False)
            y = jnp.einsum("bshk,hkd->bsd", o,
                           params["cross"]["wo"].astype(dtype))
            new_cache["cross_k"], new_cache["cross_v"] = k, v
        else:
            k, v = attn_lib.gqa_kv(params["cross"], acfg, enc_out,
                                   enc_positions, dtype)
            y = attn_lib.gqa_forward(params["cross"], acfg, xq, positions,
                                     window=0, dtype=dtype,
                                     block_kv=cfg.attn_block_kv,
                                     kv_override=(k, v, enc_positions),
                                     causal=False)
            if mode == "prefill":
                new_cache["cross_k"], new_cache["cross_v"] = k, v
        h = h + y

    if plan.mlp == "none":
        return h, new_cache, aux
    x2 = L.rmsnorm(params["ln2"], h, cfg.norm_eps)
    if plan.mlp == "moe":
        y, aux = moe_lib.moe_forward(params["moe"], cfg, x2, rules=rules,
                                     parallel=parallel,
                                     decode=(mode == "decode"), dtype=dtype)
    else:
        y = L.mlp(params["mlp"], x2, cfg.mlp_act, dtype)
    return h + _seq_shard(y), new_cache, aux


def _cache_len(cfg: ModelConfig, plan: LayerPlan) -> int:
    if plan.window > 0:
        return min(plan.window, cfg.max_seq_len)
    return cfg.max_seq_len


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

class Model:
    """Functional model bound to (cfg, parallel, rules)."""

    def __init__(self, cfg: ModelConfig,
                 parallel: Optional[ParallelConfig] = None,
                 rules: Optional[Rules] = None):
        cfg.validate()
        self.cfg = cfg
        self.parallel = parallel or ParallelConfig()
        self.rules = rules
        self.dtype = jnp.dtype(cfg.dtype)
        self.plans = layer_plans(cfg)
        self.segments = segment_plans(self.plans)
        if cfg.encoder_layers:
            enc_cfg = dataclasses.replace(
                cfg, num_layers=cfg.encoder_layers, family="dense",
                moe=dataclasses.replace(cfg.moe, num_experts=0))
            self.enc_plans = layer_plans(enc_cfg, decoder=False)
            self.enc_plans = [dataclasses.replace(p, cross_attn=False)
                              for p in self.enc_plans]
            self.enc_segments = segment_plans(self.enc_plans)
        else:
            self.enc_plans, self.enc_segments = [], []

    # -- specs / init -------------------------------------------------------

    def specs(self) -> dict:
        cfg = self.cfg
        specs: dict = {"embed": L.embed_specs(cfg)}
        specs["segments"] = self._stack_specs(self.segments)
        specs["final_norm"] = L.rmsnorm_specs(cfg.d_model)
        head = L.lm_head_specs(cfg)
        if head:
            specs["lm_head"] = head
        if cfg.family == "audio":
            specs["enc_segments"] = self._stack_specs(self.enc_segments)
            specs["enc_final_norm"] = L.rmsnorm_specs(cfg.d_model)
            specs["dec_pos"] = ParamSpec((cfg.max_seq_len, cfg.d_model),
                                         (None, "embed"), stddev=0.02)
        return specs

    def _stack_specs(self, segments: list[Segment]) -> list:
        out = []
        for seg in segments:
            pattern = tuple(_layer_specs(self.cfg, p) for p in seg.pattern)
            out.append(stack_specs(pattern, seg.repeat))
        return out

    def init(self, key: jax.Array) -> Params:
        return init_params(self.specs(), key)

    def abstract(self, shardings=None) -> Params:
        return abstract_params(self.specs(), shardings)

    # -- embedding ----------------------------------------------------------

    def _embed_inputs(self, params: Params, batch: dict):
        """Returns (h, positions, loss_weights)."""
        cfg = self.cfg
        tok = batch["tokens"]
        h = L.embed(params["embed"], tok, self.dtype, cfg.d_model)
        weights = batch.get("weights")
        if weights is None:
            weights = jnp.ones(tok.shape, jnp.float32)
        if cfg.frontend == "patch_stub":
            patches = batch["patches"].astype(self.dtype)   # (B, Np, d)
            h = jnp.concatenate([patches, h], axis=1)
            weights = jnp.concatenate(
                [jnp.zeros(patches.shape[:2], jnp.float32), weights], axis=1)
        positions = jnp.arange(h.shape[1], dtype=jnp.int32)
        return h, positions, weights

    # -- stacks -------------------------------------------------------------

    def _run_segments(self, params_segs, segments, h, *, positions, mode,
                      caches=None, cur_index=None, enc_out=None,
                      enc_positions=None, causal=True, max_cache_len=0):
        """Apply all segments; returns (h, new_caches, aux_total)."""
        cfg, parallel, rules = self.cfg, self.parallel, self.rules
        aux_total = jnp.float32(0.0)
        new_caches = []
        for si, seg in enumerate(segments):
            p_stack = params_segs[si]
            c_stack = caches[si] if caches is not None else None

            def body(carry, xs, _seg=seg):
                hh, aux = carry
                p_slice, c_slice = xs
                ncs = []
                for li, plan in enumerate(_seg.pattern):
                    c = c_slice[li] if c_slice is not None else None
                    hh, nc, a = _apply_layer(
                        cfg, parallel, rules, plan, p_slice[li], hh,
                        positions=positions, dtype=self.dtype, mode=mode,
                        cache=c, cur_index=cur_index, enc_out=enc_out,
                        enc_positions=enc_positions, causal=causal,
                        max_cache_len=max_cache_len)
                    ncs.append(nc)
                    aux = aux + a
                return (hh, aux), tuple(ncs)

            if parallel.remat != "none" and mode == "train":
                policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                          if parallel.remat == "dots" else None)
                body = jax.checkpoint(body, policy=policy,
                                      prevent_cse=False)

            if parallel.scan_layers:
                (h, aux_total), nc_stack = jax.lax.scan(
                    body, (h, aux_total), (p_stack, c_stack))
            else:
                # unrolled python loop (cost-analysis calibration + small
                # models): identical math, no while-loop in the HLO
                ncs_all = []
                for r in range(seg.repeat):
                    xs = jax.tree_util.tree_map(lambda x, _r=r: x[_r],
                                                (p_stack, c_stack))
                    (h, aux_total), nc = body((h, aux_total), xs)
                    ncs_all.append(nc)
                nc_stack = jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs), *ncs_all)
            new_caches.append(nc_stack)
        return h, new_caches, aux_total

    # -- public entry points ------------------------------------------------

    def hidden_states(self, params: Params, batch: dict,
                      mode: str = "train", max_cache_len: int = 0):
        """Full-sequence forward to final hidden states.

        Returns (h, weights, caches, aux). caches is None unless prefill.
        """
        cfg = self.cfg
        h, positions, weights = self._embed_inputs(params, batch)
        enc_out = enc_positions = None
        if cfg.family == "audio":
            enc_h = batch["frames"].astype(self.dtype)      # (B, Senc, d)
            enc_pos = jnp.arange(enc_h.shape[1], dtype=jnp.int32)
            enc_h = enc_h + L.sinusoidal_positions(
                enc_h.shape[1], cfg.d_model).astype(self.dtype)
            enc_h, _, _ = self._run_segments(
                params["enc_segments"], self.enc_segments, enc_h,
                positions=enc_pos, mode="train", causal=False)
            enc_out = L.rmsnorm(params["enc_final_norm"], enc_h, cfg.norm_eps)
            enc_positions = enc_pos
            h = h + params["dec_pos"][positions].astype(self.dtype)
        if self.rules is not None:
            h = constrain(h, self.rules, "batch", "seq", None)
        h, caches, aux = self._run_segments(
            params["segments"], self.segments, h, positions=positions,
            mode=mode, enc_out=enc_out, enc_positions=enc_positions,
            max_cache_len=max_cache_len)
        h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        return h, weights, (caches if mode == "prefill" else None), aux

    def logits_fn(self, params: Params):
        cfg = self.cfg
        def fn(h):
            return L.lm_head(params.get("lm_head"), params["embed"], h,
                             cfg.tie_embeddings, self.dtype)
        return fn

    def loss(self, params: Params, batch: dict):
        """Mean cross-entropy (+ z-loss + MoE aux). Returns (loss, metrics)."""
        cfg = self.cfg
        h, weights, _, aux = self.hidden_states(params, batch, mode="train")
        labels = batch["labels"]
        if cfg.frontend == "patch_stub":
            pad = h.shape[1] - labels.shape[1]
            labels = jnp.pad(labels, ((0, 0), (pad, 0)))
        z = getattr(self, "z_loss", 1e-4)
        total, wsum = L.softmax_xent_chunked(
            self.logits_fn(params), h, labels, weights, z_loss=z)
        xent = total / jnp.maximum(wsum, 1.0)
        loss = xent + aux
        return loss, {"loss": loss, "xent": xent, "aux": aux,
                      "tokens": wsum}

    def forward_logits(self, params: Params, batch: dict) -> jax.Array:
        """(B, S, V) logits — for small-model evaluation/serving only."""
        h, _, _, _ = self.hidden_states(params, batch, mode="train")
        return self.logits_fn(params)(h)

    def prefill(self, params: Params, batch: dict,
                max_cache_len: int = 0):
        """Run the prompt, build caches. Returns (last_logits, caches).

        ``max_cache_len`` sizes the full-attention ring buffers (the decode
        horizon); 0 means the model's max context."""
        h, _, caches, _ = self.hidden_states(params, batch, mode="prefill",
                                             max_cache_len=max_cache_len)
        logits = self.logits_fn(params)(h[:, -1:])
        return logits[:, 0], caches

    def decode_step(self, params: Params, caches, tokens: jax.Array,
                    cur_index):
        """One decode step. tokens: (B,) int32; cur_index: scalar position.

        Returns (logits (B, V), new_caches).
        """
        cfg = self.cfg
        h = L.embed(params["embed"], tokens[:, None], self.dtype, cfg.d_model)
        if cfg.family == "audio":
            pos_e = jax.lax.dynamic_slice_in_dim(params["dec_pos"],
                                                 cur_index, 1, axis=0)
            h = h + pos_e[None].astype(self.dtype)
        h, new_caches, _ = self._run_segments(
            params["segments"], self.segments, h, positions=None,
            mode="decode", caches=caches, cur_index=cur_index)
        h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        logits = self.logits_fn(params)(h)
        return logits[:, 0], new_caches

    # -- cache bootstrap for dry-runs ---------------------------------------

    def init_caches(self, batch: int, prompt_len: int) -> Any:
        """Concrete zero caches sized for a `prompt_len` context."""
        cfg = self.cfg
        caches = []
        for seg in self.segments:
            pattern_caches = []
            for plan in seg.pattern:
                c: dict = {}
                if plan.mixer == "attn":
                    clen = min(_cache_len(cfg, plan), max(prompt_len, 1))
                    if cfg.attention.kind == "mla":
                        c["kv"] = attn_lib.mla_cache_init(
                            cfg.attention, batch, clen, self.dtype)
                    else:
                        c["kv"] = attn_lib.gqa_cache_init(
                            cfg.attention, batch, clen, self.dtype)
                else:
                    c["ssm"] = mamba_lib.mamba_cache_init(
                        cfg.ssm, batch, cfg.d_model, self.dtype)
                if plan.cross_attn:
                    a = cfg.attention
                    c["cross_k"] = jnp.zeros(
                        (batch, cfg.encoder_seq, a.num_kv_heads, a.head_dim),
                        self.dtype)
                    c["cross_v"] = jnp.zeros_like(c["cross_k"])
                pattern_caches.append(c)
            stacked = jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x[None],
                                           (seg.repeat,) + x.shape),
                tuple(pattern_caches))
            caches.append(stacked)
        return caches
