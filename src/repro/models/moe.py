"""Mixture-of-Experts FFN with three execution paths.

1. ``gshard``  — expert parallelism over the ``model`` mesh axis via
   shard_map + lax.all_to_all (GShard/Switch dispatch adapted to TPU: tokens
   are sequence-sharded across the model axis, scattered into per-expert
   capacity buffers, exchanged with a single all-to-all, processed with one
   dense batched matmul per shard (MXU-friendly), and combined with the
   reverse all-to-all). Used when num_experts % model_axis == 0.

2. ``tp``      — expert-tensor-parallel grouped matmul: every model shard
   holds an eff-slice of *all* experts, dispatches its data-shard's tokens
   locally into (E, C, d) capacity buffers and computes a batched matmul with
   its slice; partial outputs are psum-reduced over the model axis. No
   all-to-all; works for any expert count (e.g. mixtral's 8 experts on a
   16-wide model axis). FLOPs stay ~active (capacity-bounded), unlike a
   dense all-experts evaluation.

3. ``dense``   — evaluate all experts and combine with routing weights.
   Exact (no capacity drops); used for tiny smoke tests and as the decode
   path where weight reads, not FLOPs, dominate.

All paths share the router; dropped-token behaviour is capacity-based with
renormalized top-k gates (tokens past capacity fall through on the residual).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.config import ModelConfig, MoEConfig, ParallelConfig
from repro.models.layers import _act, mlp, mlp_specs
from repro.models.spec import ParamSpec
from repro.sharding import MODEL, Rules, data_axes

Params = Any


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------

def moe_specs(d_model: int, cfg: MoEConfig, act: str) -> dict:
    E, eff = cfg.num_experts, cfg.expert_ff
    glu = act.endswith("_glu")
    s_in, s_out = d_model ** -0.5, eff ** -0.5
    specs = {
        "router": ParamSpec((d_model, E), ("embed", None), stddev=s_in),
        "w1": ParamSpec((E, d_model, eff), ("experts", "embed", "expert_mlp"),
                        stddev=s_in),
        "w2": ParamSpec((E, eff, d_model), ("experts", "expert_mlp", "embed"),
                        stddev=s_out),
    }
    if glu:
        specs["w3"] = ParamSpec((E, d_model, eff),
                                ("experts", "embed", "expert_mlp"),
                                stddev=s_in)
    if cfg.num_shared_experts:
        specs["shared"] = mlp_specs(d_model, cfg.num_shared_experts * eff, act)
    return specs


# ---------------------------------------------------------------------------
# router + local capacity dispatch (shared by gshard/tp paths)
# ---------------------------------------------------------------------------

def _route(router_w: jax.Array, x: jax.Array, cfg: MoEConfig):
    """x: (T, d) -> (gates (T,k), expert_idx (T,k), aux_loss, probs (T,E))."""
    logits = (x @ router_w.astype(x.dtype)).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)                 # (T, k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    return gates, idx, probs


def _aux_loss(probs: jax.Array, idx: jax.Array, E: int) -> jax.Array:
    """Switch-style load-balancing loss: E * sum_e f_e * P_e."""
    assign = jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(axis=1)  # (T, E)
    f = assign.mean(axis=0)
    p = probs.mean(axis=0)
    return E * jnp.sum(f * p)


def _dispatch_indices(idx: jax.Array, E: int, C: int):
    """Position-in-expert for each (token, choice); >=C means dropped."""
    T, k = idx.shape
    flat = idx.reshape(-1)                                   # (T*k,)
    onehot = jax.nn.one_hot(flat, E, dtype=jnp.int32)        # (T*k, E)
    pos_all = jnp.cumsum(onehot, axis=0) - onehot            # prior count
    pos = jnp.take_along_axis(pos_all, flat[:, None], axis=1)[:, 0]
    keep = pos < C
    slot = jnp.where(keep, flat * C + pos, E * C)            # OOB -> dropped
    return slot.reshape(T, k), keep.reshape(T, k)


def _scatter_tokens(x: jax.Array, slot: jax.Array, E: int, C: int):
    """x: (T, d), slot: (T, k) -> buffer (E, C, d)."""
    T, d = x.shape
    k = slot.shape[1]
    buf = jnp.zeros((E * C, d), x.dtype)
    xk = jnp.broadcast_to(x[:, None, :], (T, k, d)).reshape(T * k, d)
    buf = buf.at[slot.reshape(-1)].add(xk, mode="drop")
    return buf.reshape(E, C, d)


def _gather_tokens(buf: jax.Array, slot: jax.Array, gates: jax.Array,
                   keep: jax.Array, dtype) -> jax.Array:
    """buffer (E, C, d), slot (T, k) -> (T, d) combined output."""
    E, C, d = buf.shape
    T, k = slot.shape
    flat = buf.reshape(E * C, d)
    out = jnp.take(flat, jnp.clip(slot.reshape(-1), 0, E * C - 1), axis=0)
    out = out.reshape(T, k, d)
    w = (gates * keep).astype(dtype)
    return jnp.einsum("tkd,tk->td", out, w)


def _expert_ffn(xb: jax.Array, w1, w2, w3, glu: bool, act: str,
                dtype) -> jax.Array:
    """Batched-over-experts FFN. xb: (E, C, d)."""
    h = jnp.einsum("ecd,edf->ecf", xb, w1.astype(dtype))
    h = _act(act, h)
    if glu:
        h = h * jnp.einsum("ecd,edf->ecf", xb, w3.astype(dtype))
    return jnp.einsum("ecf,efd->ecd", h, w2.astype(dtype))


# ---------------------------------------------------------------------------
# path 1: gshard (EP over model axis, all-to-all)
# ---------------------------------------------------------------------------

def _gshard_local(cfg: MoEConfig, act: str, dtype, C: int, glu: bool,
                  axis_names: tuple, router_w, w1, w2, w3, x):
    """Per-device body under shard_map. x: (B_loc, S_loc, d)."""
    B, S, d = x.shape
    E = cfg.num_experts
    xt = x.reshape(B * S, d)
    gates, idx, probs = _route(router_w, xt, cfg)
    aux = jax.lax.pmean(_aux_loss(probs, idx, E), axis_name=axis_names)
    slot, keep = _dispatch_indices(idx, E, C)
    buf = _scatter_tokens(xt, slot, E, C)                    # (E, C, d)
    # exchange: every model shard keeps E_loc experts, receives M chunks
    buf = jax.lax.all_to_all(buf, MODEL, split_axis=0, concat_axis=1,
                             tiled=True)                     # (E_loc, C*M, d)
    out = _expert_ffn(buf, w1, w2, w3, glu, act, dtype)
    out = jax.lax.all_to_all(out, MODEL, split_axis=1, concat_axis=0,
                             tiled=True)                     # (E, C, d)
    y = _gather_tokens(out, slot, gates, keep, dtype)
    return y.reshape(B, S, d), aux


def moe_gshard(params: Params, cfg: MoEConfig, x: jax.Array, *,
               rules: Rules, act: str, dtype) -> tuple[jax.Array, jax.Array]:
    mesh = rules.mesh
    M = mesh.shape[MODEL] if MODEL in mesh.axis_names else 1
    B, S, d = x.shape
    dax = data_axes(mesh)
    dp = math.prod(mesh.shape[a] for a in dax) if dax else 1
    toks_loc = (B // dp) * (S // M)
    E = cfg.num_experts
    C = max(1, int(math.ceil(toks_loc * cfg.top_k * cfg.capacity_factor / E)))
    glu = "w3" in params
    w3 = params["w3"] if glu else jnp.zeros((E, 1, 1), params["w1"].dtype)
    espec = P(MODEL, None, None)                             # (E, d, eff) EP
    fn = shard_map(
        partial(_gshard_local, cfg, act, dtype, C, glu, mesh.axis_names),
        mesh=mesh,
        in_specs=(P(None, None), espec, espec, espec,
                  P(dax if dax else None, MODEL, None)),
        out_specs=(P(dax if dax else None, MODEL, None), P()),
        check_rep=False,
    )
    return fn(params["router"], params["w1"], params["w2"], w3, x)


# ---------------------------------------------------------------------------
# path 2: expert-tensor-parallel grouped matmul (no all-to-all)
# ---------------------------------------------------------------------------

def _tp_local(cfg: MoEConfig, act: str, dtype, C: int, glu: bool,
              axis_names: tuple, router_w, w1, w2, w3, x):
    """x: (B_loc, S, d) — replicated over model axis; weights eff-sliced.

    The eff-slice partial sums are reduced AFTER the token combine: psum of
    the dense (T, d) output instead of the (E, C, d) capacity buffers —
    combine is linear in the buffer, so the results are identical while the
    all-reduce shrinks by E*C/T (~2.5x at capacity 1.25) and runs in the
    compute dtype."""
    B, S, d = x.shape
    E = cfg.num_experts
    xt = x.reshape(B * S, d)
    gates, idx, probs = _route(router_w, xt, cfg)
    aux = jax.lax.pmean(_aux_loss(probs, idx, E), axis_name=axis_names)
    slot, keep = _dispatch_indices(idx, E, C)
    buf = _scatter_tokens(xt, slot, E, C)
    out = _expert_ffn(buf, w1, w2, w3, glu, act, dtype)      # partial (eff slice)
    y = _gather_tokens(out, slot, gates, keep, dtype)        # partial (T, d)
    y = jax.lax.psum(y.astype(dtype), axis_name=MODEL)       # sum eff slices
    return y.reshape(B, S, d), aux


def moe_tp(params: Params, cfg: MoEConfig, x: jax.Array, *,
           rules: Rules, act: str, dtype) -> tuple[jax.Array, jax.Array]:
    mesh = rules.mesh
    B, S, d = x.shape
    dax = data_axes(mesh)
    dp = math.prod(mesh.shape[a] for a in dax) if dax else 1
    toks_loc = (B // dp) * S
    E = cfg.num_experts
    C = max(1, int(math.ceil(toks_loc * cfg.top_k * cfg.capacity_factor / E)))
    glu = "w3" in params
    M = mesh.shape[MODEL] if MODEL in mesh.axis_names else 1
    w3 = params["w3"] if glu else jnp.zeros((E, 1, M), params["w1"].dtype)
    espec = P(None, None, MODEL)                 # (E, d, eff): eff TP-sliced
    fn = shard_map(
        partial(_tp_local, cfg, act, dtype, C, glu, mesh.axis_names),
        mesh=mesh,
        in_specs=(P(None, None), espec, P(None, MODEL, None), espec,
                  P(dax if dax else None, None, None)),
        out_specs=(P(dax if dax else None, None, None), P()),
        check_rep=False,
    )
    return fn(params["router"], params["w1"], params["w2"], w3, x)


# ---------------------------------------------------------------------------
# path 3: dense all-experts (exact; smoke tests + decode)
# ---------------------------------------------------------------------------

def moe_dense(params: Params, cfg: MoEConfig, x: jax.Array, *,
              act: str, dtype) -> tuple[jax.Array, jax.Array]:
    B, S, d = x.shape
    E = cfg.num_experts
    xt = x.reshape(B * S, d)
    gates, idx, probs = _route(params["router"], xt, cfg)
    aux = _aux_loss(probs, idx, E)
    w = jnp.zeros((B * S, E), jnp.float32)
    w = w.at[jnp.arange(B * S)[:, None], idx].set(gates)
    h = jnp.einsum("td,edf->tef", xt, params["w1"].astype(dtype))
    h = _act(act, h)
    if "w3" in params:
        h = h * jnp.einsum("td,edf->tef", xt, params["w3"].astype(dtype))
    out_e = jnp.einsum("tef,efd->ted", h, params["w2"].astype(dtype))
    y = jnp.einsum("ted,te->td", out_e, w.astype(dtype))
    return y.reshape(B, S, d), aux


def moe_gather_decode(params: Params, cfg: MoEConfig, x: jax.Array, *,
                      act: str, dtype) -> tuple[jax.Array, jax.Array]:
    """Small-batch decode: gather only the top-k experts' weights per token.

    Beats dense-all when B*S*k << E (e.g. batch-1 long-context decode):
    HBM reads drop from all-E weights to k weights per token.
    """
    B, S, d = x.shape
    xt = x.reshape(B * S, d)
    gates, idx, probs = _route(params["router"], xt, cfg)
    aux = _aux_loss(probs, idx, cfg.num_experts)
    w1 = jnp.take(params["w1"], idx, axis=0)      # (T, k, d, eff)
    w2 = jnp.take(params["w2"], idx, axis=0)
    h = jnp.einsum("td,tkdf->tkf", xt, w1.astype(dtype))
    h = _act(act, h)
    if "w3" in params:
        w3 = jnp.take(params["w3"], idx, axis=0)
        h = h * jnp.einsum("td,tkdf->tkf", xt, w3.astype(dtype))
    out = jnp.einsum("tkf,tkfd->tkd", h, w2.astype(dtype))
    y = jnp.einsum("tkd,tk->td", out, gates.astype(dtype))
    return y.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# top-level entry
# ---------------------------------------------------------------------------

def moe_forward(params: Params, model_cfg: ModelConfig, x: jax.Array, *,
                rules: Optional[Rules], parallel: Optional[ParallelConfig],
                decode: bool, dtype) -> tuple[jax.Array, jax.Array]:
    cfg = model_cfg.moe
    act = model_cfg.mlp_act
    impl = "dense"
    if not decode and parallel is not None and rules is not None:
        mesh = rules.mesh
        M = mesh.shape[MODEL] if MODEL in mesh.axis_names else 1
        if parallel.moe_impl == "gshard":
            B, S, _ = x.shape
            dax = data_axes(mesh)
            dp = math.prod(mesh.shape[a] for a in dax) if dax else 1
            if (cfg.num_experts % M == 0 and S % M == 0 and B % dp == 0
                    and parallel.expert_parallel):
                impl = "gshard"
            elif cfg.expert_ff % M == 0 and B % dp == 0:
                impl = "tp"
        elif parallel.moe_impl == "dense":
            impl = "dense"
    if decode and parallel is not None:
        B, S, _ = x.shape
        if (parallel.decode_moe_impl == "gather"
                and B * S * cfg.top_k < cfg.num_experts):
            impl = "gather"

    if impl == "gshard":
        y, aux = moe_gshard(params, cfg, x, rules=rules, act=act, dtype=dtype)
    elif impl == "tp":
        y, aux = moe_tp(params, cfg, x, rules=rules, act=act, dtype=dtype)
    elif impl == "gather":
        y, aux = moe_gather_decode(params, cfg, x, act=act, dtype=dtype)
    else:
        y, aux = moe_dense(params, cfg, x, act=act, dtype=dtype)

    if cfg.num_shared_experts:
        y = y + mlp(params["shared"], x, act, dtype)
    return y, aux * cfg.router_aux_coef
