from repro.models.model import Model, LayerPlan, layer_plans, segment_plans

__all__ = ["Model", "LayerPlan", "layer_plans", "segment_plans"]
