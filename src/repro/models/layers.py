"""Core layers: norms, MLPs, embeddings, rotary embeddings, losses.

All layers are (specs, apply) function pairs operating on plain dict pytrees.
Compute dtype is bf16 (configurable); parameters are stored fp32 and cast at
use — the mixed-precision recipe the paper's framework (InternEvo) uses.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.spec import ParamSpec

Params = Any


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_specs(dim: int) -> dict:
    return {"scale": ParamSpec((dim,), ("embed",), init="ones")}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# MLP (dense FFN)
# ---------------------------------------------------------------------------

def mlp_specs(d_model: int, d_ff: int, act: str) -> dict:
    glu = act.endswith("_glu")
    specs = {
        "w1": ParamSpec((d_model, d_ff), ("embed", "mlp"),
                        stddev=d_model ** -0.5),
        "w2": ParamSpec((d_ff, d_model), ("mlp", "embed"),
                        stddev=d_ff ** -0.5),
    }
    if glu:
        specs["w3"] = ParamSpec((d_model, d_ff), ("embed", "mlp"),
                                stddev=d_model ** -0.5)
    return specs


def _act(name: str, x: jax.Array) -> jax.Array:
    if name.startswith("silu"):
        return jax.nn.silu(x)
    if name.startswith("gelu"):
        return jax.nn.gelu(x)
    if name == "relu2":  # nemotron-4 squared ReLU
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(f"unknown activation {name!r}")


def mlp(params: Params, x: jax.Array, act: str, dtype: Any) -> jax.Array:
    w1 = params["w1"].astype(dtype)
    h = _act(act, x @ w1)
    if act.endswith("_glu"):
        h = h * (x @ params["w3"].astype(dtype))
    return h @ params["w2"].astype(dtype)


# ---------------------------------------------------------------------------
# Embedding + LM head
# ---------------------------------------------------------------------------

def embed_specs(cfg: ModelConfig) -> dict:
    # N(0, 1/d): the sqrt(d) input scaling then yields unit-variance hidden
    # states, and tied-embedding logits stay O(1) at init.
    return {"tok": ParamSpec((cfg.padded_vocab, cfg.d_model),
                             ("vocab", "embed"),
                             stddev=cfg.d_model ** -0.5)}


def embed(params: Params, tokens: jax.Array, dtype: Any,
          d_model: int) -> jax.Array:
    w = params["tok"].astype(dtype)
    h = jnp.take(w, tokens, axis=0)
    return h * jnp.asarray(d_model, dtype) ** 0.5


def lm_head_specs(cfg: ModelConfig) -> dict:
    if cfg.tie_embeddings:
        return {}
    return {"w": ParamSpec((cfg.d_model, cfg.padded_vocab),
                           ("embed", "vocab"), stddev=cfg.d_model ** -0.5)}


def lm_head(params: Params, embed_params: Params, h: jax.Array,
            tie: bool, dtype: Any) -> jax.Array:
    if tie:
        w = embed_params["tok"].astype(dtype).T
    else:
        w = params["w"].astype(dtype)
    return h @ w


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, n_heads, head_dim); positions: broadcastable to (..., S)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, dim: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings (encoder)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    inv = jnp.exp(-jnp.log(10000.0) * jnp.arange(dim // 2, dtype=jnp.float32)
                  / max(dim // 2 - 1, 1))
    ang = pos * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Loss: chunked softmax cross-entropy (+ z-loss), stable in fp32
# ---------------------------------------------------------------------------

def softmax_xent_chunked(logits_fn, h: jax.Array, labels: jax.Array,
                         weights: jax.Array, *, chunk: int = 1024,
                         z_loss: float = 0.0) -> tuple[jax.Array, jax.Array]:
    """Cross entropy without materializing (B, S, V) fp32 logits.

    ``logits_fn(h_chunk) -> (B, c, V)`` maps hidden states to logits (bf16 ok);
    the reduction is computed per sequence-chunk in fp32. Returns
    (sum_loss, sum_weight).
    """
    B, S, _ = h.shape
    chunk = min(chunk, S)
    n = S // chunk
    rem = S - n * chunk

    def one(h_c, y_c, w_c):
        logits = logits_fn(h_c).astype(jnp.float32)            # (B, c, V)
        lse = jax.nn.logsumexp(logits, axis=-1)                # (B, c)
        ll = jnp.take_along_axis(logits, y_c[..., None], axis=-1)[..., 0]
        nll = lse - ll
        if z_loss:
            nll = nll + z_loss * jnp.square(lse)
        return jnp.sum(nll * w_c), jnp.sum(w_c)

    if n > 0:
        h_m = h[:, :n * chunk].reshape(B, n, chunk, -1).swapaxes(0, 1)
        y_m = labels[:, :n * chunk].reshape(B, n, chunk).swapaxes(0, 1)
        w_m = weights[:, :n * chunk].reshape(B, n, chunk).swapaxes(0, 1)

        def body(carry, xs):
            loss, wsum = carry
            l, w = one(*xs)
            return (loss + l, wsum + w), None

        (loss, wsum), _ = jax.lax.scan(
            body, (jnp.float32(0.0), jnp.float32(0.0)), (h_m, y_m, w_m))
    else:
        loss = wsum = jnp.float32(0.0)
    if rem:
        l, w = one(h[:, n * chunk:], labels[:, n * chunk:],
                   weights[:, n * chunk:])
        loss, wsum = loss + l, wsum + w
    return loss, wsum
