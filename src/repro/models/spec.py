"""ParamSpec: abstract parameter descriptions (shape + logical axes + init).

Models are built in two phases:
  1. ``*_specs(cfg)``     -> pytree of ParamSpec (no allocation; drives both
                             the dry-run via ShapeDtypeStruct and sharding)
  2. ``init_params``      -> materialize real arrays from the spec tree
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class ParamSpec(NamedTuple):
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]           # logical axis names per dim
    dtype: Any = jnp.float32
    init: str = "normal"                      # normal | zeros | ones | eye_conv
    stddev: float = 0.02


def is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def spec_map(fn, tree: Any) -> Any:
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_spec)


def abstract_params(spec_tree: Any, shardings: Any = None) -> Any:
    """ShapeDtypeStruct tree for lowering without allocation."""
    if shardings is None:
        return spec_map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), spec_tree)
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        spec_tree, shardings, is_leaf=is_spec)


def init_params(spec_tree: Any, key: jax.Array) -> Any:
    """Materialize parameters. Deterministic per-leaf via path folding."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        spec_tree, is_leaf=is_spec)
    leaves = []
    for path, spec in flat:
        path_hash = _stable_hash("/".join(str(p) for p in path))
        k = jax.random.fold_in(key, path_hash)
        leaves.append(_init_one(spec, k))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _init_one(spec: ParamSpec, key: jax.Array) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "normal":
        x = jax.random.normal(key, spec.shape, jnp.float32) * spec.stddev
        return x.astype(spec.dtype)
    if spec.init == "a_log":  # mamba: A in [1, 16), stored as log
        a = jax.random.uniform(key, spec.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(a).astype(spec.dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def _stable_hash(s: str) -> int:
    h = 2166136261
    for ch in s.encode():
        h = (h ^ ch) * 16777619 % (1 << 31)
    return h


def stack_specs(spec_tree: Any, n: int) -> Any:
    """Add a leading scanned-layers dim (logical axis "stacked")."""
    return spec_map(
        lambda s: ParamSpec((n,) + s.shape, ("stacked",) + s.axes,
                            s.dtype, s.init, s.stddev),
        spec_tree)


def num_params(spec_tree: Any) -> int:
    return sum(int(np.prod(s.shape)) for s in
               jax.tree_util.tree_leaves(spec_tree, is_leaf=is_spec)
               if is_spec(s))
