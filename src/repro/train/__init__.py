from repro.train.optimizer import (AdamState, adamw_init, adamw_update,
                                   clip_by_global_norm, compress_grads,
                                   compressor_init, global_norm, lr_schedule)
from repro.train.train_step import (abstract_batch, batch_shardings,
                                    compile_train_step, make_train_step,
                                    opt_rules, state_shardings)

__all__ = [
    "AdamState", "adamw_init", "adamw_update", "clip_by_global_norm",
    "compress_grads", "compressor_init", "global_norm", "lr_schedule",
    "abstract_batch", "batch_shardings", "compile_train_step",
    "make_train_step", "opt_rules", "state_shardings",
]
