"""AdamW with fp32 master state, global-norm clipping and LR schedules.

Hand-rolled (no optax in this environment) but shaped like a production
optimizer: states are a pytree mirroring params so sharding rules apply
leaf-wise; ZeRO-1 shards m/v over the data axes while params stay replicated
(see ``repro.train.train_step.opt_rules``); an optional int8 error-feedback
gradient compressor implements the paper-era "distributed optimization trick"
for bandwidth-constrained reductions.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig

Params = Any


class AdamState(NamedTuple):
    m: Params
    v: Params
    step: jax.Array


def adamw_init(params: Params) -> AdamState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamState(m=zeros,
                     v=jax.tree_util.tree_map(jnp.copy, zeros),
                     step=jnp.zeros((), jnp.int32))


def adamw_abstract(params: Params) -> AdamState:
    zeros = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params)
    return AdamState(m=zeros, v=zeros,
                     step=jax.ShapeDtypeStruct((), jnp.int32))


def global_norm(tree: Params) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads: Params, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def lr_schedule(cfg: TrainConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to 10% of peak."""
    step = step.astype(jnp.float32)
    warm = cfg.learning_rate * step / max(cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.learning_rate * (0.1 + 0.45 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_update(grads: Params, state: AdamState, params: Params,
                 cfg: TrainConfig) -> tuple[Params, AdamState, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2, eps = cfg.adam_b1, cfg.adam_b2, cfg.adam_eps
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + eps) + cfg.weight_decay * p
        return (p - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamState(new_m, new_v, step), metrics


# ---------------------------------------------------------------------------
# int8 error-feedback gradient compression (optional distributed-opt trick)
# ---------------------------------------------------------------------------

class CompressorState(NamedTuple):
    error: Params     # residual feedback buffers (fp32)


def compressor_init(params: Params) -> CompressorState:
    return CompressorState(error=jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def compress_decompress(g: jax.Array, err: jax.Array):
    """Quantize (g + err) to int8 w/ per-tensor scale; return dequant + new err.

    In a real deployment the int8 payload is what crosses the wire (4x less
    DCN traffic than fp32); error feedback keeps the optimizer unbiased over
    time. Here we model the numerics end-to-end.
    """
    x = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, x - deq


def compress_grads(grads: Params, state: CompressorState):
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(state.error)
    out = [compress_decompress(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_e = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return new_g, CompressorState(error=new_e)
