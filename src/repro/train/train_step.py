"""jit'd train-step factory: loss -> grads -> (clip) -> AdamW, with
microbatch gradient accumulation and explicit in/out shardings.

ZeRO placement (paper's hierarchical-ZeRO adapted to GSPMD):
  zero="none"   params+opt replicated over data axes (pure DP)
  zero="zero1"  params replicated, m/v sharded over data axes
  zero="zero3"  params+opt sharded over data axes (FSDP)
  zero="zero3_hier"  like zero3 but sharded over the pod-local axis only
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ParallelConfig, TrainConfig
from repro.models import Model
from repro.models.spec import abstract_params
from repro.sharding import Rules, make_rules, tree_shardings
from repro.train.optimizer import (AdamState, adamw_abstract, adamw_init,
                                   adamw_update, compress_grads,
                                   compressor_init)

Params = Any


# ---------------------------------------------------------------------------
# sharding helpers
# ---------------------------------------------------------------------------

def opt_rules(mesh: Mesh, parallel: ParallelConfig) -> Rules:
    """Rules for optimizer state: ZeRO-1 shards opt even when params aren't."""
    if parallel.zero == "zero1":
        return make_rules(mesh, dataclasses.replace(parallel, zero="zero3"))
    return make_rules(mesh, parallel)


def state_shardings(model: Model, mesh: Mesh, parallel: ParallelConfig):
    """(param_shardings, opt_shardings) NamedSharding trees."""
    specs = model.specs()
    prules = make_rules(mesh, parallel)
    orules = opt_rules(mesh, parallel)
    p_sh = tree_shardings(prules, specs)
    m_sh = tree_shardings(orules, specs)
    opt_sh = AdamState(m=m_sh, v=m_sh,
                       step=NamedSharding(mesh, P()))
    return p_sh, opt_sh


def batch_shardings(mesh: Mesh, parallel: ParallelConfig, batch_tree: Any):
    """Shard every batch leaf's leading dim over the data axes."""
    rules = make_rules(mesh, parallel)

    def one(x):
        axes = ("batch",) + (None,) * (len(x.shape) - 1)
        return rules.sharding(x.shape, axes)

    return jax.tree_util.tree_map(one, batch_tree)


def abstract_batch(model: Model, batch_size: int, seq_len: int) -> dict:
    """ShapeDtypeStruct stand-ins for a training batch."""
    cfg = model.cfg
    b: dict = {
        "tokens": jax.ShapeDtypeStruct((batch_size, seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch_size, seq_len), jnp.int32),
        "weights": jax.ShapeDtypeStruct((batch_size, seq_len), jnp.float32),
    }
    if cfg.frontend == "patch_stub":
        b["patches"] = jax.ShapeDtypeStruct(
            (batch_size, cfg.num_patches, cfg.d_model), jnp.float32)
    if cfg.frontend == "audio_stub":
        b["frames"] = jax.ShapeDtypeStruct(
            (batch_size, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return b


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

def make_train_step(model: Model, tcfg: TrainConfig,
                    grad_shardings: Any = None) -> Callable:
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    Pure function; jit with shardings via ``compile_train_step``.
    ``grad_shardings``: optional NamedSharding tree pinned onto the gradient
    tree right after autodiff — tells GSPMD to reduce each gradient straight
    into its ZeRO shard (reduce-scatter) instead of materializing a
    replicated all-reduce first.
    """
    bf16_grads = model.parallel.grad_dtype == "bfloat16"

    def _pin(grads):
        if grad_shardings is None:
            return grads
        return jax.tree_util.tree_map(
            jax.lax.with_sharding_constraint, grads, grad_shardings)

    def loss_fn(p, mb):
        loss, metrics = model.loss(p, mb)
        return loss, metrics

    if bf16_grads:
        # differentiate w.r.t. the bf16 cast of the params: gradients (and
        # their cross-device reductions) materialize in bf16; AdamW applies
        # them to the fp32 master copies.
        from repro.utils import cast_floating
        _grad = jax.value_and_grad(
            lambda pc, mb: loss_fn(pc, mb), has_aux=True)

        def grad_fn(p, mb):
            out, g = _grad(cast_floating(p, jnp.bfloat16), mb)
            return out, g
    else:
        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(params, opt_state, batch):
        k = tcfg.microbatches
        if k > 1:
            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]),
                batch)

            def body(acc, mb):
                (loss, metrics), grads = grad_fn(params, mb)
                acc = jax.tree_util.tree_map(jnp.add, acc, grads)
                return acc, metrics

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, metrics_all = jax.lax.scan(body, zeros, mbs)
            grads = _pin(jax.tree_util.tree_map(lambda g: g / k, grads))
            metrics = jax.tree_util.tree_map(lambda m: m.mean(), metrics_all)
        else:
            (loss, metrics), grads = grad_fn(params, batch)
            grads = _pin(grads)

        new_params, new_opt, opt_metrics = adamw_update(
            grads, opt_state, params, tcfg)
        metrics.update(opt_metrics)
        return new_params, new_opt, metrics

    return step


def compile_train_step(model: Model, tcfg: TrainConfig, mesh: Mesh,
                       parallel: ParallelConfig, *,
                       batch_size: Optional[int] = None,
                       seq_len: Optional[int] = None,
                       lower_only: bool = False,
                       donate: bool = True):
    """Lower (and optionally compile) the train step with full shardings.

    Returns (fn_or_lowered, param_shardings, opt_shardings, batch_shardings).
    """
    bs = batch_size or tcfg.global_batch
    sl = seq_len or tcfg.seq_len
    p_sh, o_sh = state_shardings(model, mesh, parallel)
    ab = abstract_batch(model, bs, sl)
    b_sh = batch_shardings(mesh, parallel, ab)
    step = make_train_step(model, tcfg, grad_shardings=p_sh)
    jitted = jax.jit(
        step,
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1) if donate else (),
    )
    if lower_only:
        abstract_p = abstract_params(model.specs(), p_sh)
        abstract_o = _abstract_opt(abstract_p, o_sh)
        ab_sharded = jax.tree_util.tree_map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            ab, b_sh)
        with mesh:
            lowered = jitted.lower(abstract_p, abstract_o, ab_sharded)
        return lowered, p_sh, o_sh, b_sh
    return jitted, p_sh, o_sh, b_sh


def _abstract_opt(abstract_p, o_sh) -> AdamState:
    m = jax.tree_util.tree_map(
        lambda p, sh: jax.ShapeDtypeStruct(p.shape, jnp.float32, sharding=sh),
        abstract_p, o_sh.m)
    v = jax.tree_util.tree_map(
        lambda p, sh: jax.ShapeDtypeStruct(p.shape, jnp.float32, sharding=sh),
        abstract_p, o_sh.v)
    return AdamState(m=m, v=v,
                     step=jax.ShapeDtypeStruct((), jnp.int32,
                                               sharding=o_sh.step))
