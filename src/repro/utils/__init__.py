"""Small shared utilities: pytree helpers, timers, deterministic RNG, logging."""
from __future__ import annotations

import contextlib
import dataclasses
import logging
import math
import time
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

logger = logging.getLogger("repro")
if not logger.handlers:
    _h = logging.StreamHandler()
    _h.setFormatter(logging.Formatter("[%(asctime)s %(levelname)s %(name)s] %(message)s"))
    logger.addHandler(_h)
    logger.setLevel(logging.INFO)


# ---------------------------------------------------------------------------
# pytree helpers
# ---------------------------------------------------------------------------

def tree_size_bytes(tree: Any) -> int:
    """Total bytes of all array leaves (works on ShapeDtypeStruct too)."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for leaf in leaves:
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            total += int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
    return total


def tree_num_params(tree: Any) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(int(np.prod(l.shape)) for l in leaves if hasattr(l, "shape"))


def tree_flatten_with_paths(tree: Any) -> list[tuple[str, Any]]:
    """Flatten a pytree into ("a/b/c", leaf) pairs using jax key paths."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        out.append(("/".join(_path_str(p) for p in path), leaf))
    return out


def _path_str(entry: Any) -> str:
    if isinstance(entry, jax.tree_util.DictKey):
        return str(entry.key)
    if isinstance(entry, jax.tree_util.SequenceKey):
        return str(entry.idx)
    if isinstance(entry, jax.tree_util.GetAttrKey):
        return str(entry.name)
    return str(entry)


def tree_allclose(a: Any, b: Any, rtol: float = 1e-5, atol: float = 1e-5) -> bool:
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    if len(la) != len(lb):
        return False
    return all(np.allclose(np.asarray(x), np.asarray(y), rtol=rtol, atol=atol)
               for x, y in zip(la, lb))


def cast_floating(tree: Any, dtype: Any) -> Any:
    """Cast floating-point leaves of a pytree to ``dtype``; leave ints alone."""
    def _cast(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree_util.tree_map(_cast, tree)


# ---------------------------------------------------------------------------
# timing
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Timer:
    name: str = ""
    elapsed: float = 0.0

    def __enter__(self) -> "Timer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.elapsed = time.perf_counter() - self._t0


@contextlib.contextmanager
def log_time(name: str) -> Iterator[None]:
    t0 = time.perf_counter()
    yield
    logger.info("%s took %.3fs", name, time.perf_counter() - t0)


def timeit_median(fn: Callable[[], Any], *, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time per call in seconds. Blocks on jax arrays."""
    for _ in range(warmup):
        _block(fn())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        _block(fn())
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def _block(x: Any) -> None:
    for leaf in jax.tree_util.tree_leaves(x):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


# ---------------------------------------------------------------------------
# misc numeric helpers
# ---------------------------------------------------------------------------

def round_up(x: int, m: int) -> int:
    return int(math.ceil(x / m) * m)


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB", "PiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f}{unit}"
        n /= 1024.0
    return f"{n:.2f}EiB"


def human_count(n: float) -> str:
    for unit in ("", "K", "M", "B", "T"):
        if abs(n) < 1000.0:
            return f"{n:.2f}{unit}"
        n /= 1000.0
    return f"{n:.2f}Q"
