"""Offline stand-in for the ``hypothesis`` property-testing library.

Shim policy
-----------
The tier-1 suite property-tests several invariants with ``hypothesis``
(``@given`` over random schedules, fleets, shard shapes, ...). That package
is not available in the hermetic offline environment, so this module
provides the *minimal* API subset those tests use — ``given``, ``settings``
and the ``strategies`` combinators below — backed by deterministic seeded
sampling (seed derived from the test's qualified name, so failures are
reproducible run-to-run and machine-to-machine).

``install()`` registers the shim under the ``hypothesis`` /
``hypothesis.strategies`` module names **only when the real package is
missing** (see ``tests/conftest.py``); with real hypothesis installed the
shim is inert. The shim intentionally does NOT implement shrinking,
the example database, or health checks — it is a deterministic example
runner, not a replacement. Tests must restrict themselves to:

    given(**kwargs)                 # keyword strategies only
    settings(max_examples=, deadline=, ...)
    assume(condition)
    strategies.integers / floats / booleans / sampled_from / lists /
               tuples / sets / just / data / one_of / text / dictionaries
"""
from __future__ import annotations

import inspect
import random
import sys
import types
import zlib
from typing import Any, Callable, Optional, Sequence

_DEFAULT_MAX_EXAMPLES = 100


class UnsatisfiedAssumption(Exception):
    """Raised by ``assume(False)``; the current example is skipped."""


def assume(condition: Any) -> bool:
    if not condition:
        raise UnsatisfiedAssumption()
    return True


# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------

class SearchStrategy:
    """A strategy is just a seeded draw function."""

    def __init__(self, draw_fn: Callable[[random.Random], Any],
                 label: str = "strategy"):
        self._draw = draw_fn
        self._label = label

    def do_draw(self, rng: random.Random) -> Any:
        return self._draw(rng)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"<shim.{self._label}>"


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.randint(min_value, max_value),
                          f"integers({min_value},{max_value})")


def floats(min_value: float, max_value: float, **_ignored) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.uniform(min_value, max_value),
                          f"floats({min_value},{max_value})")


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: bool(rng.getrandbits(1)), "booleans")


def just(value: Any) -> SearchStrategy:
    return SearchStrategy(lambda rng: value, "just")


def sampled_from(elements: Sequence[Any]) -> SearchStrategy:
    elements = list(elements)
    if not elements:
        raise ValueError("sampled_from requires a non-empty sequence")
    return SearchStrategy(lambda rng: rng.choice(elements), "sampled_from")


def lists(elements: SearchStrategy, *, min_size: int = 0,
          max_size: Optional[int] = None) -> SearchStrategy:
    hi = max_size if max_size is not None else min_size + 10

    def draw(rng: random.Random) -> list:
        n = rng.randint(min_size, hi)
        return [elements.do_draw(rng) for _ in range(n)]

    return SearchStrategy(draw, "lists")


def tuples(*elements: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: tuple(e.do_draw(rng) for e in elements), "tuples")


def sets(elements: SearchStrategy, *, min_size: int = 0,
         max_size: Optional[int] = None) -> SearchStrategy:
    hi = max_size if max_size is not None else min_size + 10

    def draw(rng: random.Random) -> set:
        target = rng.randint(min_size, hi)
        out: set = set()
        # the element domain may be smaller than ``target``; bound attempts
        for _ in range(max(20 * (target + 1), 50)):
            if len(out) >= target:
                break
            out.add(elements.do_draw(rng))
        return out

    return SearchStrategy(draw, "sets")


def one_of(*strategies: SearchStrategy) -> SearchStrategy:
    """Uniform choice over branch strategies (the shim has no shrinking,
    so there is no bias toward earlier branches like real hypothesis)."""
    if not strategies:
        raise ValueError("one_of requires at least one strategy")

    def draw(rng: random.Random) -> Any:
        return strategies[rng.randrange(len(strategies))].do_draw(rng)

    return SearchStrategy(draw, f"one_of({len(strategies)})")


_DEFAULT_ALPHABET = ("abcdefghijklmnopqrstuvwxyz"
                     "ABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789_")


def text(alphabet: Any = _DEFAULT_ALPHABET, *, min_size: int = 0,
         max_size: Optional[int] = None) -> SearchStrategy:
    """Strings over ``alphabet`` (a string/sequence of characters, or a
    SearchStrategy drawing single characters)."""
    hi = max_size if max_size is not None else min_size + 10

    def draw(rng: random.Random) -> str:
        n = rng.randint(min_size, hi)
        if isinstance(alphabet, SearchStrategy):
            return "".join(str(alphabet.do_draw(rng)) for _ in range(n))
        chars = list(alphabet)
        if not chars:
            if min_size > 0:
                raise ValueError("empty alphabet with min_size > 0")
            return ""
        return "".join(rng.choice(chars) for _ in range(n))

    return SearchStrategy(draw, f"text(min={min_size},max={hi})")


def dictionaries(keys: SearchStrategy, values: SearchStrategy, *,
                 min_size: int = 0,
                 max_size: Optional[int] = None) -> SearchStrategy:
    """Dicts with drawn keys/values. Like :func:`sets`, the key domain may
    be smaller than the requested size, so draw attempts are bounded."""
    hi = max_size if max_size is not None else min_size + 10

    def draw(rng: random.Random) -> dict:
        target = rng.randint(min_size, hi)
        out: dict = {}
        for _ in range(max(20 * (target + 1), 50)):
            if len(out) >= target:
                break
            out[keys.do_draw(rng)] = values.do_draw(rng)
        return out

    return SearchStrategy(draw, "dictionaries")


class DataObject:
    """Interactive draws inside a test body (``data.draw(strategy)``)."""

    def __init__(self, rng: random.Random):
        self._rng = rng

    def draw(self, strategy: SearchStrategy, label: str = "") -> Any:
        return strategy.do_draw(self._rng)


class _DataStrategy(SearchStrategy):
    def __init__(self):
        super().__init__(lambda rng: DataObject(rng), "data")


def data() -> SearchStrategy:
    return _DataStrategy()


# ---------------------------------------------------------------------------
# given / settings
# ---------------------------------------------------------------------------

def given(*args: SearchStrategy, **kwargs: SearchStrategy):
    """Keyword-strategy decorator. Each example draws every strategy from a
    ``random.Random`` seeded by (test qualname, example index), so the run
    is fully deterministic. Parameters not supplied by strategies stay in
    the wrapper's signature for pytest fixture injection."""
    if args:
        raise TypeError("the hypothesis shim supports keyword strategies "
                        "only, e.g. @given(n=st.integers(0, 5))")

    def decorate(fn: Callable) -> Callable:
        sig = inspect.signature(fn)
        missing = set(kwargs) - set(sig.parameters)
        if missing:
            raise TypeError(f"@given got unexpected arguments {missing} "
                            f"for {fn.__name__}{sig}")
        fixture_params = [p for name, p in sig.parameters.items()
                          if name not in kwargs]
        base_seed = zlib.crc32(
            f"{fn.__module__}.{fn.__qualname__}".encode()) & 0xFFFFFFFF

        def wrapper(*fargs, **fkwargs):
            cfg = getattr(wrapper, "_shim_config", {})
            max_examples = cfg.get("max_examples", _DEFAULT_MAX_EXAMPLES)
            for i in range(max_examples):
                rng = random.Random(base_seed * 100_003 + i)
                drawn = {name: strat.do_draw(rng)
                         for name, strat in kwargs.items()}
                try:
                    fn(*fargs, **fkwargs, **drawn)
                except UnsatisfiedAssumption:
                    continue
                except Exception:
                    shown = {k: v for k, v in drawn.items()
                             if not isinstance(v, DataObject)}
                    sys.stderr.write(f"\nFalsifying example "
                                     f"({fn.__qualname__}, example {i}): "
                                     f"{shown}\n")
                    raise

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.__signature__ = sig.replace(parameters=fixture_params)
        wrapper._shim_config = dict(
            getattr(fn, "_shim_config_pending", {}))  # settings-under-given
        wrapper._shim_given = dict(kwargs)
        return wrapper

    return decorate


def settings(*, max_examples: int = _DEFAULT_MAX_EXAMPLES,
             deadline: Any = None, **_ignored):
    """Records ``max_examples``; ``deadline`` and everything else is a
    no-op in the shim. Works above or below ``@given``."""

    def decorate(fn: Callable) -> Callable:
        if hasattr(fn, "_shim_config"):          # settings over given
            fn._shim_config["max_examples"] = max_examples
        else:                                     # given over settings
            pending = dict(getattr(fn, "_shim_config_pending", {}))
            pending["max_examples"] = max_examples
            fn._shim_config_pending = pending
        return fn

    return decorate


# ---------------------------------------------------------------------------
# installation
# ---------------------------------------------------------------------------

def install(force: bool = False) -> bool:
    """Register the shim as ``hypothesis`` in ``sys.modules``.

    Returns True when the shim was installed, False when the real package
    exists (the shim then stays out of the way). Idempotent."""
    if not force:
        try:
            import hypothesis
            return bool(getattr(hypothesis, "__shim__", False))
        except ImportError:
            pass
    strategies = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "just", "sampled_from",
                 "lists", "tuples", "sets", "data", "one_of", "text",
                 "dictionaries", "SearchStrategy"):
        setattr(strategies, name, globals()[name])
    hyp = types.ModuleType("hypothesis")
    hyp.__doc__ = __doc__
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.strategies = strategies
    hyp.__shim__ = True
    hyp.__version__ = "0.0-repro-shim"
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strategies
    return True
