"""Acme-style workload generator (paper §3).

Each cluster is a ``WorkloadSpec``: per-job-type mixes calibrated against the
paper's figures —

  * Fig. 4: evaluation dominates job *count* (92.9% in Kalos) while
    pretraining dominates GPU *time* (94.0% in Kalos, 69.5% in Seren);
  * Fig. 5: GPU demand per type (eval <=4, pretraining >100, debug wide);
  * Fig. 2/6: median GPU job duration ~2 minutes, <5% of pretraining jobs
    exceed one day (frequent failures cut them short);
  * Fig. 17: ~40% of jobs fail consuming ~10% of GPU resources, completed
    jobs consume only 20-30%, canceled jobs ~7% of count but >60% of time.

Durations are per-type log-normals; the constructor *calibrates* a per-type
duration scale so the aggregate GPU-time shares land on the paper's targets
regardless of how the other knobs are set.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

SIX_MONTHS_MIN = 6 * 30 * 24 * 60.0


@dataclasses.dataclass(frozen=True)
class TypeSpec:
    name: str
    count_frac: float            # share of job count
    gputime_frac: float          # target share of total GPU time
    demand_log2_mean: float      # GPU demand ~ 2**round(N(mean, sd)), >=min
    demand_log2_sd: float
    demand_min: int
    demand_max: int
    dur_log_mean: float          # minutes, log-normal (pre-calibration)
    dur_log_sd: float
    cpu_only_frac: float = 0.0
    # per-type (completed, failed, canceled) mix; None -> cluster default.
    # Pretraining skews canceled (paper A.1: canceled jobs are 7% of count
    # but >60% of GPU time — "large-scale pretraining jobs being canceled").
    status_probs: Optional[tuple[float, float, float]] = None


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    name: str
    n_gpu_jobs: int
    n_gpus: int
    types: tuple[TypeSpec, ...]
    # final status mix (Fig. 17): (completed, failed, canceled)
    status_count_frac: tuple[float, float, float] = (0.53, 0.40, 0.07)
    status_gputime_frac: tuple[float, float, float] = (0.28, 0.10, 0.62)


# configured duration medians are chosen to be *consistent* with the target
# GPU-time shares (so the calibration below only nudges them), keeping each
# type's duration distribution realistic: eval ~1.5 min, pretraining roughly
# an order of magnitude above the rest with a heavy tail (<5% beyond a day).
KALOS = WorkloadSpec(
    name="Kalos", n_gpu_jobs=20_000, n_gpus=2416,
    types=(
        TypeSpec("evaluation", 0.929, 0.008, 0.6, 0.8, 1, 8, math.log(1.8), 1.0),
        TypeSpec("pretrain",   0.032, 0.940, 9.0, 0.8, 128, 2048, math.log(17.0), 1.6,
                 status_probs=(0.23, 0.22, 0.55)),
        TypeSpec("debug",      0.030, 0.049, 3.0, 2.0, 1, 64, math.log(14.0), 1.5),
        TypeSpec("other",      0.009, 0.003, 1.0, 1.5, 1, 64, math.log(5.0), 1.4),
    ))

SEREN = WorkloadSpec(
    name="Seren", n_gpu_jobs=664_000, n_gpus=2288,
    types=(
        TypeSpec("evaluation", 0.80, 0.010, 0.5, 0.8, 1, 8, math.log(1.0), 1.0),
        TypeSpec("pretrain",   0.009, 0.695, 8.0, 1.0, 32, 1024, math.log(23.0), 1.6,
                 status_probs=(0.23, 0.22, 0.55)),
        TypeSpec("sft",        0.050, 0.080, 3.5, 1.0, 4, 64, math.log(11.0), 1.3),
        TypeSpec("mllm",       0.060, 0.110, 3.5, 1.5, 1, 256, math.log(7.0), 1.5),
        TypeSpec("debug",      0.050, 0.070, 3.0, 2.0, 1, 256, math.log(5.0), 1.5),
        TypeSpec("other",      0.031, 0.035, 1.0, 1.5, 1, 64, math.log(30.0), 1.4),
    ))


@dataclasses.dataclass(slots=True)
class JobRecord:
    job_id: int
    jtype: str
    gpus: int
    submit_min: float
    duration_min: float          # runtime excluding queueing
    status: str                  # completed | failed | canceled
    queue_min: float = 0.0       # filled by the scheduler sim; inf = never ran
    # filled by the failure-aware replay (repro.cluster.replay):
    restarts: int = 0            # injected-failure restarts
    lost_gpu_min: float = 0.0    # work rolled back to the last checkpoint
    requeue_wait_min: float = 0.0  # queueing after failures (excl. queue_min)
    # submitted to the revocable-lease tier: the job runs on *any* idle
    # capacity (including the pretraining reservation's unused quota), is
    # always periodically checkpointed, and is preempted back to its last
    # checkpoint the instant dispatch or elastic regrowth reclaims the
    # lease — the paper's §3.2 quota-reclamation preemption as a
    # scheduling policy (see repro.cluster.replay)
    best_effort: bool = False
    # real architecture behind the job (a repro.configs registry name).
    # Tagged on a configurable fraction of pretraining jobs; under
    # ReplayConfig(runtime_model="roofline") the replay derives the job's
    # width-scaling curve from the arch's calibrated roofline cell, so
    # elastic shrink/regrow reprices via modeled parallel efficiency
    # instead of linear stretching. None = nominal trace-minute pricing.
    arch: Optional[str] = None
    # -- engine-transient state (repro.cluster.replay / scheduler) ----------
    # Declared so the class can carry __slots__: the replay engine reads
    # and writes these per event, and slot access keeps the hottest loop of
    # the million-job replay off the per-instance dict. Excluded from
    # __init__/repr/eq; the replay's reset loop (re)initializes them.
    _alloc: tuple = dataclasses.field(
        init=False, repr=False, compare=False, default=("lo", 0, 0))
    _arrived_at: float = dataclasses.field(
        init=False, repr=False, compare=False, default=0.0)
    _done: float = dataclasses.field(
        init=False, repr=False, compare=False, default=0.0)
    _started: bool = dataclasses.field(
        init=False, repr=False, compare=False, default=False)
    _running: bool = dataclasses.field(
        init=False, repr=False, compare=False, default=False)
    _width: int = dataclasses.field(
        init=False, repr=False, compare=False, default=0)
    _epoch: int = dataclasses.field(
        init=False, repr=False, compare=False, default=0)
    _prog: float = dataclasses.field(
        init=False, repr=False, compare=False, default=0.0)
    _seg_start: float = dataclasses.field(
        init=False, repr=False, compare=False, default=0.0)
    _head_since: Optional[float] = dataclasses.field(
        init=False, repr=False, compare=False, default=None)
    _shadow_est: Optional[float] = dataclasses.field(
        init=False, repr=False, compare=False, default=None)
    _nodes: Optional[dict] = dataclasses.field(
        init=False, repr=False, compare=False, default=None)
    _hi: bool = dataclasses.field(
        init=False, repr=False, compare=False, default=False)
    # width-scaling curve (launch.cost_model.WidthCurve) resolved from
    # ``arch`` by the replay's reset loop; None = nominal repricing
    _curve: Optional[object] = dataclasses.field(
        init=False, repr=False, compare=False, default=None)

    @property
    def gpu_time(self) -> float:
        return self.gpus * self.duration_min

    @property
    def started(self) -> bool:
        """Meaningful after a queue sim: never-started jobs carry an
        infinite ``queue_min`` sentinel."""
        return math.isfinite(self.queue_min)


@dataclasses.dataclass(slots=True)
class RequestRecord:
    """One inference request for the serving replay (serve_replay).

    ``out_tokens`` counts every generated token including the first one the
    prefill pass produces, so a request with ``out_tokens == 1`` finishes at
    prefill and never occupies a decode slot."""
    req_id: int
    arrival_min: float
    prompt_tokens: int
    out_tokens: int
    # filled by the serving replay (repro.cluster.serve_replay):
    ttft_min: float = math.inf   # arrival -> first token (prefill done)
    done_min: float = math.inf   # arrival-relative completion; inf = rejected
    decoded: int = 0             # decode tokens produced so far (<= out-1)
    evictions: int = 0           # KV evictions this request suffered
    retries: int = 0             # failure-kill retries through prefill
    # -- engine-transient state (repro.cluster.serve_replay) ----------------
    # Slot-declared for the same reason as JobRecord's transient fields:
    # the decode loop touches them per membership event at 1M+ request
    # scale. ``_res`` counts residencies — it versions the request's entry
    # in an instance's completion heap, so eviction is a lazy deletion.
    _res: int = dataclasses.field(
        init=False, repr=False, compare=False, default=0)
    _inst: int = dataclasses.field(
        init=False, repr=False, compare=False, default=-1)
    _admit_v: float = dataclasses.field(
        init=False, repr=False, compare=False, default=0.0)
    _base: int = dataclasses.field(
        init=False, repr=False, compare=False, default=0)
    # fault-injection transients: ``_pfe`` versions the request's in-flight
    # prefill pass (a failed prefill server lazily voids its _P_DONE),
    # ``_pfi`` names the prefill instance serving it, ``_skips`` bounds
    # head-of-line skip starvation, ``_fcls`` remembers the failure class
    # that last killed/retried it (SLO-violation attribution).
    _pfe: int = dataclasses.field(
        init=False, repr=False, compare=False, default=0)
    _pfi: int = dataclasses.field(
        init=False, repr=False, compare=False, default=-1)
    _skips: int = dataclasses.field(
        init=False, repr=False, compare=False, default=0)
    _fcls: object = dataclasses.field(
        init=False, repr=False, compare=False, default=None)


def generate_requests(n_requests: int, *, seed: int = 0,
                      horizon_min: float = 1440.0,
                      prompt_log_mean: float = math.log(600.0),
                      prompt_log_sd: float = 1.1,
                      out_log_mean: float = math.log(150.0),
                      out_log_sd: float = 0.8,
                      max_prompt: int = 16384,
                      max_out: int = 4096,
                      burst_frac: float = 0.1,
                      n_bursts: int = 48,
                      burst_width_min: float = 3.0,
                      diurnal: bool = True) -> list[RequestRecord]:
    """Draw the serving-trace request population (diurnal + bursty).

    The arrival process mirrors ``generate_jobs``' submission shape: a
    uniform draw thinned toward the daytime sine bump (``diurnal``), plus a
    ``burst_frac`` share of requests re-homed onto ``n_bursts`` random
    burst centers with one-sided exponential spread — the traffic-spike
    profile the serving replay's admission/eviction machinery is built
    for. Arrival and token draws use *separate* seeded streams (both
    derived from ``seed``), so turning the burst/diurnal knobs reshuffles
    arrivals while every request's prompt/output lengths stay
    bit-identical. Returns records sorted by arrival with ``req_id``
    assigned in arrival order."""
    n = int(n_requests)
    arr_rng = np.random.default_rng((seed << 3) ^ 0x5E2E)
    tok_rng = np.random.default_rng((seed << 3) ^ 0x70C5)
    arrival = arr_rng.uniform(0.0, horizon_min, n)
    if diurnal:
        day_phase = (arrival % 1440.0) / 1440.0
        keep = arr_rng.random(n) < (0.5 + 0.5 * np.sin(np.pi * day_phase) ** 2)
        arrival = np.where(keep, arrival, arr_rng.uniform(0, horizon_min, n))
    if burst_frac > 0.0 and n_bursts > 0:
        centers = arr_rng.uniform(0.0, horizon_min, n_bursts)
        which = centers[arr_rng.integers(0, n_bursts, n)]
        offset = arr_rng.exponential(burst_width_min, n)
        in_burst = arr_rng.random(n) < burst_frac
        arrival = np.where(in_burst, np.minimum(which + offset, horizon_min),
                           arrival)
    prompt = np.clip(
        np.exp(tok_rng.normal(prompt_log_mean, prompt_log_sd, n)),
        16, max_prompt).astype(np.int64)
    out = np.clip(
        np.exp(tok_rng.normal(out_log_mean, out_log_sd, n)),
        1, max_out).astype(np.int64)
    order = np.argsort(arrival, kind="stable")
    return [RequestRecord(i, float(arrival[j]), int(prompt[j]), int(out[j]))
            for i, j in enumerate(order)]


def _calibrate_scales(spec: WorkloadSpec, rng: np.random.Generator) -> dict:
    """Per-type duration multiplier so GPU-time shares hit the targets.

    Anchored on *evaluation* (scale 1): its short durations set the overall
    median (92.9% of jobs), so the calibration rescales every other type's
    durations around it rather than distorting the eval distribution."""
    scales = {}
    base = {}
    for t in spec.types:
        n = max(int(spec.n_gpu_jobs * t.count_frac), 1)
        d = _sample_demand(t, n, rng)
        dur = np.exp(rng.normal(t.dur_log_mean, t.dur_log_sd, n))
        base[t.name] = float(np.sum(d * dur))
    total_target = sum(t.gputime_frac for t in spec.types)
    anchor = next((t for t in spec.types if t.name == "evaluation"),
                  spec.types[0])
    total = base[anchor.name] / (anchor.gputime_frac / total_target)
    for t in spec.types:
        want = total * (t.gputime_frac / total_target)
        scales[t.name] = want / max(base[t.name], 1e-9)
    scales[anchor.name] = 1.0
    return scales


def _sample_demand(t: TypeSpec, n: int, rng: np.random.Generator) -> np.ndarray:
    raw = rng.normal(t.demand_log2_mean, t.demand_log2_sd, n)
    d = np.exp2(np.round(raw)).astype(np.int64)
    return np.clip(d, t.demand_min, t.demand_max)


# job types eligible for the revocable-lease best-effort tier. Flagged jobs
# are *demoted* below both FIFO classes in exchange for running on any idle
# capacity, so eligibility is about tolerating revocation, not about the
# class's normal priority: debug/other are short spare-pool work, and
# sft/mllm — though reserved-quota classes when submitted normally — are
# the checkpointed types whose progress survives a preemption. Evaluation
# is excluded (its trials have the §6.2 borrowing path) and so is
# pretraining (it holds the reservation the tier scavenges).
BEST_EFFORT_TYPES = ("debug", "other", "sft", "mllm")

# default architecture pool for ``generate_jobs(arch_frac=...)``: the
# registry names (repro.configs) a tagged pretraining job is drawn from —
# the paper's own InternLM family plus a spread of dense and MoE archs so
# a roofline-model replay exercises both collective profiles.
PRETRAIN_ARCHS = ("internlm-7b", "internlm-123b", "gemma3-27b",
                  "nemotron-4-15b", "mixtral-8x22b", "deepseek-v2-lite-16b")


def generate_jobs(spec: WorkloadSpec, *, seed: int = 0,
                  n_jobs: Optional[int] = None,
                  horizon_min: float = SIX_MONTHS_MIN,
                  best_effort_frac: float = 0.0,
                  best_effort_types: Optional[tuple] = None,
                  arch_frac: float = 0.0,
                  arch_pool: Optional[tuple] = None) -> list[JobRecord]:
    """Draw the 6-month job population (submission via a diurnal Poisson).

    ``best_effort_frac`` submits that fraction of eligible-type jobs
    (``best_effort_types``, default :data:`BEST_EFFORT_TYPES`) to the
    revocable-lease tier (``JobRecord.best_effort``). Flagging uses its own
    RNG stream, so the generated population is bit-identical to
    ``best_effort_frac=0`` in every other field.

    ``arch_frac`` tags that fraction of *pretraining* jobs with a real
    config name from ``arch_pool`` (default :data:`PRETRAIN_ARCHS`) in
    ``JobRecord.arch``. Tagging likewise uses its own RNG stream: every
    other field is bit-identical to ``arch_frac=0``, and under the default
    ``runtime_model="nominal"`` the tag is inert."""
    rng = np.random.default_rng(seed)
    scales = _calibrate_scales(spec, np.random.default_rng(seed + 1))
    n_total = n_jobs or spec.n_gpu_jobs
    jobs: list[JobRecord] = []
    jid = 0
    comp, fail, canc = spec.status_count_frac
    for t in spec.types:
        n = max(int(round(n_total * t.count_frac)), 1)
        demand = _sample_demand(t, n, rng)
        dur = np.exp(rng.normal(t.dur_log_mean, t.dur_log_sd, n)) * scales[t.name]
        dur = np.clip(dur, 0.05, horizon_min / 4)
        # diurnal submission: denser during the day, bursty for evaluation
        submit = rng.uniform(0, horizon_min, n)
        day_phase = (submit % 1440.0) / 1440.0
        keep = rng.random(n) < (0.5 + 0.5 * np.sin(np.pi * day_phase) ** 2)
        submit = np.where(keep, submit, rng.uniform(0, horizon_min, n))
        if t.name == "evaluation":
            # evals arrive in per-checkpoint batches: every tracked model's
            # whole ~60-dataset suite is submitted at once
            n_batches = max(n // 240, 1)
            batch_times = np.sort(rng.uniform(0, horizon_min, n_batches))
            submit = batch_times[rng.integers(0, n_batches, n)] \
                + rng.uniform(0, 0.5, n)
        probs = t.status_probs or (comp, fail, canc)
        status = rng.choice(["completed", "failed", "canceled"], size=n,
                            p=list(probs))
        # failures die early (paper: errors at the beginning of workloads) —
        # except pretraining, whose failures are mid-run infra faults with
        # long times-to-failure (Table 3: NVLink TTF median 155 min)
        if t.name == "pretrain":
            ttf = np.exp(rng.normal(math.log(150.0), 1.0, n))
        else:
            ttf = np.exp(rng.normal(0.6, 1.2, n))
        dur = np.where(status == "failed", np.minimum(dur, ttf), dur)
        for i in range(n):
            jobs.append(JobRecord(jid, t.name, int(demand[i]),
                                  float(submit[i]), float(dur[i]),
                                  str(status[i])))
            jid += 1
    jobs.sort(key=lambda j: j.submit_min)
    if best_effort_frac > 0.0:
        be_types = frozenset(best_effort_types if best_effort_types
                             is not None else BEST_EFFORT_TYPES)
        be_rng = np.random.default_rng((seed << 1) ^ 0xBE57)
        for j in jobs:
            if j.jtype in be_types and be_rng.random() < best_effort_frac:
                j.best_effort = True
    if arch_frac > 0.0:
        pool = tuple(arch_pool if arch_pool is not None else PRETRAIN_ARCHS)
        arch_rng = np.random.default_rng((seed << 2) ^ 0xA6C4)
        for j in jobs:
            if j.jtype == "pretrain" and arch_rng.random() < arch_frac:
                j.arch = pool[int(arch_rng.integers(0, len(pool)))]
    return jobs
