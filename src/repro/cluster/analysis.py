"""Trace analysis: recompute the paper's §3 headline statistics.

One function per figure/claim; ``trace_summary`` bundles them for the
benchmark harness (benchmarks/bench_trace.py) which checks them against the
paper's reported values.
"""
from __future__ import annotations

import collections
from typing import Iterable, Optional

import numpy as np

from repro.cluster.failures import QUOTA_RECLAIM
from repro.cluster.workload import JobRecord


def _median(xs) -> float:
    return float(np.median(np.asarray(list(xs), dtype=np.float64))) if xs else 0.0


def duration_stats(jobs: list[JobRecord]) -> dict:
    """Fig. 2a: GPU job duration distribution."""
    d = np.array([j.duration_min for j in jobs])
    return {
        "median_min": float(np.median(d)),
        "mean_min": float(np.mean(d)),
        "p95_min": float(np.percentile(d, 95)),
        "frac_over_1day": float(np.mean(d > 1440.0)),
    }


def type_shares(jobs: list[JobRecord]) -> dict:
    """Fig. 4: job count share and GPU-time share per workload type."""
    count = collections.Counter(j.jtype for j in jobs)
    gpu_time = collections.defaultdict(float)
    for j in jobs:
        gpu_time[j.jtype] += j.gpu_time
    n = len(jobs)
    total = sum(gpu_time.values()) or 1.0
    return {t: {"count_frac": count[t] / n,
                "gputime_frac": gpu_time[t] / total}
            for t in count}


def demand_stats(jobs: list[JobRecord]) -> dict:
    """Fig. 3/5: GPU-demand distribution overall and per type."""
    by_type = collections.defaultdict(list)
    for j in jobs:
        by_type[j.jtype].append(j.gpus)
    gpus = np.array([j.gpus for j in jobs])
    gpu_time = np.array([j.gpu_time for j in jobs])
    big = gpus >= 256
    single = gpus <= 1
    return {
        "median_by_type": {t: _median(v) for t, v in by_type.items()},
        "frac_jobs_single_gpu": float(np.mean(single)),
        "frac_jobs_ge8": float(np.mean(gpus > 8)),
        "gputime_frac_single_gpu": float(gpu_time[single].sum() / gpu_time.sum()),
        "gputime_frac_ge256": float(gpu_time[big].sum() / gpu_time.sum()),
        "mean_gpus": float(np.mean(gpus)),
    }


def queue_stats(jobs: list[JobRecord]) -> dict:
    """Fig. 6: queueing delay per type (needs simulate_queue first).

    Jobs that never started carry the ``NEVER_STARTED`` (inf) sentinel;
    they are excluded from the delay statistics and reported separately so
    an impossible job can't masquerade as a zero-wait one."""
    by_type = collections.defaultdict(list)
    never = collections.Counter()
    for j in jobs:
        if np.isfinite(j.queue_min):
            by_type[j.jtype].append(j.queue_min)
        else:
            never[j.jtype] += 1
    out = {t: {"median_min": _median(v),
               "mean_min": float(np.mean(v)) if v else 0.0,
               "n_never_started": int(never.pop(t, 0))}
           for t, v in by_type.items()}
    for t, n in never.items():     # types where *no* job ever started
        out[t] = {"median_min": 0.0, "mean_min": 0.0, "n_never_started": n}
    return out


def status_stats(jobs: list[JobRecord]) -> dict:
    """Fig. 17: final status shares by count and GPU time."""
    count = collections.Counter(j.status for j in jobs)
    gpu_time = collections.defaultdict(float)
    for j in jobs:
        gpu_time[j.status] += j.gpu_time
    n = len(jobs)
    total = sum(gpu_time.values()) or 1.0
    return {s: {"count_frac": count[s] / n,
                "gputime_frac": gpu_time[s] / total}
            for s in count}


def utilization_profile(jobs: list[JobRecord], n_gpus: int,
                        horizon_min: float) -> dict:
    """Fig. 2b-adjacent: time-averaged cluster GPU allocation."""
    # sweep-line over start/finish events (never-started jobs excluded)
    events = []
    for j in jobs:
        if not np.isfinite(j.queue_min):
            continue
        start = j.submit_min + j.queue_min
        events.append((start, j.gpus))
        events.append((start + j.duration_min, -j.gpus))
    events.sort()
    t_prev, used, acc = 0.0, 0, 0.0
    peak = 0
    for t, delta in events:
        acc += used * (t - t_prev)
        t_prev = t
        used += delta
        peak = max(peak, used)
    return {"mean_allocation_frac": acc / (n_gpus * horizon_min),
            "peak_allocation": peak}


def recovery_stats(result) -> dict:
    """§6 analogue: how injected failures were recovered, per applied policy
    and per diagnosis verdict (needs a ``replay_trace`` ReplayResult).

    Complements the queue/lost-GPU views above with the recovery side:
    which share of incidents each policy absorbed, the GPU-hours it cost,
    and — with diagnosis-in-the-loop enabled — the per-injected-class
    verdict mix plus the hardware-verdict hit rate (the paper's diagnosis
    accuracy headline for node faults).
    """
    total = sum(result.policies.values()) or 1
    policies = {
        p: {"count": int(c),
            "frac": c / total,
            "gpu_hours_lost": result.by_policy[p].lost_gpu_min / 60.0
            if p in result.by_policy else 0.0,
            "restart_overhead_min": result.by_policy[p].overhead_min
            if p in result.by_policy else 0.0}
        for p, c in sorted(result.policies.items())}
    verdicts = {}
    for cls_name, counter in sorted(result.verdicts.items()):
        n = sum(counter.values()) or 1
        verdicts[cls_name] = {v: {"count": int(c), "frac": c / n}
                              for v, c in sorted(counter.items())}
    hw = result.verdicts.get("hardware", {})
    hw_total = sum(hw.values())
    return {
        "incidents": int(total if result.policies else 0),
        "policies": policies,
        "diagnosis_verdicts": verdicts,
        "hardware_verdict_recall": (hw.get("hardware", 0) / hw_total
                                    if hw_total else None),
        "elastic": {"shrinks": result.elastic_shrinks,
                    "regrows": result.elastic_regrows},
    }


def serving_fault_stats(result) -> dict:
    """Serving-side analogue of :func:`recovery_stats`: how injected §5
    incidents degraded a ``replay_requests`` run (needs a
    ``ServeReplayResult`` produced with ``config.injector`` set).

    Top-level scalars give the episode totals — retries/drops/shed,
    destroyed-and-recomputed KV work (``killed_tokens``), goodput lost to
    dropped requests, wall minutes spent degraded, and the recovery mix
    (hardware-verdict respawns vs transient in-place restarts).
    ``by_class`` attributes all of it, plus TTFT/TPOT SLO violations, to
    the failure class that caused it. This is what ``summary()["faults"]``
    embeds, so every leaf is a plain scalar (schema contract).
    """
    stats = result.fault_stats or {}
    by_class = {}
    for name in sorted(stats):
        fs = stats[name]
        by_class[name] = {
            "failures": int(fs.failures),
            "prefill": int(fs.prefill),
            "decode": int(fs.decode),
            "retries": int(fs.retries),
            "drops": int(fs.drops),
            "shed": int(fs.shed),
            "killed_tokens": int(fs.killed_tokens),
            "lost_goodput_tokens": int(fs.lost_goodput_tokens),
            "slo_ttft_violations": int(fs.slo_ttft),
            "slo_tpot_violations": int(fs.slo_tpot),
            "downtime_min": float(fs.downtime_min),
            "verdicts": {v: int(c) for v, c in sorted(fs.verdicts.items())},
        }
    return {
        "injected": int(result.faults_injected),
        "retries": int(result.retries_total),
        "drops": len(result.dropped_ids),
        "shed": len(result.shed_ids),
        "hol_skips": int(result.hol_skips),
        "killed_tokens": int(result.killed_tokens),
        "lost_goodput_tokens": int(sum(
            fs.lost_goodput_tokens for fs in stats.values())),
        "degraded_min": float(result.degraded_min),
        "respawns": int(result.respawns),
        "inplace_restarts": int(result.inplace_restarts),
        "cordoned_nodes": int(result.cordoned_nodes),
        "by_class": by_class,
    }


def _tail(xs, qs=(50, 95, 99)) -> dict:
    arr = np.asarray(xs, dtype=np.float64)
    if arr.size == 0:
        return {f"p{q}_min": 0.0 for q in qs} | {"n": 0, "mean_min": 0.0}
    pcts = np.percentile(arr, qs)
    out = {f"p{q}_min": float(v) for q, v in zip(qs, pcts)}
    out["n"] = int(arr.size)
    out["mean_min"] = float(arr.mean())
    return out


def head_delay_stats(result) -> dict:
    """Head-delay tail (the EASY characterization figure): percentiles of
    how long blocked FIFO heads waited before starting, and of the
    shadow-estimate error (realized minus estimated wait — the part a real
    EASY scheduler cannot foresee because future failures/repairs are
    unknowable). Needs a ``replay_trace`` ReplayResult; the estimate tail
    is sampled per ``ReplayConfig.head_delay_sample`` (every head under
    ``backfill="easy"``)."""
    out = _tail(result.head_delays)
    out["shadow_error"] = _tail(result.shadow_errors)
    return out


def pool_stats(result, *, be_total: Optional[int] = None,
               be_never: Optional[int] = None) -> dict:
    """Elastic-capacity-pool ledger stats (§6.1 x §6.2): time-integrated
    free capacity, opportunistic regrowth activity (incl. the explicit
    re-shard stalls it paid), the best-effort revocable-lease tier, and —
    when a ``TrialBorrower`` was attached — borrowed GPU-minutes, lease
    and preemption counts. Needs a ``replay_trace`` ReplayResult.

    ``be_total``/``be_never`` let ``ReplayResult.summary()`` pass the
    best-effort-tier counts it already accumulated in its single job-record
    pass; when omitted, the records are scanned here (same counts)."""
    borrow = result.borrow or {}
    borrowed = borrow.get("borrowed_gpu_min", 0.0)
    free = result.pool_free_gpu_min
    reclaim = result.by_class.get(QUOTA_RECLAIM)
    if be_total is None:
        be_total = sum(1 for j in result.jobs if j.best_effort)
        be_never = sum(1 for j in result.jobs
                       if j.best_effort and not j.started)
    return {
        "free_gpu_hours": free / 60.0,
        "horizon_min": result.horizon_min,
        "regrowth": {
            # total width-restoration events: from the free pool
            # (opportunistic) plus at the lender node's repair
            "events": result.pool_regrows + result.elastic_regrows,
            "pool_regrows": result.pool_regrows,
            "pool_regrown_gpus": result.pool_regrown_gpus,
            "repair_regrows": result.elastic_regrows,
            "shrinks": result.elastic_shrinks,
            "reshard_events": result.pool_reshard_events,
            "reshard_stall_min": result.pool_reshard_min,
        },
        "best_effort": {
            # the revocable-lease tier: §3.2 quota reclamation as policy
            "jobs": int(be_total),
            "lease_starts": result.be_lease_starts,
            "revocations": reclaim.failures if reclaim else 0,
            "lost_gpu_hours": reclaim.lost_gpu_min / 60.0 if reclaim else 0.0,
            "revoke_overhead_min": reclaim.overhead_min if reclaim else 0.0,
            "never_started": int(be_never),
        },
        "borrow": borrow,
        "borrowed_gpu_min": borrowed,
        # share of otherwise-idle free capacity the eval trials soaked up
        "borrow_utilization": borrowed / free if free > 0 else 0.0,
    }


def placement_stats(result) -> dict:
    """Node-local placement view (§6.1 x §6.2, Fig. 16): where the
    ``NodeLedger`` stood at drain, and how borrowed eval shards' model
    loads collapsed under per-node storage-NIC contention. Empty when
    ``ReplayConfig.placement`` is off.

    ``load_by_concurrency`` bins each borrowed lease's realized model-load
    minutes by the number of loads sharing its node's NIC at acquisition;
    ``load_collapse_x`` is the mean load time at the highest observed
    concurrency over the solo (k=1) load — the paper's Fig. 16-left
    stress curve reproduced inside the replay."""
    base = result.placement
    if not base:
        return {}
    out = dict(base)
    borrow = (result.borrow or {}).get("placement") or {}
    bins = borrow.get("load_by_concurrency") or {}
    if bins:
        ks = sorted(bins)
        solo = bins[ks[0]]["mean_load_min"]
        peak = bins[ks[-1]]["mean_load_min"]
        out["load_by_concurrency"] = {str(k): bins[k] for k in ks}
        out["max_load_concurrency"] = ks[-1]
        out["load_collapse_x"] = peak / solo if solo > 0 else 0.0
    return out


def trace_summary(jobs: list[JobRecord], n_gpus: int,
                  horizon_min: float) -> dict:
    return {
        "n_jobs": len(jobs),
        "duration": duration_stats(jobs),
        "type_shares": type_shares(jobs),
        "demand": demand_stats(jobs),
        "queue": queue_stats(jobs),
        "status": status_stats(jobs),
        "utilization": utilization_profile(jobs, n_gpus, horizon_min),
    }
