"""Slurm-like scheduler with pretraining quota reservation (§2.2 / §3.2).

The paper's policy: "the majority of resources [are] reserved for pretraining
jobs to minimize their queuing delays. Evaluation jobs are scheduled with a
lower priority, utilizing the limited spare resources." — which inverts the
classic finding that big jobs wait longest: here the small, short *eval*
jobs see the longest queueing delay.

Event-driven simulation over the generated job population. Two GPU pools:
a reserved pool admitting only high-priority types (pretrain/sft/mllm) and a
spare pool for everything (best-effort). Jobs that can't start queue FIFO
within their priority class.

``simulate_queue`` is a thin wrapper over the failure-aware replay engine
(``repro.cluster.replay``) with injection disabled, so the pure queuing
path and the failure-injected path share one dispatch implementation.
Jobs that never run (impossible demands, or stuck behind a wedged FIFO
head in legacy mode) get ``queue_min = NEVER_STARTED`` instead of a
misleading 0.0.
"""
from __future__ import annotations

import dataclasses
import math

from repro.cluster.workload import JobRecord

HIGH_PRIORITY = ("pretrain", "sft", "mllm")

# sentinel queue delay for jobs that never started; keeps them trivially
# separable from genuinely zero-wait jobs (math.isfinite(queue_min))
NEVER_STARTED = math.inf


@dataclasses.dataclass(slots=True)
class ReservationScheduler:
    total_gpus: int
    reserved_frac: float = 0.85     # quota held for pretraining-class jobs
    # derived in __post_init__; declared (init/repr/compare-free, so the
    # construction API and eq semantics are unchanged) because the class
    # carries __slots__ — one instance sits on every replay hot path
    reserved: int = dataclasses.field(init=False, repr=False, compare=False)
    spare: int = dataclasses.field(init=False, repr=False, compare=False)
    free_reserved: int = dataclasses.field(init=False, repr=False,
                                           compare=False)
    free_spare: int = dataclasses.field(init=False, repr=False,
                                        compare=False)

    def __post_init__(self):
        self.reserved = int(self.total_gpus * self.reserved_frac)
        self.spare = self.total_gpus - self.reserved
        self.free_reserved = self.reserved
        self.free_spare = self.spare

    def can_start(self, job: JobRecord) -> bool:
        if job.jtype in HIGH_PRIORITY:
            return job.gpus <= self.free_reserved + self.free_spare
        if job.gpus <= self.spare:
            return job.gpus <= self.free_spare
        # oversized best-effort job (wider than the whole spare pool):
        # allowed to borrow reserved capacity so it cannot wedge the queue
        return job.gpus <= self.free_reserved + self.free_spare

    def start(self, job: JobRecord) -> None:
        if job.jtype in HIGH_PRIORITY or job.gpus > self.spare:
            take_r = min(job.gpus, self.free_reserved)
            self.free_reserved -= take_r
            self.free_spare -= job.gpus - take_r
            job._alloc = ("hi", take_r, job.gpus - take_r)  # type: ignore
        else:
            self.free_spare -= job.gpus
            job._alloc = ("lo", 0, job.gpus)                # type: ignore

    def finish(self, job: JobRecord) -> None:
        _, r, s = job._alloc                                # type: ignore
        self.free_reserved += r
        self.free_spare += s

    # -- revocable best-effort leases (§3.2 quota reclamation as policy) ----

    def can_lease(self, job: JobRecord) -> bool:
        """A revocable lease may draw *any* idle capacity — including the
        pretraining reservation's unused quota — because it is reclaimed
        the instant a queued job or a regrowing shrunken job wants it."""
        return job.gpus <= self.free_reserved + self.free_spare

    def lease(self, job: JobRecord) -> None:
        """Start ``job`` on a revocable best-effort lease: spare pool
        first, then idle reserved quota (the §3.2 reclamation target).
        The allocation kind ``"be"`` marks it revocable; the GPUs come
        back through the ordinary :meth:`finish` when the job completes
        or the lease is revoked."""
        take_s = min(job.gpus, self.free_spare)
        take_r = job.gpus - take_s
        self.free_spare -= take_s
        self.free_reserved -= take_r
        job._alloc = ("be", take_r, take_s)                 # type: ignore

    # -- cordon accounting (used by the failure-aware replay) ---------------

    def cordon(self, gpus: int) -> tuple[int, int]:
        """Remove up to ``gpus`` currently-free GPUs from the pools (a
        faulty node leaving the cluster). Takes from the reserved pool
        first. Returns the (reserved, spare) split actually taken, which
        must be handed back verbatim to :meth:`uncordon`. If fewer than
        ``gpus`` are free (the node's GPUs were partly re-allocated before
        the cordon landed), only the free portion is removed. The takes are
        clamped at zero so a cordon landing on an empty (or transiently
        inconsistent) pool is an exact no-op instead of silently *adding*
        capacity — repeated cordon/uncordon cycles must round-trip."""
        take_r = max(0, min(gpus, self.free_reserved))
        take_s = max(0, min(gpus - take_r, self.free_spare))
        self.free_reserved -= take_r
        self.free_spare -= take_s
        return take_r, take_s

    def uncordon(self, take_r: int, take_s: int) -> None:
        """Return GPUs removed by :meth:`cordon` (node repaired)."""
        self.free_reserved += take_r
        self.free_spare += take_s

    # -- elastic resize (diagnosis-driven recovery, repro.cluster.replay) ---

    def release_partial(self, job: JobRecord, gpus: int) -> tuple[int, int]:
        """Detach ``gpus`` GPUs from ``job``'s live allocation *without*
        returning them to the free pools — they leave the cluster with the
        job's cordoned node. Returns the (reserved, spare) split detached;
        hand it to :meth:`uncordon` at repair time (or :meth:`reacquire` to
        grow the job back). Spare-pool GPUs are shed first so the
        pretraining reservation recovers its quota at the repair."""
        kind, alloc_r, alloc_s = job._alloc              # type: ignore
        take_s = min(gpus, alloc_s)
        take_r = min(gpus - take_s, alloc_r)
        job._alloc = (kind, alloc_r - take_r, alloc_s - take_s)  # type: ignore
        return take_r, take_s

    def reacquire(self, job: JobRecord, take_r: int, take_s: int) -> None:
        """Grow ``job``'s live allocation by GPUs that come straight off a
        repaired node (the inverse of :meth:`release_partial`); the free
        pools are bypassed because the GPUs were never free."""
        kind, alloc_r, alloc_s = job._alloc              # type: ignore
        job._alloc = (kind, alloc_r + take_r, alloc_s + take_s)  # type: ignore

    def grow(self, job: JobRecord, gpus: int) -> tuple[int, int]:
        """Opportunistic elastic regrowth: grant up to ``gpus`` currently
        *free* GPUs to a running job's allocation (a shrunken job reclaiming
        width from the pool before its lender node repairs). Admission
        follows the reservation policy: a ``"hi"`` (reserved-quota)
        allocation draws reserved-then-spare; a ``"lo"`` (spare-pool)
        allocation may only grow from the spare pool, so regrowth can
        never eat into the pretraining reservation; a ``"be"`` revocable
        lease grows like it leased (spare first, then idle reserved —
        still reclaimable on demand). Returns the (reserved, spare) split
        granted, which is folded into ``job._alloc`` and comes back to the
        pools through the ordinary :meth:`finish`."""
        kind, alloc_r, alloc_s = job._alloc              # type: ignore
        if kind == "hi":
            take_r = max(0, min(gpus, self.free_reserved))
            take_s = max(0, min(gpus - take_r, self.free_spare))
        elif kind == "be":
            # a revocable lease regrows like it leased: spare first, then
            # idle reserved quota (still revocable, so it cannot hurt the
            # reservation — the quota reclaims it on demand)
            take_s = max(0, min(gpus, self.free_spare))
            take_r = max(0, min(gpus - take_s, self.free_reserved))
        else:
            take_r = 0
            take_s = max(0, min(gpus, self.free_spare))
        self.free_reserved -= take_r
        self.free_spare -= take_s
        job._alloc = (kind, alloc_r + take_r, alloc_s + take_s)  # type: ignore
        return take_r, take_s


def simulate_queue(jobs: list[JobRecord], total_gpus: int, *,
                   reserved_frac: float = 0.85, backfill: bool = False,
                   reject_impossible: bool = True) -> list[JobRecord]:
    """Fill ``queue_min`` on every job by replaying the trace.

    Delegates to the unified replay engine with failure injection disabled;
    see ``repro.cluster.replay`` for the dispatch mechanics and the
    ``backfill`` policy. Jobs that never start (e.g. demand exceeds the
    cluster) are marked with :data:`NEVER_STARTED`.
    """
    from repro.cluster.replay import ReplayConfig, replay_trace
    replay_trace(jobs, total_gpus, reserved_frac=reserved_frac,
                 config=ReplayConfig(injector=None, backfill=backfill,
                                     reject_impossible=reject_impossible))
    return jobs
