"""Slurm-like scheduler with pretraining quota reservation (§2.2 / §3.2).

The paper's policy: "the majority of resources [are] reserved for pretraining
jobs to minimize their queuing delays. Evaluation jobs are scheduled with a
lower priority, utilizing the limited spare resources." — which inverts the
classic finding that big jobs wait longest: here the small, short *eval*
jobs see the longest queueing delay.

Event-driven simulation over the generated job population. Two GPU pools:
a reserved pool admitting only high-priority types (pretrain/sft/mllm) and a
spare pool for everything (best-effort). Jobs that can't start queue FIFO
within their priority class.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Iterable, Optional

from repro.cluster.workload import JobRecord

HIGH_PRIORITY = ("pretrain", "sft", "mllm")


@dataclasses.dataclass
class ReservationScheduler:
    total_gpus: int
    reserved_frac: float = 0.85     # quota held for pretraining-class jobs

    def __post_init__(self):
        self.reserved = int(self.total_gpus * self.reserved_frac)
        self.spare = self.total_gpus - self.reserved
        self.free_reserved = self.reserved
        self.free_spare = self.spare

    def can_start(self, job: JobRecord) -> bool:
        if job.jtype in HIGH_PRIORITY:
            return job.gpus <= self.free_reserved + self.free_spare
        if job.gpus <= self.spare:
            return job.gpus <= self.free_spare
        # oversized best-effort job (wider than the whole spare pool):
        # allowed to borrow reserved capacity so it cannot wedge the queue
        return job.gpus <= self.free_reserved + self.free_spare

    def start(self, job: JobRecord) -> None:
        if job.jtype in HIGH_PRIORITY or job.gpus > self.spare:
            take_r = min(job.gpus, self.free_reserved)
            self.free_reserved -= take_r
            self.free_spare -= job.gpus - take_r
            job._alloc = ("hi", take_r, job.gpus - take_r)  # type: ignore
        else:
            self.free_spare -= job.gpus
            job._alloc = ("lo", 0, job.gpus)                # type: ignore

    def finish(self, job: JobRecord) -> None:
        _, r, s = job._alloc                                # type: ignore
        self.free_reserved += r
        self.free_spare += s


def simulate_queue(jobs: list[JobRecord], total_gpus: int, *,
                   reserved_frac: float = 0.85) -> list[JobRecord]:
    """Fill ``queue_min`` on every job by replaying the trace."""
    sched = ReservationScheduler(total_gpus, reserved_frac)
    # event heap: (time, seq, kind, job); kinds: 0=finish first, 1=arrive
    events: list[tuple[float, int, int, JobRecord]] = []
    seq = 0
    for j in jobs:
        heapq.heappush(events, (j.submit_min, seq, 1, j))
        seq += 1
    wait_hi: list[JobRecord] = []
    wait_lo: list[JobRecord] = []

    def try_start(now: float) -> None:
        nonlocal seq
        # high-priority first (reservation), then best-effort, both FIFO
        for q in (wait_hi, wait_lo):
            i = 0
            while i < len(q):
                j = q[i]
                if sched.can_start(j):
                    q.pop(i)
                    sched.start(j)
                    j.queue_min = now - j.submit_min
                    heapq.heappush(events,
                                   (now + j.duration_min, seq, 0, j))
                    seq += 1
                else:
                    # FIFO head-of-line: don't let later jobs jump the queue
                    break
            # (only the head blocks; backfill is intentionally off — the
            #  paper's eval delay comes exactly from this HoL behaviour)

    while events:
        now, _, kind, job = heapq.heappop(events)
        if kind == 0:
            sched.finish(job)
        else:
            (wait_hi if job.jtype in HIGH_PRIORITY else wait_lo).append(job)
        try_start(now)
    return jobs
