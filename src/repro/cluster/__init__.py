"""Simulated datacenter: workload generator, scheduler, failure-aware trace
replay, trace analysis (§3 + §5)."""
from repro.cluster.workload import (BEST_EFFORT_TYPES, JobRecord,
                                    RequestRecord, WorkloadSpec, KALOS,
                                    SEREN, generate_jobs, generate_requests)
from repro.cluster.scheduler import (NEVER_STARTED, ReservationScheduler,
                                     simulate_queue)
from repro.cluster.failures import (DEFAULT_TAXONOMY, QUOTA_RECLAIM, SERVE,
                                    SERVING_TAXONOMY, FailureInjector,
                                    ReplayFailureClass,
                                    synthesize_failure_log)
from repro.cluster.replay import (DiagnosisLoop, NodeLedger, ReplayConfig,
                                  ReplayResult, replay_trace)
from repro.cluster.serve_replay import (ServeReplayConfig, ServeReplayResult,
                                        replay_requests)
from repro.cluster.analysis import (head_delay_stats, placement_stats,
                                    pool_stats, recovery_stats,
                                    serving_fault_stats, trace_summary)

__all__ = ["JobRecord", "WorkloadSpec", "KALOS", "SEREN", "generate_jobs",
           "BEST_EFFORT_TYPES", "RequestRecord", "generate_requests",
           "ServeReplayConfig", "ServeReplayResult", "replay_requests",
           "ReservationScheduler", "simulate_queue", "NEVER_STARTED",
           "FailureInjector", "ReplayFailureClass", "DEFAULT_TAXONOMY",
           "SERVING_TAXONOMY", "SERVE", "QUOTA_RECLAIM",
           "synthesize_failure_log", "DiagnosisLoop",
           "NodeLedger", "ReplayConfig", "ReplayResult", "replay_trace",
           "head_delay_stats", "placement_stats", "pool_stats",
           "recovery_stats", "serving_fault_stats", "trace_summary"]
