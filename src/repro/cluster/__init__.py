"""Simulated datacenter: workload generator, scheduler, trace analysis (§3)."""
from repro.cluster.workload import (JobRecord, WorkloadSpec, KALOS, SEREN,
                                    generate_jobs)
from repro.cluster.scheduler import ReservationScheduler, simulate_queue
from repro.cluster.analysis import trace_summary

__all__ = ["JobRecord", "WorkloadSpec", "KALOS", "SEREN", "generate_jobs",
           "ReservationScheduler", "simulate_queue", "trace_summary"]
