"""Failure taxonomy + injector for cluster trace replay (paper §5, Table 2/3).

The paper characterizes three broad interruption classes that dominate an
LLM datacenter's lost GPU time; this module models their *incidence*, while
``repro.core.ft.events`` models their *log signatures*:

  * ``hardware``  — GPU/NVLink/ECC faults. The failed node must be located
    (two-round allgather sweep, §6.1 design 3) and cordoned; its GPUs leave
    the schedulable pool until repaired. Table 3: NVLinkError alone accounts
    for 30% of lost GPU time with a median time-to-failure of 155 min.
  * ``infra``     — network / storage / connection faults (IB flaps, PFS
    brownouts). The job dies and restarts, but the node is healthy, so no
    cordon: only rollback + restart cost is paid.
  * ``preemption``— best-effort jobs evicted when the pretraining quota
    reclaims spare capacity (§3.2). No hardware involvement; the job simply
    loses progress since its last checkpoint and requeues.

Incidence is an inhomogeneous-in-type, homogeneous-in-time Poisson process:
each class carries a per-GPU-hour hazard rate per job type, so a 1024-GPU
pretraining job fails ~500x more often than a 2-GPU evaluation — exactly the
paper's "failures concentrate in pretraining" observation (§5.1). Rates
below are calibrated so a Kalos-sized six-month trace sees O(Table 3's ~350
infra+hardware incidents) when replayed at full scale.

``FailureInjector.draw`` samples the next time-to-failure for one execution
attempt of a job. It is deliberately *per-attempt*: a restarted job re-rolls
its hazard, matching the memoryless exponential model.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Optional, Sequence

HARDWARE, INFRA, PREEMPTION = "hardware", "infra", "preemption"

# the jtype under which the serving replay draws failures: serving *is* the
# reservation (§3.2), so the PREEMPTION class is disabled for it below —
# only the physical §5 hazards (hardware, infra) strike serving instances
SERVE = "serve"

# the *emergent* counterpart of the injected PREEMPTION class: a best-effort
# job preempted because dispatch or elastic regrowth reclaimed its revocable
# lease (repro.cluster.replay). Kept as a separate ledger key so the
# injected incidence model and the scheduling policy reconcile side by side
# in ``lost_gpu_hours_by_class``.
QUOTA_RECLAIM = "quota_reclaim"

# job types eligible for periodic checkpointing (the paper's asynchronous
# checkpoint subsystem, §6.1 design 1, targets long pretraining-class jobs;
# short eval/debug jobs restart from scratch)
CHECKPOINTED_TYPES = ("pretrain", "sft", "mllm")


@dataclasses.dataclass(frozen=True)
class ReplayFailureClass:
    """One §5 interruption class as seen by the replay engine."""
    name: str                       # hardware | infra | preemption
    rate_per_gpu_hour: float        # base exponential hazard
    jtype_mult: dict                # per-jtype multiplier (0 disables)
    needs_cordon: bool = False      # run the two-round sweep + cordon a node
    restart_overhead_min: float = 10.0   # diagnose + reschedule + re-init
    repair_min: float = 0.0         # cordon duration before GPUs return
    # Table-3 failure types (repro.core.ft.events.BY_NAME keys) whose log
    # templates an injected incident of this class synthesizes; empty means
    # the class has its own templates (scheduler preemption notices)
    log_failure_types: tuple = ()

    def rate_for(self, jtype: str) -> float:
        """Hazard in failures per GPU-hour for one job of ``jtype``."""
        return self.rate_per_gpu_hour * self.jtype_mult.get(jtype, 1.0)


# Defaults calibrated against Table 3 (restart_avg column for overheads;
# NVLink/CUDA/ECC TTF medians for the hardware hazard) and §5.2's "around
# two failures per day" at ~2.4k-GPU scale.
DEFAULT_TAXONOMY: tuple[ReplayFailureClass, ...] = (
    ReplayFailureClass(
        HARDWARE, rate_per_gpu_hour=6e-5,
        # evals are too short-lived to hit uncorrectable hardware faults
        jtype_mult={"evaluation": 0.1, "other": 0.2},
        needs_cordon=True,
        restart_overhead_min=30.0,      # Table 3 NVLink restart avg 95.6 min
        repair_min=24 * 60.0,           # node drained for ~a day
        log_failure_types=("NVLinkError", "CUDAError", "ECCError",
                           "NodeFailure", "NetworkError")),
    ReplayFailureClass(
        INFRA, rate_per_gpu_hour=1.2e-4,
        jtype_mult={"evaluation": 0.3},
        needs_cordon=False,
        restart_overhead_min=10.0,
        # node-healthy faults: auxiliary services, remote storage — the
        # diagnosis pipeline should call these transient/auto-recoverable
        log_failure_types=("ConnectionError", "S3StorageError")),
    ReplayFailureClass(
        PREEMPTION, rate_per_gpu_hour=2.0e-4,
        # only best-effort (spare-pool) types can be preempted — the
        # reservation shields pretraining-class jobs (§3.2)
        jtype_mult={"pretrain": 0.0, "sft": 0.0, "mllm": 0.0, SERVE: 0.0},
        needs_cordon=False,
        restart_overhead_min=2.0),
)

# the serving fleet's view of the taxonomy: preemption excluded outright
# (serving is the reservation that *causes* preemptions, it never suffers
# them). A DEFAULT_TAXONOMY injector is equally safe for jtype ``SERVE`` —
# preemption's per-jtype multiplier is 0.0 there, and zero-rate classes are
# skipped without consuming RNG — so both spellings draw identically.
SERVING_TAXONOMY: tuple[ReplayFailureClass, ...] = tuple(
    c for c in DEFAULT_TAXONOMY if c.name != PREEMPTION)

# scheduler-initiated eviction notices (paper §3.2 quota reclamation) — the
# preemption class has no Table-3 root cause, so it carries its own log
# tail. Deliberately *not* error-shaped: a preemption is an orderly
# eviction, and its notice must not collide with the NodeFailure log
# signature ("slurmstepd: error: ... unexpectedly rebooted") or the
# diagnosis pipeline would cordon a healthy node.
PREEMPTION_LOG_TEMPLATES: tuple[str, ...] = (
    "slurmstepd: *** JOB {d} CANCELLED AT {d}:{d} DUE TO PREEMPTION ***",
    "INFO [sched] best-effort quota reclaimed: reservation pretrain-{d} expanding",
    "srun: Force Terminated job {d} (preempted by higher-priority reservation)",
)


def synthesize_failure_log(cls: ReplayFailureClass, *, seed: int = 0,
                           n_normal: int = 24, flavor: str = "train"
                           ) -> tuple[list[str], Optional[str]]:
    """Synthesize the runtime-log snippet an injected ``cls`` incident would
    leave behind: init banner + metric spam + a cascaded failure tail drawn
    from the class's Table-3 template pool (``repro.core.ft.events``).

    Returns ``(lines, truth)`` where ``truth`` is the ground-truth Table-3
    failure name (``None`` for scheduler preemptions, which have no Table-3
    root cause). The replay engine feeds these through the §6.1 diagnosis
    pipeline and lets the verdict pick the recovery policy.
    ``flavor="serve"`` emits an inference engine's banner/heartbeat instead
    of a trainer's (same failure tails, same RNG consumption).
    """
    from repro.core.ft.events import BY_NAME, fill_template, generate_log
    rng = random.Random(seed ^ 0xFA11)
    if cls.log_failure_types:
        weights = [BY_NAME[n].num for n in cls.log_failure_types]
        truth = rng.choices(cls.log_failure_types, weights=weights, k=1)[0]
        return (generate_log(BY_NAME[truth], seed=rng.randrange(2 ** 30),
                             n_normal=n_normal, flavor=flavor), truth)
    lines = generate_log(None, seed=rng.randrange(2 ** 30),
                         n_normal=n_normal, flavor=flavor)
    for t in PREEMPTION_LOG_TEMPLATES:
        lines.append(fill_template(t, rng))
    return lines, None

BY_CLASS = {c.name: c for c in DEFAULT_TAXONOMY}


class FailureInjector:
    """Seeded sampler of per-attempt failure times for the replay engine.

    ``draw(jtype, gpus, remaining_min)`` returns ``(ttf_min, cls)`` for the
    earliest injected failure within the attempt's remaining runtime, or
    ``None`` if the attempt completes cleanly. Sampling is O(#classes) per
    start event, which keeps million-job replays cheap.
    """

    def __init__(self, taxonomy: Sequence[ReplayFailureClass] = DEFAULT_TAXONOMY,
                 *, seed: int = 0, rate_scale: float = 1.0):
        self.taxonomy = tuple(taxonomy)
        self.rate_scale = rate_scale
        self._rng = random.Random(seed ^ 0x5EED)
        # draw() runs once per execution attempt — the hottest injector
        # path of a million-job replay — so the per-class ``rate_for``
        # lookups are cached per jtype. The cached value is exactly
        # ``rate_for``'s product, so ``rate * gpus * rate_scale`` below
        # rounds identically to the uncached expression (bit-exact replay
        # contract: the RNG consumption pattern must not change either,
        # which is why zero-rate classes are still skipped *without*
        # drawing).
        self._rates_by_jtype: dict = {}

    def _rates(self, jtype: str) -> tuple:
        table = tuple((cls.rate_for(jtype), cls) for cls in self.taxonomy)
        self._rates_by_jtype[jtype] = table
        return table

    def rates_for(self, jtype: str) -> tuple:
        """Cached ``(rate_for(jtype), cls)`` pairs — the replay engine
        inlines :meth:`draw`'s loop into its start path and reads the
        per-jtype table through this accessor."""
        table = self._rates_by_jtype.get(jtype)
        if table is None:
            table = self._rates(jtype)
        return table

    def draw(self, jtype: str, gpus: int, remaining_min: float
             ) -> Optional[tuple[float, ReplayFailureClass]]:
        # running (best_t, best_cls) scalars instead of a tuple per
        # candidate: seeding best_t with remaining_min folds the
        # ``ttf < remaining and ttf < best`` pair into one compare, with
        # identical winners (the first strict improvement wins either way)
        best_t = remaining_min
        best_cls = None
        rand = self._rng.random
        log = math.log
        scale = self.rate_scale
        table = self._rates_by_jtype.get(jtype)
        if table is None:
            table = self._rates(jtype)
        for rate, cls in table:
            rate_hr = rate * gpus * scale
            if rate_hr <= 0.0:
                continue
            u = rand()
            if u < 1e-300:
                u = 1e-300
            # exponential TTF in minutes
            ttf = -log(u) / rate_hr * 60.0
            if ttf < best_t:
                best_t = ttf
                best_cls = cls
        if best_cls is None:
            return None
        return best_t, best_cls
