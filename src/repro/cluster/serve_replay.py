"""Serving-cluster replay: the inference counterpart of ``replay_trace``.

One event loop drives a disaggregated serving fleet through a request
trace (``workload.generate_requests``) at Seren scale — 1M+ requests in
seconds of wall time — with the mechanisms the distributed-LLM-serving
literature treats as defining (continuous batching, prefill/decode
disaggregation, paged KV with eviction) modeled explicitly:

  * **Prefill fleet** — ``n_prefill`` instances of ``gpus_per_instance``
    GPUs each, a FIFO k-server queue: a request's prompt pass (and any
    KV-recompute pass after an eviction) takes ``prompt_tokens`` over the
    instance's modeled token throughput. TTFT is arrival → first prefill
    completion, queueing included.
  * **Decode fleet** — ``n_decode`` instances running continuous batching
    with per-event admission: an instance decodes one token per resident
    request per step, and the step time is an affine function of batch
    occupancy (``ServeRates.step_time_s``), so all residents share a
    common per-slot progress clock (``vtime``, in tokens). Membership
    changes (admission, completion, eviction) reprice the whole batch at
    once — the same epoch-stamped lazy-deletion-heap pattern as the
    training replay, O(log n) per membership change instead of O(tokens).
  * **Paged KV** — each decode instance owns ``kv_pages`` pages of
    ``page_tokens`` tokens. Residents' KV grows one token per decoded
    token; the engine enforces the *conservative page bound*
    ``sum_i ceil(tokens_i / page) <= tokens_total / page + batch`` so
    pages can never exceed capacity. When growth exhausts the bound, the
    newest resident is evicted LIFO: its generated tokens are kept, its
    KV is lost, and it re-enters the *prefill* queue for a recompute pass
    over ``prompt + decoded`` tokens before decoding resumes — the
    eviction/recompute accounting the property tests pin.
  * **Pricing** — all rates come from ``launch.cost_model``'s
    prefill/decode ``CostCell``s (``CostModel.serve_rates``): committed
    dry-run artifacts when present, the deterministic analytic fallback
    otherwise, same provenance discipline as the roofline replay.
  * **Fault injection (§5)** — with a ``failures.FailureInjector``
    attached, the hardware/infra taxonomy strikes serving instances
    (preemption excluded: serving *is* the reservation). A failed
    instance synthesizes a per-class serving log, the ``core/ft``
    ``DiagnosisLoop`` reads it, and the verdict picks recovery: hardware
    → cordon the instance's nodes on the ``NodeLedger`` and respawn on
    free capacity (after REPAIR if the fleet is capacity-tight);
    transient infra → in-place restart after the class's overhead.
    In-flight decode residents lose their KV and retry through the
    prefill fleet (prompt + already-generated tokens, bounded retry
    budget with exponential backoff, then counted dropped), extending
    the conservation law to
    ``evicted_tokens + killed_tokens == recompute_prefill_tokens``.
    While any instance is down, admission runs in graceful-degradation
    mode: ``max_batch``/headroom tighten to protect tail latency, the
    head-of-line skip window widens, and excess queue growth is shed
    with per-class accounting (``summary()["faults"]``).

The fleet is stood up through a :class:`~repro.cluster.replay.NodeLedger`
(instances allocate concrete node GPUs), so serving placement shares the
training replay's physical accounting and the stretch goal of
co-scheduling both on one ledger stays a config change, not a rewrite.

Determinism contract: no wall clock, no unseeded RNG — the trace carries
the workload's randomness and the injector/diagnosis draws come from
their own seeded streams (``seed ^ 0x5EED`` / ``seed ^ 0xD1A6``), so
failure draws never perturb the trace generator's burst/diurnal/token
streams; flat heap tuples ordered by ``(time, seq)``; the module is
on replint's hot list, so every class is slotted. ``summary()`` follows
the ``ReplayResult.summary()`` schema conventions (see README "Result
schemas"): stable top-level keys, plain-scalar leaves, memoized and
deep-copied so repeated calls are side-effect-free. With no injector and
``hol_skip_window=0`` (the defaults) the engine is bit-exact with the
pre-fault engine — the committed ``serve_20k`` golden pins it.

  >>> from repro.cluster import (ServeReplayConfig, generate_requests,
  ...                            replay_requests)
  >>> reqs = generate_requests(200_000, seed=0, horizon_min=300.0)
  >>> res = replay_requests(reqs, ServeReplayConfig())
  >>> res.summary()["slo"]["joint_attainment"]  # doctest: +SKIP
"""
from __future__ import annotations

import copy
import dataclasses
import heapq
import math
from collections import deque
from typing import Optional

import numpy as np

from repro.cluster.failures import SERVE
from repro.cluster.replay import (VERDICT_HARDWARE, DiagnosisLoop,
                                  NodeLedger)

# event kinds (flat heap tuples: (t_min, seq, kind, payload, epoch))
_P_DONE, _D_STEP, _D_EVICT = 0, 1, 2
# fault-injection kinds: instance failure, instance (re)start, node
# repair, and a killed request's backoff-delayed prefill retry
_I_FAIL, _I_UP, _I_REPAIR, _RETRY = 3, 4, 5, 6
_EPS = 1e-9


@dataclasses.dataclass(frozen=True, slots=True)
class ServeReplayConfig:
    """Frozen knob set for one serving replay.

    Fleet shape: ``n_prefill + n_decode`` instances of
    ``gpus_per_instance`` GPUs are allocated node-locally out of
    ``total_gpus`` (``node_gpus`` per node) through a ``NodeLedger``.
    ``max_batch`` caps continuous-batching occupancy per decode instance;
    ``kv_pages`` * ``page_tokens`` is its KV capacity. Admission requires
    ``admit_headroom_tokens`` of growth room beyond the request's resident
    KV so a fresh admission cannot trigger an instant eviction; an
    eviction frees at least ``evict_headroom_tokens``. SLO targets are
    what ``summary()['slo']`` grades attainment against. ``cost_model``
    is a ``launch.cost_model.CostModel`` (or anything with a
    ``serve_rates(arch, gpus)``); ``None`` loads the committed dry-run
    artifacts with analytic fallback, exactly like the training replay's
    roofline mode.

    Fault knobs (all inert at their defaults — the no-injection replay is
    bit-exact with the pre-fault engine): ``injector`` is a
    ``failures.FailureInjector`` drawing per-attempt §5 hazards under the
    ``SERVE`` jtype; ``diagnosis`` an optional pre-built ``DiagnosisLoop``
    (``None`` builds a serving-flavored one with ``diagnosis_variants``
    log variants). A killed request retries through prefill up to
    ``retry_budget`` times with ``retry_backoff_min * 2**(retries-1)``
    backoff, then counts dropped. ``hol_skip_window`` lets admission scan
    past a blocked FIFO head (0 = strict FIFO); a head is never skipped
    more than ``hol_skip_limit`` times (starvation bound). While any
    instance is down, the effective batch cap shrinks to
    ``max_batch * degraded_max_batch_frac``, the admission headroom
    stretches by ``degraded_headroom_mult``, the skip window widens to at
    least ``degraded_hol_skip``, and arrivals beyond
    ``degraded_shed_queue`` pending requests are shed (0 disables)."""
    total_gpus: int = 256
    node_gpus: int = 8
    n_prefill: int = 4
    n_decode: int = 16
    gpus_per_instance: int = 8
    max_batch: int = 64
    kv_pages: int = 4096
    page_tokens: int = 16
    admit_headroom_tokens: int = 256
    evict_headroom_tokens: int = 1024
    arch: str = "internlm-7b"
    cost_model: Optional[object] = None
    ttft_slo_s: float = 10.0
    tpot_slo_ms: float = 300.0
    # -- fault injection + graceful degradation (inert by default) ----------
    injector: Optional[object] = None
    diagnosis: Optional[object] = None
    diagnosis_variants: int = 8
    retry_budget: int = 3
    retry_backoff_min: float = 0.25
    hol_skip_window: int = 0
    hol_skip_limit: int = 64
    degraded_max_batch_frac: float = 0.5
    degraded_headroom_mult: float = 2.0
    degraded_hol_skip: int = 8
    degraded_shed_queue: int = 4096


class _DecodeInstance:
    """Continuous-batching state for one decode instance.

    ``vtime`` is the shared progress clock in *tokens per resident*: every
    resident decodes at the same one-token-per-step rate, so a request
    admitted at ``vtime`` v0 with r tokens remaining finishes when
    ``vtime`` reaches v0 + r. Resident KV is the closed form
    ``static + b * vtime - admit_vsum`` (``static`` sums residents'
    tokens-at-admission, ``admit_vsum`` their admission vtimes), which
    keeps token accounting exact under float accumulation — nothing
    drifts because nothing is incrementally summed."""
    __slots__ = ("idx", "b", "vtime", "t0", "rate", "static", "admit_vsum",
                 "epoch", "ends", "batch", "sched_fv", "occ", "peak_bound",
                 "down")

    def __init__(self, idx: int) -> None:
        self.idx = idx
        self.b = 0                 # current batch occupancy
        self.vtime = 0.0           # tokens decoded per resident since start
        self.t0 = 0.0              # wall minute of the last advance
        self.rate = 0.0            # d vtime / d minute at current occupancy
        self.static = 0.0          # sum of residents' tokens at admission
        self.admit_vsum = 0.0      # sum of residents' admission vtimes
        self.epoch = 0             # invalidates scheduled D_STEP/D_EVICT
        self.ends: list = []       # (finish_vtime, seq, req, res) min-heap
        self.batch: dict = {}      # req_id -> req, insertion-ordered (LIFO)
        self.sched_fv = 0.0        # finish_vtime the live D_STEP targets
        self.occ = 0.0             # time-integrated occupancy (batch-min)
        self.peak_bound = 0.0      # max conservative page bound observed
        self.down = False          # failed and not yet recovered


class _FaultClassStats:
    """Per-failure-class serving impact ledger (``summary()["faults"]``)."""
    __slots__ = ("failures", "prefill", "decode", "retries", "drops",
                 "shed", "killed_tokens", "lost_goodput_tokens",
                 "slo_ttft", "slo_tpot", "downtime_min", "verdicts")

    def __init__(self) -> None:
        self.failures = 0          # injected incidents of this class
        self.prefill = 0           # ... that hit a prefill instance
        self.decode = 0            # ... that hit a decode instance
        self.retries = 0           # killed requests sent back to prefill
        self.drops = 0             # retry budget exhausted
        self.shed = 0              # arrivals shed while this class degraded
        self.killed_tokens = 0     # KV/work tokens destroyed then recomputed
        self.lost_goodput_tokens = 0   # prompt+decoded work of drops, wasted
        self.slo_ttft = 0          # TTFT SLO violations attributed here
        self.slo_tpot = 0          # TPOT SLO violations attributed here
        self.downtime_min = 0.0    # summed instance-down wall minutes
        self.verdicts: dict = {}   # diagnosis verdict -> count


@dataclasses.dataclass(slots=True)
class ServeReplayResult:
    """Outcome of one serving replay; ``summary()`` is the stable API."""
    requests: list
    config: ServeReplayConfig
    events_processed: int = 0
    completed: int = 0
    rejected_ids: list = dataclasses.field(default_factory=list)
    stale_events: int = 0
    # -- token conservation ledger (see tests/test_serve_replay) ------------
    decoded_tokens: int = 0        # decode tokens produced (never re-decoded)
    prefill_tokens: int = 0        # all tokens prefilled, recomputes included
    recompute_prefill_tokens: int = 0   # prefill side of eviction recovery
    evictions: int = 0
    evicted_tokens: int = 0        # KV tokens dropped by paging pressure
    killed_tokens: int = 0         # KV/work tokens destroyed by failures
    #   conservation: evicted_tokens + killed_tokens
    #              == recompute_prefill_tokens
    # -- pressure / occupancy ------------------------------------------------
    occ_time_min: float = 0.0      # sum over instances of integral(batch dt)
    peak_batch: int = 0
    kv_peak_pages: float = 0.0     # max conservative page bound, any instance
    admit_wait_sum_min: float = 0.0
    admit_wait_n: int = 0
    horizon_min: float = 0.0       # last event timestamp
    nodes_used: int = 0
    rates_source: str = ""
    rates_prefill_tok_s: float = 0.0
    rates_decode_fixed_s: float = 0.0
    rates_decode_per_seq_s: float = 0.0
    # -- fault injection (populated only when config.injector is set) --------
    faults_injected: int = 0
    retries_total: int = 0
    dropped_ids: list = dataclasses.field(default_factory=list)
    shed_ids: list = dataclasses.field(default_factory=list)
    hol_skips: int = 0             # head-of-line skips (also sans injector)
    degraded_min: float = 0.0      # wall minutes with >=1 instance down
    respawns: int = 0              # hardware-verdict re-allocations
    inplace_restarts: int = 0      # transient-verdict in-place restarts
    cordoned_nodes: int = 0
    fault_stats: Optional[dict] = dataclasses.field(
        default=None, repr=False)  # class name -> _FaultClassStats
    # memoized summary() tree (same discipline as ReplayResult: built once,
    # deep-copied on every return so callers cannot mutate the memo)
    _summary: Optional[dict] = dataclasses.field(
        default=None, repr=False, compare=False)

    def summary(self) -> dict:
        """JSON-ready serving scorecard: TTFT/TPOT tails, SLO attainment,
        batch occupancy and KV pressure — the serving analogue of
        ``ReplayResult.summary()`` and bound by the same schema contract
        (README "Result schemas"). With fault injection enabled the tree
        additionally carries a ``"faults"`` section (the serving-side
        analogue of ``recovery_stats``); without an injector the tree is
        unchanged, keeping the no-injection goldens bit-exact."""
        if self._summary is None:
            self._summary = self._build_summary()
        return copy.deepcopy(self._summary)

    def _build_summary(self) -> dict:
        cfg = self.config
        # one pass: per finished request collect TTFT (s) and, when it
        # decoded at all, TPOT (ms); out==1 requests pass the TPOT half of
        # the joint SLO vacuously
        ttft, tpot, tpot_padded = [], [], []
        for r in self.requests:
            if not math.isfinite(r.done_min):
                continue
            ttft.append(r.ttft_min * 60.0)
            if r.out_tokens > 1:
                ms = ((r.done_min - r.ttft_min)
                      / (r.out_tokens - 1) * 60_000.0)
                tpot.append(ms)
                tpot_padded.append(ms)
            else:
                tpot_padded.append(0.0)
        ttft_s = np.asarray(ttft, dtype=np.float64)
        tpot_ms = np.asarray(tpot, dtype=np.float64)
        horizon = self.horizon_min
        decode_gpu_min = cfg.n_decode * max(horizon, _EPS)
        n = len(self.requests)
        ttft_ok = tpot_ok = joint = 0.0
        if ttft_s.size:
            ttft_hit = ttft_s <= cfg.ttft_slo_s
            tpot_hit = (np.asarray(tpot_padded, dtype=np.float64)
                        <= cfg.tpot_slo_ms)
            ttft_ok = float(ttft_hit.mean())
            tpot_ok = float((tpot_ms <= cfg.tpot_slo_ms).mean()) \
                if tpot_ms.size else 1.0
            joint = float((ttft_hit & tpot_hit).mean())
        out = {
            "n_requests": n,
            "completed": self.completed,
            "rejected": len(self.rejected_ids),
            "events_processed": self.events_processed,
            "stale_events": self.stale_events,
            "horizon_min": float(horizon),
            "ttft": _tail_s(ttft_s),
            "tpot": _tail_ms(tpot_ms),
            "slo": {
                "ttft_target_s": float(cfg.ttft_slo_s),
                "tpot_target_ms": float(cfg.tpot_slo_ms),
                "ttft_attainment": ttft_ok,
                "tpot_attainment": tpot_ok,
                "joint_attainment": joint,
            },
            "throughput": {
                "decoded_tokens": self.decoded_tokens,
                "prefill_tokens": self.prefill_tokens,
                "decoded_tok_per_s": float(
                    self.decoded_tokens / max(horizon * 60.0, _EPS)),
                "requests_per_min": float(n / max(horizon, _EPS)),
            },
            "batch": {
                "mean_occupancy": float(self.occ_time_min / decode_gpu_min),
                "peak_occupancy": self.peak_batch,
                "max_batch": cfg.max_batch,
                "admit_wait_mean_min": float(
                    self.admit_wait_sum_min / max(self.admit_wait_n, 1)),
            },
            "kv": {
                "pages_per_instance": cfg.kv_pages,
                "page_tokens": cfg.page_tokens,
                "peak_pages": float(self.kv_peak_pages),
                "peak_pages_frac": float(
                    self.kv_peak_pages / max(cfg.kv_pages, 1)),
                "evictions": self.evictions,
                "evicted_tokens": self.evicted_tokens,
                "recompute_prefill_tokens": self.recompute_prefill_tokens,
            },
            "fleet": {
                "total_gpus": cfg.total_gpus,
                "n_prefill": cfg.n_prefill,
                "n_decode": cfg.n_decode,
                "gpus_per_instance": cfg.gpus_per_instance,
                "nodes_used": self.nodes_used,
            },
            "cost_model": {
                "arch": cfg.arch,
                "source": self.rates_source,
                "prefill_tok_s": float(self.rates_prefill_tok_s),
                "decode_fixed_ms": float(
                    self.rates_decode_fixed_s * 1e3),
                "decode_per_seq_ms": float(
                    self.rates_decode_per_seq_s * 1e3),
            },
        }
        if self.fault_stats is not None:
            from repro.cluster.analysis import serving_fault_stats
            out["faults"] = serving_fault_stats(self)
        return out


def _tail_s(arr: np.ndarray, qs=(50, 95, 99)) -> dict:
    if arr.size == 0:
        return {f"p{q}_s": 0.0 for q in qs} | {"n": 0, "mean_s": 0.0}
    pcts = np.percentile(arr, qs)
    out = {f"p{q}_s": float(v) for q, v in zip(qs, pcts)}
    out["n"] = int(arr.size)
    out["mean_s"] = float(arr.mean())
    return out


def _tail_ms(arr: np.ndarray, qs=(50, 95, 99)) -> dict:
    if arr.size == 0:
        return {f"p{q}_ms": 0.0 for q in qs} | {"n": 0, "mean_ms": 0.0}
    pcts = np.percentile(arr, qs)
    out = {f"p{q}_ms": float(v) for q, v in zip(qs, pcts)}
    out["n"] = int(arr.size)
    out["mean_ms"] = float(arr.mean())
    return out


def replay_requests(requests: list,
                    config: Optional[ServeReplayConfig] = None
                    ) -> ServeReplayResult:
    """Replay a request trace through the serving fleet; see module doc.

    ``requests`` are :class:`~repro.cluster.workload.RequestRecord`s; the
    engine writes ``ttft_min`` / ``done_min`` / ``decoded`` / ``evictions``
    / ``retries`` into them (arrival-relative minutes) and returns the
    result object. The trace need not be pre-sorted."""
    cfg = config if config is not None else ServeReplayConfig()
    if cfg.n_prefill < 1 or cfg.n_decode < 1:
        raise ValueError("need at least one prefill and one decode instance")
    need = (cfg.n_prefill + cfg.n_decode) * cfg.gpus_per_instance
    if need > cfg.total_gpus:
        raise ValueError(
            f"fleet needs {need} GPUs but total_gpus={cfg.total_gpus}")
    if cfg.kv_pages * cfg.page_tokens <= cfg.admit_headroom_tokens:
        raise ValueError("KV capacity below the admission headroom")
    if cfg.retry_budget < 0 or cfg.retry_backoff_min <= 0.0:
        raise ValueError("retry_budget must be >= 0 with positive backoff")
    if cfg.hol_skip_window < 0 or cfg.hol_skip_limit < 1:
        raise ValueError("hol_skip_window >= 0 and hol_skip_limit >= 1")
    if not 0.0 < cfg.degraded_max_batch_frac <= 1.0 \
            or cfg.degraded_headroom_mult < 1.0:
        raise ValueError("degraded_max_batch_frac in (0, 1] and "
                         "degraded_headroom_mult >= 1 required")

    cm = cfg.cost_model
    if cm is None:
        from repro.launch.cost_model import CostModel
        cm = CostModel.load(archs=(cfg.arch,))
    rates = cm.serve_rates(cfg.arch, cfg.gpus_per_instance)
    fixed_s = rates.decode_fixed_s
    per_seq_s = rates.decode_per_seq_s
    prefill_min_per_tok = 1.0 / (rates.prefill_tok_s * 60.0)

    # node-local placement: every instance allocates concrete node GPUs
    n_nodes = max(cfg.total_gpus // cfg.node_gpus, 1)
    ledger = NodeLedger(n_nodes, cfg.node_gpus, cfg.total_gpus)
    n_prefill = cfg.n_prefill
    gpi = cfg.gpus_per_instance
    placements = [ledger.alloc(gpi)
                  for _ in range(n_prefill + cfg.n_decode)]
    nodes_used = len({node for pl in placements for node in pl if node >= 0})

    res = ServeReplayResult(requests=requests, config=cfg,
                            nodes_used=nodes_used,
                            rates_source=rates.source,
                            rates_prefill_tok_s=rates.prefill_tok_s,
                            rates_decode_fixed_s=fixed_s,
                            rates_decode_per_seq_s=per_seq_s)

    page = cfg.page_tokens
    cap_pages = cfg.kv_pages
    max_batch = cfg.max_batch
    admit_headroom = cfg.admit_headroom_tokens
    evict_headroom = cfg.evict_headroom_tokens
    # a request whose full resident KV cannot fit an otherwise-empty
    # instance under the conservative bound can never be served
    max_resident = (cap_pages - 1) * page - admit_headroom

    insts = [_DecodeInstance(i) for i in range(cfg.n_decode)]
    up_insts = insts            # admission candidates (rebuilt on fail/up)
    # prefill fleet: FIFO k-server queue as a (free_at, idx) heap
    pf = [(0.0, i) for i in range(n_prefill)]
    heapq.heapify(pf)

    events: list = []
    heappush = heapq.heappush
    heappop = heapq.heappop
    pending: deque = deque()    # (ready_min, req) awaiting decode admission
    seq = 0                     # heap tiebreak counter

    order = sorted(range(len(requests)),
                   key=lambda i: requests[i].arrival_min)
    arrivals = [requests[i] for i in order]
    n_arr = len(arrivals)

    # running counters (folded into res after the loop)
    completed = 0
    decoded_tokens = 0
    prefill_tokens = 0
    recompute_prefill_tokens = 0
    evictions = 0
    evicted_tokens = 0
    stale = 0
    admit_wait_sum = 0.0
    admit_wait_n = 0
    peak_batch = 0
    events_processed = 0
    hol_skips = 0

    # -- fault-injection state (all inert when no injector is attached) -----
    inj = cfg.injector
    injecting = inj is not None
    dloop = cfg.diagnosis
    if injecting and dloop is None:
        dloop = DiagnosisLoop(n_variants=cfg.diagnosis_variants,
                              flavor="serve")
    stats: dict = {}            # class name -> _FaultClassStats
    killed_tokens = 0
    retries_total = 0
    faults_injected = 0
    respawns = 0
    inplace_restarts = 0
    cordoned_nodes = 0
    degraded_min = 0.0
    degraded_since = 0.0
    # (is_decode, idx) -> (class name, down-since minute); insertion order
    # makes the *oldest* outstanding failure the degradation episode's
    # attribution cause
    active_faults: dict = {}
    # per prefill instance: authoritative free_at (a failed instance's heap
    # entry goes stale by mismatch), and the in-flight passes it would lose
    pf_free = [0.0] * n_prefill
    pf_sched: list = [dict() for _ in range(n_prefill)]
    pf_blocked: deque = deque()     # passes waiting for any prefill instance
    placement_dead = [False] * len(placements)  # hardware-killed allocation
    pending_repairs: list = []      # outstanding _I_REPAIR fire times
    retry_budget = cfg.retry_budget
    retry_backoff = cfg.retry_backoff_min
    shed_queue = cfg.degraded_shed_queue
    hol_skip_limit = cfg.hol_skip_limit
    # effective (possibly degraded) admission knobs
    eff_max_batch = max_batch
    eff_headroom = admit_headroom
    eff_skip = cfg.hol_skip_window
    inject_until = arrivals[-1].arrival_min if arrivals else 0.0

    def start_prefill(req, now: float, tokens: int, recompute: bool) -> None:
        nonlocal seq, prefill_tokens, recompute_prefill_tokens
        while True:
            if not pf:
                # every prefill instance is down: park the pass; _I_UP
                # re-dispatches the queue in FIFO order
                pf_blocked.append((req, tokens, recompute))
                return
            free_at, i = heappop(pf)
            if injecting and pf_free[i] != free_at:
                continue        # stale entry (instance failed or re-keyed)
            break
        start = free_at if free_at > now else now
        done = start + tokens * prefill_min_per_tok
        heappush(pf, (done, i))
        seq += 1
        heappush(events, (done, seq, _P_DONE, req, req._pfe))
        prefill_tokens += tokens
        if recompute:
            recompute_prefill_tokens += tokens
        if injecting:
            pf_free[i] = done
            pf_sched[i][req.req_id] = req
            req._pfi = i

    def advance(inst, now: float) -> None:
        dt = now - inst.t0
        b = inst.b
        if dt > 0.0:
            if b:
                inst.vtime += dt * inst.rate
                inst.occ += dt * b
            inst.t0 = now
        if b:
            bound = ((inst.static + b * inst.vtime - inst.admit_vsum)
                     / page + b)
            if bound > inst.peak_bound:
                inst.peak_bound = bound

    def reschedule(inst, now: float) -> None:
        nonlocal seq, stale
        ends = inst.ends
        while ends:
            fv, _s, req, r = ends[0]
            if req._res == r and req._inst == inst.idx:
                break
            heappop(ends)
            stale += 1
        b = inst.b
        if not b or not ends:
            return
        rate = inst.rate
        t_done = now + (ends[0][0] - inst.vtime) / rate
        free = ((cap_pages - b) * page
                - (inst.static + b * inst.vtime - inst.admit_vsum))
        t_evict = now + (free / (b * rate) if free > 0.0 else 0.0)
        seq += 1
        if t_evict < t_done:
            heappush(events, (t_evict, seq, _D_EVICT, inst.idx, inst.epoch))
        else:
            inst.sched_fv = ends[0][0]
            heappush(events, (t_done, seq, _D_STEP, inst.idx, inst.epoch))

    def do_admit(inst, req, ready: float, now: float) -> None:
        """Book one admission onto ``inst`` (caller removed it from
        ``pending``); identical arithmetic to the pre-fault inline path."""
        nonlocal seq, admit_wait_sum, admit_wait_n, peak_batch
        admit_wait_sum += now - ready
        admit_wait_n += 1
        advance(inst, now)
        req._res += 1
        req._inst = inst.idx
        req._admit_v = inst.vtime
        req._base = req.prompt_tokens + req.decoded
        inst.static += req._base
        inst.admit_vsum += inst.vtime
        inst.batch[req.req_id] = req
        inst.b += 1
        if inst.b > peak_batch:
            peak_batch = inst.b
        rem = req.out_tokens - 1 - req.decoded
        seq += 1
        heappush(inst.ends, (inst.vtime + rem, seq, req, req._res))
        inst.rate = 60.0 / (fixed_s + inst.b * per_seq_s)
        inst.epoch += 1
        reschedule(inst, now)

    def admit(now: float) -> None:
        nonlocal hol_skips
        while pending:
            ready, req = pending[0]
            base = req.prompt_tokens + req.decoded
            best = None
            best_b = eff_max_batch
            for inst in up_insts:
                b = inst.b
                if b >= best_b:
                    continue
                # projected resident tokens at `now` without mutating
                toks = (inst.static
                        + b * (inst.vtime + (now - inst.t0) * inst.rate)
                        - inst.admit_vsum)
                if toks + base <= (cap_pages - b - 1) * page \
                        - eff_headroom:
                    best = inst
                    best_b = b
            if best is not None:
                pending.popleft()
                do_admit(best, req, ready, now)
                continue
            # FIFO head blocked: optionally scan a bounded window of the
            # queue for a smaller admissible request (head-of-line skip);
            # the per-head skip cap bounds starvation — after
            # ``hol_skip_limit`` skips the queue is strict FIFO again
            # until the head itself admits
            if not eff_skip or req._skips >= hol_skip_limit:
                return
            # precompute each instance's admission room at `now` — it is
            # candidate-invariant, so the window scan is O(window + insts)
            # rather than O(window * insts), and a queue blocked by sheer
            # KV fullness exits after the single room pass
            cands = []
            max_room = -1.0
            for inst in up_insts:
                b = inst.b
                if b >= eff_max_batch:
                    continue
                toks = (inst.static
                        + b * (inst.vtime + (now - inst.t0) * inst.rate)
                        - inst.admit_vsum)
                room = (cap_pages - b - 1) * page - eff_headroom - toks
                cands.append((b, len(cands), room, inst))
                if room > max_room:
                    max_room = room
            if max_room < 1.0:      # nothing fits even a 1-token request
                return
            cands.sort()            # lowest occupancy first (stable order)
            admitted = False
            limit = len(pending) - 1
            if limit > eff_skip:
                limit = eff_skip
            for k in range(1, limit + 1):
                ready2, req2 = pending[k]
                base2 = req2.prompt_tokens + req2.decoded
                if base2 > max_room:
                    continue
                for _b, _o, room, cand in cands:
                    if base2 <= room:
                        del pending[k]
                        req._skips += 1
                        hol_skips += 1
                        do_admit(cand, req2, ready2, now)
                        admitted = True
                        break
                if admitted:
                    break
            if not admitted:
                return

    def remove(inst, req) -> None:
        """Drop a resident from the closed-form KV accounting."""
        inst.static -= req._base
        inst.admit_vsum -= req._admit_v
        inst.b -= 1
        del inst.batch[req.req_id]
        req._res += 1           # lazy-delete its completion-heap entry

    def finish(req, now: float) -> None:
        nonlocal completed, decoded_tokens
        decoded_tokens += req.out_tokens - 1 - req.decoded
        req.decoded = req.out_tokens - 1
        req.done_min = now - req.arrival_min
        completed += 1
        if injecting:
            # SLO-violation attribution: a request a failure touched blames
            # that class; an untouched request finishing during a degraded
            # episode blames the episode's (oldest outstanding) cause
            cls_name = req._fcls
            if cls_name is None and active_faults:
                cls_name = next(iter(active_faults.values()))[0]
            if cls_name is not None:
                fs = stats.get(cls_name)
                if fs is None:
                    fs = stats[cls_name] = _FaultClassStats()
                if req.ttft_min * 60.0 > cfg.ttft_slo_s:
                    fs.slo_ttft += 1
                if req.out_tokens > 1 \
                        and ((req.done_min - req.ttft_min)
                             / (req.out_tokens - 1) * 60_000.0
                             > cfg.tpot_slo_ms):
                    fs.slo_tpot += 1

    # -- fault-injection helpers (never called without an injector) ---------

    def class_stats(name: str) -> _FaultClassStats:
        fs = stats.get(name)
        if fs is None:
            fs = stats[name] = _FaultClassStats()
        return fs

    def set_degraded(on: bool) -> None:
        nonlocal eff_max_batch, eff_headroom, eff_skip
        if on:
            eff_max_batch = max(1, int(max_batch
                                       * cfg.degraded_max_batch_frac))
            eff_headroom = int(admit_headroom * cfg.degraded_headroom_mult)
            eff_skip = max(cfg.hol_skip_window, cfg.degraded_hol_skip)
        else:
            eff_max_batch = max_batch
            eff_headroom = admit_headroom
            eff_skip = cfg.hol_skip_window

    def schedule_fail(is_decode: int, idx: int, now: float) -> None:
        """Draw the §5 hazard for one fresh instance attempt."""
        nonlocal seq
        remaining = inject_until - now
        if remaining <= 0.0:
            return
        hit = inj.draw(SERVE, gpi, remaining)
        if hit is None:
            return
        ttf, cls = hit
        seq += 1
        heappush(events, (now + ttf, seq, _I_FAIL, (is_decode, idx, cls), 0))

    def kill_request(req, cls, now: float) -> None:
        """One request's KV/work was destroyed by ``cls``: retry through
        the prefill fleet with exponential backoff, or count it dropped
        once the budget is spent. ``killed_tokens`` is charged only for
        retried work — the recompute pass balances it exactly, keeping
        ``evicted + killed == recomputed`` an identity."""
        nonlocal seq, killed_tokens, retries_total
        fs = class_stats(cls.name)
        req._fcls = cls.name
        if req.retries >= retry_budget:
            res.dropped_ids.append(req.req_id)
            fs.drops += 1
            fs.lost_goodput_tokens += req.prompt_tokens + req.decoded
            return
        req.retries += 1
        retries_total += 1
        fs.retries += 1
        lost = req.prompt_tokens + req.decoded
        killed_tokens += lost
        fs.killed_tokens += lost
        delay = retry_backoff * (2.0 ** (req.retries - 1))
        seq += 1
        heappush(events, (now + delay, seq, _RETRY, req, 0))

    def next_respawn_wait(now: float) -> float:
        """When a hardware respawn finds no free capacity it re-arms at
        the earliest outstanding REPAIR (capacity returns there); a short
        poll is the fallback if none is pending."""
        best = math.inf
        for t in pending_repairs:
            if now < t < best:
                best = t
        return best if math.isfinite(best) else now + 5.0

    def on_instance_fail(payload, now: float) -> None:
        nonlocal seq, faults_injected, decoded_tokens, respawns, \
            inplace_restarts, cordoned_nodes, degraded_since, up_insts
        is_dec, idx, cls = payload
        fs = class_stats(cls.name)
        faults_injected += 1
        fs.failures += 1
        if is_dec:
            fs.decode += 1
        else:
            fs.prefill += 1
        # -- diagnosis-in-the-loop: a serving-flavored per-class log runs
        # through the §6.1 pipeline; the verdict picks the recovery
        if dloop is not None:
            vclass, _, _ = dloop.verdict(cls)
            fs.verdicts[vclass] = fs.verdicts.get(vclass, 0) + 1
            hardware = vclass == VERDICT_HARDWARE
        else:
            hardware = cls.needs_cordon
        # -- teardown: resident KV / in-flight prefill work is destroyed --
        if is_dec:
            inst = insts[idx]
            advance(inst, now)
            v = inst.vtime
            for req in list(inst.batch.values()):
                prog = int(v - req._admit_v)
                if prog < 0:
                    prog = 0
                dec = req.decoded + prog
                if dec > req.out_tokens - 1:
                    dec = req.out_tokens - 1
                decoded_tokens += dec - req.decoded
                req.decoded = dec
                req._res += 1       # lazy-delete any completion-heap entry
                req._inst = -1
                if dec >= req.out_tokens - 1:
                    # fully decoded at the kill instant: tokens already
                    # streamed out, nothing to rebuild
                    finish(req, now)
                else:
                    kill_request(req, cls, now)
            inst.batch.clear()
            inst.ends.clear()
            inst.b = 0
            inst.static = 0.0
            inst.admit_vsum = 0.0
            inst.vtime = 0.0
            inst.sched_fv = 0.0
            inst.rate = 0.0
            inst.t0 = now
            inst.epoch += 1         # voids scheduled _D_STEP/_D_EVICT
            inst.down = True
            up_insts = [i for i in insts if not i.down]
        else:
            pf_free[idx] = -1.0     # stale-key every live heap entry
            affected = list(pf_sched[idx].values())
            pf_sched[idx].clear()
            for req in affected:
                req._pfe += 1       # voids its scheduled _P_DONE
                req._pfi = -1
                kill_request(req, cls, now)
        # -- recovery: verdict-driven, mirroring the training replay ------
        pidx = n_prefill + idx if is_dec else idx
        if hardware:
            # release-then-cordon, the training replay's ordering: the dead
            # instance's GPUs rejoin their nodes' free pools, and the node
            # drain sweeps them (plus any bystander free GPUs) into the
            # cordon; everything returns together at REPAIR via add_free
            nodes = tuple(n for n in placements[pidx] if n >= 0)
            ledger.release(placements[pidx])
            cfree = 0
            for n in nodes:
                cfree += ledger.cordon_node(n)
            cordoned_nodes += len(nodes)
            placement_dead[pidx] = True
            t_repair = now + max(cls.repair_min, _EPS)
            pending_repairs.append(t_repair)
            seq += 1
            heappush(events, (t_repair, seq, _I_REPAIR,
                              (nodes, cfree, t_repair), 0))
        seq += 1
        heappush(events, (now + cls.restart_overhead_min, seq, _I_UP,
                          (is_dec, idx), 0))
        # -- graceful degradation bookkeeping -----------------------------
        if not active_faults:
            degraded_since = now
            set_degraded(True)
        active_faults[(is_dec, idx)] = (cls.name, now)

    def on_instance_up(payload, now: float) -> None:
        nonlocal seq, respawns, inplace_restarts, degraded_min, up_insts
        is_dec, idx = payload
        pidx = n_prefill + idx if is_dec else idx
        if placement_dead[pidx]:
            # hardware verdict: the old allocation died with its cordoned
            # nodes — respawn needs fresh capacity, else wait for REPAIR
            if ledger.free_total() < gpi:
                seq += 1
                heappush(events, (next_respawn_wait(now), seq, _I_UP,
                                  (is_dec, idx), 0))
                return
            placements[pidx] = ledger.alloc(gpi)
            placement_dead[pidx] = False
            respawns += 1
        else:
            inplace_restarts += 1
        entry = active_faults.pop((is_dec, idx), None)
        if entry is not None:
            class_stats(entry[0]).downtime_min += now - entry[1]
        if not active_faults:
            degraded_min += now - degraded_since
            set_degraded(False)
        if is_dec:
            inst = insts[idx]
            inst.down = False
            inst.t0 = now
            up_insts = [i for i in insts if not i.down]
        else:
            pf_free[idx] = now
            heappush(pf, (now, idx))
            while pf_blocked and pf:
                req, tokens, recompute = pf_blocked.popleft()
                start_prefill(req, now, tokens, recompute)
        schedule_fail(is_dec, idx, now)     # fresh attempt, fresh hazard
        admit(now)

    def on_repair(payload, now: float) -> None:
        nodes, cfree, t_repair = payload
        try:
            pending_repairs.remove(t_repair)
        except ValueError:
            pass
        ledger.repair_nodes(nodes)
        # the drained cordon share — dead instance's GPUs included, since
        # release preceded the cordon — returns to the free pools
        if cfree:
            ledger.add_free(cfree, prefer=nodes)
        admit(now)

    # draw each instance's initial attempt hazard (fixed order: prefill
    # 0..P-1 then decode 0..D-1 — the injector stream is positional)
    if injecting:
        res.fault_stats = stats
        for i in range(n_prefill):
            schedule_fail(0, i, 0.0)
        for j in range(cfg.n_decode):
            schedule_fail(1, j, 0.0)

    arr_i = 0
    while arr_i < n_arr or events:
        if events and (arr_i >= n_arr
                       or events[0][0] <= arrivals[arr_i].arrival_min):
            now, _s, kind, payload, epoch = heappop(events)
            events_processed += 1
            if kind == _P_DONE:
                req = payload
                if injecting:
                    if epoch != req._pfe:
                        stale += 1
                        continue
                    if req._pfi >= 0:
                        pf_sched[req._pfi].pop(req.req_id, None)
                        req._pfi = -1
                if math.isinf(req.ttft_min):
                    req.ttft_min = now - req.arrival_min
                    if req.out_tokens <= 1:
                        finish(req, now)
                        continue
                pending.append((now, req))
                admit(now)
            elif kind == _D_STEP:
                inst = insts[payload]
                if epoch != inst.epoch:
                    stale += 1
                    continue
                advance(inst, now)
                if inst.vtime < inst.sched_fv:
                    # float round-trip through (fv - vtime)/rate * rate can
                    # land a hair short of the targeted finish; clamp so
                    # the completion below always pops
                    inst.vtime = inst.sched_fv
                ends = inst.ends
                v = inst.vtime + _EPS
                while ends and ends[0][0] <= v:
                    _fv, _s2, req, r = heappop(ends)
                    if req._res != r or req._inst != inst.idx:
                        stale += 1
                        continue
                    remove(inst, req)
                    finish(req, now)
                inst.rate = (60.0 / (fixed_s + inst.b * per_seq_s)
                             if inst.b else 0.0)
                inst.epoch += 1
                reschedule(inst, now)
                admit(now)
            elif kind == _D_EVICT:
                inst = insts[payload]
                if epoch != inst.epoch:
                    stale += 1
                    continue
                advance(inst, now)
                while inst.b > 1:
                    free = ((cap_pages - inst.b) * page
                            - (inst.static + inst.b * inst.vtime
                               - inst.admit_vsum))
                    if free >= evict_headroom:
                        break
                    rid = next(reversed(inst.batch))   # LIFO victim
                    req = inst.batch[rid]
                    prog = int(inst.vtime - req._admit_v)
                    if prog < 0:
                        prog = 0
                    dec = req.decoded + prog
                    if dec > req.out_tokens - 1:
                        dec = req.out_tokens - 1
                    remove(inst, req)
                    if dec >= req.out_tokens - 1:
                        # fully decoded at the eviction instant: there is
                        # no KV worth rebuilding, the request just ends
                        finish(req, now)
                        continue
                    decoded_tokens += dec - req.decoded
                    req.decoded = dec
                    req.evictions += 1
                    evictions += 1
                    evicted_tokens += req.prompt_tokens + dec
                    start_prefill(req, now, req.prompt_tokens + dec, True)
                inst.rate = (60.0 / (fixed_s + inst.b * per_seq_s)
                             if inst.b else 0.0)
                inst.epoch += 1
                reschedule(inst, now)
                admit(now)
            elif kind == _I_FAIL:
                on_instance_fail(payload, now)
                continue    # fault machinery never advances the service
            elif kind == _I_UP:     # horizon (a +24h REPAIR tail must not
                on_instance_up(payload, now)    # dilute throughput rates)
                continue
            elif kind == _I_REPAIR:
                on_repair(payload, now)
                continue
            else:   # _RETRY: backoff elapsed, re-enter the prefill fleet
                req = payload
                start_prefill(req, now, req.prompt_tokens + req.decoded,
                              True)
        else:
            req = arrivals[arr_i]
            arr_i += 1
            events_processed += 1
            now = req.arrival_min
            if req.prompt_tokens + req.out_tokens - 1 > max_resident:
                res.rejected_ids.append(req.req_id)
                continue
            if injecting and active_faults and shed_queue \
                    and len(pending) >= shed_queue:
                # graceful degradation: beyond the queue cap, arriving
                # load is shed outright and attributed to the episode
                res.shed_ids.append(req.req_id)
                cls_name = next(iter(active_faults.values()))[0]
                class_stats(cls_name).shed += 1
                continue
            start_prefill(req, now, req.prompt_tokens, False)
        if now > res.horizon_min:
            res.horizon_min = now

    if injecting:
        # close still-open degradation episodes at the final horizon
        now = res.horizon_min
        for (key, (cls_name, t0)) in list(active_faults.items()):
            class_stats(cls_name).downtime_min += now - t0
        if active_faults:
            degraded_min += now - degraded_since
        res.faults_injected = faults_injected
        res.killed_tokens = killed_tokens
        res.retries_total = retries_total
        res.degraded_min = degraded_min
        res.respawns = respawns
        res.inplace_restarts = inplace_restarts
        res.cordoned_nodes = cordoned_nodes
    res.events_processed = events_processed
    res.completed = completed
    res.decoded_tokens = decoded_tokens
    res.prefill_tokens = prefill_tokens
    res.recompute_prefill_tokens = recompute_prefill_tokens
    res.evictions = evictions
    res.evicted_tokens = evicted_tokens
    res.stale_events = stale
    res.admit_wait_sum_min = admit_wait_sum
    res.admit_wait_n = admit_wait_n
    res.peak_batch = peak_batch
    res.hol_skips = hol_skips
    res.occ_time_min = math.fsum(i.occ for i in insts)
    res.kv_peak_pages = max((i.peak_bound for i in insts), default=0.0)
    return res
