"""Serving-cluster replay: the inference counterpart of ``replay_trace``.

One event loop drives a disaggregated serving fleet through a request
trace (``workload.generate_requests``) at Seren scale — 1M+ requests in
seconds of wall time — with the mechanisms the distributed-LLM-serving
literature treats as defining (continuous batching, prefill/decode
disaggregation, paged KV with eviction) modeled explicitly:

  * **Prefill fleet** — ``n_prefill`` instances of ``gpus_per_instance``
    GPUs each, a FIFO k-server queue: a request's prompt pass (and any
    KV-recompute pass after an eviction) takes ``prompt_tokens`` over the
    instance's modeled token throughput. TTFT is arrival → first prefill
    completion, queueing included.
  * **Decode fleet** — ``n_decode`` instances running continuous batching
    with per-event admission: an instance decodes one token per resident
    request per step, and the step time is an affine function of batch
    occupancy (``ServeRates.step_time_s``), so all residents share a
    common per-slot progress clock (``vtime``, in tokens). Membership
    changes (admission, completion, eviction) reprice the whole batch at
    once — the same epoch-stamped lazy-deletion-heap pattern as the
    training replay, O(log n) per membership change instead of O(tokens).
  * **Paged KV** — each decode instance owns ``kv_pages`` pages of
    ``page_tokens`` tokens. Residents' KV grows one token per decoded
    token; the engine enforces the *conservative page bound*
    ``sum_i ceil(tokens_i / page) <= tokens_total / page + batch`` so
    pages can never exceed capacity. When growth exhausts the bound, the
    newest resident is evicted LIFO: its generated tokens are kept, its
    KV is lost, and it re-enters the *prefill* queue for a recompute pass
    over ``prompt + decoded`` tokens before decoding resumes — the
    eviction/recompute accounting the property tests pin.
  * **Pricing** — all rates come from ``launch.cost_model``'s
    prefill/decode ``CostCell``s (``CostModel.serve_rates``): committed
    dry-run artifacts when present, the deterministic analytic fallback
    otherwise, same provenance discipline as the roofline replay.

The fleet is stood up through a :class:`~repro.cluster.replay.NodeLedger`
(instances allocate concrete node GPUs), so serving placement shares the
training replay's physical accounting and the stretch goal of
co-scheduling both on one ledger stays a config change, not a rewrite.

Determinism contract: no wall clock, no RNG (the trace carries all the
randomness), flat heap tuples ordered by ``(time, seq)``; the module is
on replint's hot list, so every class is slotted. ``summary()`` follows
the ``ReplayResult.summary()`` schema conventions (see README "Result
schemas"): stable top-level keys, plain-scalar leaves, memoized and
deep-copied so repeated calls are side-effect-free.

  >>> from repro.cluster import (ServeReplayConfig, generate_requests,
  ...                            replay_requests)
  >>> reqs = generate_requests(200_000, seed=0, horizon_min=300.0)
  >>> res = replay_requests(reqs, ServeReplayConfig())
  >>> res.summary()["slo"]["joint_attainment"]  # doctest: +SKIP
"""
from __future__ import annotations

import copy
import dataclasses
import heapq
import math
from collections import deque
from typing import Optional

import numpy as np

from repro.cluster.replay import NodeLedger

# event kinds (flat heap tuples: (t_min, seq, kind, payload, epoch))
_P_DONE, _D_STEP, _D_EVICT = 0, 1, 2
_EPS = 1e-9


@dataclasses.dataclass(frozen=True, slots=True)
class ServeReplayConfig:
    """Frozen knob set for one serving replay.

    Fleet shape: ``n_prefill + n_decode`` instances of
    ``gpus_per_instance`` GPUs are allocated node-locally out of
    ``total_gpus`` (``node_gpus`` per node) through a ``NodeLedger``.
    ``max_batch`` caps continuous-batching occupancy per decode instance;
    ``kv_pages`` * ``page_tokens`` is its KV capacity. Admission requires
    ``admit_headroom_tokens`` of growth room beyond the request's resident
    KV so a fresh admission cannot trigger an instant eviction; an
    eviction frees at least ``evict_headroom_tokens``. SLO targets are
    what ``summary()['slo']`` grades attainment against. ``cost_model``
    is a ``launch.cost_model.CostModel`` (or anything with a
    ``serve_rates(arch, gpus)``); ``None`` loads the committed dry-run
    artifacts with analytic fallback, exactly like the training replay's
    roofline mode."""
    total_gpus: int = 256
    node_gpus: int = 8
    n_prefill: int = 4
    n_decode: int = 16
    gpus_per_instance: int = 8
    max_batch: int = 64
    kv_pages: int = 4096
    page_tokens: int = 16
    admit_headroom_tokens: int = 256
    evict_headroom_tokens: int = 1024
    arch: str = "internlm-7b"
    cost_model: Optional[object] = None
    ttft_slo_s: float = 10.0
    tpot_slo_ms: float = 300.0


class _DecodeInstance:
    """Continuous-batching state for one decode instance.

    ``vtime`` is the shared progress clock in *tokens per resident*: every
    resident decodes at the same one-token-per-step rate, so a request
    admitted at ``vtime`` v0 with r tokens remaining finishes when
    ``vtime`` reaches v0 + r. Resident KV is the closed form
    ``static + b * vtime - admit_vsum`` (``static`` sums residents'
    tokens-at-admission, ``admit_vsum`` their admission vtimes), which
    keeps token accounting exact under float accumulation — nothing
    drifts because nothing is incrementally summed."""
    __slots__ = ("idx", "b", "vtime", "t0", "rate", "static", "admit_vsum",
                 "epoch", "ends", "batch", "sched_fv", "occ", "peak_bound")

    def __init__(self, idx: int) -> None:
        self.idx = idx
        self.b = 0                 # current batch occupancy
        self.vtime = 0.0           # tokens decoded per resident since start
        self.t0 = 0.0              # wall minute of the last advance
        self.rate = 0.0            # d vtime / d minute at current occupancy
        self.static = 0.0          # sum of residents' tokens at admission
        self.admit_vsum = 0.0      # sum of residents' admission vtimes
        self.epoch = 0             # invalidates scheduled D_STEP/D_EVICT
        self.ends: list = []       # (finish_vtime, seq, req, res) min-heap
        self.batch: dict = {}      # req_id -> req, insertion-ordered (LIFO)
        self.sched_fv = 0.0        # finish_vtime the live D_STEP targets
        self.occ = 0.0             # time-integrated occupancy (batch-min)
        self.peak_bound = 0.0      # max conservative page bound observed


@dataclasses.dataclass(slots=True)
class ServeReplayResult:
    """Outcome of one serving replay; ``summary()`` is the stable API."""
    requests: list
    config: ServeReplayConfig
    events_processed: int = 0
    completed: int = 0
    rejected_ids: list = dataclasses.field(default_factory=list)
    stale_events: int = 0
    # -- token conservation ledger (see tests/test_serve_replay) ------------
    decoded_tokens: int = 0        # decode tokens produced (never re-decoded)
    prefill_tokens: int = 0        # all tokens prefilled, recomputes included
    recompute_prefill_tokens: int = 0   # prefill side of eviction recovery
    evictions: int = 0
    evicted_tokens: int = 0        # KV tokens dropped (== recompute charge)
    # -- pressure / occupancy ------------------------------------------------
    occ_time_min: float = 0.0      # sum over instances of integral(batch dt)
    peak_batch: int = 0
    kv_peak_pages: float = 0.0     # max conservative page bound, any instance
    admit_wait_sum_min: float = 0.0
    admit_wait_n: int = 0
    horizon_min: float = 0.0       # last event timestamp
    nodes_used: int = 0
    rates_source: str = ""
    rates_prefill_tok_s: float = 0.0
    rates_decode_fixed_s: float = 0.0
    rates_decode_per_seq_s: float = 0.0
    # memoized summary() tree (same discipline as ReplayResult: built once,
    # deep-copied on every return so callers cannot mutate the memo)
    _summary: Optional[dict] = dataclasses.field(
        default=None, repr=False, compare=False)

    def summary(self) -> dict:
        """JSON-ready serving scorecard: TTFT/TPOT tails, SLO attainment,
        batch occupancy and KV pressure — the serving analogue of
        ``ReplayResult.summary()`` and bound by the same schema contract
        (README "Result schemas")."""
        if self._summary is None:
            self._summary = self._build_summary()
        return copy.deepcopy(self._summary)

    def _build_summary(self) -> dict:
        cfg = self.config
        # one pass: per finished request collect TTFT (s) and, when it
        # decoded at all, TPOT (ms); out==1 requests pass the TPOT half of
        # the joint SLO vacuously
        ttft, tpot, tpot_padded = [], [], []
        for r in self.requests:
            if not math.isfinite(r.done_min):
                continue
            ttft.append(r.ttft_min * 60.0)
            if r.out_tokens > 1:
                ms = ((r.done_min - r.ttft_min)
                      / (r.out_tokens - 1) * 60_000.0)
                tpot.append(ms)
                tpot_padded.append(ms)
            else:
                tpot_padded.append(0.0)
        ttft_s = np.asarray(ttft, dtype=np.float64)
        tpot_ms = np.asarray(tpot, dtype=np.float64)
        horizon = self.horizon_min
        decode_gpu_min = cfg.n_decode * max(horizon, _EPS)
        n = len(self.requests)
        ttft_ok = tpot_ok = joint = 0.0
        if ttft_s.size:
            ttft_hit = ttft_s <= cfg.ttft_slo_s
            tpot_hit = (np.asarray(tpot_padded, dtype=np.float64)
                        <= cfg.tpot_slo_ms)
            ttft_ok = float(ttft_hit.mean())
            tpot_ok = float((tpot_ms <= cfg.tpot_slo_ms).mean()) \
                if tpot_ms.size else 1.0
            joint = float((ttft_hit & tpot_hit).mean())
        return {
            "n_requests": n,
            "completed": self.completed,
            "rejected": len(self.rejected_ids),
            "events_processed": self.events_processed,
            "stale_events": self.stale_events,
            "horizon_min": float(horizon),
            "ttft": _tail_s(ttft_s),
            "tpot": _tail_ms(tpot_ms),
            "slo": {
                "ttft_target_s": float(cfg.ttft_slo_s),
                "tpot_target_ms": float(cfg.tpot_slo_ms),
                "ttft_attainment": ttft_ok,
                "tpot_attainment": tpot_ok,
                "joint_attainment": joint,
            },
            "throughput": {
                "decoded_tokens": self.decoded_tokens,
                "prefill_tokens": self.prefill_tokens,
                "decoded_tok_per_s": float(
                    self.decoded_tokens / max(horizon * 60.0, _EPS)),
                "requests_per_min": float(n / max(horizon, _EPS)),
            },
            "batch": {
                "mean_occupancy": float(self.occ_time_min / decode_gpu_min),
                "peak_occupancy": self.peak_batch,
                "max_batch": cfg.max_batch,
                "admit_wait_mean_min": float(
                    self.admit_wait_sum_min / max(self.admit_wait_n, 1)),
            },
            "kv": {
                "pages_per_instance": cfg.kv_pages,
                "page_tokens": cfg.page_tokens,
                "peak_pages": float(self.kv_peak_pages),
                "peak_pages_frac": float(
                    self.kv_peak_pages / max(cfg.kv_pages, 1)),
                "evictions": self.evictions,
                "evicted_tokens": self.evicted_tokens,
                "recompute_prefill_tokens": self.recompute_prefill_tokens,
            },
            "fleet": {
                "total_gpus": cfg.total_gpus,
                "n_prefill": cfg.n_prefill,
                "n_decode": cfg.n_decode,
                "gpus_per_instance": cfg.gpus_per_instance,
                "nodes_used": self.nodes_used,
            },
            "cost_model": {
                "arch": cfg.arch,
                "source": self.rates_source,
                "prefill_tok_s": float(self.rates_prefill_tok_s),
                "decode_fixed_ms": float(
                    self.rates_decode_fixed_s * 1e3),
                "decode_per_seq_ms": float(
                    self.rates_decode_per_seq_s * 1e3),
            },
        }


def _tail_s(arr: np.ndarray, qs=(50, 95, 99)) -> dict:
    if arr.size == 0:
        return {f"p{q}_s": 0.0 for q in qs} | {"n": 0, "mean_s": 0.0}
    pcts = np.percentile(arr, qs)
    out = {f"p{q}_s": float(v) for q, v in zip(qs, pcts)}
    out["n"] = int(arr.size)
    out["mean_s"] = float(arr.mean())
    return out


def _tail_ms(arr: np.ndarray, qs=(50, 95, 99)) -> dict:
    if arr.size == 0:
        return {f"p{q}_ms": 0.0 for q in qs} | {"n": 0, "mean_ms": 0.0}
    pcts = np.percentile(arr, qs)
    out = {f"p{q}_ms": float(v) for q, v in zip(qs, pcts)}
    out["n"] = int(arr.size)
    out["mean_ms"] = float(arr.mean())
    return out


def replay_requests(requests: list,
                    config: Optional[ServeReplayConfig] = None
                    ) -> ServeReplayResult:
    """Replay a request trace through the serving fleet; see module doc.

    ``requests`` are :class:`~repro.cluster.workload.RequestRecord`s; the
    engine writes ``ttft_min`` / ``done_min`` / ``decoded`` / ``evictions``
    into them (arrival-relative minutes) and returns the result object.
    The trace need not be pre-sorted."""
    cfg = config if config is not None else ServeReplayConfig()
    if cfg.n_prefill < 1 or cfg.n_decode < 1:
        raise ValueError("need at least one prefill and one decode instance")
    need = (cfg.n_prefill + cfg.n_decode) * cfg.gpus_per_instance
    if need > cfg.total_gpus:
        raise ValueError(
            f"fleet needs {need} GPUs but total_gpus={cfg.total_gpus}")
    if cfg.kv_pages * cfg.page_tokens <= cfg.admit_headroom_tokens:
        raise ValueError("KV capacity below the admission headroom")

    cm = cfg.cost_model
    if cm is None:
        from repro.launch.cost_model import CostModel
        cm = CostModel.load(archs=(cfg.arch,))
    rates = cm.serve_rates(cfg.arch, cfg.gpus_per_instance)
    fixed_s = rates.decode_fixed_s
    per_seq_s = rates.decode_per_seq_s
    prefill_min_per_tok = 1.0 / (rates.prefill_tok_s * 60.0)

    # node-local placement: every instance allocates concrete node GPUs
    n_nodes = max(cfg.total_gpus // cfg.node_gpus, 1)
    ledger = NodeLedger(n_nodes, cfg.node_gpus, cfg.total_gpus)
    placements = [ledger.alloc(cfg.gpus_per_instance)
                  for _ in range(cfg.n_prefill + cfg.n_decode)]
    nodes_used = len({node for pl in placements for node in pl if node >= 0})

    res = ServeReplayResult(requests=requests, config=cfg,
                            nodes_used=nodes_used,
                            rates_source=rates.source,
                            rates_prefill_tok_s=rates.prefill_tok_s,
                            rates_decode_fixed_s=fixed_s,
                            rates_decode_per_seq_s=per_seq_s)

    page = cfg.page_tokens
    cap_pages = cfg.kv_pages
    cap_tokens = cap_pages * page
    max_batch = cfg.max_batch
    admit_headroom = cfg.admit_headroom_tokens
    evict_headroom = cfg.evict_headroom_tokens
    # a request whose full resident KV cannot fit an otherwise-empty
    # instance under the conservative bound can never be served
    max_resident = (cap_pages - 1) * page - admit_headroom

    insts = [_DecodeInstance(i) for i in range(cfg.n_decode)]
    # prefill fleet: FIFO k-server queue as a (free_at, idx) heap
    pf = [(0.0, i) for i in range(cfg.n_prefill)]
    heapq.heapify(pf)

    events: list = []
    heappush = heapq.heappush
    heappop = heapq.heappop
    pending: deque = deque()    # (ready_min, req) awaiting decode admission
    seq = 0                     # heap tiebreak counter

    order = sorted(range(len(requests)),
                   key=lambda i: requests[i].arrival_min)
    arrivals = [requests[i] for i in order]
    n_arr = len(arrivals)

    # running counters (folded into res after the loop)
    completed = 0
    decoded_tokens = 0
    prefill_tokens = 0
    recompute_prefill_tokens = 0
    evictions = 0
    evicted_tokens = 0
    stale = 0
    admit_wait_sum = 0.0
    admit_wait_n = 0
    peak_batch = 0
    events_processed = 0

    def start_prefill(req, now: float, tokens: int, recompute: bool) -> None:
        nonlocal seq, prefill_tokens, recompute_prefill_tokens
        free_at, i = heappop(pf)
        start = free_at if free_at > now else now
        done = start + tokens * prefill_min_per_tok
        heappush(pf, (done, i))
        seq += 1
        heappush(events, (done, seq, _P_DONE, req, 0))
        prefill_tokens += tokens
        if recompute:
            recompute_prefill_tokens += tokens

    def advance(inst, now: float) -> None:
        dt = now - inst.t0
        b = inst.b
        if dt > 0.0:
            if b:
                inst.vtime += dt * inst.rate
                inst.occ += dt * b
            inst.t0 = now
        if b:
            bound = ((inst.static + b * inst.vtime - inst.admit_vsum)
                     / page + b)
            if bound > inst.peak_bound:
                inst.peak_bound = bound

    def reschedule(inst, now: float) -> None:
        nonlocal seq, stale
        ends = inst.ends
        while ends:
            fv, _s, req, r = ends[0]
            if req._res == r and req._inst == inst.idx:
                break
            heappop(ends)
            stale += 1
        b = inst.b
        if not b or not ends:
            return
        rate = inst.rate
        t_done = now + (ends[0][0] - inst.vtime) / rate
        free = ((cap_pages - b) * page
                - (inst.static + b * inst.vtime - inst.admit_vsum))
        t_evict = now + (free / (b * rate) if free > 0.0 else 0.0)
        seq += 1
        if t_evict < t_done:
            heappush(events, (t_evict, seq, _D_EVICT, inst.idx, inst.epoch))
        else:
            inst.sched_fv = ends[0][0]
            heappush(events, (t_done, seq, _D_STEP, inst.idx, inst.epoch))

    def admit(now: float) -> None:
        nonlocal seq, admit_wait_sum, admit_wait_n, peak_batch
        while pending:
            ready, req = pending[0]
            base = req.prompt_tokens + req.decoded
            best = None
            best_b = max_batch
            for inst in insts:
                b = inst.b
                if b >= best_b:
                    continue
                # projected resident tokens at `now` without mutating
                toks = (inst.static
                        + b * (inst.vtime + (now - inst.t0) * inst.rate)
                        - inst.admit_vsum)
                if toks + base <= (cap_pages - b - 1) * page \
                        - admit_headroom:
                    best = inst
                    best_b = b
            if best is None:
                return      # FIFO head blocked; retry at the next event
            pending.popleft()
            admit_wait_sum += now - ready
            admit_wait_n += 1
            inst = best
            advance(inst, now)
            req._res += 1
            req._inst = inst.idx
            req._admit_v = inst.vtime
            req._base = base
            inst.static += base
            inst.admit_vsum += inst.vtime
            inst.batch[req.req_id] = req
            inst.b += 1
            if inst.b > peak_batch:
                peak_batch = inst.b
            rem = req.out_tokens - 1 - req.decoded
            seq += 1
            heappush(inst.ends, (inst.vtime + rem, seq, req, req._res))
            inst.rate = 60.0 / (fixed_s + inst.b * per_seq_s)
            inst.epoch += 1
            reschedule(inst, now)

    def remove(inst, req) -> None:
        """Drop a resident from the closed-form KV accounting."""
        inst.static -= req._base
        inst.admit_vsum -= req._admit_v
        inst.b -= 1
        del inst.batch[req.req_id]
        req._res += 1           # lazy-delete its completion-heap entry

    def finish(req, now: float) -> None:
        nonlocal completed, decoded_tokens
        decoded_tokens += req.out_tokens - 1 - req.decoded
        req.decoded = req.out_tokens - 1
        req.done_min = now - req.arrival_min
        completed += 1

    arr_i = 0
    while arr_i < n_arr or events:
        if events and (arr_i >= n_arr
                       or events[0][0] <= arrivals[arr_i].arrival_min):
            now, _s, kind, payload, epoch = heappop(events)
            events_processed += 1
            if kind == _P_DONE:
                req = payload
                if math.isinf(req.ttft_min):
                    req.ttft_min = now - req.arrival_min
                    if req.out_tokens <= 1:
                        finish(req, now)
                        continue
                pending.append((now, req))
                admit(now)
            elif kind == _D_STEP:
                inst = insts[payload]
                if epoch != inst.epoch:
                    stale += 1
                    continue
                advance(inst, now)
                if inst.vtime < inst.sched_fv:
                    # float round-trip through (fv - vtime)/rate * rate can
                    # land a hair short of the targeted finish; clamp so
                    # the completion below always pops
                    inst.vtime = inst.sched_fv
                ends = inst.ends
                v = inst.vtime + _EPS
                while ends and ends[0][0] <= v:
                    _fv, _s2, req, r = heappop(ends)
                    if req._res != r or req._inst != inst.idx:
                        stale += 1
                        continue
                    remove(inst, req)
                    finish(req, now)
                inst.rate = (60.0 / (fixed_s + inst.b * per_seq_s)
                             if inst.b else 0.0)
                inst.epoch += 1
                reschedule(inst, now)
                admit(now)
            else:   # _D_EVICT
                inst = insts[payload]
                if epoch != inst.epoch:
                    stale += 1
                    continue
                advance(inst, now)
                while inst.b > 1:
                    free = ((cap_pages - inst.b) * page
                            - (inst.static + inst.b * inst.vtime
                               - inst.admit_vsum))
                    if free >= evict_headroom:
                        break
                    rid = next(reversed(inst.batch))   # LIFO victim
                    req = inst.batch[rid]
                    prog = int(inst.vtime - req._admit_v)
                    if prog < 0:
                        prog = 0
                    dec = req.decoded + prog
                    if dec > req.out_tokens - 1:
                        dec = req.out_tokens - 1
                    remove(inst, req)
                    if dec >= req.out_tokens - 1:
                        # fully decoded at the eviction instant: there is
                        # no KV worth rebuilding, the request just ends
                        finish(req, now)
                        continue
                    decoded_tokens += dec - req.decoded
                    req.decoded = dec
                    req.evictions += 1
                    evictions += 1
                    evicted_tokens += req.prompt_tokens + dec
                    start_prefill(req, now, req.prompt_tokens + dec, True)
                inst.rate = (60.0 / (fixed_s + inst.b * per_seq_s)
                             if inst.b else 0.0)
                inst.epoch += 1
                reschedule(inst, now)
                admit(now)
        else:
            req = arrivals[arr_i]
            arr_i += 1
            events_processed += 1
            now = req.arrival_min
            if req.prompt_tokens + req.out_tokens - 1 > max_resident:
                res.rejected_ids.append(req.req_id)
                continue
            start_prefill(req, now, req.prompt_tokens, False)
        if now > res.horizon_min:
            res.horizon_min = now

    res.events_processed = events_processed
    res.completed = completed
    res.decoded_tokens = decoded_tokens
    res.prefill_tokens = prefill_tokens
    res.recompute_prefill_tokens = recompute_prefill_tokens
    res.evictions = evictions
    res.evicted_tokens = evicted_tokens
    res.stale_events = stale
    res.admit_wait_sum_min = admit_wait_sum
    res.admit_wait_n = admit_wait_n
    res.peak_batch = peak_batch
    res.occ_time_min = math.fsum(i.occ for i in insts)
    res.kv_peak_pages = max((i.peak_bound for i in insts), default=0.0)
    return res
