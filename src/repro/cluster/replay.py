"""Failure-aware, event-driven cluster trace replay (paper §3.2 + §5).

This is the first subsystem that exercises *scheduling* and *fault
tolerance* in one scenario: it replays a ``workload.generate_jobs``
population through the ``ReservationScheduler`` while injecting the §5
interruption taxonomy (``repro.cluster.failures``) into running jobs —
reproducing the paper's joint characterization of queuing delay (Fig. 6),
restart counts and lost GPU hours (Figs. 13-14, Table 2/3 analogues).

Mechanics
---------
A single event heap drives the simulation. Event kinds:

  ``FINISH``  a running job completes and frees its GPUs;
  ``ARRIVE``  a job is submitted (or *re*-submitted after a failure);
  ``FAIL``    an injected interruption kills a running job;
  ``REPAIR``  a cordoned node returns to the schedulable pool.

Waiting jobs live in two ``deque``-backed FIFO classes (reservation-priority
and best-effort), so dispatch is O(1) per started job instead of the
O(queue) list ``pop(0)`` rescans the old ``simulate_queue`` paid — that
change alone is what lets a ~1M-job synthetic trace replay in seconds.
``simulate_queue`` is now a thin wrapper over this engine with injection
disabled, so the two paths can never drift.

Failure handling per injected event (class ``hardware``/``infra``/
``preemption``):

  1. the job's GPUs are freed and its progress rolls back to the last
     periodic checkpoint (``CheckpointManager``-style accounting: work since
     the last multiple of ``checkpoint_interval_min`` is *lost GPU time*;
     non-checkpointed types restart from zero);
  2. ``hardware`` failures mark a fleet node faulty and run the §6.1
     ``two_round_detection`` sweep; detected nodes are cordoned and their
     GPUs leave the pool until a ``REPAIR`` event ``repair_min`` later;
  3. the job re-queues at the *back* of its priority class (a restart is a
     resubmission) with its remaining work plus the class's restart
     overhead, up to ``max_restarts`` attempts — beyond that the job is
     killed, mirroring the paper's jobs that exhaust automatic recovery.

Backfill
--------
``backfill=True`` enables a bounded-window greedy backfill: when the FIFO
head does not fit, up to ``backfill_window`` later jobs in the same class
may start if they fit in the *currently free* GPUs. This is deliberately
aggressive (it can delay the head, unlike conservative/EASY backfill) and
exists to quantify how much of the paper's eval queuing delay is pure
head-of-line blocking; the default (off) preserves the paper's policy.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import math
import random
from typing import Optional

import numpy as np

from repro.cluster.failures import (CHECKPOINTED_TYPES, FailureInjector,
                                    ReplayFailureClass)
from repro.cluster.scheduler import (HIGH_PRIORITY, NEVER_STARTED,
                                     ReservationScheduler)
from repro.cluster.workload import JobRecord
from repro.core.ft.detection import SimulatedFleet, two_round_detection
from repro.utils import logger

# event kinds (heap tiebreak is the unique seq, so the numeric order only
# documents intent: frees before admissions at identical timestamps)
FINISH, ARRIVE, FAIL, REPAIR = 0, 1, 2, 3


@dataclasses.dataclass
class ReplayConfig:
    injector: Optional[FailureInjector] = None   # None = pure queue replay
    checkpoint_interval_min: float = 30.0        # §6.1 async ckpt cadence
    checkpointed_types: tuple = CHECKPOINTED_TYPES
    backfill: bool = False
    backfill_window: int = 32
    max_restarts: int = 8
    node_gpus: int = 8                            # GPUs lost per cordon
    max_cordon_frac: float = 0.25                 # never drain >25% of fleet
    reject_impossible: bool = True                # gpus > cluster -> reject
    seed: int = 0                                 # node-pick determinism
    record_segments: bool = False                 # keep per-attempt run spans


@dataclasses.dataclass
class ClassStats:
    failures: int = 0
    lost_gpu_min: float = 0.0        # rolled-back work x GPUs
    overhead_min: float = 0.0        # restart downtime (wall, not GPU-time)


@dataclasses.dataclass
class ReplayResult:
    jobs: list
    events_processed: int = 0
    by_class: dict = dataclasses.field(default_factory=dict)
    cordon_events: int = 0
    detection_probes: int = 0
    killed_job_ids: list = dataclasses.field(default_factory=list)
    rejected_job_ids: list = dataclasses.field(default_factory=list)
    # with record_segments: (job_id, gpus, start_min, end_min, outcome)
    # per execution attempt, outcome in {"finish", "fail"}
    segments: list = dataclasses.field(default_factory=list)

    # -- aggregates ---------------------------------------------------------

    @property
    def total_restarts(self) -> int:
        return sum(j.restarts for j in self.jobs)

    @property
    def lost_gpu_hours(self) -> float:
        return sum(s.lost_gpu_min for s in self.by_class.values()) / 60.0

    def summary(self) -> dict:
        """JSON-ready per-jtype queue-delay quantiles, restart counts and
        lost-GPU-hours — the Fig. 6 / Fig. 13-14 / Table 2 analogues."""
        by_type: dict[str, list] = collections.defaultdict(list)
        for j in self.jobs:
            by_type[j.jtype].append(j)
        queue = {}
        restarts = {}
        lost = {}
        for t, js in sorted(by_type.items()):
            waits = np.array([j.queue_min for j in js
                              if math.isfinite(j.queue_min)])
            never = sum(1 for j in js if not math.isfinite(j.queue_min))
            if waits.size:
                p50, p90, p99 = np.percentile(waits, [50, 90, 99])
            else:
                p50 = p90 = p99 = 0.0
            queue[t] = {"p50_min": float(p50), "p90_min": float(p90),
                        "p99_min": float(p99), "n": int(waits.size),
                        "n_never_started": int(never)}
            restarts[t] = {"total": int(sum(j.restarts for j in js)),
                           "max": int(max((j.restarts for j in js),
                                          default=0)),
                           "jobs_restarted": int(sum(1 for j in js
                                                     if j.restarts))}
            lost[t] = {"gpu_hours": float(sum(j.lost_gpu_min for j in js)
                                          / 60.0)}
        return {
            "n_jobs": len(self.jobs),
            "events_processed": self.events_processed,
            "queue_delay_quantiles": queue,
            "restart_counts": restarts,
            "lost_gpu_hours_by_jtype": lost,
            "lost_gpu_hours_by_class": {
                name: {"failures": s.failures,
                       "gpu_hours": s.lost_gpu_min / 60.0,
                       "restart_overhead_min": s.overhead_min}
                for name, s in sorted(self.by_class.items())},
            "total_restarts": self.total_restarts,
            "total_lost_gpu_hours": self.lost_gpu_hours,
            "cordon_events": self.cordon_events,
            "detection_probes": self.detection_probes,
            "killed_jobs": len(self.killed_job_ids),
            "rejected_jobs": len(self.rejected_job_ids),
        }


def replay_trace(jobs: list[JobRecord], total_gpus: int, *,
                 reserved_frac: float = 0.85,
                 config: Optional[ReplayConfig] = None) -> ReplayResult:
    """Replay ``jobs`` through the reservation scheduler, optionally with
    failure injection. Mutates each job's ``queue_min`` / ``restarts`` /
    ``lost_gpu_min`` / ``requeue_wait_min`` in place and returns the
    aggregate :class:`ReplayResult`."""
    cfg = config or ReplayConfig()
    sched = ReservationScheduler(total_gpus, reserved_frac)
    injector = cfg.injector
    ckpt_types = frozenset(cfg.checkpointed_types)
    result = ReplayResult(jobs=jobs)
    rng = random.Random(cfg.seed ^ 0xC0FFEE)

    n_nodes = max(total_gpus // cfg.node_gpus, 1)
    fleet = SimulatedFleet(n_nodes)
    max_cordoned = int(n_nodes * cfg.max_cordon_frac)

    # reset per-run state so the same job list can be replayed repeatedly
    # (e.g. with and without injection for an apples-to-apples comparison)
    for j in jobs:
        j.queue_min = 0.0
        j.requeue_wait_min = 0.0
        j.restarts = 0
        j.lost_gpu_min = 0.0
        j._done = 0.0
        j._started = False

    # event heap: (time, seq, kind, payload) — seq is globally unique, so
    # the heap order is a strict total order (deterministic replay)
    events: list = [(j.submit_min, i, ARRIVE, j)
                    for i, j in enumerate(jobs)]
    heapq.heapify(events)
    seq = len(jobs)

    wait_hi: collections.deque = collections.deque()
    wait_lo: collections.deque = collections.deque()
    hi_types = HIGH_PRIORITY

    # per-job transient state lives on the record (like sched's ``_alloc``):
    #   _arrived_at  time of the current (re)submission
    #   _done        checkpointed progress (minutes of completed work)
    #   _run_start   wall time the current attempt started

    def start(job: JobRecord, now: float) -> None:
        nonlocal seq
        sched.start(job)
        wait = now - job._arrived_at
        if not job._started:
            job._started = True
            job.queue_min = wait        # the paper's queuing delay (Fig. 6)
        else:
            job.requeue_wait_min += wait
        remaining = job.duration_min - job._done
        job._run_start = now
        hit = injector.draw(job.jtype, job.gpus, remaining) \
            if injector is not None else None
        if hit is None:
            heapq.heappush(events, (now + remaining, seq, FINISH, job))
        else:
            ttf, cls = hit
            heapq.heappush(events, (now + ttf, seq, FAIL, (job, cls)))
        seq += 1

    def backfill_scan(q: collections.deque, now: float) -> None:
        """Head is blocked: start any of the next ``backfill_window`` jobs
        that fit right now (greedy — may delay the head; see module doc)."""
        i = 1
        limit = min(len(q), cfg.backfill_window)
        while i < limit:
            j = q[i]
            if sched.can_start(j):
                del q[i]
                start(j, now)
                limit -= 1
            else:
                i += 1

    def try_start(now: float) -> None:
        for q in (wait_hi, wait_lo):
            while q:
                j = q[0]
                if sched.can_start(j):
                    q.popleft()
                    start(j, now)
                else:
                    # FIFO head-of-line: later jobs can't jump the queue
                    # (this is exactly the paper's eval-delay mechanism)
                    break
            if cfg.backfill and q:
                backfill_scan(q, now)

    def on_fail(job: JobRecord, cls: ReplayFailureClass, now: float) -> None:
        nonlocal seq
        sched.finish(job)
        if cfg.record_segments:
            result.segments.append(
                (job.job_id, job.gpus, job._run_start, now, "fail"))
        stats = result.by_class.setdefault(cls.name, ClassStats())
        stats.failures += 1
        progress = job._done + (now - job._run_start)
        if job.jtype in ckpt_types and cfg.checkpoint_interval_min > 0:
            rollback = (math.floor(progress / cfg.checkpoint_interval_min)
                        * cfg.checkpoint_interval_min)
        else:
            rollback = 0.0
        lost = progress - rollback
        job.lost_gpu_min += lost * job.gpus
        stats.lost_gpu_min += lost * job.gpus
        stats.overhead_min += cls.restart_overhead_min
        job._done = rollback
        job.restarts += 1

        if cls.needs_cordon and len(fleet.cordoned) < max_cordoned:
            # the faulty node is hidden in the fleet; locate it with the
            # §6.1 two-round allgather sweep, then cordon what it finds
            candidates = [n for n in fleet.healthy_nodes()
                          if n not in fleet.faulty]
            if candidates:
                fleet.fail({rng.choice(candidates)})
            det = two_round_detection(fleet.healthy_nodes(), fleet)
            result.detection_probes += det.probes
            if det.faulty:
                fleet.cordon(det.faulty)
                for n in det.faulty:
                    fleet.faulty.discard(n)
                take_r, take_s = sched.cordon(cfg.node_gpus * len(det.faulty))
                result.cordon_events += len(det.faulty)
                heapq.heappush(events, (now + max(cls.repair_min, 1e-9), seq,
                                        REPAIR, (det.faulty, take_r, take_s)))
                seq += 1

        if job.restarts > cfg.max_restarts:
            result.killed_job_ids.append(job.job_id)
            return
        heapq.heappush(events, (now + cls.restart_overhead_min, seq,
                                ARRIVE, job))
        seq += 1

    processed = 0
    heappop = heapq.heappop
    can_start = sched.can_start
    backfill_on = cfg.backfill
    backfill_window = cfg.backfill_window
    # Dispatch invariant: between events, every non-empty wait queue has a
    # blocked head (try_start runs to quiescence after each capacity-freeing
    # event). An ARRIVE changes no free capacity, so it can enable at most
    # *itself* — when its queue is empty (or, under backfill, when it lands
    # inside the scan window). That turns half of all events into O(1)
    # appends and is the main reason million-job replays stay in seconds.
    while events:
        now, _, kind, payload = heappop(events)
        processed += 1
        if kind == ARRIVE:
            job = payload
            if job.gpus > total_gpus:
                if cfg.reject_impossible:
                    logger.warning(
                        "job %d (%s) demands %d GPUs on a %d-GPU cluster; "
                        "rejected (never started)", job.job_id, job.jtype,
                        job.gpus, total_gpus)
                    job.queue_min = NEVER_STARTED
                    result.rejected_job_ids.append(job.job_id)
                    continue
                # legacy mode: an impossible job wedges its FIFO class and
                # everything behind it surfaces as never-started at drain
            job._arrived_at = now
            q = wait_hi if job.jtype in hi_types else wait_lo
            if (not q or (backfill_on and len(q) < backfill_window)) \
                    and can_start(job):
                start(job, now)
            else:
                q.append(job)
            continue
        if kind == FINISH:
            sched.finish(payload)
            if cfg.record_segments:
                result.segments.append(
                    (payload.job_id, payload.gpus, payload._run_start, now,
                     "finish"))
        elif kind == FAIL:
            on_fail(payload[0], payload[1], now)
        else:  # REPAIR
            nodes, take_r, take_s = payload
            fleet.repair(nodes)
            sched.uncordon(take_r, take_s)
        try_start(now)

    # jobs still waiting when the event stream drains never ran: give them
    # an unambiguous sentinel instead of the misleading default 0.0
    for q in (wait_hi, wait_lo):
        for j in q:
            if not j._started:
                j.queue_min = NEVER_STARTED
    result.events_processed = processed
    return result
