"""Failure-aware, event-driven cluster trace replay (paper §3.2 + §5 + §6).

This is the subsystem that exercises *scheduling* and *fault tolerance* in
one scenario: it replays a ``workload.generate_jobs`` population through the
``ReservationScheduler`` while injecting the §5 interruption taxonomy
(``repro.cluster.failures``) into running jobs — reproducing the paper's
joint characterization of queuing delay (Fig. 6), restart counts and lost
GPU hours (Figs. 13-14, Table 2/3 analogues) — and, when diagnosis is
enabled, closes the §6.1 loop: every injected failure synthesizes a log
snippet, runs it through the ``core/ft`` pipeline (LogCompressor →
RuleBasedDiagnoser → Failure Agent), and the verdict picks the recovery
policy.

Mechanics
---------
Dynamic events live in a lazy-deletion heap; the initial 1M-job submission
stream is consumed through a sorted *arrival cursor* instead (an arrival is
known ahead of time, so paying O(log n) heap traffic for it is pure waste —
switching the cold stream to a cursor is what brought the full 1M-job Seren
replay from ~30 s under 15 s). Event kinds:

  ``FINISH``  a running job completes and frees its GPUs;
  ``ARRIVE``  a job is *re*-submitted after a failure (initial submissions
              come from the arrival cursor);
  ``FAIL``    an injected interruption hits a running job;
  ``REPAIR``  a cordoned node returns — to the schedulable pool, or straight
              back to the elastic job that lent it.

``FINISH``/``FAIL`` payloads carry the job's *epoch*; elastic resizes bump
the epoch, so a stale end-event popped later is simply discarded
(lazy deletion) instead of paying O(n) heap surgery.

Waiting jobs live in ``deque``-backed FIFO classes (reservation-priority,
spare-pool, and the revocable best-effort lease tier), so dispatch is O(1)
per started job.

Failure handling per injected event (class ``hardware``/``infra``/
``preemption``):

  1. the job's progress rolls back to the last periodic checkpoint
     (``CheckpointManager``-style accounting: work since the last multiple
     of ``checkpoint_interval_min`` is *lost GPU time*; non-checkpointed
     types restart from zero);
  2. with ``diagnose=True`` the incident's synthesized log
     (``failures.synthesize_failure_log``) is pushed through the §6.1
     ``FailureDiagnosisSystem`` and the verdict
     (``core.ft.diagnosis.verdict_class``) picks the recovery policy:

       hardware   -> cordon + requeue, or **elastic shrink** when
                     ``elastic=True``: the failed node's GPUs leave with the
                     cordon, the job continues on its surviving nodes with
                     the remaining runtime stretched proportionally, and the
                     width is restored at the node's ``REPAIR`` event;
       transient  -> in-place restart: the job keeps its allocation, pays
                     the restart overhead, resumes from the checkpoint;
       user       -> requeue (someone must fix the script and resubmit);

     preemptions are scheduler-initiated, so they always requeue (their
     verdict is still recorded). Without diagnosis the behavior is the
     original cordon(+)requeue driven by the injected class alone.
  3. node-fault cordons run the §6.1 ``two_round_detection`` sweep first;
     cordoned GPUs return at a ``REPAIR`` event ``repair_min`` later;
  4. a requeued job re-enters at the *back* of its priority class with its
     remaining work, up to ``max_restarts`` attempts — beyond that the job
     is killed, mirroring the paper's jobs that exhaust automatic recovery.

Backfill
--------
``backfill="greedy"`` (or ``True``) enables a bounded-window greedy
backfill: when the FIFO head does not fit, up to ``backfill_window`` later
jobs in the same class may start if they fit in the *currently free* GPUs.
This is deliberately aggressive — it can delay the head. ``backfill="easy"``
is the conservative EASY variant: a later job may start only if its
estimated completion lands before the head's *shadow time* (the earliest
instant the head could start given the running jobs' scheduled ends), so the
head is never delayed. The default (off) preserves the paper's plain FIFO
policy.

The elastic capacity pool (free-GPU ledger)
-------------------------------------------
The engine keeps one *ledger* over the scheduler's free GPUs that unifies
the two §6 systems:

* **Opportunistic regrowth** (``opportunistic_regrow=True``, the default
  with ``elastic=True``): a shrunken job no longer waits for its lender
  node's ``REPAIR`` — at every dispatch/repair/completion event, leftover
  free capacity is granted back to shrunken jobs (FIFO by shrink time) via
  ``ReservationScheduler.grow``, which respects the reservation policy
  (best-effort allocations regrow from the spare pool only). Remaining
  runtime compresses proportionally in the nominal-minute accounting, and
  the node's GPUs rejoin the free pools at its eventual repair. Priority
  rule: regrowth runs strictly *after* queue dispatch, and under
  ``backfill="easy"`` a regrow is admitted only if the regrown job's new
  completion still lands before every waiting head's shadow time — so
  regrowth can never delay the EASY-protected queue head (the proof is the
  same exchange argument as EASY backfill: the granted GPUs are returned,
  with interest, before the shadow instant).
* **Trial borrowing** (``borrower=``, duck-typed to
  ``repro.core.evalsched.coordinator.TrialBorrower``): decomposed §6.2 eval
  shards lease idle-fragment and shrunken-job GPUs from the same ledger.
  Leases are *virtual overlays* on free capacity — dispatch never sees
  them, so borrowing cannot delay any queued job; after each capacity
  event the engine calls ``borrower.reconcile(now, free)`` and the
  borrower revokes leases newest-first whenever dispatch or regrowth
  consumed the capacity out from under them, charging the preempted shard
  its decomposed-trial restart cost. Borrowed GPU-minutes, lease and
  preemption counts surface in ``ReplayResult.summary()["pool"]``.
* **Head-delay characterization**: each time a job becomes a *blocked*
  FIFO head the engine records how long it stays head before starting, and
  (sampled every ``head_delay_sample`` heads; every head under EASY) the
  shadow-time estimate at that instant — ``summary()["head_delay"]``
  reports the realized p50/p95/p99 and the shadow-estimate error tail,
  quantifying how much the EASY estimate (which cannot see future
  failures/repairs) misses by at Seren scale.

Node-local revocable leases
---------------------------
Two extensions turn the ledger's leases from *node-less capacity* into
node-local, policy-revocable allocations:

* **Placement** (``placement=True``): a :class:`NodeLedger` mirrors every
  capacity movement onto the ``SimulatedFleet``'s node ids — job
  allocations pack best-fit onto concrete nodes, elastic shrinks drain the
  *job's own* faulty node, and borrowed trial shards land on nodes with
  genuinely idle GPUs. Each borrowed shard's model load then contends for
  that node's §6.2 storage NIC (``ClusterSpec.load_minutes_shared``), so
  the Fig. 16 load collapse appears inside the replay
  (``summary()["placement"]``), not just in ``evalsched``'s standalone
  simulator.
* **Best-effort tier** (jobs with ``JobRecord.best_effort``): checkpointed
  low-priority jobs start on *revocable leases* over any idle capacity —
  including the pretraining reservation's unused quota. The instant queue
  dispatch or a shrunken job's regrowth wants the GPUs, the newest leases
  are revoked: the job rolls back to its last periodic checkpoint, pays
  ``revoke_overhead_min`` and requeues at the back of its tier — the
  paper's §3.2 quota-reclamation preemption reproduced as a *scheduling
  policy* (ledger key ``quota_reclaim``) instead of an injected failure
  class, with accounting identical to an injected ``preemption``.
  Ordering within one capacity event is fixed and regression-pinned:
  queue dispatch (revoking as needed) → backfill → regrowth (revocation
  *lands before* the grow reads the free pools, so the same GPUs are never
  double-counted) → new best-effort leases → trial-borrower reconcile.

Regrowth additionally charges an explicit re-shard stall
(``reshard_cost_min``) when a shrunken job changes width — previously that
cost was folded into (i.e. hidden by) the nominal-minute stretch.
"""
from __future__ import annotations

import collections
import copy
import dataclasses
import gc
import heapq
import math
import operator
import random
import zlib
from typing import Optional, Union

import numpy as np

from repro.cluster.analysis import (head_delay_stats, placement_stats,
                                    pool_stats)
from repro.cluster.failures import (CHECKPOINTED_TYPES, PREEMPTION,
                                    QUOTA_RECLAIM, FailureInjector,
                                    ReplayFailureClass,
                                    synthesize_failure_log)
from repro.cluster.scheduler import (HIGH_PRIORITY, NEVER_STARTED,
                                     ReservationScheduler)
from repro.cluster.workload import PRETRAIN_ARCHS, JobRecord
from repro.core.ft.detection import SimulatedFleet, two_round_detection
from repro.core.ft.diagnosis import (VERDICT_HARDWARE, VERDICT_TRANSIENT,
                                     FailureDiagnosisSystem, verdict_class)
from repro.utils import logger

# event kinds (heap tiebreak is the unique seq, so the numeric order only
# documents intent: frees before admissions at identical timestamps)
FINISH, ARRIVE, FAIL, REPAIR = 0, 1, 2, 3

_SUBMIT_KEY = operator.attrgetter("submit_min")

# recovery policies an injected failure can resolve to
POLICY_REQUEUE, POLICY_INPLACE, POLICY_ELASTIC = \
    "requeue", "inplace", "elastic"
POLICY_KILLED = "killed"


class DiagnosisLoop:
    """Diagnosis-in-the-loop for injected failures (L4-style, §6.1).

    Each incident samples one of ``n_variants`` synthetic log variants for
    its class and runs it through the full ``FailureDiagnosisSystem``
    (compressor → rules → vector store → agent). Verdicts are cached per
    (class, variant), so the pipeline executes a bounded number of times no
    matter how many failures a million-job replay injects — which mirrors
    production reality: the paper's continuous learning turns repeat
    incidents into cheap rule hits.
    """

    __slots__ = ("system", "n_variants", "flavor", "_rng", "_cache",
                 "incidents")

    def __init__(self, system: Optional[FailureDiagnosisSystem] = None, *,
                 n_variants: int = 32, seed: int = 0, flavor: str = "train"):
        self.system = system or FailureDiagnosisSystem()
        self.n_variants = max(1, n_variants)
        # "train" or "serve": which banner/heartbeat the synthesized logs
        # carry (the serving replay diagnoses inference-engine logs)
        self.flavor = flavor
        self._rng = random.Random(seed ^ 0xD1A6)
        self._cache: dict = {}
        self.incidents = 0

    def verdict(self, cls: ReplayFailureClass):
        """Diagnose one injected incident of ``cls``.

        Returns ``(verdict, diagnosis, truth)`` where ``verdict`` is the
        recovery class (``hardware``/``transient``/``user``), ``diagnosis``
        the full :class:`Diagnosis`, and ``truth`` the ground-truth Table-3
        name the log was synthesized from (None for preemptions)."""
        self.incidents += 1
        variant = self._rng.randrange(self.n_variants)
        key = (cls.name, variant)
        hit = self._cache.get(key)
        if hit is None:
            seed = (zlib.crc32(cls.name.encode()) << 8) ^ variant
            lines, truth = synthesize_failure_log(cls, seed=seed,
                                                  flavor=self.flavor)
            diag = self.system.diagnose(lines)
            hit = (verdict_class(diag), diag, truth)
            self._cache[key] = hit
        return hit

    @property
    def pipeline_runs(self) -> int:
        return len(self._cache)


class NodeLedger:
    """Per-node free-GPU accounting behind the elastic capacity pool.

    Mirrors every capacity movement of the ``ReservationScheduler`` onto
    the ``SimulatedFleet``'s node ids, so leases become *node-local*:
    ``free_total()`` always equals the scheduler's summed free pools (the
    quota split is the scheduler's dimension; this ledger tracks the
    physical one). Placement policy: wide jobs take whole idle nodes
    first, the remainder best-fits into the smallest covering fragment —
    packing keeps fragmentation (and thus the per-node NIC contention
    borrowed shards see) realistic.

    Free capacity that cannot be attributed to a healthy node — the
    cluster-size remainder, or GPUs returned by a job whose node was
    drained under it — lives in the *unplaced* overflow pool. Jobs may
    draw it as a last resort (pseudo node id ``-1``); borrowed trial
    shards never do (a shard needs a concrete node NIC to load over).

    ``dirty`` collects nodes whose free count *decreased* since the
    borrower last reconciled, so node-local lease revocation is O(changed
    nodes), not O(fleet), per capacity event.
    """

    __slots__ = ("n_nodes", "node_gpus", "free", "missing", "cordoned",
                 "float_free", "dirty", "_buckets")

    def __init__(self, n_nodes: int, node_gpus: int, total_gpus: int):
        self.n_nodes = n_nodes
        self.node_gpus = min(node_gpus, total_gpus)
        self.free = [self.node_gpus] * n_nodes
        # GPUs absent from the node: drained free capacity (cordons),
        # elastic-detached allocations, and allocation shares returned to
        # the overflow pool while the node was cordoned. ``missing`` is
        # *invariant under alloc/release* (those just move GPUs between
        # free and allocated on the same node), so the per-event hot path
        # no longer maintains a per-node used counter — only the rare
        # cordon/detach/attach/repair paths touch it. A node's allocated
        # count, when needed, is node_gpus - free[n] - missing[n].
        self.missing = [0] * n_nodes
        self.cordoned: set = set()
        self.float_free = total_gpus - n_nodes * self.node_gpus
        self.dirty: set = set()
        self._buckets: list = [set() for _ in range(self.node_gpus + 1)]
        self._buckets[self.node_gpus].update(range(n_nodes))

    def free_total(self) -> int:
        """Summed free GPUs (invariant: == scheduler free; test hook)."""
        return sum(self.free) + self.float_free

    def _set_free(self, n: int, new: int) -> None:
        old = self.free[n]
        if n not in self.cordoned:
            self._buckets[old].discard(n)
            self._buckets[new].add(n)
        self.free[n] = new
        if new < old:
            self.dirty.add(n)

    # -- job allocation -----------------------------------------------------

    def alloc(self, gpus: int) -> dict:
        """Place ``gpus`` onto concrete nodes; returns ``{node: count}``.

        Runs once per job start — the per-event hot path — so the
        best-bucket probe is inlined (the index is incrementally
        maintained; no node scan, only a <= ``node_gpus``-step walk over
        the bucket array)."""
        out: dict = {}
        g = gpus
        cap = self.node_gpus
        buckets = self._buckets
        free = self.free
        dirty = self.dirty
        whole = buckets[cap]
        if g >= cap and whole:
            # a wide job can touch hundreds of nodes: bind the per-node
            # methods once, not per popped node
            pop = whole.pop
            dirty_add = dirty.add
            empty_add = buckets[0].add
            while g >= cap and whole:
                n = pop()
                free[n] = 0
                dirty_add(n)
                empty_add(n)
                out[n] = cap
                g -= cap
        while g > 0:
            # inlined _best_bucket: smallest fragment covering g, else the
            # largest smaller nonempty fragment
            lo = g if g < cap else cap
            b = 0
            for c in range(lo, cap + 1):
                if buckets[c]:
                    b = c
                    break
            else:
                for c in range(lo - 1, 0, -1):
                    if buckets[c]:
                        b = c
                        break
            if b == 0:
                break
            bucket = buckets[b]
            n = next(iter(bucket))
            k = b if b < g else g
            bucket.discard(n)
            buckets[b - k].add(n)
            free[n] = b - k
            dirty.add(n)
            out[n] = k      # a node is never visited twice in one alloc
            g -= k
        if g > 0:
            if g > self.float_free:
                raise RuntimeError("NodeLedger.alloc out of sync with the "
                                   "scheduler free pools")
            self.float_free -= g
            out[-1] = g
        return out

    def release(self, nodes: Optional[dict]) -> None:
        """Return a finished/revoked/requeued job's GPUs to the free pool.
        GPUs on a node drained while the job kept running, and unplaced
        GPUs, return through the overflow pool."""
        if not nodes:
            return
        buckets = self._buckets
        free = self.free
        cordoned = self.cordoned
        if cordoned:
            for n, k in nodes.items():
                if n < 0:
                    self.float_free += k
                elif n in cordoned:
                    # the node keeps running without these GPUs until its
                    # repair: they return through the overflow pool
                    self.missing[n] += k
                    self.float_free += k
                else:
                    old = free[n]
                    buckets[old].discard(n)
                    buckets[old + k].add(n)
                    free[n] = old + k
        else:
            for n, k in nodes.items():
                if n < 0:
                    self.float_free += k
                    continue
                old = free[n]
                buckets[old].discard(n)
                buckets[old + k].add(n)
                free[n] = old + k

    # -- elastic shrink / regrow at the lender's repair ---------------------

    def detach(self, nodes: dict, node: int) -> int:
        """Elastic shrink: the job's GPUs on ``node`` leave the cluster
        with the cordoned node (they were never free). Returns the count
        detached."""
        k = nodes.pop(node, 0)
        if k and node >= 0:
            self.missing[node] += k
        return k

    def attach(self, nodes: Optional[dict], repaired, give: int) -> None:
        """Inverse of :meth:`detach` at the lender's REPAIR: ``give`` GPUs
        rejoin the lender's allocation on the repaired node(s)."""
        if nodes is None:
            return
        for n in repaired:
            if give <= 0:
                return
            k = min(give, self.missing[n])
            if k > 0:
                self.missing[n] -= k
                nodes[n] = nodes.get(n, 0) + k
                give -= k
        if give > 0:            # defensively: headroom vanished, hold as
            nodes[-1] = nodes.get(-1, 0) + give     # unplaced allocation

    # -- cordon / repair ----------------------------------------------------

    def cordon_node(self, node: int) -> int:
        """Drain ``node``: its free GPUs leave the pools (handed back via
        :meth:`repair_nodes` + :meth:`add_free`) and the node stops being
        a placement or lease target. Returns the free GPUs drained."""
        if node < 0 or node in self.cordoned:
            return 0
        self.cordoned.add(node)
        k = self.free[node]
        self._buckets[k].discard(node)
        self.free[node] = 0
        if k:
            self.missing[node] += k
            self.dirty.add(node)
        return k

    def repair_nodes(self, nodes) -> None:
        for n in nodes:
            if n in self.cordoned:
                self.cordoned.discard(n)
                self._buckets[self.free[n]].add(n)

    def add_free(self, amount: int, prefer=()) -> None:
        """Return drained GPUs to the free pool, preferring the repaired
        node(s) up to their physical headroom; overflow is unplaced."""
        for n in prefer:
            if amount <= 0:
                return
            if n < 0 or n in self.cordoned:
                continue
            k = min(self.missing[n], amount)
            if k > 0:
                self.missing[n] -= k
                self._set_free(n, self.free[n] + k)
                amount -= k
        if amount > 0:
            self.float_free += amount

    # -- borrowed-lease placement (TrialBorrower) ---------------------------

    def lease_node(self, leases: dict) -> int:
        """Node for a new 1-GPU borrowed lease: best-fit packing — the
        smallest free fragment with lease headroom left, topped-up nodes
        first. Same philosophy as job allocation (keep whole nodes free
        for real jobs), and the source of the §6.2 reality the paper
        stress-tested: a burst of trial shards piles onto one node's
        storage NIC and their loads collapse (Fig. 16). Returns -1 when
        only unplaced capacity is left."""
        if not leases:
            # fast path (no live leases): headroom == fragment size, so
            # the first node of the smallest nonempty bucket wins — the
            # identical choice the scan below would make (h == b for every
            # member, the h == 1 early return only fires when b == 1, and
            # ties keep the first node in set-iteration order)
            for b in range(1, self.node_gpus + 1):
                bucket = self._buckets[b]
                if bucket:
                    return next(iter(bucket))
            return -1
        # only nodes carrying live leases can have headroom != their free
        # level; precompute those levels so lease-free buckets resolve to
        # their first node without scanning potentially hundreds of members
        free = self.free
        lease_levels = {free[n] for n in leases}
        best, best_h = -1, 0
        for b in range(1, self.node_gpus + 1):
            bucket = self._buckets[b]
            if not bucket:
                continue
            if b not in lease_levels:
                # every member has h == b (>= 1): the scan would keep the
                # first node (ties never improve; h == 1 only when b == 1,
                # which also returns the first node)
                return next(iter(bucket))
            for n in bucket:
                h = b - leases.get(n, 0)
                if h <= 0:
                    continue
                if h == 1:          # one slot left: finishes packing a node
                    return n
                if best < 0 or h < best_h:
                    best, best_h = n, h
            if best >= 0:
                return best         # smallest-fragment bucket had headroom
        return best


@dataclasses.dataclass(slots=True)
class ReplayConfig:
    injector: Optional[FailureInjector] = None   # None = pure queue replay
    checkpoint_interval_min: float = 30.0        # §6.1 async ckpt cadence
    checkpointed_types: tuple = CHECKPOINTED_TYPES
    backfill: Union[bool, str] = False           # False | "greedy" | "easy"
    backfill_window: int = 32
    max_restarts: int = 8
    node_gpus: int = 8                            # GPUs lost per cordon
    max_cordon_frac: float = 0.25                 # never drain >25% of fleet
    reject_impossible: bool = True                # gpus > cluster -> reject
    seed: int = 0                                 # node-pick determinism
    record_segments: bool = False                 # keep per-attempt run spans
    # -- §6.1 diagnosis-in-the-loop recovery --------------------------------
    diagnose: bool = False                        # run the core/ft pipeline
    diagnosis: Optional[object] = None            # DiagnosisLoop or
    #                                               FailureDiagnosisSystem
    diagnosis_variants: int = 32                  # log variants per class
    elastic: bool = False                         # allow elastic shrink
    recovery_policy: str = "auto"                 # or force one policy:
    #                                               requeue|inplace|elastic
    # -- elastic capacity pool (free-GPU ledger) ----------------------------
    opportunistic_regrow: bool = True             # shrunken jobs reclaim
    #                                               width from the free pool
    borrower: Optional[object] = None             # evalsched TrialBorrower
    #                                               (reconcile/close protocol)
    head_delay_sample: int = 64                   # shadow-estimate sampling
    #                                               (every Nth head; 0 = off;
    #                                                EASY samples every head)
    # -- node-local revocable leases ----------------------------------------
    placement: bool = False                       # NodeLedger on SimulatedFleet
    #                                               ids: jobs/leases land on
    #                                               concrete nodes, borrowed
    #                                               shards pay NIC-contended
    #                                               model loads
    reshard_cost_min: float = 0.0                 # explicit regrow re-shard
    #                                               stall (pool + repair
    #                                               regrows), replacing the
    #                                               implicit nominal-minute
    #                                               folding
    revoke_overhead_min: float = 2.0              # preempted best-effort
    #                                               lease restart overhead
    #                                               (PREEMPTION-class parity)
    # -- pluggable runtime model --------------------------------------------
    runtime_model: str = "nominal"                # "nominal" | "roofline":
    #                                               how elastic width changes
    #                                               reprice remaining runtime.
    #                                               "nominal" stretches
    #                                               linearly (w/gpus);
    #                                               "roofline" consults the
    #                                               arch's calibrated width
    #                                               curve for jobs tagged
    #                                               with JobRecord.arch
    cost_model: Optional[object] = None           # launch.cost_model
    #                                               .CostModel; None under
    #                                               "roofline" loads the
    #                                               default (artifacts +
    #                                               analytic fallback)


@dataclasses.dataclass(slots=True)
class ClassStats:
    failures: int = 0
    lost_gpu_min: float = 0.0        # rolled-back work x GPUs
    overhead_min: float = 0.0        # restart downtime (wall, not GPU-time)


@dataclasses.dataclass(slots=True)
class ReplayResult:
    jobs: list
    events_processed: int = 0
    by_class: dict = dataclasses.field(default_factory=dict)
    cordon_events: int = 0
    detection_probes: int = 0
    killed_job_ids: list = dataclasses.field(default_factory=list)
    rejected_job_ids: list = dataclasses.field(default_factory=list)
    # with record_segments: (job_id, width, start_min, end_min, outcome)
    # per constant-width execution span, outcome in {"finish", "fail",
    # "resize"} — elastic width changes close one span and open the next
    segments: list = dataclasses.field(default_factory=list)
    # -- diagnosis-driven recovery ------------------------------------------
    policies: collections.Counter = dataclasses.field(
        default_factory=collections.Counter)      # applied policy -> count
    by_policy: dict = dataclasses.field(default_factory=dict)
    verdicts: dict = dataclasses.field(default_factory=dict)
    #   injected class -> Counter of diagnosis verdict classes
    elastic_shrinks: int = 0
    elastic_regrows: int = 0         # width restored at the lender's REPAIR
    stale_events: int = 0            # lazy-deleted end events
    diagnosis_incidents: int = 0
    diagnosis_pipeline_runs: int = 0
    # -- elastic capacity pool (free-GPU ledger) ----------------------------
    pool_regrows: int = 0            # opportunistic regrow events (free pool)
    pool_regrown_gpus: int = 0       # GPUs reclaimed across those events
    pool_reshard_events: int = 0     # regrows that paid the re-shard stall
    pool_reshard_min: float = 0.0    # summed explicit re-shard stall (wall)
    pool_free_gpu_min: float = 0.0   # time-integrated free (idle) capacity
    horizon_min: float = 0.0         # last event timestamp (ledger window)
    borrow: Optional[dict] = None    # TrialBorrower.stats() when borrowing
    be_lease_starts: int = 0         # best-effort jobs started on leases
    placement: Optional[dict] = None  # NodeLedger drain state (placement on)
    # runtime-model accounting (None under the default "nominal" model, so
    # summaries — and the committed golden fixtures — are unchanged unless
    # a roofline replay was requested)
    runtime_model_stats: Optional[dict] = None
    head_delays: list = dataclasses.field(default_factory=list)
    #   realized minutes each blocked FIFO head waited before starting
    shadow_errors: list = dataclasses.field(default_factory=list)
    #   realized-minus-estimated head wait (EASY shadow estimate error)
    # memoized summary() tree (built on first call; the per-jtype
    # aggregation walks every job record, which is ~1M touches at Seren
    # scale and used to re-run — with a re-sort of every per-class dict —
    # on each call)
    _summary: Optional[dict] = dataclasses.field(
        default=None, repr=False, compare=False)

    # -- aggregates ---------------------------------------------------------

    @property
    def total_restarts(self) -> int:
        return sum(j.restarts for j in self.jobs)

    @property
    def lost_gpu_hours(self) -> float:
        return sum(s.lost_gpu_min for s in self.by_class.values()) / 60.0

    def summary(self) -> dict:
        """JSON-ready per-jtype queue-delay quantiles, restart counts,
        lost-GPU-hours and recovery/diagnosis breakdowns — the Fig. 6 /
        Fig. 13-14 / Table 2 analogues.

        Built once and memoized; every call returns a deep copy, so
        repeated calls are side-effect-free — mutating a returned tree
        (or the result's ``borrow``/``placement`` dicts it used to share
        references with) can no longer change what the next call sees."""
        if self._summary is None:
            self._summary = self._build_summary()
        return copy.deepcopy(self._summary)

    def _build_summary(self) -> dict:
        # One pass over the job records into packed per-type accumulators
        # (waits list, never/restart counters, sequential lost-GPU sum),
        # then numpy for the quantiles. Bit-exact vs the old per-metric
        # re-scan: the wait arrays hold the same values in the same order
        # (np.percentile is order-independent anyway), counters are
        # integers, and the lost-GPU float sum accumulates in the same
        # job order as the old ``sum()`` over the grouped records.
        aggs: dict[str, list] = {}
        isfinite = math.isfinite
        n_be = be_never = 0
        for j in self.jobs:
            a = aggs.get(j.jtype)
            if a is None:
                #      [waits, n_never, restarts, max_restarts,
                #       jobs_restarted, lost_gpu_min]
                a = aggs[j.jtype] = [[], 0, 0, 0, 0, 0.0]
            q = j.queue_min
            if isfinite(q):
                a[0].append(q)
                never = False
            else:
                a[1] += 1
                never = True
            r = j.restarts
            if r:
                a[2] += r
                if r > a[3]:
                    a[3] = r
                a[4] += 1
            a[5] += j.lost_gpu_min
            if j.best_effort:
                n_be += 1
                if never:       # JobRecord.started == isfinite(queue_min)
                    be_never += 1
        queue = {}
        restarts = {}
        lost = {}
        for t in sorted(aggs):
            a = aggs[t]
            waits = np.array(a[0])
            if waits.size:
                p50, p90, p99 = np.percentile(waits, [50, 90, 99])
            else:
                p50 = p90 = p99 = 0.0
            queue[t] = {"p50_min": float(p50), "p90_min": float(p90),
                        "p99_min": float(p99), "n": int(waits.size),
                        "n_never_started": int(a[1])}
            restarts[t] = {"total": int(a[2]), "max": int(a[3]),
                           "jobs_restarted": int(a[4])}
            lost[t] = {"gpu_hours": float(a[5] / 60.0)}
        summary = {
            "n_jobs": len(self.jobs),
            "events_processed": self.events_processed,
            "queue_delay_quantiles": queue,
            "restart_counts": restarts,
            "lost_gpu_hours_by_jtype": lost,
            "lost_gpu_hours_by_class": {
                name: {"failures": s.failures,
                       "gpu_hours": s.lost_gpu_min / 60.0,
                       "restart_overhead_min": s.overhead_min}
                for name, s in sorted(self.by_class.items())},
            "total_restarts": sum(a[2] for a in aggs.values()),
            "total_lost_gpu_hours": self.lost_gpu_hours,
            "cordon_events": self.cordon_events,
            "detection_probes": self.detection_probes,
            "killed_jobs": len(self.killed_job_ids),
            "rejected_jobs": len(self.rejected_job_ids),
            "recovery": {
                "policies": dict(self.policies),
                "by_policy": {
                    p: {"failures": s.failures,
                        "gpu_hours": s.lost_gpu_min / 60.0,
                        "restart_overhead_min": s.overhead_min}
                    for p, s in sorted(self.by_policy.items())},
                "diagnosis_verdicts": {c: dict(v) for c, v
                                       in sorted(self.verdicts.items())},
                "elastic": {"shrinks": self.elastic_shrinks,
                            "regrows": self.elastic_regrows},
                "diagnosis": {
                    "incidents": self.diagnosis_incidents,
                    "pipeline_runs": self.diagnosis_pipeline_runs},
            },
            "pool": pool_stats(self, be_total=n_be, be_never=be_never),
            "head_delay": head_delay_stats(self),
            "placement": placement_stats(self),
        }
        if self.runtime_model_stats is not None:
            # key present only for roofline replays: the nominal-mode
            # summary tree — and every committed golden fixture built from
            # it — must stay byte-identical
            summary["runtime_model"] = self.runtime_model_stats
        return summary


def replay_trace(jobs: list[JobRecord], total_gpus: int, *,
                 reserved_frac: float = 0.85,
                 config: Optional[ReplayConfig] = None) -> ReplayResult:
    """Replay ``jobs`` through the reservation scheduler, optionally with
    failure injection and diagnosis-driven recovery. Mutates each job's
    ``queue_min`` / ``restarts`` / ``lost_gpu_min`` / ``requeue_wait_min``
    in place and returns the aggregate :class:`ReplayResult`."""
    cfg = config or ReplayConfig()
    sched = ReservationScheduler(total_gpus, reserved_frac)
    injector = cfg.injector
    ckpt_types = frozenset(cfg.checkpointed_types)
    interval = cfg.checkpoint_interval_min
    result = ReplayResult(jobs=jobs)
    rng = random.Random(cfg.seed ^ 0xC0FFEE)

    n_nodes = max(total_gpus // cfg.node_gpus, 1)
    fleet = SimulatedFleet(n_nodes)
    max_cordoned = int(n_nodes * cfg.max_cordon_frac)

    if cfg.recovery_policy not in ("auto", POLICY_REQUEUE, POLICY_INPLACE,
                                   POLICY_ELASTIC):
        raise ValueError(f"unknown recovery_policy {cfg.recovery_policy!r}")
    if cfg.runtime_model not in ("nominal", "roofline"):
        raise ValueError(f"unknown runtime_model {cfg.runtime_model!r}")
    cost_model = None
    if cfg.runtime_model == "roofline":
        cost_model = cfg.cost_model
        if cost_model is None:
            # default model: calibrated cells from artifacts/dryrun/** when
            # present, deterministic analytic fallback otherwise (lazy
            # import — nominal replays never touch the launch stack)
            from repro.launch.cost_model import CostModel
            cost_model = CostModel.load(archs=PRETRAIN_ARCHS)
    diagnosis: Optional[DiagnosisLoop] = None
    diag_incidents0 = diag_runs0 = 0
    if injector is not None and (cfg.diagnose or cfg.diagnosis is not None):
        d = cfg.diagnosis
        if isinstance(d, DiagnosisLoop):
            # a shared loop keeps its verdict cache warm across replays;
            # snapshot its counters so this result reports per-run deltas
            diagnosis = d
            diag_incidents0 = d.incidents
            diag_runs0 = d.pipeline_runs
        else:
            diagnosis = DiagnosisLoop(d, n_variants=cfg.diagnosis_variants,
                                      seed=cfg.seed)

    backfill_policy = None
    if cfg.backfill:
        backfill_policy = "greedy" if cfg.backfill is True else cfg.backfill
        if backfill_policy not in ("greedy", "easy"):
            raise ValueError(f"unknown backfill policy {cfg.backfill!r}")
    greedy = backfill_policy == "greedy"
    easy = backfill_policy == "easy"

    # Per-run job state is reset lazily, at each record's initial-arrival
    # cursor step (one fused pass instead of an extra 1M-iteration loop
    # up front): nothing reads a job's transient state before its first
    # arrival — events exist only for started jobs, the wait queues only
    # hold arrived ones, and the cursor drains every record before the
    # replay ends — so the same job list still replays repeatedly with
    # identical results. ``_hi`` hoists the priority-class membership test
    # onto the record because the dispatch hot path probes it per event.
    hi_types = HIGH_PRIORITY

    # initial submissions are consumed through a cursor over the
    # time-sorted trace (stable sort == the old (submit, index) heap order,
    # so replays stay bit-exact); only *dynamic* events — finishes, failures,
    # requeues, repairs — pay for the heap, which therefore stays small
    # (O(running jobs), not O(trace)).
    arrivals = sorted(jobs, key=_SUBMIT_KEY)
    events: list = []
    seq = len(jobs)

    wait_hi: collections.deque = collections.deque()
    wait_lo: collections.deque = collections.deque()
    wait_be: collections.deque = collections.deque()   # revocable-lease tier
    # running best-effort leases, insertion-ordered (dict: O(1) removal,
    # reversed() gives the LIFO revocation order); the (reserved, spare)
    # totals are maintained incrementally because the blocked-head probe
    # consults them on every event of a saturated replay
    be_running: dict = {}
    be_r_total = be_s_total = 0
    ledger: Optional[NodeLedger] = None
    if cfg.placement:
        ledger = NodeLedger(n_nodes, cfg.node_gpus, total_gpus)
    # -- elastic capacity pool state ----------------------------------------
    # shrunken jobs (width < nominal) eligible for opportunistic regrowth,
    # FIFO by shrink time; entries are dropped lazily once a job regrew to
    # full width or stopped running
    shrunken: dict = {}
    regrow = cfg.opportunistic_regrow
    borrower = cfg.borrower
    # Dirty-flag reconcile trigger: the borrower used to be reconciled
    # after *every* event, but a reconcile is provably a no-op unless
    # (a) total free capacity changed since the last real reconcile,
    # (b) a node's free count dropped under its lease cover (the ledger's
    #     ``dirty`` set is non-empty), or
    # (c) a leased shard's scheduled completion has passed (the borrower's
    #     ``_min_done`` watermark) so progress must be folded/chained.
    # Anything else the borrower could do — revoke, or acquire a new
    # lease — is a pure function of those three inputs: with free
    # unchanged, no dirty node and no completion due, the previous
    # reconcile already leased up to free/max_leases/placeability, and
    # pending/active only ever change inside reconcile itself. Skipping
    # those calls removes one full scan per event on the saturated
    # configurations (~2 calls per event before).
    if borrower is None:
        _reconcile = None
    elif not all(hasattr(borrower, a) for a in
                 ("_min_done", "active", "pending", "max_leases")):
        # duck-typed borrower without the TrialBorrower state surface: no
        # safe skip condition, reconcile after every event (old behavior)
        if ledger is not None:
            def _reconcile(now, free, _b=borrower.reconcile, _l=ledger):
                _b(now, free, _l)
        else:
            _reconcile = borrower.reconcile
    else:
        _b_reconcile = borrower.reconcile
        _last_free = -1

        def _reconcile(now, free):
            # Reconcile only when the borrower could actually act:
            #   fold/chain    — a scheduled completion passed (_min_done);
            #   node revoke   — a node's free count dropped (ledger.dirty);
            #   global revoke — more leases than free capacity;
            #   new lease     — slack under free AND max_leases AND work
            #                   pending AND free moved since the last real
            #                   reconcile (otherwise that reconcile already
            #                   leased up to the placeability limit).
            # Everything else is provably a no-op (regression-pinned by
            # test_reconcile_skip_guard_is_a_pure_optimization).
            nonlocal _last_free
            if now < borrower._min_done \
                    and (ledger is None or not ledger.dirty):
                na = len(borrower.active)
                if na == free:
                    return
                if na < free and (free == _last_free
                                  or na >= borrower.max_leases
                                  or not borrower.pending):
                    return
            _last_free = free
            if ledger is not None:
                _b_reconcile(now, free, ledger)
            else:
                _b_reconcile(now, free)
    head_sample = cfg.head_delay_sample
    head_ctr = 0

    heappush = heapq.heappush
    heappop = heapq.heappop
    can_start = sched.can_start
    ledger_alloc = ledger.alloc if ledger is not None else None
    ledger_release = ledger.release if ledger is not None else None
    # failure sampling runs once per execution attempt; for the standard
    # FailureInjector the draw loop is inlined at the two scheduling sites
    # below (keep in sync with FailureInjector.draw — same table, same RNG
    # consumption, same arithmetic order, so the injected stream is
    # bit-identical); duck-typed injectors (scripted test doubles) fall
    # back to their draw() method
    draw = inj_rates = inj_rand = None
    inj_scale = 0.0
    if injector is not None:
        # exact-type check, not isinstance: a FailureInjector *subclass*
        # may override draw(), and the inline path would silently bypass
        # the override by reading the parent's tables/RNG directly
        if type(injector) is FailureInjector:
            # the start path reads the per-jtype table cache dict directly
            # (no method call per attempt); misses fill it lazily
            inj_rates = injector._rates_by_jtype
            inj_fill = injector.rates_for
            inj_rand = injector._rng.random
            inj_scale = injector.rate_scale
        else:
            draw = injector.draw
    log = math.log

    # per-job transient state lives on the record (like sched's ``_alloc``):
    #   _arrived_at  time of the current (re)submission
    #   _done        checkpointed progress (nominal minutes of work)
    #   _prog        nominal progress as of _seg_start
    #   _seg_start   wall time the current constant-width segment started
    #                (may sit in the future during restart re-init)
    #   _width       current width; < gpus while elastically shrunken
    #   _epoch       bumped on every resize/restart to void in-flight events
    # Progress is accounted in *nominal* minutes: a job at width w advances
    # w/gpus nominal minutes per wall minute, so executed GPU-time for p
    # nominal minutes is p*gpus regardless of the width trajectory.


    def start(job: JobRecord, now: float, lease: bool = False) -> None:
        nonlocal seq, be_r_total, be_s_total
        g = job.gpus
        # pool bookkeeping inlined from ReservationScheduler.start/.lease
        # (keep in sync) — one method call per started job of a million-job
        # replay is real money
        if lease:
            fs = sched.free_spare
            take_s = g if g <= fs else fs
            take_r = g - take_s
            sched.free_spare = fs - take_s
            sched.free_reserved -= take_r
            job._alloc = ("be", take_r, take_s)
            be_running[job.job_id] = job
            be_r_total += take_r
            be_s_total += take_s
            result.be_lease_starts += 1
        elif job._hi or g > spare:
            fr = sched.free_reserved
            take_r = g if g <= fr else fr
            sched.free_reserved = fr - take_r
            sched.free_spare -= g - take_r
            job._alloc = ("hi", take_r, g - take_r)
        else:
            sched.free_spare -= g
            job._alloc = ("lo", 0, g)
        if ledger is not None:
            job._nodes = ledger_alloc(g)
        job._running = True
        job._width = w = job.gpus
        wait = now - job._arrived_at
        if job._started:
            job.requeue_wait_min += wait
        else:
            job._started = True
            job.queue_min = wait        # the paper's queuing delay (Fig. 6)
        if job._head_since is not None:
            # close the head episode: realized head delay, and — when a
            # shadow estimate was sampled — the estimate's error
            realized = now - job._head_since
            result.head_delays.append(realized)
            if job._shadow_est is not None:
                result.shadow_errors.append(realized - job._shadow_est)
                job._shadow_est = None
            job._head_since = None
        job._prog = job._done
        job._seg_start = now
        job._epoch = ep = job._epoch + 1
        remaining = job.duration_min - job._done
        # events are single flat tuples — (t, seq, kind, job, epoch[, cls])
        # — instead of a (t, seq, kind, payload) pair of allocations; the
        # heap never compares past seq (unique), so mixed lengths are safe
        best_cls = None
        if inj_rates is not None:
            best_t = remaining
            table = inj_rates.get(job.jtype)
            if table is None:
                table = inj_fill(job.jtype)
            for rate, cls in table:
                rate_hr = rate * w * inj_scale
                if rate_hr <= 0.0:
                    continue
                u = inj_rand()
                if u < 1e-300:
                    u = 1e-300
                ttf = -log(u) / rate_hr * 60.0
                if ttf < best_t:
                    best_t = ttf
                    best_cls = cls
        elif draw is not None:
            hit = draw(job.jtype, w, remaining)
            if hit is not None:
                best_t, best_cls = hit
        if best_cls is None:
            heappush(events, (now + remaining, seq, FINISH, job, ep))
        else:
            heappush(events, (now + best_t, seq, FAIL, job, ep, best_cls))
        seq += 1

    def schedule_end(job: JobRecord) -> None:
        """(Re)schedule the job's end event from ``_seg_start`` at the
        current width, with the remaining runtime stretched proportionally
        — or, for a curve-carrying job under ``runtime_model="roofline"``,
        by the arch's modeled progress rate at this width — and a fresh
        (memoryless) failure draw."""
        nonlocal seq
        job._epoch = ep = job._epoch + 1
        w = job._width
        curve = job._curve
        if curve is None:
            remaining = (job.duration_min - job._prog) * job.gpus / w
        else:
            remaining = (job.duration_min - job._prog) / curve.rate(w)
        best_cls = None
        if inj_rates is not None:           # inlined draw (see start)
            best_t = remaining
            table = inj_rates.get(job.jtype)
            if table is None:
                table = inj_fill(job.jtype)
            for rate, cls in table:
                rate_hr = rate * w * inj_scale
                if rate_hr <= 0.0:
                    continue
                u = inj_rand()
                if u < 1e-300:
                    u = 1e-300
                ttf = -log(u) / rate_hr * 60.0
                if ttf < best_t:
                    best_t = ttf
                    best_cls = cls
        elif draw is not None:
            hit = draw(job.jtype, w, remaining)
            if hit is not None:
                best_t, best_cls = hit
        t0 = job._seg_start
        if best_cls is None:
            heappush(events, (t0 + remaining, seq, FINISH, job, ep))
        else:
            heappush(events, (t0 + best_t, seq, FAIL, job, ep, best_cls))
        seq += 1

    def sweep(prefer=None):
        """Hide the faulty node in the fleet, then locate it with the §6.1
        two-round allgather sweep. With placement on, the fault lands on
        one of the failing job's *own* nodes (``prefer``) — a hardware
        fault physically lives where the job ran."""
        if prefer:
            candidates = [n for n in prefer
                          if n >= 0 and n not in fleet.cordoned
                          and n not in fleet.faulty]
        else:
            candidates = None
        if not candidates:
            candidates = [n for n in fleet.healthy_nodes()
                          if n not in fleet.faulty]
        if candidates:
            fleet.fail({rng.choice(candidates)})
        det = two_round_detection(fleet.healthy_nodes(), fleet)
        result.detection_probes += det.probes
        return det

    def bump_policy(policy: str, cstats: ClassStats, lost_gpu: float,
                    overhead: float) -> None:
        cstats.overhead_min += overhead
        result.policies[policy] += 1
        p = result.by_policy.setdefault(policy, ClassStats())
        p.failures += 1
        p.lost_gpu_min += lost_gpu
        p.overhead_min += overhead

    def stop_running(job: JobRecord) -> None:
        """A running job leaves the cluster (finish/requeue/kill): free its
        scheduler allocation, its ledger nodes, and its lease slot.
        (Pool hand-back inlined from ReservationScheduler.finish — keep in
        sync.)"""
        nonlocal be_r_total, be_s_total
        kind, r, s = job._alloc
        sched.free_reserved += r
        sched.free_spare += s
        job._running = False
        if kind == "be":
            del be_running[job.job_id]
            be_r_total -= r
            be_s_total -= s
        if ledger_release is not None:
            ledger_release(job._nodes)
            job._nodes = None

    def revoke_lease(job: JobRecord, now: float) -> None:
        """Quota reclamation: preempt a running best-effort lease. The job
        rolls back to its last periodic checkpoint (best-effort jobs are
        always checkpointed — that is what makes them safe to revoke),
        pays ``revoke_overhead_min`` and requeues at the back of its tier.
        The rollback/requeue accounting is identical to an injected
        ``preemption`` failure (parity-tested); the incident lands in the
        ``quota_reclaim`` class ledger so the emergent policy stays
        separable from the injected class."""
        nonlocal seq
        w = job._width
        if job._curve is None:
            progress = job._prog \
                + max(0.0, now - job._seg_start) * w / job.gpus
        else:
            progress = job._prog \
                + max(0.0, now - job._seg_start) * job._curve.rate(w)
        if cfg.record_segments and now > job._seg_start:
            result.segments.append(
                (job.job_id, w, job._seg_start, now, "revoke"))
        if interval > 0:
            rollback = math.floor(progress / interval) * interval
        else:
            rollback = 0.0
        lost_gpu = (progress - rollback) * job.gpus
        job.lost_gpu_min += lost_gpu
        job._done = rollback
        job.restarts += 1
        job._epoch += 1             # void the in-flight FINISH/FAIL event
        stop_running(job)
        cstats = result.by_class.setdefault(QUOTA_RECLAIM, ClassStats())
        cstats.failures += 1
        cstats.lost_gpu_min += lost_gpu
        if job.restarts > cfg.max_restarts:
            result.killed_job_ids.append(job.job_id)
            return
        cstats.overhead_min += cfg.revoke_overhead_min
        heappush(events, (now + cfg.revoke_overhead_min, seq, ARRIVE, job))
        seq += 1

    def ensure_free(job: JobRecord, now: float) -> bool:
        """Dispatch wants capacity a revocable lease holds: preempt
        best-effort leases newest-first (LIFO) until ``job`` fits in the
        pools its class may draw. Returns whether it now fits; revokes
        nothing when the lease stack cannot cover the shortfall.

        The victim prefix is selected by *simulating* the pool arithmetic
        over a lazy ``reversed`` view (a revocation returns exactly the
        lease's ``(r, s)`` split, so the simulated pools match the real
        ones) and only then revoking — the old implementation copied the
        entire ``be_running`` dict and re-probed ``can_start`` per
        candidate, an O(live leases) rescan on every blocked head of a
        saturated replay."""
        g = job.gpus
        free_r = sched.free_reserved
        free_s = sched.free_spare
        if job._hi or g > spare:
            if g > free_r + free_s + be_r_total + be_s_total:
                return False
            if g <= free_r + free_s:        # already fits: revoke nothing
                return True
            victims = []
            for j in reversed(be_running.values()):
                _, jr, js = j._alloc
                victims.append(j)
                free_r += jr
                free_s += js
                if g <= free_r + free_s:
                    break
        else:
            if g > free_s + be_s_total:
                return False
            if g <= free_s:
                return True
            victims = []
            for j in reversed(be_running.values()):
                js = j._alloc[2]
                if js == 0:
                    continue        # reserved-only lease: can't help a
                victims.append(j)   # spare-pool job
                free_s += js
                if g <= free_s:
                    break
        for j in victims:
            revoke_lease(j, now)
        return can_start(job)

    def revoke_for_regrow(need: int, spare_only: bool, now: float) -> None:
        """Regrowth wants ``need`` GPUs beyond the real free pools: revoke
        best-effort leases newest-first until they are freed. Must run
        *before* ``sched.grow`` reads the pools — revocation has to land
        first or the same GPUs would be double-counted (ordering pinned by
        the lease/regrow audit regression tests). Victims are collected
        over the lazy ``reversed`` view first (no full-dict copy), then
        revoked in the same newest-first order."""
        freed = 0
        victims = []
        for j in reversed(be_running.values()):
            if freed >= need:
                break
            if spare_only:
                c = j._alloc[2]
                if c == 0:
                    continue
            else:
                c = j._alloc[1] + j._alloc[2]
            victims.append(j)
            freed += c
        for j in victims:
            revoke_lease(j, now)

    def lease_pass(now: float) -> None:
        """Start waiting best-effort jobs (FIFO) on leftover free capacity.
        Runs strictly after dispatch and regrowth — a lease only ever
        consumes capacity nobody with priority wanted at this instant —
        and before the trial borrower, which is the lowest tier."""
        while wait_be:
            j = wait_be[0]
            if j.gpus > sched.free_reserved + sched.free_spare:
                break
            wait_be.popleft()
            start(j, now, lease=True)

    def _fits(job: JobRecord, free_r: int, free_s: int) -> bool:
        """can_start against a hypothetical (reserved, spare) free split."""
        if job._hi or job.gpus > spare:
            return job.gpus <= free_r + free_s
        return job.gpus <= free_s

    def shadow_start(head: JobRecord) -> float:
        """EASY reservation: the earliest time ``head`` could start given
        the running jobs' scheduled ends (an estimate — future failures and
        repairs are unknowable, exactly as in a real EASY scheduler).

        The live end set is read straight off the event heap: every
        running job has exactly one in-flight FINISH/FAIL event (stale
        epochs filtered like the pop path), so the engine no longer
        maintains — and prunes — a parallel running-ends list per start.
        Ties in scheduled end time land in heap order rather than start
        order, which cannot change the returned shadow *time* (the
        crossing point accumulates the same (r, s) multiset up to any
        given t)."""
        live = [(e[0], e[3]) for e in events
                if (k := e[2]) != ARRIVE and k != REPAIR
                and (j := e[3])._running and e[4] == j._epoch]
        live.sort(key=operator.itemgetter(0))
        free_r, free_s = sched.free_reserved, sched.free_spare
        if be_running:
            # revocable leases are free capacity *for the head* — dispatch
            # preempts them on demand, so the estimate must not wait for
            # their scheduled ends (their allocs are skipped below)
            free_r += be_r_total
            free_s += be_s_total
        for t, j in live:
            kind, r, s = j._alloc
            if kind == "be":
                continue
            free_r += r
            free_s += s
            if _fits(head, free_r, free_s):
                return t
        return math.inf

    def backfill_scan(q: collections.deque, now: float) -> None:
        """Head is blocked: start any of the next ``backfill_window`` jobs
        that fit right now. Greedy may delay the head; EASY additionally
        requires the candidate's estimated completion to land before the
        head's shadow time, so the head's start is protected."""
        if easy:
            shadow = shadow_start(q[0])
            if not math.isfinite(shadow):
                return
        i = 1
        limit = min(len(q), bf_window)
        while i < limit:
            j = q[i]
            if can_start(j) and (not easy or
                                 now + (j.duration_min - j._done)
                                 <= shadow + 1e-9):
                del q[i]
                start(j, now)
                limit -= 1
            else:
                i += 1

    def mark_head(job: JobRecord, now: float) -> None:
        """A job just became the *blocked* head of its FIFO class: open a
        head episode (realized delay recorded at start) and, on the
        sampling cadence, take a shadow estimate of its remaining wait."""
        nonlocal head_ctr
        if job._head_since is not None:
            return
        job._head_since = now
        head_ctr += 1
        if head_sample and (easy or head_ctr % head_sample == 0):
            est = shadow_start(job)
            if math.isfinite(est):
                job._shadow_est = max(est - now, 0.0)

    def regrow_pass(now: float) -> None:
        """Opportunistic regrowth from the free pool: after dispatch has
        quiesced, leftover free capacity goes back to shrunken jobs (FIFO
        by shrink time). Runs strictly after the wait queues, and under
        EASY only when the regrown job's compressed completion still lands
        before every waiting head's shadow time — the same exchange
        argument that keeps EASY backfill head-safe (the granted GPUs are
        all returned at the job's completion, before the shadow instant).

        Regrowth outranks best-effort leases: the admitted width may be
        covered by revoking leases (newest-first), and the revocation must
        *land* before ``sched.grow`` reads the pools — granting and
        revoking against one snapshot would double-count the leased GPUs
        (the capacity-event ordering audit; regression-pinned). The width
        change pays the explicit ``reshard_cost_min`` stall."""
        nonlocal be_r_total, be_s_total
        reshard = cfg.reshard_cost_min
        for jid in list(shrunken):
            job = shrunken[jid]
            if not job._running or job._width >= job.gpus:
                del shrunken[jid]
                continue
            kind = job._alloc[0]
            free_now = sched.free_spare if kind == "lo" \
                else sched.free_reserved + sched.free_spare
            avail = free_now
            if be_running and kind != "be":
                avail += be_s_total if kind == "lo" \
                    else be_r_total + be_s_total
            k = min(job.gpus - job._width, avail)
            if k <= 0:
                continue
            w = job._width
            curve = job._curve
            if now > job._seg_start:
                t_base = now
                if curve is None:
                    prog = job._prog + (now - job._seg_start) * w / job.gpus
                else:
                    prog = job._prog + (now - job._seg_start) * curve.rate(w)
            else:                       # still paying restart re-init
                t_base = job._seg_start
                prog = job._prog
            if easy and (wait_hi or wait_lo):
                if curve is None:
                    rem = (job.duration_min - prog) * job.gpus / (w + k)
                else:
                    rem = (job.duration_min - prog) / curve.rate(w + k)
                new_end = t_base + reshard + rem
                ok = True
                for q in (wait_hi, wait_lo):
                    if q and new_end > shadow_start(q[0]) + 1e-9:
                        ok = False
                        break
                if not ok:
                    continue
            if k > free_now:
                revoke_for_regrow(k - free_now, kind == "lo", now)
            take_r, take_s = sched.grow(job, k)
            got = take_r + take_s
            if got <= 0:
                continue
            if kind == "be":
                be_r_total += take_r
                be_s_total += take_s
            if ledger is not None:
                for n, c in ledger.alloc(got).items():
                    job._nodes[n] = job._nodes.get(n, 0) + c
            if now > job._seg_start:
                if cfg.record_segments:
                    result.segments.append(
                        (job.job_id, w, job._seg_start, now, "resize"))
                job._prog = prog
                job._seg_start = now
            if reshard > 0.0:
                # explicit re-shard stall: the job re-partitions onto its
                # new width before computing again
                job._seg_start += reshard
                result.pool_reshard_events += 1
                result.pool_reshard_min += reshard
            job._width = w + got
            result.pool_regrows += 1
            result.pool_regrown_gpus += got
            if job._width >= job.gpus:
                del shrunken[jid]
            schedule_end(job)

    # try_start runs after every capacity-freeing event, which makes the
    # blocked-head probe the single hottest check of a million-job replay —
    # so the pool test is inlined here (keep in sync with
    # ReservationScheduler.can_start) instead of paying a method call per
    # probe. FIFO head-of-line: later jobs can't jump the queue (this is
    # exactly the paper's eval-delay mechanism); backfill, when enabled,
    # relaxes that under its policy's constraint.
    spare = sched.spare

    def try_start(now: float) -> None:
        free_r = sched.free_reserved
        free_s = sched.free_spare
        while wait_hi:
            j = wait_hi[0]
            g = j.gpus
            if g > free_r + free_s:           # hi class draws both pools
                # the head may still fit by reclaiming revocable leases;
                # the totals precheck is inlined so the common blocked
                # probe costs two compares, not an ensure_free call
                if not be_running \
                        or g > free_r + free_s + be_r_total + be_s_total \
                        or not ensure_free(j, now):
                    break
            wait_hi.popleft()
            start(j, now)
            free_r = sched.free_reserved
            free_s = sched.free_spare
        while wait_lo:
            j = wait_lo[0]
            g = j.gpus
            if g <= spare:                     # lo class: spare pool only,
                if g > free_s:                 # unless wider than the pool
                    if not be_running or g > free_s + be_s_total \
                            or not ensure_free(j, now):
                        break
            elif g > free_r + free_s:
                if not be_running \
                        or g > free_r + free_s + be_r_total + be_s_total \
                        or not ensure_free(j, now):
                    break
            wait_lo.popleft()
            start(j, now)
            free_r = sched.free_reserved
            free_s = sched.free_spare
        if backfill_policy is not None:
            if wait_hi:
                backfill_scan(wait_hi, now)
            if wait_lo:
                backfill_scan(wait_lo, now)
        if regrow and shrunken \
                and (be_running
                     or sched.free_reserved + sched.free_spare > 0):
            # two-int guard: under the saturated bench configurations the
            # pools are usually dry, so skip the shrunken scan entirely
            # (revocable leases count as reclaimable capacity)
            regrow_pass(now)
        if wait_be and wait_be[0].gpus \
                <= sched.free_reserved + sched.free_spare:
            lease_pass(now)
        if head_sample:
            # inline the already-marked fast path: try_start runs per event
            # and the head usually opened its episode long ago
            if wait_hi and wait_hi[0]._head_since is None:
                mark_head(wait_hi[0], now)
            if wait_lo and wait_lo[0]._head_since is None:
                mark_head(wait_lo[0], now)

    reject_impossible = cfg.reject_impossible
    bf_window = cfg.backfill_window

    def on_arrive(job: JobRecord, now: float) -> None:
        if job.gpus > total_gpus:
            if reject_impossible:
                logger.warning(
                    "job %d (%s) demands %d GPUs on a %d-GPU cluster; "
                    "rejected (never started)", job.job_id, job.jtype,
                    job.gpus, total_gpus)
                job.queue_min = NEVER_STARTED
                result.rejected_job_ids.append(job.job_id)
                return
            # legacy mode: an impossible job wedges its FIFO class and
            # everything behind it surfaces as never-started at drain
        job._arrived_at = now
        if job.best_effort:
            # revocable-lease tier: strictly below both FIFO classes — a
            # lease only ever starts on currently-free capacity (it never
            # preempts anything itself), FIFO within the tier
            if not wait_be and sched.can_lease(job):
                start(job, now, lease=True)
            else:
                wait_be.append(job)
            return
        q = wait_hi if job._hi else wait_lo
        # Dispatch invariant: between events, every non-empty wait queue has
        # a blocked head (try_start runs to quiescence after each
        # capacity-freeing event). An ARRIVE changes no free capacity, so it
        # can enable at most *itself* — when its queue is empty, or when a
        # backfill policy admits it past the blocked head (greedy: it merely
        # fits; EASY: its completion must also land before the head's
        # shadow time, so the head is never delayed). A blocked direct
        # start may still reclaim revocable best-effort leases.
        if not q:
            # inlined can_start (keep in sync with
            # ReservationScheduler.can_start): one probe per arrival
            g = job.gpus
            if job._hi or g > spare:
                fits = g <= sched.free_reserved + sched.free_spare
            else:
                fits = g <= sched.free_spare
            if fits or (be_running and ensure_free(job, now)):
                start(job, now)
                return
        elif backfill_policy is not None and len(q) < bf_window \
                and can_start(job) and (
                greedy or (easy and now + (job.duration_min - job._done)
                           <= shadow_start(q[0]) + 1e-9)):
            # without a backfill policy a job behind a blocked head can
            # never jump it, so the old unconditional can_start probe here
            # was a wasted pool check per queued arrival
            start(job, now)
            return
        q.append(job)
        if head_sample and len(q) == 1:
            mark_head(job, now)       # arrived straight into a blocked head

    def on_fail(job: JobRecord, cls: ReplayFailureClass, now: float) -> bool:
        """Handle one injected failure; returns True iff pool capacity was
        freed (so the caller knows whether a dispatch pass is needed)."""
        nonlocal seq, be_r_total, be_s_total
        # the job's nodes before any release: aims the sweep (and outlives
        # stop_running, which clears job._nodes)
        job_nodes = list(job._nodes) if job._nodes else None
        # -- fold the failed segment & roll back to the last checkpoint ----
        w = job._width
        if job._curve is None:
            progress = job._prog \
                + max(0.0, now - job._seg_start) * w / job.gpus
        else:
            progress = job._prog \
                + max(0.0, now - job._seg_start) * job._curve.rate(w)
        if cfg.record_segments and now > job._seg_start:
            result.segments.append(
                (job.job_id, w, job._seg_start, now, "fail"))
        if (job.jtype in ckpt_types or job.best_effort) and interval > 0:
            rollback = math.floor(progress / interval) * interval
        else:
            rollback = 0.0
        lost_gpu = (progress - rollback) * job.gpus
        job.lost_gpu_min += lost_gpu
        job._done = rollback
        job.restarts += 1
        cstats = result.by_class.setdefault(cls.name, ClassStats())
        cstats.failures += 1
        cstats.lost_gpu_min += lost_gpu
        # restart overhead is charged where the policy lands (bump_policy):
        # a failure that kills the job restarts nothing, so by_class and
        # by_policy overhead totals must reconcile

        # -- diagnosis-in-the-loop: verdict picks the recovery policy ------
        if diagnosis is not None:
            vclass, _, _ = diagnosis.verdict(cls)
            result.verdicts.setdefault(
                cls.name, collections.Counter())[vclass] += 1
        else:
            vclass = None

        if cfg.recovery_policy != "auto":
            policy = cfg.recovery_policy
        elif vclass is None or cls.name == PREEMPTION:
            # classic class-driven recovery; preemption is additionally
            # scheduler-initiated — the quota wants the GPUs back, so the
            # job must requeue no matter what its log looks like
            policy = POLICY_REQUEUE
        elif vclass == VERDICT_HARDWARE and cfg.elastic:
            policy = POLICY_ELASTIC
        elif vclass == VERDICT_TRANSIENT:
            policy = POLICY_INPLACE
        else:
            policy = POLICY_REQUEUE
        node_fault = cls.name != PREEMPTION and (
            vclass == VERDICT_HARDWARE if vclass is not None
            else cls.needs_cordon)
        over_budget = job.restarts > cfg.max_restarts

        # -- elastic shrink: drop the failed node, keep running ------------
        swept = False
        released = False
        if policy == POLICY_ELASTIC and not over_budget \
                and len(fleet.cordoned) < max_cordoned:
            det = sweep(job_nodes)
            swept = True
            if ledger is None:
                k = cfg.node_gpus * len(det.faulty)
            else:
                # placement: the job sheds exactly its GPUs on the faulty
                # node(s) — the shrink width is physical, not nominal
                k = sum(job._nodes.get(n, 0) for n in det.faulty) \
                    if job._nodes else 0
            if det.faulty and 0 < k < w:
                fleet.cordon(det.faulty)
                for n in det.faulty:
                    fleet.faulty.discard(n)
                take_r, take_s = sched.release_partial(job, k)
                if job._alloc[0] == "be":
                    be_r_total -= take_r
                    be_s_total -= take_s
                cf_r = cf_s = 0
                if ledger is not None:
                    # the node drains entirely: the job's GPUs leave with
                    # it, and so do its still-free GPUs (other jobs on the
                    # node keep running until their own completion)
                    cfree = 0
                    for n in det.faulty:
                        ledger.detach(job._nodes, n)
                        cfree += ledger.cordon_node(n)
                    if cfree:
                        cf_r, cf_s = sched.cordon(cfree)
                job._width = w - k
                shrunken[job.job_id] = job    # eligible for pool regrowth
                result.cordon_events += len(det.faulty)
                result.elastic_shrinks += 1
                bump_policy(POLICY_ELASTIC, cstats, lost_gpu,
                            cls.restart_overhead_min)
                heappush(events, (now + max(cls.repair_min, 1e-9), seq,
                                  REPAIR, (det.faulty, take_r, take_s, job,
                                           cf_r, cf_s)))
                seq += 1
                # resume from the checkpoint on the surviving nodes once
                # re-init is paid; the remaining runtime stretches by
                # gpus/width (progress is nominal-minute denominated)
                job._prog = rollback
                job._seg_start = now + cls.restart_overhead_min
                schedule_end(job)
                return cf_r + cf_s > 0
            if det.faulty:
                # node located, but the job is too narrow to shed it: free
                # the job first so the pool cordon can absorb its GPUs,
                # then fall through to the requeue path
                stop_running(job)
                released = True
                fleet.cordon(det.faulty)
                for n in det.faulty:
                    fleet.faulty.discard(n)
                if ledger is None:
                    # node-less approximation: without placement the node's
                    # free-GPU share is unknowable, and the rest of the node
                    # is held by co-located jobs that keep running to their
                    # own completion — so only the failing job's released
                    # share may drain (draining the nominal node width
                    # double-counts the co-located jobs' GPUs)
                    take_r, take_s = sched.cordon(min(job.gpus, k))
                else:
                    cfree = sum(ledger.cordon_node(n) for n in det.faulty)
                    take_r, take_s = sched.cordon(cfree)
                result.cordon_events += len(det.faulty)
                heappush(events, (now + max(cls.repair_min, 1e-9), seq,
                                  REPAIR, (det.faulty, take_r, take_s, None,
                                           0, 0)))
                seq += 1
            policy = POLICY_REQUEUE

        # -- in-place restart: keep the allocation, pay the overhead -------
        if policy == POLICY_INPLACE and not over_budget:
            bump_policy(POLICY_INPLACE, cstats, lost_gpu,
                        cls.restart_overhead_min)
            job._prog = rollback
            job._seg_start = now + cls.restart_overhead_min
            schedule_end(job)
            return False

        # -- requeue (and the kill path for every policy) ------------------
        if not released:
            stop_running(job)
        if node_fault and not swept and len(fleet.cordoned) < max_cordoned:
            det = sweep(job_nodes)
            if det.faulty:
                fleet.cordon(det.faulty)
                for n in det.faulty:
                    fleet.faulty.discard(n)
                if ledger is None:
                    # same node-less clamp as the narrow-elastic fallback:
                    # co-located holders keep running, so the drain is
                    # bounded by the failing job's own released GPUs
                    take_r, take_s = sched.cordon(
                        min(job.gpus, cfg.node_gpus * len(det.faulty)))
                else:
                    # the job's GPUs already returned to its nodes via
                    # stop_running, so the node drain sweeps them up
                    cfree = sum(ledger.cordon_node(n) for n in det.faulty)
                    take_r, take_s = sched.cordon(cfree)
                result.cordon_events += len(det.faulty)
                heappush(events, (now + max(cls.repair_min, 1e-9), seq,
                                  REPAIR, (det.faulty, take_r, take_s, None,
                                           0, 0)))
                seq += 1
        if over_budget:
            result.killed_job_ids.append(job.job_id)
            bump_policy(POLICY_KILLED, cstats, lost_gpu, 0.0)
            return True
        bump_policy(POLICY_REQUEUE, cstats, lost_gpu,
                    cls.restart_overhead_min)
        heappush(events, (now + cls.restart_overhead_min, seq, ARRIVE, job))
        seq += 1
        return True

    def on_repair(payload, now: float) -> None:
        nonlocal be_r_total, be_s_total
        nodes, take_r, take_s, lender, cf_r, cf_s = payload
        fleet.repair(nodes)
        if ledger is not None:
            ledger.repair_nodes(nodes)
        if lender is not None and lender._running \
                and lender._width < lender.gpus:
            # the node's GPUs go straight back to the elastic job that lent
            # them; any excess (the job already regrew) rejoins the pools,
            # as do the free GPUs drained with the node's cordon (cf_*)
            give = min(lender.gpus - lender._width, take_r + take_s)
            give_r = min(give, take_r)
            give_s = give - give_r
            sched.reacquire(lender, give_r, give_s)
            if lender._alloc[0] == "be":
                be_r_total += give_r
                be_s_total += give_s
            sched.uncordon(take_r - give_r + cf_r, take_s - give_s + cf_s)
            if ledger is not None:
                ledger.attach(lender._nodes, nodes, give)
                ledger.add_free(take_r + take_s - give + cf_r + cf_s,
                                prefer=nodes)
            if now > lender._seg_start:
                if cfg.record_segments:
                    result.segments.append(
                        (lender.job_id, lender._width, lender._seg_start,
                         now, "resize"))
                if lender._curve is None:
                    lender._prog += (now - lender._seg_start) \
                        * lender._width / lender.gpus
                else:
                    lender._prog += (now - lender._seg_start) \
                        * lender._curve.rate(lender._width)
                lender._seg_start = now
            if cfg.reshard_cost_min > 0.0:
                # the width change at the repair pays the same explicit
                # re-shard stall as a pool regrow
                lender._seg_start += cfg.reshard_cost_min
                result.pool_reshard_events += 1
                result.pool_reshard_min += cfg.reshard_cost_min
            lender._width += give
            result.elastic_regrows += 1
            schedule_end(lender)
        else:
            sched.uncordon(take_r + cf_r, take_s + cf_s)
            if ledger is not None:
                ledger.add_free(take_r + take_s + cf_r + cf_s, prefer=nodes)

    ai, n_arr = 0, len(arrivals)
    # the cursor's peek runs once per event of the whole replay; a packed
    # float list beats an attribute dereference per peek
    arrival_times = [j.submit_min for j in arrivals]
    next_arr = arrival_times[0] if n_arr else math.inf
    # free-GPU ledger: capacity is piecewise-constant between events, so
    # integrating free GPU-minutes only needs a running timestamp; the
    # accumulator lives in locals (one attribute store per *replay*, not
    # per event) — same sequential float additions, so the integral is
    # bit-identical to the per-event attribute version
    pool_t = 0.0
    pool_free_acc = 0.0
    record_segments = cfg.record_segments
    stale = 0
    # pause the cyclic GC across the event loop: the replay allocates
    # millions of short-lived tuples/dicts and keeps a 1M-record job list
    # alive, so generational collections both fire constantly and rescan a
    # huge stable heap (~10% of the wall at Seren scale); nothing in the
    # loop relies on collection, and the previous state is restored on any
    # exit path
    _gc_was_on = gc.isenabled()
    if _gc_was_on:
        gc.disable()
    try:
        while True:
            # initial submissions win exact-time ties against dynamic
            # events, matching the old all-in-one-heap sequence numbering
            if ai < n_arr and (not events or next_arr <= events[0][0]):
                job = arrivals[ai]
                ai += 1
                now = next_arr
                next_arr = arrival_times[ai] if ai < n_arr else math.inf
                if now > pool_t:
                    pool_free_acc += (now - pool_t) * (
                        sched.free_reserved + sched.free_spare)
                    pool_t = now
                # lazy per-run reset (see the note above the loop): this
                # is the record's first touch of this replay
                job.queue_min = 0.0
                job.requeue_wait_min = 0.0
                job.restarts = 0
                job.lost_gpu_min = 0.0
                job._done = 0.0
                job._started = False
                job._running = False
                job._width = job.gpus
                job._epoch = 0
                job._prog = 0.0
                job._seg_start = 0.0
                job._head_since = None
                job._shadow_est = None
                job._nodes = None
                job._hi = job.jtype in hi_types
                # resolve the arch's width curve once per job (cached per
                # (arch, gpus) inside the model); always (re)assigned so a
                # record replayed under a different runtime model can't
                # carry a stale curve
                job._curve = None if cost_model is None \
                    or job.arch is None \
                    else cost_model.job_curve(job.arch, job.gpus)
                on_arrive(job, now)
                if _reconcile is not None:
                    # the arrival may have started and consumed leased
                    # capacity
                    _reconcile(now, sched.free_reserved + sched.free_spare)
                continue
            if not events:
                break
            e = heappop(events)
            now = e[0]
            kind = e[2]
            if now > pool_t:
                pool_free_acc += (now - pool_t) * (
                    sched.free_reserved + sched.free_spare)
                pool_t = now
            if kind == FINISH:
                job = e[3]
                if e[4] != job._epoch:
                    stale += 1
                    continue
                # inlined stop_running() — the single hottest branch of
                # the loop (keep in sync)
                akind, r, s = job._alloc
                sched.free_reserved += r
                sched.free_spare += s
                job._running = False
                if akind == "be":
                    del be_running[job.job_id]
                    be_r_total -= r
                    be_s_total -= s
                if ledger_release is not None:
                    ledger_release(job._nodes)
                    job._nodes = None
                if record_segments:
                    result.segments.append(
                        (job.job_id, job._width, job._seg_start, now,
                         "finish"))
            elif kind == FAIL:
                job = e[3]
                if e[4] != job._epoch:
                    stale += 1
                    continue
                if not on_fail(job, e[5], now):
                    continue                  # no pool capacity changed
            elif kind == ARRIVE:
                on_arrive(e[3], now)
                if _reconcile is not None:
                    _reconcile(now, sched.free_reserved + sched.free_spare)
                continue
            else:  # REPAIR
                on_repair(e[3], now)
            try_start(now)
            if _reconcile is not None:
                _reconcile(now, sched.free_reserved + sched.free_spare)
    finally:
        if _gc_was_on:
            gc.enable()
    result.stale_events = stale
    result.pool_free_gpu_min = pool_free_acc
    # every dynamic event was pushed exactly once (seq advanced with each
    # push) and the heap drained, so the processed count is arithmetic —
    # no per-event counter in the hot loop: initial arrivals + dynamic
    # pushes - lazy-deleted pops
    processed = n_arr + (seq - len(jobs)) - stale

    # jobs still waiting when the event stream drains never ran: give them
    # an unambiguous sentinel instead of the misleading default 0.0
    for q in (wait_hi, wait_lo, wait_be):
        for j in q:
            if not j._started:
                j.queue_min = NEVER_STARTED
    result.events_processed = processed
    result.horizon_min = pool_t
    if cost_model is not None:
        n_tagged = n_modeled = 0
        archs: collections.Counter = collections.Counter()
        for j in jobs:
            if j.arch is not None:
                n_tagged += 1
                if j._curve is not None:
                    n_modeled += 1
                    archs[j.arch] += 1
        result.runtime_model_stats = {
            "model": cfg.runtime_model,
            "jobs_tagged": n_tagged,
            "jobs_modeled": n_modeled,
            "archs": dict(sorted(archs.items())),
        }
    if ledger is not None:
        result.placement = {
            "n_nodes": ledger.n_nodes,
            "node_gpus": ledger.node_gpus,
            "cordoned_nodes": len(ledger.cordoned),
            "unplaced_free_gpus": ledger.float_free,
        }
    if borrower is not None:
        borrower.close(pool_t)
        result.borrow = borrower.stats()
    if diagnosis is not None:
        result.diagnosis_incidents = diagnosis.incidents - diag_incidents0
        result.diagnosis_pipeline_runs = \
            diagnosis.pipeline_runs - diag_runs0
    return result
