"""Static Pallas ``pallas_call`` contract checker (``RPL1xx`` family).

The three kernels (``flash_attention`` / ``rmsnorm`` / ``ssd``) encode
their BlockSpec/grid/index_map contracts in code that nothing verifies
until a TPU run fails — and the dev boxes here have no TPU. This module
imports each kernel *without executing it*: ``pl.pallas_call`` is swapped
for a capturing stub, the kernel entry point is traced with small
shape-representative dummy operands, and every captured call is checked
statically:

``RPL101``  index_map arity != grid rank, or its returned block-index
            tuple's length != the block-shape rank
``RPL102``  block-shape rank != operand rank
``RPL103``  a block dim does not divide the operand dim (this repo's
            kernels assert divisibility — ops.py pads — so a non-divisor
            block is always a bug here, not an implicit-padding request)
``RPL104``  trailing block dim is MXU-misaligned: neither 1 (scalar-ish
            lane), a multiple of 128 (the MXU/VPU lane width — see the
            Pallas TPU tiling table), nor the full operand dim (whole-axis
            blocks, e.g. a resident reduction axis)
``RPL105``  kernel signature arity != n_inputs + n_outputs + n_scratch
            (after ``functools.partial`` binding)

Run over the shipped kernels (what CI does)::

    PYTHONPATH=src python -m repro.quality.pallas_check \\
        --report artifacts/lint/pallas_check.json

Exit 0 when every kernel passes, 1 otherwise. The unit fixtures
(``tests/fixtures/pallas_broken.py``) are deliberately broken kernels the
checker must flag — the test that the checker itself cannot rot.
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import functools
import inspect
import itertools
import json
import os
import sys
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.quality.rules import Finding

MXU_LANE = 128


@dataclasses.dataclass(slots=True)
class CapturedCall:
    """One intercepted ``pl.pallas_call``: the static contract plus the
    operand avals it was applied to. ``extra_kwargs`` records every
    keyword the stub did not model (``interpret``, ``compiler_params``,
    future Pallas API surface) so the report can show what the checker
    ignored instead of silently dropping it."""
    kernel: Callable
    grid: tuple
    in_specs: list
    out_specs: list
    out_shape: list
    scratch_shapes: list
    operands: list          # jax.ShapeDtypeStruct per input
    #: sorted unmodeled keyword names
    extra_kwargs: list = dataclasses.field(default_factory=list)


class _CapturingPallasCall:
    """Stand-in for ``pl.pallas_call``: records the call contract and
    returns zeros of ``out_shape`` instead of lowering — so kernels are
    checkable on hosts with no TPU and without running interpret mode."""

    def __init__(self):
        self.calls: list[CapturedCall] = []

    def __call__(self, kernel, *, grid=None, in_specs=None, out_specs=None,
                 out_shape=None, scratch_shapes=(), grid_spec=None,
                 **_kwargs):
        if grid_spec is not None:
            # a pl.GridSpec bundles grid/in_specs/out_specs; unpack it so
            # the same per-spec checks run on either calling convention
            grid = getattr(grid_spec, "grid", grid)
            in_specs = getattr(grid_spec, "in_specs", in_specs)
            out_specs = getattr(grid_spec, "out_specs", out_specs)
        multi_out = isinstance(out_shape, (list, tuple))
        out_list = list(out_shape) if multi_out else [out_shape]

        def bound(*operands):
            self.calls.append(CapturedCall(
                kernel=kernel,
                grid=tuple(grid) if grid is not None else (),
                in_specs=list(in_specs) if in_specs is not None else [],
                out_specs=(list(out_specs)
                           if isinstance(out_specs, (list, tuple))
                           else [out_specs]),
                out_shape=out_list,
                scratch_shapes=list(scratch_shapes),
                operands=[jax.ShapeDtypeStruct(o.shape, o.dtype)
                          for o in operands],
                extra_kwargs=sorted(_kwargs)))
            outs = [jnp.zeros(s.shape, s.dtype) for s in out_list]
            return outs if multi_out else outs[0]

        return bound


@contextlib.contextmanager
def capture_pallas_calls():
    """Swap ``pl.pallas_call`` for the capturing stub (restored on exit).
    The kernels resolve ``pl.pallas_call`` at call time through the module
    object, so patching the attribute intercepts them without reimports."""
    stub = _CapturingPallasCall()
    original = pl.pallas_call
    pl.pallas_call = stub
    try:
        yield stub
    finally:
        pl.pallas_call = original


# ---------------------------------------------------------------------------
# checks over one captured call
# ---------------------------------------------------------------------------

def _positional_arity(fn: Callable) -> Optional[int]:
    """Positional (ref) parameters a kernel body accepts, after unwrapping
    ``functools.partial`` keyword binding; None when it takes *args."""
    n_bound = 0
    while isinstance(fn, functools.partial):
        n_bound += len(fn.args)
        fn = fn.func
    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):  # pragma: no cover - builtins
        return None
    n = 0
    for p in sig.parameters.values():
        if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD):
            n += 1
        elif p.kind == p.VAR_POSITIONAL:
            return None
    return n - n_bound


def _index_map_arity(spec) -> Optional[int]:
    imap = getattr(spec, "index_map", None)
    if imap is None:
        return None
    try:
        return len(inspect.signature(imap).parameters)
    except (TypeError, ValueError):  # pragma: no cover
        return None


def grid_corners(grid: tuple) -> list[tuple]:
    """The deduplicated corners of the grid index space: every combination
    of first/last step per axis. An ``index_map`` that misbehaves only
    off-origin (conditional shapes, wrong arithmetic on the last block)
    shows up here long before a full-grid walk — shared by this checker
    and ``pallas_cost``'s exhaustive RPL203 pass."""
    if not grid:
        return [()]
    axes = [(0,) if n <= 1 else (0, n - 1) for n in grid]
    return sorted(set(itertools.product(*axes)))


def eval_index_map(spec, step: tuple) -> tuple:
    """Evaluate ``spec.index_map`` at one grid step, normalized to a tuple
    of ints. Exceptions propagate — callers decide how to report them."""
    idx = spec.index_map(*step)
    if not isinstance(idx, tuple):
        idx = (idx,)
    return tuple(int(i) for i in idx)


def _check_spec(findings: list, where: str, path: str, spec,
                aval, grid: tuple) -> None:
    """All BlockSpec-vs-operand checks for one (spec, aval) pair."""
    def emit(code: str, message: str) -> None:
        findings.append(Finding(code=code, path=path, line=0, col=0,
                                message=f"{where}: {message}",
                                snippet=where))

    block = getattr(spec, "block_shape", None)
    if block is None:
        return                      # whole-operand spec: nothing to check
    block = tuple(block)

    arity = _index_map_arity(spec)
    if arity is not None and arity != len(grid):
        emit("RPL101", f"index_map takes {arity} args but the grid has "
             f"rank {len(grid)} — every grid axis must reach the map")
        return                      # calling it below would TypeError

    if len(block) != len(aval.shape):
        emit("RPL102", f"block shape {block} has rank {len(block)} but "
             f"the operand is rank {len(aval.shape)} {tuple(aval.shape)}")
        return                      # per-dim checks are meaningless now

    imap = getattr(spec, "index_map", None)
    if imap is not None and arity == len(grid):
        # evaluate at every grid corner, not just the origin: a map that
        # special-cases the first block (or divides wrongly near the last)
        # returns the right rank at (0,...,0) and the wrong one elsewhere
        for corner in grid_corners(grid):
            try:
                idx = eval_index_map(spec, corner)
            except Exception as exc:  # noqa: BLE001 - any raise is the bug
                emit("RPL101", f"index_map raised at grid corner "
                     f"{corner}: {exc!r}")
                break
            if len(idx) != len(block):
                emit("RPL101", f"index_map at grid corner {corner} returns "
                     f"{len(idx)} block indices but the block shape "
                     f"{block} has rank {len(block)}")
                break

    for d, (b, full) in enumerate(zip(block, aval.shape)):
        if b is None:               # None = whole axis, always legal
            continue
        if not isinstance(b, int) or b <= 0:
            emit("RPL103", f"dim {d}: block size {b!r} is not a positive "
                 "int")
        elif full % b != 0:
            emit("RPL103", f"dim {d}: block size {b} does not divide the "
                 f"operand dim {full} (ops.py pads to the contract; a "
                 "non-divisor block silently reads OOB-padded garbage)")

    last = block[-1]
    if (isinstance(last, int) and last > 1 and last % MXU_LANE != 0
            and last != aval.shape[-1]):
        emit("RPL104", f"trailing block dim {last} is MXU-misaligned: "
             f"not 1, not a multiple of {MXU_LANE}, and not the whole "
             f"operand dim {aval.shape[-1]} — the lane axis would be "
             "re-tiled with padding on every block")


def check_call(call: CapturedCall, path: str) -> list[Finding]:
    """Statically verify one captured ``pallas_call`` contract."""
    findings: list[Finding] = []
    grid = call.grid

    for i, (spec, aval) in enumerate(zip(call.in_specs, call.operands)):
        _check_spec(findings, f"in_specs[{i}]", path, spec, aval, grid)
    for i, (spec, shape) in enumerate(zip(call.out_specs, call.out_shape)):
        _check_spec(findings, f"out_specs[{i}]", path, spec, shape, grid)

    if len(call.in_specs) != len(call.operands):
        findings.append(Finding(
            code="RPL105", path=path, line=0, col=0,
            message=f"{len(call.in_specs)} in_specs for "
                    f"{len(call.operands)} operands", snippet="in_specs"))

    expected = (len(call.operands) + len(call.out_shape)
                + len(call.scratch_shapes))
    arity = _positional_arity(call.kernel)
    if arity is not None and arity != expected:
        findings.append(Finding(
            code="RPL105", path=path, line=0, col=0,
            message=f"kernel body takes {arity} refs but the call wires "
                    f"{len(call.operands)} inputs + {len(call.out_shape)} "
                    f"outputs + {len(call.scratch_shapes)} scratch = "
                    f"{expected}", snippet="kernel arity"))

    for i, scratch in enumerate(call.scratch_shapes):
        shape = getattr(scratch, "shape", None)
        if shape is not None and any(
                (not isinstance(d, int)) or d <= 0 for d in shape):
            findings.append(Finding(
                code="RPL103", path=path, line=0, col=0,
                message=f"scratch_shapes[{i}]: non-positive dim in "
                        f"{tuple(shape)}", snippet=f"scratch[{i}]"))
    return findings


def check_traced(trace: Callable[[], Any], path: str) -> list[Finding]:
    """Run ``trace`` (a thunk invoking kernel entry points) under the
    capturing stub and check every ``pallas_call`` it makes."""
    with capture_pallas_calls() as stub:
        trace()
    findings: list[Finding] = []
    for call in stub.calls:
        findings.extend(check_call(call, path))
    return findings


# ---------------------------------------------------------------------------
# the shipped kernels
# ---------------------------------------------------------------------------

def _trace_flash_attention() -> None:
    from repro.kernels.flash_attention.kernel import flash_attention_pallas
    B, H, KV, S, D = 1, 4, 2, 256, 128
    q = jnp.zeros((B, H, S, D), jnp.float32)
    k = jnp.zeros((B, KV, S, D), jnp.float32)
    pos = jnp.zeros((B, S), jnp.int32)
    flash_attention_pallas(q, k, k, pos, pos, causal=True, window=64,
                           softcap=30.0)


def _trace_rmsnorm() -> None:
    from repro.kernels.rmsnorm.kernel import rmsnorm_pallas
    rows, d = 256, 512
    rmsnorm_pallas(jnp.zeros((rows, d), jnp.float32),
                   jnp.zeros((d,), jnp.float32))


def _trace_ssd() -> None:
    from repro.kernels.ssd.kernel import ssd_pallas
    B, L, H, P, G, N = 1, 256, 4, 64, 2, 32
    ssd_pallas(jnp.zeros((B, L, H, P), jnp.float32),
               jnp.zeros((B, L, H), jnp.float32),
               jnp.zeros((H,), jnp.float32),
               jnp.zeros((B, L, G, N), jnp.float32),
               jnp.zeros((B, L, G, N), jnp.float32))


SHIPPED_KERNELS: dict[str, Callable[[], None]] = {
    "src/repro/kernels/flash_attention/kernel.py": _trace_flash_attention,
    "src/repro/kernels/rmsnorm/kernel.py": _trace_rmsnorm,
    "src/repro/kernels/ssd/kernel.py": _trace_ssd,
}


def shipped_report() -> tuple[list[Finding], list[str]]:
    """Check every shipped kernel; also collect the unmodeled
    ``pallas_call`` keyword names the stub saw, so the report surfaces
    API surface the checker ignores instead of silently dropping it."""
    findings: list[Finding] = []
    kwargs_seen: set[str] = set()
    for path, trace in SHIPPED_KERNELS.items():
        with capture_pallas_calls() as stub:
            trace()
        for call in stub.calls:
            findings.extend(check_call(call, path))
            kwargs_seen.update(call.extra_kwargs)
    return findings, sorted(kwargs_seen)


def check_shipped() -> list[Finding]:
    return shipped_report()[0]


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.quality.pallas_check",
        description=__doc__.splitlines()[0])
    ap.add_argument("--report", default=None,
                    help="write the JSON report here (e.g. "
                         "artifacts/lint/pallas_check.json)")
    args = ap.parse_args(argv)
    findings, kwargs_seen = shipped_report()
    for f in findings:
        print(f"{f.path}: {f.code} {f.message}")
    if args.report:
        report = {
            "tool": "replint.pallas_check",
            "kernels": list(SHIPPED_KERNELS),
            "n_findings": len(findings),
            "clean": not findings,
            "extra_kwargs_seen": kwargs_seen,
            "findings": [{"code": f.code, "path": f.path,
                          "message": f.message} for f in findings],
        }
        os.makedirs(os.path.dirname(args.report) or ".", exist_ok=True)
        with open(args.report, "w", encoding="utf-8") as f:
            json.dump(report, f, indent=1)
            f.write("\n")
    n = len(SHIPPED_KERNELS)
    print(f"pallas_check: {n} kernels, {len(findings)} findings")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
