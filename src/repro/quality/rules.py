"""AST rules for ``replint`` (the ``RPL0xx`` determinism/hygiene family).

Every rule exists because the replay engine's correctness contract is
*bit-exact determinism*: the golden-summary fixtures pin the full summary
tree of 50k-job traces, and PR 5's hot-path rewrite was only committable
because RNG streams and float-op order were provably unchanged. The
failure mode these rules guard against is not a crash — it is a mysterious
golden-fixture diff three PRs later.

Rule codes
----------
``RPL000``  file does not parse (syntax error)
``RPL001``  unseeded RNG: module-level ``random.*`` / ``np.random.*``
            draws, unseeded ``random.Random()`` / ``np.random.default_rng()``
            construction, or global ``seed()`` calls — every drawing
            function must thread an explicit seeded generator
``RPL002``  set-iteration order escaping into an ordered sink (``for``
            over a set expression, ``list()`` / ``tuple()`` / ``enumerate``
            of one, a set expression inside a ``heappush`` payload, or an
            ordered comprehension over one); wrap in ``sorted(...)``
``RPL003``  wall-clock (``time.time`` / ``perf_counter`` / ``datetime.now``
            ...) or ``id()`` ordering inside declared engine modules —
            simulation time is event time, and ``id()`` varies run-to-run
``RPL004``  bare ``print()`` in library code (use ``repro.utils.logger``)
``RPL005``  class in a declared hot module without ``__slots__`` (plain
            body declaration or ``@dataclass(slots=True)``)

Scoping: which paths a rule applies to is decided here (path predicates),
not by the caller — ``benchmarks/`` may print, only engine modules are
held to the wall-clock rule, only hot modules to ``__slots__``.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Callable, Optional

# ---------------------------------------------------------------------------
# findings
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation. ``snippet`` (the stripped source line) is the
    baseline fingerprint component, so grandfathered findings survive line
    drift but not edits to the offending statement."""
    __slots__ = ("code", "path", "line", "col", "message", "snippet")
    code: str
    path: str          # repo-relative, forward slashes
    line: int
    col: int
    message: str
    snippet: str

    def fingerprint(self) -> tuple:
        return (self.path, self.code, self.snippet)

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.code} {self.message}"


# ---------------------------------------------------------------------------
# path scoping
# ---------------------------------------------------------------------------

def _in_library(path: str) -> bool:
    return "repro/" in path and "/quality/" not in path


def _in_engine(path: str) -> bool:
    # the event-driven simulation core: all of cluster/, plus the evalsched
    # pieces that run inside the replay loop (coordinator/trial/simulator —
    # runner.py measures real eval-stage wall time on purpose)
    if "repro/cluster/" in path:
        return True
    return any(path.endswith(m) for m in (
        "repro/core/evalsched/coordinator.py",
        "repro/core/evalsched/trial.py",
        "repro/core/evalsched/simulator.py"))


def _in_hot(path: str) -> bool:
    # launch/cost_model.py and launch/hlo_analysis.py joined the list when
    # the replay started repricing every elastic event through them — they
    # are engine-adjacent hot paths now, not offline tooling
    return path.endswith(("repro/cluster/replay.py",
                          "repro/cluster/scheduler.py",
                          "repro/cluster/serve_replay.py",
                          "repro/launch/cost_model.py",
                          "repro/launch/hlo_analysis.py"))


def _anywhere(path: str) -> bool:
    return True


# code -> (one-line summary, path predicate)
RULES: dict[str, tuple[str, Callable[[str], bool]]] = {
    "RPL000": ("file does not parse", _anywhere),
    "RPL001": ("unseeded module-level RNG draw", _anywhere),
    "RPL002": ("set-iteration order escapes into an ordered sink",
               _anywhere),
    "RPL003": ("wall-clock/id() ordering in engine code", _in_engine),
    "RPL004": ("print() in library code", _in_library),
    "RPL005": ("record class in hot module lacks __slots__", _in_hot),
}

# ---------------------------------------------------------------------------
# RPL001 tables
# ---------------------------------------------------------------------------

# stdlib ``random`` module-level functions that draw from (or reseed) the
# hidden global Mersenne Twister
_PY_DRAWS = frozenset((
    "random", "randint", "randrange", "uniform", "choice", "choices",
    "shuffle", "sample", "gauss", "normalvariate", "lognormvariate",
    "expovariate", "betavariate", "gammavariate", "paretovariate",
    "vonmisesvariate", "weibullvariate", "triangular", "getrandbits",
    "randbytes", "seed",
))

# legacy ``numpy.random`` module-level API (the hidden global RandomState)
_NP_DRAWS = frozenset((
    "rand", "randn", "randint", "random", "random_sample", "ranf",
    "sample", "uniform", "choice", "shuffle", "permutation", "normal",
    "standard_normal", "exponential", "poisson", "beta", "gamma",
    "binomial", "lognormal", "geometric", "bytes", "seed",
))

# constructors that are fine *seeded* but violations bare
_GENERATORS = frozenset((
    "random.Random", "random.SystemRandom",
    "numpy.random.default_rng", "numpy.random.RandomState",
))

_WALL_CLOCK = frozenset((
    "time.time", "time.time_ns", "time.perf_counter",
    "time.perf_counter_ns", "time.monotonic", "time.monotonic_ns",
    "time.process_time", "time.process_time_ns",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "datetime.date.today",
))

_SLOTS_EXEMPT_BASES = frozenset((
    "Enum", "IntEnum", "StrEnum", "Flag", "NamedTuple", "Protocol",
    "TypedDict", "ABC",
))

_ORDERED_SINKS = frozenset(("list", "tuple", "enumerate", "iter",
                            "reversed"))


# ---------------------------------------------------------------------------
# the visitor
# ---------------------------------------------------------------------------

def _is_set_expr(node: ast.AST) -> bool:
    """Syntactically set-valued: a set literal, a set comprehension, or a
    direct ``set(...)`` / ``frozenset(...)`` call. (Variables that *hold*
    sets need type inference; this rule is deliberately syntactic — the
    fixture corpus and the engine's own history show the direct forms are
    where the leaks happen.)"""
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    return (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Name)
            and node.func.id in ("set", "frozenset"))


class _RuleVisitor(ast.NodeVisitor):
    def __init__(self, path: str, src_lines: list[str]):
        self.path = path
        self.lines = src_lines
        self.findings: list[Finding] = []
        # alias -> canonical dotted module/name ("np" -> "numpy",
        # "randint" -> "random.randint"); module-level only, which covers
        # the idiomatic import styles the repo uses
        self.aliases: dict[str, str] = {}
        self.active = {code for code, (_, applies) in RULES.items()
                       if applies(path)}

    # -- plumbing -----------------------------------------------------------

    def _emit(self, code: str, node: ast.AST, message: str) -> None:
        if code not in self.active:
            return
        line = getattr(node, "lineno", 1)
        snippet = (self.lines[line - 1].strip()
                   if 0 < line <= len(self.lines) else "")
        self.findings.append(Finding(
            code=code, path=self.path, line=line,
            col=getattr(node, "col_offset", 0) + 1,
            message=message, snippet=snippet))

    def _canonical(self, node: ast.AST) -> Optional[str]:
        """Resolve ``np.random.default_rng`` -> ``numpy.random.default_rng``
        through the module's import aliases; None for non-name chains or
        chains rooted at a local variable."""
        parts: list[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        head = self.aliases.get(node.id)
        if head is None:
            return None
        parts.append(head)
        return ".".join(reversed(parts))

    # -- imports ------------------------------------------------------------

    def visit_Import(self, node: ast.Import) -> None:
        for a in node.names:
            self.aliases[a.asname or a.name.split(".")[0]] = (
                a.name if a.asname else a.name.split(".")[0])
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module and node.level == 0:
            for a in node.names:
                self.aliases[a.asname or a.name] = f"{node.module}.{a.name}"
        self.generic_visit(node)

    # -- RPL001 / RPL003 / RPL004: calls ------------------------------------

    def visit_Call(self, node: ast.Call) -> None:
        name = self._canonical(node.func)
        if name:
            last = name.rsplit(".", 1)[-1]
            if name == f"random.{last}" and last in _PY_DRAWS:
                self._emit("RPL001", node,
                           f"module-level random.{last}() draws from the "
                           "hidden global RNG; thread an explicit seeded "
                           "random.Random")
            elif (name.startswith("numpy.random.") and last in _NP_DRAWS
                  and name == f"numpy.random.{last}"):
                self._emit("RPL001", node,
                           f"module-level np.random.{last}() draws from "
                           "the hidden global RandomState; thread an "
                           "explicit np.random.Generator")
            elif (name in _GENERATORS and not node.args
                  and not any(kw.arg in ("seed", "x") for kw in
                              node.keywords)):
                self._emit("RPL001", node,
                           f"{name}() without a seed is entropy-seeded; "
                           "pass an explicit seed")
            elif name in _WALL_CLOCK:
                self._emit("RPL003", node,
                           f"{name}() in engine code: simulation time is "
                           "event time, wall-clock reads are "
                           "nondeterministic")
        if isinstance(node.func, ast.Name):
            fid = node.func.id
            if fid == "print" and fid not in self.aliases:
                self._emit("RPL004", node,
                           "print() in library code; use repro.utils.logger")
            elif (fid == "id" and fid not in self.aliases and node.args):
                self._emit("RPL003", node,
                           "id() in engine code: CPython addresses vary "
                           "run-to-run, any ordering built on them is "
                           "nondeterministic")
            elif fid in _ORDERED_SINKS and any(
                    _is_set_expr(a) for a in node.args):
                self._emit("RPL002", node,
                           f"{fid}() over a set expression materializes "
                           "nondeterministic iteration order; use "
                           "sorted(...)")
        # heappush((..., set_expr, ...)) — a set leaking into heap order
        if (name in ("heapq.heappush", "heapq.heappushpop", "heapq.merge")
                or (isinstance(node.func, ast.Name)
                    and self.aliases.get(node.func.id, "").startswith(
                        "heapq."))):
            for a in ast.walk(node):
                if a is not node and _is_set_expr(a):
                    self._emit("RPL002", node,
                               "set expression inside a heap push: set "
                               "order leaks into event order")
                    break
        self.generic_visit(node)

    # -- RPL002: ordered iteration over set expressions ---------------------

    def visit_For(self, node: ast.For) -> None:
        if _is_set_expr(node.iter):
            self._emit("RPL002", node,
                       "for-loop over a set expression iterates in "
                       "nondeterministic order; use sorted(...)")
        self.generic_visit(node)

    def _check_comp(self, node) -> None:
        for gen in node.generators:
            if _is_set_expr(gen.iter):
                self._emit("RPL002", gen.iter,
                           "ordered comprehension over a set expression; "
                           "use sorted(...)")
        self.generic_visit(node)

    # SetComp is exempt: set-in, set-out — no order escapes
    visit_ListComp = _check_comp
    visit_GeneratorExp = _check_comp
    visit_DictComp = _check_comp

    # -- RPL005: __slots__ in hot modules -----------------------------------

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        if "RPL005" in self.active:
            self._check_slots(node)
        self.generic_visit(node)

    def _check_slots(self, node: ast.ClassDef) -> None:
        for base in node.bases:
            tail = base.attr if isinstance(base, ast.Attribute) else (
                base.id if isinstance(base, ast.Name) else "")
            if (tail in _SLOTS_EXEMPT_BASES or tail.endswith("Exception")
                    or tail.endswith("Error")):
                return
        for stmt in node.body:
            targets = stmt.targets if isinstance(stmt, ast.Assign) else (
                [stmt.target] if isinstance(stmt, ast.AnnAssign) else [])
            for t in targets:
                if isinstance(t, ast.Name) and t.id == "__slots__":
                    return
        for dec in node.decorator_list:
            if isinstance(dec, ast.Call):
                for kw in dec.keywords:
                    if (kw.arg == "slots"
                            and isinstance(kw.value, ast.Constant)
                            and kw.value.value is True):
                        return
        self._emit("RPL005", node,
                   f"class {node.name} in a declared hot module has no "
                   "__slots__ (add one, or @dataclass(slots=True)); "
                   "instance dicts cost the engine's record-heavy paths")


def lint_source(path: str, source: str) -> list[Finding]:
    """Run every applicable rule over one file's source; returns raw
    findings (suppressions and the baseline are the caller's job —
    ``repro.quality.lint``)."""
    lines = source.splitlines()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as exc:
        return [Finding(code="RPL000", path=path, line=exc.lineno or 1,
                        col=(exc.offset or 0) + 1,
                        message=f"syntax error: {exc.msg}",
                        snippet=(lines[exc.lineno - 1].strip()
                                 if exc.lineno and
                                 exc.lineno <= len(lines) else ""))]
    visitor = _RuleVisitor(path, lines)
    visitor.visit(tree)
    visitor.findings.sort(key=lambda f: (f.line, f.col, f.code))
    return visitor.findings
