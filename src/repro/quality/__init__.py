"""replint — repo-native static analysis for the reproduction.

Two rule families, one quality gate:

* ``repro.quality.lint`` (``python -m repro.quality.lint PATH...``) —
  AST-based determinism & engine-hygiene rules (``RPL0xx``) over the
  library: unseeded RNG draws, set-iteration order escaping into ordered
  sinks, wall-clock / ``id()`` ordering inside the replay engine, bare
  ``print`` in library code, and ``__slots__`` enforcement in the declared
  hot modules. Findings can be suppressed inline
  (``# replint: disable=RPL001``) or grandfathered in the committed
  baseline (``src/repro/quality/baseline.json``).

* ``repro.quality.pallas_check`` (``python -m repro.quality.pallas_check``)
  — imports the Pallas kernels *without a TPU* and statically verifies
  every ``pl.pallas_call`` contract (``RPL1xx``): index_map arity vs grid
  rank, block-shape rank/divisibility vs the operand, MXU 128-alignment of
  trailing block dims, kernel-signature arity vs specs + scratch.

Both are wired into the CI ``lint`` job (see ``.github/workflows/ci.yml``)
and fail it on any non-baseline finding; the JSON reports land in
``artifacts/lint/``. The replay engine's correctness story is bit-exact
determinism (``tests/test_golden_summary.py``), so violations that would
only surface as a mysterious golden-fixture diff are caught at lint time
instead.

(No eager re-exports: ``python -m repro.quality.lint`` must not find the
submodule pre-imported by its own package — import ``repro.quality.lint``
/ ``repro.quality.pallas_check`` directly.)
"""
