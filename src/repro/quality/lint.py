"""``replint`` driver: file discovery, suppressions, baseline, report, CLI.

Usage (what the CI ``lint`` job runs)::

    PYTHONPATH=src python -m repro.quality.lint src/repro benchmarks \\
        examples --report artifacts/lint/replint.json

Exit 0 when every finding is suppressed or baselined, 1 otherwise, 2 on
usage errors. See ``repro.quality.rules`` for the rule codes.

Suppressions
------------
A finding is suppressed by a comment on its own line::

    x = random.random()   # replint: disable=RPL001

``disable=RPL001,RPL003`` suppresses several codes, bare ``disable``
suppresses every rule on that line. Suppressions are counted in the report
so they cannot accumulate silently.

Baseline
--------
``src/repro/quality/baseline.json`` (committed) holds grandfathered
findings as ``(path, code, stripped-source-line)`` fingerprints — stable
across line drift, invalidated by edits to the offending statement.
Non-baseline findings fail the run; stale baseline entries are reported so
the file shrinks monotonically. Regenerate with ``--write-baseline`` (the
tree this PR ships has an **empty** baseline — keep it that way).
"""
from __future__ import annotations

import argparse
import collections
import json
import os
import re
import sys
from typing import Iterable, Optional

from repro.quality.rules import RULES, Finding, lint_source

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baseline.json")

_SUPPRESS_RE = re.compile(
    r"#\s*replint:\s*disable(?:=(?P<codes>[A-Z0-9,\s]+))?")

# directories never worth descending into
_SKIP_DIRS = frozenset(("__pycache__", ".git", ".github", "node_modules",
                        ".venv", "venv"))


def iter_py_files(paths: Iterable[str]) -> list[str]:
    out: list[str] = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
        elif os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs if d not in _SKIP_DIRS)
                out.extend(os.path.join(root, f) for f in sorted(files)
                           if f.endswith(".py"))
        else:
            raise FileNotFoundError(p)
    # normalized repo-relative forward-slash paths keep fingerprints and
    # rule scoping identical across machines and invocation directories
    return [os.path.relpath(f).replace(os.sep, "/") for f in out]


def _suppressed_codes(line: str) -> Optional[frozenset]:
    """Codes disabled on ``line``; empty frozenset = all codes; None = no
    suppression comment."""
    m = _SUPPRESS_RE.search(line)
    if not m:
        return None
    codes = m.group("codes")
    if codes is None:
        return frozenset()
    return frozenset(c.strip() for c in codes.split(",") if c.strip())


def lint_file(path: str) -> tuple[list[Finding], int]:
    """Returns (unsuppressed findings, suppressed count) for one file."""
    with open(path, encoding="utf-8") as f:
        source = f.read()
    lines = source.splitlines()
    kept: list[Finding] = []
    n_suppressed = 0
    for finding in lint_source(path, source):
        raw = lines[finding.line - 1] if finding.line <= len(lines) else ""
        codes = _suppressed_codes(raw)
        if codes is not None and (not codes or finding.code in codes):
            n_suppressed += 1
        else:
            kept.append(finding)
    return kept, n_suppressed


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------

def load_baseline(path: str) -> collections.Counter:
    """Multiset of grandfathered fingerprints (missing file = empty)."""
    if not os.path.exists(path):
        return collections.Counter()
    with open(path, encoding="utf-8") as f:
        entries = json.load(f)
    return collections.Counter(
        (e["path"], e["code"], e["snippet"]) for e in entries)


def write_baseline(path: str, findings: list[Finding]) -> None:
    entries = [{"path": f.path, "code": f.code, "snippet": f.snippet}
               for f in findings]
    with open(path, "w", encoding="utf-8") as f:
        json.dump(entries, f, indent=1, sort_keys=True)
        f.write("\n")


def apply_baseline(findings: list[Finding], baseline: collections.Counter
                   ) -> tuple[list[Finding], int, int]:
    """Split ``findings`` against the baseline multiset. Returns
    (new findings, n_baselined, n_stale_baseline_entries)."""
    remaining = collections.Counter(baseline)
    new: list[Finding] = []
    n_baselined = 0
    for f in findings:
        fp = f.fingerprint()
        if remaining.get(fp, 0) > 0:
            remaining[fp] -= 1
            n_baselined += 1
        else:
            new.append(f)
    return new, n_baselined, sum(remaining.values())


# ---------------------------------------------------------------------------
# runs
# ---------------------------------------------------------------------------

def _collect(paths: Iterable[str]) -> tuple[list, list[Finding], int]:
    files = iter_py_files(paths)
    findings: list[Finding] = []
    n_suppressed = 0
    for path in files:
        got, sup = lint_file(path)
        findings.extend(got)
        n_suppressed += sup
    return files, findings, n_suppressed


def _make_report(paths: Iterable[str], files: list, new: list[Finding],
                 n_suppressed: int, n_baselined: int, n_stale: int) -> dict:
    return {
        "tool": "replint",
        "rules": {code: summary for code, (summary, _) in RULES.items()},
        "paths": list(paths),
        "n_files": len(files),
        "n_findings": len(new),
        "n_suppressed": n_suppressed,
        "n_baselined": n_baselined,
        "n_stale_baseline": n_stale,
        "clean": not new,
        "findings": [{"code": f.code, "path": f.path, "line": f.line,
                      "col": f.col, "message": f.message,
                      "snippet": f.snippet} for f in new],
    }


def run_lint(paths: Iterable[str], *,
             baseline_path: str = DEFAULT_BASELINE) -> dict:
    """Lint ``paths``; returns the JSON-ready report dict. ``clean`` is
    True when no finding survives suppressions + baseline."""
    files, findings, n_suppressed = _collect(paths)
    new, n_baselined, n_stale = apply_baseline(
        findings, load_baseline(baseline_path))
    return _make_report(paths, files, new, n_suppressed, n_baselined,
                        n_stale)


def verdict(paths: Iterable[str] = ("src/repro",)) -> dict:
    """Compact verdict for stamping into bench artifacts (see
    ``benchmarks/run.py`` / ``check_regression.py``): bench numbers from a
    tree with non-baseline lint findings must not become baselines."""
    report = run_lint(paths)
    return {"clean": report["clean"], "findings": report["n_findings"],
            "baselined": report["n_baselined"]}


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.quality.lint",
        description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="+", help="files/directories to lint")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="grandfathered-findings file (default: the "
                         "committed package baseline)")
    ap.add_argument("--report", default=None,
                    help="write the JSON report here (e.g. "
                         "artifacts/lint/replint.json)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="regenerate --baseline from the current findings "
                         "and exit 0 (each entry must be justified in the "
                         "PR that commits it)")
    args = ap.parse_args(argv)

    try:
        files, findings, n_suppressed = _collect(args.paths)
    except FileNotFoundError as exc:
        print(f"replint: no such path: {exc}", file=sys.stderr)
        return 2

    if args.write_baseline:
        write_baseline(args.baseline, findings)
        print(f"replint: wrote {len(findings)} baseline entries to "
              f"{args.baseline}")
        return 0

    new, n_baselined, n_stale = apply_baseline(
        findings, load_baseline(args.baseline))
    for f in new:
        print(f.render())
    if n_stale:
        print(f"replint: {n_stale} stale baseline entries (fixed or "
              f"edited findings) — regenerate with --write-baseline")

    if args.report:
        report = _make_report(args.paths, files, new, n_suppressed,
                              n_baselined, n_stale)
        os.makedirs(os.path.dirname(args.report) or ".", exist_ok=True)
        with open(args.report, "w", encoding="utf-8") as f:
            json.dump(report, f, indent=1)
            f.write("\n")

    print(f"replint: {len(files)} files, {len(new)} findings "
          f"({n_suppressed} suppressed, {n_baselined} baselined)")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
