"""Static Pallas kernel resource & roofline analyzer (``RPL2xx`` family).

Where ``pallas_check`` verifies the *contract* of every ``pallas_call``
(BlockSpec arity/rank/divisibility — RPL1xx), this module derives the
*resources* the call will consume, on hosts with no TPU:

* the kernel body is abstract-interpreted — each ref is bound to a
  block-shaped aval (``_RefBox``), ``pl.program_id`` / ``pl.when`` are
  replaced by static stand-ins, and the body is lowered through
  ``jax.make_jaxpr``; walking the jaxpr eqns with one set of FLOP/byte
  constants (shared with ``launch.hlo_analysis``) yields per-grid-step
  FLOPs, transcendental counts, and the VMEM footprint (operand/output
  blocks double-buffered by the pipeline, scratch single);
* every ``index_map`` is evaluated over the *full* grid (not just the
  corners, as ``pallas_check`` does) to compute exact HBM bytes moved per
  operand, block revisit factors, and output-tiling coverage.

From these, gated rules:

``RPL201``  VMEM budget overflow: 2x(input+output blocks) + scratch
            exceeds the per-core budget (16 MiB)
``RPL202``  pathological revisit: an *input* operand is re-fetched across
            a grid axis its index_map ignores (revisit factor > 1) and is
            not listed in the kernel module's declared
            ``STREAMING_OPERANDS`` allowance
``RPL203``  output tiling leaves gaps (tiles never written, today's
            silent-garbage class) or overlaps (a block written in more
            than one non-consecutive run — a double-write)
``RPL204``  a kernel ref the jaxpr never reads nor writes (dead wiring)

and a per-(kernel, shape) static cost table — FLOPs, HBM bytes,
arithmetic intensity, roofline-% via ``launch.roofline`` peaks — written
to ``artifacts/lint/pallas_cost.json``. The table is the ground truth the
ROADMAP's kernel perf push benchmarks against
(``benchmarks/bench_kernel_cost.py`` records it in the trajectory;
``check_regression`` fails CI when a kernel edit degrades predicted
intensity), and ``CostModel``'s analytic kernel constant is cross-checked
against the static intensity envelope here.

Run over the shipped kernels (what CI does)::

    PYTHONPATH=src python -m repro.quality.pallas_cost \\
        --report artifacts/lint/pallas_cost.json

Exit 0 when every kernel passes and the cost-model cross-check holds.
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import itertools
import json
import math
import os
import sys
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.launch.hlo_analysis import dtype_bytes
from repro.launch.roofline import HBM_BW, PEAK_FLOPS
from repro.quality.pallas_check import (CapturedCall, capture_pallas_calls,
                                        check_call, eval_index_map)
from repro.quality.rules import Finding

#: per-core VMEM budget (bytes) — the Pallas TPU guide's ~16 MiB/core
VMEM_BUDGET_BYTES = 16 * 1024 * 1024

#: slack factor for the CostModel cross-check: the analytic fusion-level
#: intensity must lie inside [min_kernel / SLACK, max_kernel * SLACK] of
#: the statically-derived per-kernel intensities
COST_MODEL_SLACK = 1.25


# ---------------------------------------------------------------------------
# kernel-body abstract interpretation
# ---------------------------------------------------------------------------

class _RefBox:
    """Mutable stand-in for a Pallas Ref during abstract interpretation.

    Holds a block-shaped traced array; ``[]`` reads and ``[]=`` writes are
    counted (RPL204) while staying traceable — writes go through
    ``.at[idx].set`` so the body lowers to a normal jaxpr. ``__jax_array__``
    lets ``jnp.zeros_like(ref)``-style shape probes work without counting
    as a data read.
    """
    __slots__ = ("val", "name", "reads", "writes")

    def __init__(self, val, name: str) -> None:
        self.val = val
        self.name = name
        self.reads = 0
        self.writes = 0

    @property
    def shape(self):
        return self.val.shape

    @property
    def dtype(self):
        return self.val.dtype

    def __getitem__(self, idx):
        self.reads += 1
        return self.val[idx]

    def __setitem__(self, idx, value):
        self.writes += 1
        self.val = self.val.at[idx].set(value)

    def __jax_array__(self):
        return self.val


@contextlib.contextmanager
def _static_pallas_env():
    """Patch the Pallas primitives kernels use for control flow so a body
    traces outside ``pallas_call``: ``program_id`` becomes step 0 and
    ``pl.when`` runs its body unconditionally. Consequence (documented
    convention): conditionally-executed work is charged on *every* grid
    step, making the static FLOP count an upper bound — for the shipped
    kernels the ``@pl.when`` bodies are O(block) init/writeback next to
    O(block^2) matmuls, <3% of a step."""
    orig_pid, orig_when = pl.program_id, pl.when

    def _when(_cond):
        def deco(fn):
            fn()
            return fn
        return deco

    pl.program_id = lambda axis: jnp.int32(0)
    pl.when = _when
    try:
        yield
    finally:
        pl.program_id, pl.when = orig_pid, orig_when


def _ref_shape(spec, aval) -> tuple:
    """Shape of the ref the kernel body sees for one (spec, operand):
    ``None`` block dims are squeezed out of the view; a spec without a
    block_shape (or no spec) passes the whole operand through."""
    block = getattr(spec, "block_shape", None)
    if block is None:
        return tuple(aval.shape)
    return tuple(int(b) for b in block if b is not None)


def _block_dims(spec, aval) -> tuple:
    """Extent of one resident block in operand coordinates (``None`` block
    dims span the whole axis)."""
    block = getattr(spec, "block_shape", None)
    if block is None:
        return tuple(aval.shape)
    return tuple(int(aval.shape[d]) if b is None else int(b)
                 for d, b in enumerate(block))


def trace_body(call: CapturedCall) -> tuple:
    """Lower one captured call's kernel body to a jaxpr with every ref
    bound to its block-shaped aval. Returns ``(jaxpr, refs)`` where
    ``refs`` is the list of ``_RefBox`` (inputs, then outputs, then
    scratch) carrying read/write counts from the trace."""
    ref_specs: list[tuple[str, tuple, Any]] = []
    for i, (spec, aval) in enumerate(zip(call.in_specs, call.operands)):
        ref_specs.append((f"in[{i}]", _ref_shape(spec, aval), aval.dtype))
    for i, (spec, aval) in enumerate(zip(call.out_specs, call.out_shape)):
        ref_specs.append((f"out[{i}]", _ref_shape(spec, aval), aval.dtype))
    for i, scr in enumerate(call.scratch_shapes):
        ref_specs.append((f"scratch[{i}]", tuple(scr.shape), scr.dtype))

    refs: list[_RefBox] = []

    def run(*arrays):
        boxes = [_RefBox(a, name)
                 for a, (name, _, _) in zip(arrays, ref_specs)]
        refs.clear()
        refs.extend(boxes)
        call.kernel(*boxes)
        return tuple(b.val for b in boxes)

    avals = [jax.ShapeDtypeStruct(shape, dtype)
             for _, shape, dtype in ref_specs]
    with _static_pallas_env():
        jaxpr = jax.make_jaxpr(run)(*avals)
    return jaxpr.jaxpr, refs


# one set of per-primitive cost conventions (bytes come from
# hlo_analysis.dtype_bytes so both analyzers price with the same tables)
_TRANSCENDENTAL = frozenset({
    "exp", "exp2", "expm1", "log", "log1p", "logistic", "tanh", "sinh",
    "cosh", "sin", "cos", "tan", "asin", "acos", "atan", "atan2", "sqrt",
    "rsqrt", "cbrt", "pow", "erf", "erfc", "erf_inv",
})
_ELEMENTWISE = frozenset({
    "add", "sub", "mul", "div", "max", "min", "rem", "neg", "abs", "sign",
    "floor", "ceil", "round", "select_n", "clamp", "nextafter", "and",
    "or", "xor", "not", "shift_left", "shift_right_logical",
    "shift_right_arithmetic", "integer_pow", "square", "add_any",
})
_REDUCTION = frozenset({
    "reduce_sum", "reduce_max", "reduce_min", "reduce_prod", "reduce_and",
    "reduce_or", "reduce_xor", "argmax", "argmin", "cumsum", "cumprod",
    "cummax", "cummin", "cumlogsumexp",
})


def _n_elems(shape) -> int:
    return int(math.prod(shape)) if shape else 1


def jaxpr_flops(jaxpr) -> tuple[int, int]:
    """(flops, transcendentals) for one jaxpr, recursing into sub-jaxprs.

    Conventions: ``dot_general`` is 2*batch*M*N*K from its
    dimension_numbers; elementwise float/int arithmetic is 1/element
    (bool-valued ops — comparisons, logical masks — are free);
    transcendentals are 1 flop/element *and* counted separately;
    reductions/cumulations cost one pass over the input; data movement
    (broadcast/slice/convert/scatter from ref writes) is free.
    """
    flops = 0
    transc = 0
    for eqn in jaxpr.eqns:
        # recurse into sub-jaxprs (pjit, custom_jvp, remat, ...) first
        recursed = False
        for v in eqn.params.values():
            sub = v if hasattr(v, "eqns") else getattr(v, "jaxpr", None)
            if sub is not None and hasattr(sub, "eqns"):
                f, t = jaxpr_flops(sub)
                flops += f
                transc += t
                recursed = True
        if recursed:
            continue
        prim = eqn.primitive.name
        out_aval = eqn.outvars[0].aval
        if prim == "dot_general":
            (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
            lhs = eqn.invars[0].aval.shape
            rhs = eqn.invars[1].aval.shape
            batch = _n_elems([lhs[i] for i in lb])
            k = _n_elems([lhs[i] for i in lc])
            m = _n_elems([lhs[i] for i in range(len(lhs))
                          if i not in lc and i not in lb])
            n = _n_elems([rhs[i] for i in range(len(rhs))
                          if i not in rc and i not in rb])
            flops += 2 * batch * m * n * k
        elif prim in _REDUCTION:
            flops += _n_elems(eqn.invars[0].aval.shape)
        elif prim in _TRANSCENDENTAL:
            n = _n_elems(out_aval.shape)
            flops += n
            transc += n
        elif prim in _ELEMENTWISE:
            if getattr(out_aval.dtype, "kind", "f") != "b":
                flops += _n_elems(out_aval.shape)
        # everything else (broadcast, slice, convert, scatter, iota,
        # reshape, transpose, gather, ...) is data movement: 0 flops
    return flops, transc


# ---------------------------------------------------------------------------
# full-grid index_map walk
# ---------------------------------------------------------------------------

@dataclasses.dataclass(slots=True)
class OperandCost:
    """Static traffic/coverage stats for one operand over the full grid."""
    name: str
    block: tuple            # resident block extent, operand coords
    block_bytes: int
    fetches: int            # pipeline copies: index-change transitions
    distinct: int           # distinct block indices touched
    revisit: float          # fetches / distinct
    hbm_bytes: int          # fetches * block_bytes
    max_runs_per_block: int  # >1 on an output = non-consecutive re-write
    gap_tiles: int          # output tiles never written (0 for inputs)
    expected_tiles: int


def walk_spec(spec, aval, grid: tuple, *, is_output: bool,
              name: str) -> OperandCost:
    """Evaluate one spec's ``index_map`` over every grid step, in the
    pipeline's lexicographic order (innermost axis fastest), and derive
    exact traffic: a *fetch* is an index-change transition (the pipeline
    keeps a resident block across steps whose index repeats consecutively);
    output bytes count one writeback per run."""
    full = tuple(int(d) for d in aval.shape)
    block = _block_dims(spec, aval)
    block_bytes = _n_elems(block) * dtype_bytes(aval.dtype)
    imap = getattr(spec, "index_map", None)

    fetches = 0
    last_idx: Optional[tuple] = None
    runs: dict[tuple, int] = {}
    for step in itertools.product(*(range(n) for n in grid)):
        idx = eval_index_map(spec, step) if imap is not None \
            else (0,) * len(full)
        if idx != last_idx:
            fetches += 1
            runs[idx] = runs.get(idx, 0) + 1
            last_idx = idx
    distinct = len(runs)

    expected_tiles = _n_elems([full[d] // block[d] if block[d] else 1
                               for d in range(len(full))])
    gap_tiles = 0
    if is_output:
        tile_grid = [range(full[d] // block[d]) if block[d] else range(1)
                     for d in range(len(full))]
        covered = sum(1 for tile in itertools.product(*tile_grid)
                      if tile in runs)
        gap_tiles = expected_tiles - covered

    return OperandCost(
        name=name, block=block, block_bytes=block_bytes, fetches=fetches,
        distinct=distinct, revisit=fetches / max(distinct, 1),
        hbm_bytes=fetches * block_bytes,
        max_runs_per_block=max(runs.values(), default=0),
        gap_tiles=gap_tiles, expected_tiles=expected_tiles)


# ---------------------------------------------------------------------------
# one captured call -> cost record + RPL2xx findings
# ---------------------------------------------------------------------------

def analyze_call(call: CapturedCall, path: str, *,
                 streaming: Optional[dict] = None,
                 label: str = "") -> tuple[dict, list[Finding]]:
    """Full static analysis of one captured ``pallas_call``: the cost
    record (FLOPs / HBM bytes / VMEM / roofline prediction) and any
    RPL201-204 findings. ``streaming`` is the kernel's declared RPL202
    allowance ({operand position: reason})."""
    streaming = streaming or {}
    findings: list[Finding] = []

    def emit(code: str, where: str, message: str) -> None:
        findings.append(Finding(code=code, path=path, line=0, col=0,
                                message=f"{where}: {message}",
                                snippet=where))

    grid = call.grid
    steps = _n_elems(grid)

    jaxpr, refs = trace_body(call)
    step_flops, step_transc = jaxpr_flops(jaxpr)

    in_costs = [walk_spec(spec, aval, grid, is_output=False,
                          name=f"in[{i}]")
                for i, (spec, aval) in enumerate(zip(call.in_specs,
                                                     call.operands))]
    out_costs = [walk_spec(spec, aval, grid, is_output=True,
                           name=f"out[{i}]")
                 for i, (spec, aval) in enumerate(zip(call.out_specs,
                                                      call.out_shape))]

    # RPL201 — VMEM budget: in/out blocks are double-buffered by the
    # pipeline (next block streams in while this one computes), scratch is
    # single-instance
    block_bytes = sum(c.block_bytes for c in in_costs + out_costs)
    scratch_bytes = sum(_n_elems(tuple(s.shape)) * dtype_bytes(s.dtype)
                        for s in call.scratch_shapes)
    vmem_bytes = 2 * block_bytes + scratch_bytes
    if vmem_bytes > VMEM_BUDGET_BYTES:
        emit("RPL201", "vmem", f"{vmem_bytes} bytes of VMEM "
             f"(2x{block_bytes} double-buffered blocks + {scratch_bytes} "
             f"scratch) exceeds the {VMEM_BUDGET_BYTES}-byte per-core "
             "budget")

    # RPL202 — undeclared input revisit
    for i, c in enumerate(in_costs):
        if c.revisit > 1.0 and i not in streaming:
            emit("RPL202", c.name,
                 f"re-fetched {c.fetches} times for {c.distinct} distinct "
                 f"blocks (revisit x{c.revisit:.1f}) across a grid axis "
                 "its index_map ignores — declare it in the module's "
                 "STREAMING_OPERANDS with a reason, or reorder the grid")

    # RPL203 — output coverage
    for c in out_costs:
        if c.gap_tiles:
            emit("RPL203", c.name,
                 f"output tiling leaves {c.gap_tiles} of "
                 f"{c.expected_tiles} tiles unwritten — those regions "
                 "keep whatever HBM held before the call")
        if c.max_runs_per_block > 1:
            emit("RPL203", c.name,
                 f"an output block is written in {c.max_runs_per_block} "
                 "non-consecutive runs — later visits silently overwrite "
                 "earlier results (double-write)")

    # RPL204 — dead refs
    for box in refs:
        if box.reads == 0 and box.writes == 0:
            emit("RPL204", box.name,
                 "ref is never read nor written by the kernel body — "
                 "dead wiring (block still streams through VMEM every "
                 "step)")

    flops = step_flops * steps
    hbm_bytes = sum(c.hbm_bytes for c in in_costs + out_costs)
    intensity = flops / hbm_bytes if hbm_bytes else 0.0
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm_bytes / HBM_BW
    bound_s = max(compute_s, memory_s)
    cost = {
        "kernel": path,
        "shape": label,
        "grid": list(grid),
        "steps": steps,
        "flops_per_step": step_flops,
        "transcendentals_per_step": step_transc,
        "flops": flops,
        "hbm_bytes": hbm_bytes,
        "vmem_bytes": vmem_bytes,
        "arithmetic_intensity": intensity,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "bound": "compute" if compute_s >= memory_s else "memory",
        "roofline_frac": compute_s / bound_s if bound_s else 0.0,
        "operands": [dataclasses.asdict(c) | {"block": list(c.block)}
                     for c in in_costs + out_costs],
    }
    return cost, findings


def analyze_traced(trace: Callable[[], Any], path: str, *,
                   streaming: Optional[dict] = None,
                   label: str = "",
                   contract_check: bool = True
                   ) -> tuple[list[dict], list[Finding]]:
    """Run ``trace`` under the capturing stub and fully analyze every
    ``pallas_call`` it makes. Contract violations (RPL1xx) are reported
    too and short-circuit resource analysis for that call — deriving
    costs from a malformed spec would be noise."""
    with capture_pallas_calls() as stub:
        trace()
    costs: list[dict] = []
    findings: list[Finding] = []
    for call in stub.calls:
        contract = check_call(call, path) if contract_check else []
        if contract:
            findings.extend(contract)
            continue
        cost, fnd = analyze_call(call, path, streaming=streaming,
                                 label=label)
        costs.append(cost)
        findings.extend(fnd)
    return costs, findings


# ---------------------------------------------------------------------------
# the shipped kernels, over a representative shape table
# ---------------------------------------------------------------------------

@dataclasses.dataclass(slots=True)
class KernelCase:
    """One (kernel, shape) row of the static cost table."""
    path: str               # kernel module (reporting key)
    module: str             # import path holding STREAMING_OPERANDS
    label: str              # shape label in the table
    trace: Callable[[], None]


def _flash(B, H, KV, S, D, **kw):
    def trace():
        from repro.kernels.flash_attention.kernel import \
            flash_attention_pallas
        q = jnp.zeros((B, H, S, D), jnp.float32)
        k = jnp.zeros((B, KV, S, D), jnp.float32)
        pos = jnp.zeros((B, S), jnp.int32)
        flash_attention_pallas(q, k, k, pos, pos, **kw)
    return trace


def _rms(rows, d):
    def trace():
        from repro.kernels.rmsnorm.kernel import rmsnorm_pallas
        rmsnorm_pallas(jnp.zeros((rows, d), jnp.float32),
                       jnp.zeros((d,), jnp.float32))
    return trace


def _ssd(B, L, H, P, G, N):
    def trace():
        from repro.kernels.ssd.kernel import ssd_pallas
        ssd_pallas(jnp.zeros((B, L, H, P), jnp.float32),
                   jnp.zeros((B, L, H), jnp.float32),
                   jnp.zeros((H,), jnp.float32),
                   jnp.zeros((B, L, G, N), jnp.float32),
                   jnp.zeros((B, L, G, N), jnp.float32))
    return trace


_FLASH_PATH = "src/repro/kernels/flash_attention/kernel.py"
_RMS_PATH = "src/repro/kernels/rmsnorm/kernel.py"
_SSD_PATH = "src/repro/kernels/ssd/kernel.py"

#: the static cost table rows: each kernel at its pallas_check trace shape
#: plus one model-scale shape (what the perf push will tune against)
KERNEL_CASES: list[KernelCase] = [
    KernelCase(_FLASH_PATH, "repro.kernels.flash_attention.kernel",
               "b1_h4_kv2_s256_d128",
               _flash(1, 4, 2, 256, 128, causal=True, window=64,
                      softcap=30.0)),
    KernelCase(_FLASH_PATH, "repro.kernels.flash_attention.kernel",
               "b1_h8_kv8_s2048_d128",
               _flash(1, 8, 8, 2048, 128, causal=True)),
    KernelCase(_RMS_PATH, "repro.kernels.rmsnorm.kernel",
               "r256_d512", _rms(256, 512)),
    KernelCase(_RMS_PATH, "repro.kernels.rmsnorm.kernel",
               "r4096_d4096", _rms(4096, 4096)),
    KernelCase(_SSD_PATH, "repro.kernels.ssd.kernel",
               "b1_l256_h4_p64_g2_n32", _ssd(1, 256, 4, 64, 2, 32)),
    KernelCase(_SSD_PATH, "repro.kernels.ssd.kernel",
               "b2_l2048_h8_p64_g2_n64", _ssd(2, 2048, 8, 64, 2, 64)),
]


def _streaming_for(module: str) -> dict:
    import importlib
    mod = importlib.import_module(module)
    return getattr(mod, "STREAMING_OPERANDS", {})


def analyze_shipped() -> tuple[list[dict], list[Finding]]:
    costs: list[dict] = []
    findings: list[Finding] = []
    for case in KERNEL_CASES:
        c, f = analyze_traced(case.trace, case.path,
                              streaming=_streaming_for(case.module),
                              label=case.label)
        costs.extend(c)
        findings.extend(f)
    return costs, findings


def crosscheck_cost_model(costs: list[dict],
                          slack: float = COST_MODEL_SLACK) -> dict:
    """Cross-check ``CostModel``'s analytic fusion-level intensity against
    the statically-derived per-kernel envelope.

    The analytic cells assume ``ANALYTIC_FLOPS_PER_BYTE`` flops of useful
    work per HBM byte for a whole fused step; a whole step is a mix of the
    kernels analyzed here, so that constant must lie *inside* the envelope
    [min kernel intensity / slack, max kernel intensity * slack] — if a
    kernel edit collapses the envelope below it (or the constant drifts
    outside), the analytic replay cells no longer describe the kernels
    this repo actually ships.
    """
    from repro.launch.cost_model import ANALYTIC_FLOPS_PER_BYTE
    intensities = {f"{c['kernel']}@{c['shape']}": c["arithmetic_intensity"]
                   for c in costs}
    if not intensities:
        return {"ok": False, "reason": "no cost rows"}
    lo = min(intensities.values()) / slack
    hi = max(intensities.values()) * slack
    ok = lo <= ANALYTIC_FLOPS_PER_BYTE <= hi
    return {
        "ok": ok,
        "analytic_flops_per_byte": ANALYTIC_FLOPS_PER_BYTE,
        "envelope": [lo, hi],
        "slack": slack,
        "kernel_intensities": intensities,
    }


def verdict() -> dict:
    """One-line stamp for bench artifacts (mirrors ``lint.verdict``):
    clean iff zero findings *and* the cost-model cross-check holds."""
    costs, findings = analyze_shipped()
    check = crosscheck_cost_model(costs)
    return {
        "tool": "replint.pallas_cost",
        "clean": not findings and check["ok"],
        "n_findings": len(findings),
        "cost_model_ok": check["ok"],
        "n_cost_rows": len(costs),
    }


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.quality.pallas_cost",
        description=__doc__.splitlines()[0])
    ap.add_argument("--report", default=None,
                    help="write the JSON report here (e.g. "
                         "artifacts/lint/pallas_cost.json)")
    args = ap.parse_args(argv)
    costs, findings = analyze_shipped()
    check = crosscheck_cost_model(costs)
    for f in findings:
        print(f"{f.path}: {f.code} {f.message}")
    if not check["ok"]:
        print(f"pallas_cost: cost-model cross-check FAILED: "
              f"analytic {check.get('analytic_flops_per_byte')} outside "
              f"envelope {check.get('envelope')}")
    if args.report:
        report = {
            "tool": "replint.pallas_cost",
            "vmem_budget_bytes": VMEM_BUDGET_BYTES,
            "n_findings": len(findings),
            "clean": not findings and check["ok"],
            "cost_model_check": check,
            "cost_table": costs,
            "findings": [{"code": f.code, "path": f.path,
                          "message": f.message} for f in findings],
        }
        os.makedirs(os.path.dirname(args.report) or ".", exist_ok=True)
        with open(args.report, "w", encoding="utf-8") as fh:
            json.dump(report, fh, indent=1)
            fh.write("\n")
    for c in costs:
        print(f"  {c['kernel'].split('/')[-2]:>16s} {c['shape']:<24s} "
              f"{c['flops']:.3e} flops  {c['hbm_bytes']:.3e} B  "
              f"AI {c['arithmetic_intensity']:8.2f}  {c['bound']}-bound "
              f"({c['roofline_frac']:.0%} roofline)")
    print(f"pallas_cost: {len(costs)} (kernel, shape) rows, "
          f"{len(findings)} findings, cost-model check "
          f"{'ok' if check['ok'] else 'FAILED'}")
    return 0 if not findings and check["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
