"""internvl2-2b [vlm]: InternLM2-1.8B backbone — 24L, d_model 2048, 16H
GQA(kv8), d_ff 8192, vocab 92553. The InternViT vision frontend is a STUB:
``input_specs()`` supplies 256 precomputed patch embeddings (448px / 14px
patches, 4x pixel-shuffle) prepended to the text sequence. Full attention ->
long_500k skipped. [arXiv:2404.16821; hf]
"""
from repro.config import AttentionConfig, ModelConfig, register_arch


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke", family="vlm", num_layers=2, d_model=128,
        d_ff=384, vocab_size=512, max_seq_len=256, frontend="patch_stub",
        num_patches=8,
        attention=AttentionConfig(num_heads=8, num_kv_heads=4, head_dim=16),
        vocab_pad_multiple=64)


@register_arch("internvl2-2b", smoke=smoke)
def build() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b", family="vlm", num_layers=24, d_model=2048,
        d_ff=8192, vocab_size=92553, max_seq_len=32768,
        frontend="patch_stub", num_patches=256,
        attention=AttentionConfig(num_heads=16, num_kv_heads=8,
                                  head_dim=128))
