"""internlm-123b — the paper's 123B pretraining workload (Fig. 10/11/12/14).
The exact config is unpublished; this reconstruction (96L, d_model 10240,
80H, GLU d_ff 27648, vocab 103168) lands on 123B parameters with the
llama-style layout the paper states its models follow. The profiling
benchmarks (3D parallelism vs hierarchical ZeRO) target this config.
[paper §4.1]
"""
from repro.config import AttentionConfig, ModelConfig, register_arch


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internlm123-smoke", family="dense", num_layers=2, d_model=128,
        d_ff=384, vocab_size=512, max_seq_len=256,
        attention=AttentionConfig(num_heads=8, num_kv_heads=8, head_dim=16),
        vocab_pad_multiple=64)


@register_arch("internlm-123b", smoke=smoke)
def build() -> ModelConfig:
    return ModelConfig(
        name="internlm-123b", family="dense", num_layers=96, d_model=10240,
        d_ff=27648, vocab_size=103168, max_seq_len=32768,
        attention=AttentionConfig(num_heads=80, num_kv_heads=80,
                                  head_dim=128))
