"""deepseek-v2-lite-16b [moe]: 27L, d_model 2048, 16H MLA (kv_lora 512,
qk_rope 64, qk_nope 128, v_head 128), vocab 102400; first layer dense
(d_ff 10944), the rest MoE with 64 routed experts (expert_ff 1408, top-6)
plus 2 shared experts.

Pool-spec note: the pool line says both "64e top-6" and "2 shared+160
routed"; 160 routed is DeepSeek-V2-*236B*. We follow the published V2-Lite
config (64 routed) and record the discrepancy in DESIGN.md. MLA's decode
cache is the 512-d latent + rope key — full attention over it -> long_500k
skipped. [arXiv:2405.04434; hf]
"""
from repro.config import (AttentionConfig, ModelConfig, MoEConfig,
                          register_arch)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-smoke", family="moe", num_layers=3, d_model=128,
        d_ff=0, vocab_size=512, max_seq_len=256,
        attention=AttentionConfig(kind="mla", num_heads=4, num_kv_heads=4,
                                  kv_lora_rank=32, qk_nope_dim=16,
                                  qk_rope_dim=8, v_head_dim=16),
        moe=MoEConfig(num_experts=4, num_shared_experts=1, top_k=2,
                      expert_ff=64, first_k_dense=1, first_dense_ff=256),
        vocab_pad_multiple=64)


@register_arch("deepseek-v2-lite-16b", smoke=smoke)
def build() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe", num_layers=27,
        d_model=2048, d_ff=0, vocab_size=102400, max_seq_len=32768,
        attention=AttentionConfig(kind="mla", num_heads=16, num_kv_heads=16,
                                  kv_lora_rank=512, qk_nope_dim=128,
                                  qk_rope_dim=64, v_head_dim=128),
        moe=MoEConfig(num_experts=64, num_shared_experts=2, top_k=6,
                      expert_ff=1408, first_k_dense=1,
                      first_dense_ff=10944))
