"""whisper-large-v3 [audio]: encoder-decoder, 32L each side, d_model 1280,
20H MHA (kv20), d_ff 5120, vocab 51866. The conv frontend is a STUB:
``input_specs()`` supplies the 1500 precomputed frame embeddings; the
decoder uses learned positions + cross-attention into the encoder output.
Full-attention decoder (and a native target length far below 500k) ->
long_500k skipped. [arXiv:2212.04356; unverified]
"""
from repro.config import AttentionConfig, ModelConfig, register_arch


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="audio", num_layers=2, d_model=96,
        d_ff=256, vocab_size=512, max_seq_len=128, encoder_layers=2,
        encoder_seq=24, frontend="audio_stub", mlp_act="gelu",
        attention=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=24,
                                  use_rope=False),
        vocab_pad_multiple=64)


@register_arch("whisper-large-v3", smoke=smoke)
def build() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3", family="audio", num_layers=32,
        d_model=1280, d_ff=5120, vocab_size=51866, max_seq_len=32768,
        encoder_layers=32, encoder_seq=1500, frontend="audio_stub",
        mlp_act="gelu",
        attention=AttentionConfig(num_heads=20, num_kv_heads=20,
                                  head_dim=64, use_rope=False))
