"""internlm-7b — the paper's own 7B model family (§2.2: "LLMs ranging from
7B to over 123B ... transformer-based decoder-only architecture, similar to
GPT and LLaMA"). Used by the checkpoint/evaluation benchmarks as the
7B-scale reference. [hf:internlm/internlm-7b; hf]
"""
from repro.config import AttentionConfig, ModelConfig, register_arch


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internlm7-smoke", family="dense", num_layers=2, d_model=128,
        d_ff=384, vocab_size=512, max_seq_len=256,
        attention=AttentionConfig(num_heads=8, num_kv_heads=8, head_dim=16),
        vocab_pad_multiple=64)


@register_arch("internlm-7b", smoke=smoke)
def build() -> ModelConfig:
    return ModelConfig(
        name="internlm-7b", family="dense", num_layers=32, d_model=4096,
        d_ff=11008, vocab_size=103168, max_seq_len=32768,
        attention=AttentionConfig(num_heads=32, num_kv_heads=32,
                                  head_dim=128))
