"""jamba-1.5-large-398b [hybrid]: 72L, d_model 8192, 64H GQA(kv8),
d_ff 24576, vocab 65536; Mamba+attention 1:7 interleave (one attention
layer per 8-layer block) with MoE (16 experts, top-2) on every other layer.

TPU adaptation note (DESIGN.md): Jamba ships Mamba-1 selective-scan blocks;
we substitute the Mamba-2 SSD block (state 128, head 64) — the same
recurrence family with an MXU-friendly chunked form. The SSM-dominant stack
keeps decode state O(1) per layer -> long_500k RUNS.
[arXiv:2403.19887; hf]
"""
from repro.config import (AttentionConfig, ModelConfig, MoEConfig,
                          SSMConfig, register_arch)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="jamba-smoke", family="hybrid", num_layers=8, d_model=128,
        d_ff=256, vocab_size=512, max_seq_len=256,
        attn_every=8, attn_index=4,
        attention=AttentionConfig(num_heads=8, num_kv_heads=2, head_dim=16),
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, n_groups=1,
                      chunk_size=32),
        moe=MoEConfig(num_experts=4, top_k=2, expert_ff=256, moe_every=2,
                      moe_offset=1),
        vocab_pad_multiple=64)


@register_arch("jamba-1.5-large-398b", smoke=smoke)
def build() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b", family="hybrid", num_layers=72,
        d_model=8192, d_ff=24576, vocab_size=65536, max_seq_len=524288,
        attn_every=8, attn_index=4,
        attention=AttentionConfig(num_heads=64, num_kv_heads=8,
                                  head_dim=128),
        ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, n_groups=8,
                      chunk_size=256),
        moe=MoEConfig(num_experts=16, top_k=2, expert_ff=24576,
                      moe_every=2, moe_offset=1))
