"""mamba2-1.3b [ssm]: 48L, d_model 2048, attention-free, vocab 50280,
ssm_state 128 — SSD (state-space duality). d_inner = 2*d_model = 4096,
head_dim 64 (64 SSM heads), n_groups 1, conv width 4, chunk 256. Decode
carries an O(1) (B, H, P, N) state -> long_500k RUNS.
[arXiv:2405.21060; unverified]
"""
from repro.config import ModelConfig, SSMConfig, register_arch


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm", num_layers=2, d_model=96,
        d_ff=0, vocab_size=512, max_seq_len=256,
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, n_groups=1,
                      conv_width=4, chunk_size=32),
        vocab_pad_multiple=64, tie_embeddings=True)


@register_arch("mamba2-1.3b", smoke=smoke)
def build() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b", family="ssm", num_layers=48, d_model=2048,
        d_ff=0, vocab_size=50280, max_seq_len=524288,
        ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, n_groups=1,
                      conv_width=4, chunk_size=256),
        tie_embeddings=True)
