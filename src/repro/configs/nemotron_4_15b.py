"""nemotron-4-15b [dense]: 32L, d_model 6144, 48H GQA(kv8), d_ff 24576,
vocab 256000 — squared-ReLU MLP (no GLU), full attention -> long_500k
skipped. [arXiv:2402.16819; unverified]
"""
from repro.config import AttentionConfig, ModelConfig, register_arch


def smoke() -> ModelConfig:
    return ModelConfig(
        name="nemotron-smoke", family="dense", num_layers=2, d_model=96,
        d_ff=384, vocab_size=512, max_seq_len=256,
        attention=AttentionConfig(num_heads=6, num_kv_heads=2, head_dim=16),
        mlp_act="relu2", vocab_pad_multiple=64)


@register_arch("nemotron-4-15b", smoke=smoke)
def build() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b", family="dense", num_layers=32, d_model=6144,
        d_ff=24576, vocab_size=256000, max_seq_len=32768,
        attention=AttentionConfig(num_heads=48, num_kv_heads=8,
                                  head_dim=128),
        mlp_act="relu2")
