"""Architecture registry: one module per assigned arch (+ the paper's own).

Importing this package registers every ``--arch <id>`` name. Each module
defines ``build()`` (the exact published config) and ``smoke()`` (a reduced
same-family config that runs a forward/train step on CPU).
"""
from repro.configs import (deepseek_v2_lite_16b, gemma3_27b, h2o_danube_1_8b,
                           internlm_123b, internlm_7b, internvl2_2b,
                           jamba_1_5_large_398b, mamba2_1_3b, mixtral_8x22b,
                           nemotron_4_15b, smollm_360m, whisper_large_v3)

# the ten assigned architectures (pool ids)
ASSIGNED = (
    "gemma3-27b", "smollm-360m", "h2o-danube-1.8b", "nemotron-4-15b",
    "internvl2-2b", "mamba2-1.3b", "whisper-large-v3", "mixtral-8x22b",
    "deepseek-v2-lite-16b", "jamba-1.5-large-398b",
)
# the paper's own model family (InternLM — §2.2, Fig. 10/14)
PAPER = ("internlm-7b", "internlm-123b")
