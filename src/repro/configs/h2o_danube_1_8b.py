"""h2o-danube-1.8b [dense]: 24L, d_model 2560, 32H GQA(kv8), d_ff 6912,
vocab 32000 — llama+mistral mix with sliding-window attention (window 4096).
SWA bounds the decode cache -> long_500k RUNS. [arXiv:2401.16818; hf]
"""
from repro.config import AttentionConfig, ModelConfig, register_arch


def smoke() -> ModelConfig:
    return ModelConfig(
        name="danube-smoke", family="dense", num_layers=2, d_model=128,
        d_ff=384, vocab_size=512, max_seq_len=256,
        attention=AttentionConfig(num_heads=8, num_kv_heads=2, head_dim=16,
                                  sliding_window=64),
        vocab_pad_multiple=64)


@register_arch("h2o-danube-1.8b", smoke=smoke)
def build() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b", family="dense", num_layers=24, d_model=2560,
        d_ff=6912, vocab_size=32000, max_seq_len=524288,
        attention=AttentionConfig(num_heads=32, num_kv_heads=8, head_dim=80,
                                  sliding_window=4096))
