"""gemma3-27b [dense]: 62L, d_model 5376, 32H GQA(kv16), d_ff 21504,
vocab 262144; 5 local(1024-window) : 1 global attention interleave; 128k
context (extended to 512k for the long_500k cell via RoPE scaling — the
SWA-dominant layout keeps decode state bounded: only every 6th layer holds a
full-length cache). [hf:google/gemma-3-*-pt; unverified]
"""
from repro.config import AttentionConfig, ModelConfig, register_arch


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma3-smoke", family="dense", num_layers=6, d_model=96,
        d_ff=256, vocab_size=512, max_seq_len=256,
        attention=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=24,
                                  global_every=6, local_window=32),
        mlp_act="gelu_glu", vocab_pad_multiple=64)


@register_arch("gemma3-27b", smoke=smoke)
def build() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b", family="dense", num_layers=62, d_model=5376,
        d_ff=21504, vocab_size=262144, max_seq_len=524288,
        attention=AttentionConfig(num_heads=32, num_kv_heads=16,
                                  head_dim=128, rope_theta=1_000_000.0,
                                  global_every=6, local_window=1024),
        mlp_act="gelu_glu", tie_embeddings=True)
