"""mixtral-8x22b [moe]: 56L, d_model 6144, 48H GQA(kv8), vocab 32768,
MoE 8 experts top-2 with expert_ff 16384 on every layer; sliding-window
attention (4096) -> long_500k RUNS. [arXiv:2401.04088; hf]
"""
from repro.config import (AttentionConfig, ModelConfig, MoEConfig,
                          register_arch)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mixtral-smoke", family="moe", num_layers=2, d_model=128,
        d_ff=0, vocab_size=512, max_seq_len=256,
        attention=AttentionConfig(num_heads=8, num_kv_heads=2, head_dim=16,
                                  sliding_window=64),
        moe=MoEConfig(num_experts=4, top_k=2, expert_ff=256),
        vocab_pad_multiple=64)


@register_arch("mixtral-8x22b", smoke=smoke)
def build() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b", family="moe", num_layers=56, d_model=6144,
        d_ff=0, vocab_size=32768, max_seq_len=524288,
        attention=AttentionConfig(num_heads=48, num_kv_heads=8,
                                  head_dim=128, sliding_window=4096),
        moe=MoEConfig(num_experts=8, top_k=2, expert_ff=16384))
