"""smollm-360m [dense]: 32L, d_model 960, 15H GQA(kv5), d_ff 2560,
vocab 49152 — llama-architecture small model. Pure full attention ->
long_500k cell is skipped (see DESIGN.md §Arch-applicability).
[hf:HuggingFaceTB/SmolLM-360M; hf]
"""
from repro.config import AttentionConfig, ModelConfig, register_arch


def smoke() -> ModelConfig:
    return ModelConfig(
        name="smollm-smoke", family="dense", num_layers=2, d_model=120,
        d_ff=320, vocab_size=512, max_seq_len=256,
        attention=AttentionConfig(num_heads=6, num_kv_heads=2, head_dim=20),
        vocab_pad_multiple=64, tie_embeddings=True)


@register_arch("smollm-360m", smoke=smoke)
def build() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m", family="dense", num_layers=32, d_model=960,
        d_ff=2560, vocab_size=49152, max_seq_len=32768,
        attention=AttentionConfig(num_heads=15, num_kv_heads=5, head_dim=64),
        tie_embeddings=True)
