"""Serving: jit'd prefill / decode with cache shardings, batched generation."""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ParallelConfig
from repro.models import Model
from repro.sharding import Rules, make_rules

# logical axes for each KV-cache leaf, keyed by its dict name
_CACHE_AXES = {
    "k": ("batch", "kv_seq", "kv_heads", None),
    "v": ("batch", "kv_seq", "kv_heads", None),
    "pos": ("batch", "kv_seq"),
    "ckv": ("batch", "kv_seq", None),
    "k_rope": ("batch", "kv_seq", None),
    "ssm": ("batch", "ssm_heads", None, None),
    "conv_x": ("batch", None, "ssm_inner"),
    "conv_B": ("batch", None, None),
    "conv_C": ("batch", None, None),
    "cross_k": ("batch", None, "kv_heads", None),
    "cross_v": ("batch", None, "kv_heads", None),
}


def cache_shardings(caches: Any, rules: Rules) -> Any:
    """NamedShardings for a cache tree (leaves found by dict key name)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(caches)
    out = []
    for path, leaf in flat:
        name = None
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = str(entry.key)
                break
        axes = _CACHE_AXES.get(name)
        if axes is None:
            axes = ("batch",) + (None,) * (len(leaf.shape) - 1)
        # caches are stacked over scan repeats -> leading "stacked" dim
        if len(leaf.shape) == len(axes) + 1:
            axes = ("stacked",) + axes
        out.append(rules.sharding(leaf.shape, tuple(axes)))
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_caches(model: Model, batch: int, prompt_len: int,
                    rules: Optional[Rules] = None) -> Any:
    """ShapeDtypeStruct cache tree (with shardings when rules given)."""
    shapes = jax.eval_shape(lambda: model.init_caches(batch, prompt_len))
    if rules is None:
        return shapes
    sh = cache_shardings(shapes, rules)
    return jax.tree_util.tree_map(
        lambda s, h: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=h),
        shapes, sh)


def make_serve_step(model: Model):
    """decode_step(params, caches, tokens, cur_index) -> (logits, caches)."""
    def step(params, caches, tokens, cur_index):
        return model.decode_step(params, caches, tokens, cur_index)
    return step


def make_prefill(model: Model, max_cache_len: int = 0):
    def prefill(params, batch):
        return model.prefill(params, batch, max_cache_len=max_cache_len)
    return prefill


def greedy_generate(model: Model, params, prompt: jax.Array,
                    n_tokens: int) -> jax.Array:
    """Batched greedy decode (CPU-scale; used by examples/eval runner)."""
    B, S = prompt.shape
    logits, caches = model.prefill(params, {"tokens": prompt})
    step_fn = jax.jit(make_serve_step(model))
    out = []
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out.append(tok)
    for t in range(S, S + n_tokens - 1):
        logits, caches = step_fn(params, caches, tok, jnp.int32(t))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    return jnp.stack(out, axis=1)


def abstract_serve_inputs(model: Model, batch: int, kv_len: int,
                          mesh: Mesh, parallel: ParallelConfig):
    """(params, caches, tokens, cur_index) ShapeDtypeStructs for dry-runs."""
    from repro.sharding import tree_shardings
    from repro.models.spec import abstract_params

    rules = make_rules(mesh, parallel)
    p_sh = tree_shardings(rules, model.specs())
    params = abstract_params(model.specs(), p_sh)
    caches = abstract_caches(model, batch, kv_len, rules)
    tokens = jax.ShapeDtypeStruct(
        (batch,), jnp.int32,
        sharding=rules.sharding((batch,), ("batch",)))
    cur = jax.ShapeDtypeStruct((), jnp.int32,
                               sharding=NamedSharding(mesh, P()))
    return params, caches, tokens, cur


def compile_serve_step(model: Model, mesh: Mesh, parallel: ParallelConfig, *,
                       batch: int, kv_len: int, donate: bool = True):
    """Lower one decode step against a kv_len cache. Returns Lowered."""
    args = abstract_serve_inputs(model, batch, kv_len, mesh, parallel)
    step = jax.jit(make_serve_step(model),
                   donate_argnums=(1,) if donate else ())
    with mesh:
        return step.lower(*args)


def compile_prefill(model: Model, mesh: Mesh, parallel: ParallelConfig, *,
                    batch: int, seq_len: int):
    """Lower the prefill pass (prompt -> last logits + caches)."""
    from repro.models.spec import abstract_params
    from repro.sharding import tree_shardings
    from repro.train.train_step import abstract_batch, batch_shardings

    rules = make_rules(mesh, parallel)
    p_sh = tree_shardings(rules, model.specs())
    params = abstract_params(model.specs(), p_sh)
    ab = abstract_batch(model, batch, seq_len)
    ab.pop("labels"), ab.pop("weights")
    b_sh = batch_shardings(mesh, parallel, ab)
    ab = jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        ab, b_sh)
    # the prefill cell's cache horizon is the prompt itself (decode cells
    # cover the long-cache programs separately)
    fn = jax.jit(make_prefill(model, max_cache_len=seq_len))
    with mesh:
        return fn.lower(params, ab)
