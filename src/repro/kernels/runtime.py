"""Kernel dispatch switch.

``ParallelConfig.use_pallas`` enables the Pallas fast path; on this CPU
container the kernels run in interpret mode (bit-accurate body execution),
on TPU they compile to Mosaic. The pure-jnp implementations remain the
default (and the oracles).
"""
from __future__ import annotations

import contextlib
import dataclasses


@dataclasses.dataclass
class _State:
    use_pallas: bool = False
    interpret: bool = True      # CPU container: interpret; TPU: False


STATE = _State()


def configure(use_pallas: bool, interpret: bool = True) -> None:
    STATE.use_pallas = use_pallas
    STATE.interpret = interpret


@contextlib.contextmanager
def pallas_enabled(interpret: bool = True):
    prev = (STATE.use_pallas, STATE.interpret)
    STATE.use_pallas, STATE.interpret = True, interpret
    try:
        yield
    finally:
        STATE.use_pallas, STATE.interpret = prev
