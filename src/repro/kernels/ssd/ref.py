"""Oracle for the SSD kernel: the pure-jnp chunked scan used by the model."""
from __future__ import annotations

import jax

from repro.models.mamba import ssd_chunked


def ssd_ref(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
            Cm: jax.Array, *, chunk: int = 128):
    """Returns (y, final_state), matching ssd_pallas."""
    return ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk,
                       return_final_state=True)
