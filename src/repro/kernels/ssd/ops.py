"""jit'd SSD wrapper: pads L to a chunk multiple (dt=0 rows are no-ops)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ssd.kernel import ssd_pallas
from repro.utils import round_up


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
        Cm: jax.Array, *, chunk: int = 128,
        interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    B, L, H, P = x.shape
    cl = min(chunk, round_up(L, 8))
    L_p = round_up(L, cl)
    if L_p != L:
        pad = L_p - L
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))     # dt=0 -> identity
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y, state = ssd_pallas(x, dt, A, Bm, Cm, chunk=cl, interpret=interpret)
    return y[:, :L], state
