"""Pallas TPU kernel for the Mamba2 SSD chunked scan (arXiv:2405.21060).

TPU adaptation of the SSD algorithm: per (batch, head) the sequence is cut
into chunks; each chunk does an intra-chunk quadratic "attention-like" pass
(two MXU matmuls over (chunk x chunk) tiles) plus an inter-chunk rank-1
state recurrence. The chunk axis is the innermost grid dimension with
sequential ("arbitrary") semantics so the (P, N) state lives in VMEM scratch
across chunk visits — the TPU analogue of the CUDA kernel's persistent
shared-memory accumulator.

Inputs follow the oracle's layout (repro.models.mamba.ssd_chunked):
  x  (B, L, H, P)    dt (B, L, H)  [already softplus'd]
  A  (H,) negative   Bm/Cm (B, L, G, N), heads grouped H % G == 0
Grid: (B, H, L // chunk).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: RPL202 streaming allowance (see flash_attention.kernel): operand
#: positions deliberately re-fetched across grid axes their index_map
#: ignores.
STREAMING_OPERANDS = {
    2: "A is a per-head scalar re-read per batch (4-byte block)",
    3: "B blocks re-streamed for each of the H//G heads sharing a group",
    4: "C streamed with B (same head-group sharing)",
}


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, s_scr, *,
            num_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)          # (cl, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)           # (cl,)
    A = a_ref[0].astype(jnp.float32)                   # scalar
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)         # (cl, N)
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)         # (cl, N)

    dA = dt * A                                        # (cl,), <= 0
    cum = jnp.cumsum(dA)                               # (cl,)

    # intra-chunk: y_i += sum_{j<=i} exp(cum_i - cum_j) dt_j (C_i.B_j) x_j
    cl = x.shape[0]
    seg = cum[:, None] - cum[None, :]                  # (i, j)
    tri = jnp.tril(jnp.ones((cl, cl), jnp.bool_))
    # mask inside exp: keeps the (interpret-mode) backward pass NaN-free
    decay = jnp.exp(jnp.where(tri, seg, -jnp.inf))
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    W = scores * decay * dt[None, :]                   # (i, j)
    y = jax.lax.dot(W, x, preferred_element_type=jnp.float32)

    # inter-chunk: y_i += exp(cum_i) C_i . S_prev
    S_prev = s_scr[...]                                # (P, N) fp32
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, S_prev, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state update: S = exp(cum_end) S_prev + sum_j e^{cum_end-cum_j} dt_j x_j B_j^T
    w_state = jnp.exp(cum[-1] - cum) * dt              # (cl,)
    S_new = jnp.exp(cum[-1]) * S_prev + jax.lax.dot_general(
        x * w_state[:, None], Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    s_scr[...] = S_new

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ic == num_chunks - 1)
    def _final():
        state_ref[0, 0] = S_new.astype(state_ref.dtype)


def ssd_pallas(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
               Cm: jax.Array, *, chunk: int = 128,
               interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B, L, H, P), final_state (B, H, P, N)). L % chunk == 0."""
    B, L, H, P = x.shape
    G, N = Bm.shape[-2:]
    assert L % chunk == 0 and H % G == 0
    rep = H // G
    nc = L // chunk
    grid = (B, H, nc)

    x_spec = pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0))
    dt_spec = pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h))
    a_spec = pl.BlockSpec((1,), lambda b, h, c: (h,))
    bc_spec = pl.BlockSpec((1, chunk, 1, N),
                           lambda b, h, c: (b, c, h // rep, 0))
    y_spec = pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0))
    st_spec = pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0))

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    y, state = pl.pallas_call(
        functools.partial(_kernel, num_chunks=nc),
        grid=grid,
        in_specs=[x_spec, dt_spec, a_spec, bc_spec, bc_spec],
        out_specs=[y_spec, st_spec],
        out_shape=[jax.ShapeDtypeStruct((B, L, H, P), x.dtype),
                   jax.ShapeDtypeStruct((B, H, P, N), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(x, dt, A, Bm, Cm)
    return y, state
