from repro.kernels.ssd.ops import ssd
from repro.kernels.ssd.ref import ssd_ref

__all__ = ["ssd", "ssd_ref"]
