"""jit'd RMSNorm wrapper: flattens leading dims, pads rows to the block."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm.kernel import rmsnorm_pallas
from repro.utils import round_up


@partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-5,
            block_rows: int = 128, interpret: bool = True) -> jax.Array:
    shape = x.shape
    d = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, round_up(rows, 8))
    rows_p = round_up(rows, br)
    if rows_p != rows:
        x2 = jnp.pad(x2, ((0, rows_p - rows), (0, 0)))
    out = rmsnorm_pallas(x2, scale, eps=eps, block_rows=br,
                         interpret=interpret)
    return out[:rows].reshape(shape)
