"""Pallas TPU RMSNorm kernel.

Memory-bound op: each row is read once, normalized in fp32, scaled, written
once. Tiled as (block_rows, d) VMEM blocks — d stays whole (the reduction
axis must be resident), rows are the grid. For d_model up to 8192 a
128-row fp32 block is 4 MiB, comfortably inside VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: RPL202 streaming allowance (see flash_attention.kernel): empty — every
#: operand here is fetched exactly once (scale's index_map is constant, so
#: its block stays resident across the whole row walk).
STREAMING_OPERANDS: dict[int, str] = {}


def _kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                   # (rows, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_pallas(x: jax.Array, scale: jax.Array, *, eps: float = 1e-5,
                   block_rows: int = 128,
                   interpret: bool = True) -> jax.Array:
    """x: (rows, d) with rows % block_rows == 0; scale: (d,)."""
    rows, d = x.shape
    assert rows % block_rows == 0
    grid = (rows // block_rows,)
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x, scale)
