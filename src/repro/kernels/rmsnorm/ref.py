"""Pure-jnp RMSNorm oracle (same math as repro.models.layers.rmsnorm)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_ref(x: jax.Array, scale: jax.Array, *,
                eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)
