"""Pallas TPU flash-attention kernel (online softmax, VMEM-tiled).

The paper's framework (InternEvo) leans on FlashAttention for its training
throughput; this is the TPU-native adaptation: instead of a CUDA warp-level
kernel we tile for VMEM with MXU-aligned (128-multiple) block shapes and let
the innermost grid dimension walk KV blocks sequentially ("arbitrary"
semantics), carrying the online-softmax state (m, l, acc) in VMEM scratch
across block visits.

Supports GQA (query-head folding), causal masking, sliding windows (and
thereby gemma3's local:global interleave — window is static per layer) and
tanh soft-capping. Grid: (batch, q_heads, q_blocks, kv_blocks).

Position-based masking: both q and kv carry absolute positions; slots with
position < 0 are padding. This makes full/SWA/ring-buffer caches uniform.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30

#: Declared streaming allowance for the static analyzer (RPL202,
#: ``repro.quality.pallas_cost``): operand positions (in ``pallas_call``
#: argument order) that are *deliberately* re-fetched across grid axes
#: their index_map ignores, with the reason. Everything not listed here
#: must have revisit factor 1 — a new revisit pattern is a perf bug until
#: declared.
STREAMING_OPERANDS = {
    0: "q_positions re-read per q-head (tiny (1, block_q) i32 block)",
    1: "kv_positions re-streamed per (head, q-block) with the KV walk",
    3: "K streamed over every (q-head, q-block): the FlashAttention "
       "trade — O(S^2) HBM reads bought back by never materializing S^2 "
       "scores",
    4: "V streamed with K (same inner KV walk)",
}


def _kernel(q_pos_ref, kv_pos_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale: float, causal: bool,
            window: int, softcap: float, num_kv_blocks: int):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)            # (bk, D)
    q_pos = q_pos_ref[0]                           # (bq,)
    kv_pos = kv_pos_ref[0]                         # (bk,)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap

    ok = kv_pos[None, :] >= 0
    if causal:
        ok &= kv_pos[None, :] <= q_pos[:, None]
    if window > 0:
        ok &= (q_pos[:, None] - kv_pos[None, :]) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[:, :1]                          # (bq, 1)
    l_prev = l_scr[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(ok, jnp.exp(s - m_new), 0.0)   # fully-masked rows -> 0
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ik == num_kv_blocks - 1)
    def _finish():
        l = l_scr[:, :1]
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l, 1e-20)).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           q_positions: jax.Array, kv_positions: jax.Array,
                           *, causal: bool = True, window: int = 0,
                           softcap: float = 0.0, block_q: int = 128,
                           block_kv: int = 128,
                           interpret: bool = True) -> jax.Array:
    """q: (B, H, Sq, D); k, v: (B, KV, Skv, D); positions: (B, S*).

    Sq/Skv must be multiples of block_q/block_kv (ops.py pads). H % KV == 0.
    """
    B, H, Sq, D = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    assert H % KV == 0 and Sq % block_q == 0 and Skv % block_kv == 0
    G = H // KV
    nq, nk = Sq // block_q, Skv // block_kv
    grid = (B, H, nq, nk)

    q_spec = pl.BlockSpec((1, 1, block_q, D),
                          lambda b, h, iq, ik: (b, h, iq, 0))
    k_spec = pl.BlockSpec((1, 1, block_kv, D),
                          lambda b, h, iq, ik: (b, h // G, ik, 0))
    qp_spec = pl.BlockSpec((1, block_q), lambda b, h, iq, ik: (b, iq))
    kp_spec = pl.BlockSpec((1, block_kv), lambda b, h, iq, ik: (b, ik))
    o_spec = pl.BlockSpec((1, 1, block_q, D),
                          lambda b, h, iq, ik: (b, h, iq, 0))

    kernel = functools.partial(
        _kernel, scale=D ** -0.5, causal=causal, window=window,
        softcap=softcap, num_kv_blocks=nk)

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[qp_spec, kp_spec, q_spec, k_spec, k_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),   # m (lane-replicated)
            pltpu.VMEM((block_q, 128), jnp.float32),   # l
            pltpu.VMEM((block_q, D), jnp.float32),     # acc
        ],
        interpret=interpret,
        **kwargs,
    )(q_positions, kv_positions, q, k, v)
