"""jit'd public wrapper: padding, layout handling, interpret/TPU dispatch.

Model code uses (B, S, H, D) layout; the kernel wants (B, H, S, D) with
block-multiple sequence lengths. Padding KV slots carry position -1 (masked
by construction); padded query rows are sliced off on return.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.utils import round_up


@partial(jax.jit, static_argnames=("causal", "window", "softcap", "block_q",
                                   "block_kv", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    q_positions: jax.Array, kv_positions: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 128,
                    block_kv: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Skv, KV, D); positions (B, S*) or (S*,)."""
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    q_pos = jnp.broadcast_to(jnp.asarray(q_positions), (B, Sq)).astype(jnp.int32)
    kv_pos = jnp.broadcast_to(jnp.asarray(kv_positions), (B, Skv)).astype(jnp.int32)

    bq = min(block_q, round_up(Sq, 8))
    bk = min(block_kv, round_up(Skv, 8))
    Sq_p, Skv_p = round_up(Sq, bq), round_up(Skv, bk)
    qt = jnp.swapaxes(q, 1, 2)                       # (B, H, Sq, D)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    if Sq_p != Sq:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, Sq_p - Sq), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, Sq_p - Sq)),
                        constant_values=0)
    if Skv_p != Skv:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, Skv_p - Skv), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, Skv_p - Skv), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, Skv_p - Skv)),
                         constant_values=-1)
    out = flash_attention_pallas(qt, kt, vt, q_pos, kv_pos, causal=causal,
                                 window=window, softcap=softcap,
                                 block_q=bq, block_kv=bk,
                                 interpret=interpret)
    return jnp.swapaxes(out[:, :, :Sq], 1, 2)
