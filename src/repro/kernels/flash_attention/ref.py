"""Pure-jnp oracle for the flash-attention Pallas kernel.

Materializes the full (Sq, Skv) score matrix in fp32 — O(S^2) memory, only
for validation at test shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        q_positions: jax.Array, kv_positions: jax.Array,
                        *, causal: bool = True, window: int = 0,
                        softcap: float = 0.0) -> jax.Array:
    """Same layout as the kernel: q (B, H, Sq, D); k, v (B, KV, Skv, D)."""
    B, H, Sq, D = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, Sq, D).astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, k.astype(jnp.float32))
    s = s * (D ** -0.5)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    q_pos = q_positions[:, None, None, :, None]
    kv_pos = kv_positions[:, None, None, None, :]
    ok = kv_pos >= 0
    if causal:
        ok &= kv_pos <= q_pos
    if window > 0:
        ok &= (q_pos - kv_pos) < window
    s = jnp.where(ok, s, NEG_INF)
    p = jnp.where(ok, jax.nn.softmax(s, axis=-1), 0.0)  # masked rows -> 0
    out = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return out.reshape(B, H, Sq, D).astype(q.dtype)
