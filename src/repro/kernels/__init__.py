# Pallas TPU kernels for the compute hot-spots the paper's framework
# optimizes (InternEvo ships FlashAttention + fused norms; SSD covers the
# mamba-family assigned archs). Each kernel: kernel.py (pl.pallas_call +
# BlockSpec) + ops.py (jit wrapper) + ref.py (pure-jnp oracle).
from repro.kernels import runtime
from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.rmsnorm import rmsnorm as rmsnorm_kernel
from repro.kernels.rmsnorm import rmsnorm_ref
from repro.kernels.ssd import ssd as ssd_kernel
from repro.kernels.ssd import ssd_ref

__all__ = ["runtime", "flash_attention", "flash_attention_ref",
           "rmsnorm_kernel", "rmsnorm_ref", "ssd_kernel", "ssd_ref"]
