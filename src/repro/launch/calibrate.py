"""Layer-delta cost calibration for scanned models.

XLA's ``cost_analysis()`` counts a ``while`` (scan) body ONCE, regardless of
trip count — so the full-depth compile (which proves memory/sharding)
undercounts FLOPs/bytes/collectives of the layer stack. Fix: compile two
small *unrolled* depth variants that differ by exactly one period of the
dominant repeating segment, take the delta, and extrapolate:

    total(L) = cost(n1) + (R - 1) * [cost(n2) - cost(n1)]

with n1 = n_base + p, n2 = n_base + 2p, where the dominant segment repeats
R times with pattern length p and n_base = L - R*p leftover layers (layer
patterns are index-periodic, so front-truncation preserves the mix).
Encoder-decoder models scale both stacks together (equal repeats).
"""
from __future__ import annotations

import dataclasses

from repro.config import ModelConfig
from repro.models import Model


@dataclasses.dataclass(frozen=True)
class DepthVariants:
    cfg_n1: ModelConfig
    cfg_n2: ModelConfig
    k: int              # extrapolation multiplier (R - 1)


def depth_variants(cfg: ModelConfig) -> DepthVariants:
    model = Model(cfg)
    dom = max(model.segments, key=lambda s: s.repeat)
    R, p = dom.repeat, len(dom.pattern)
    n_base = cfg.num_layers - R * p
    n1, n2 = n_base + p, n_base + 2 * p
    enc1 = enc2 = cfg.encoder_layers
    if cfg.encoder_layers:
        # whisper-style: encoder repeat equals decoder repeat; scale jointly
        assert cfg.encoder_layers == cfg.num_layers, \
            "joint depth calibration assumes equal enc/dec depth"
        enc1, enc2 = n1, n2
    mk = lambda n, e: dataclasses.replace(cfg, num_layers=n,
                                          encoder_layers=e)
    return DepthVariants(mk(n1, enc1), mk(n2, enc2), R - 1)


def extrapolate(c1: dict, c2: dict, k: int) -> dict:
    """total = c1 + k * (c2 - c1), key-wise over numeric leaves."""
    out = {}
    for key in c1:
        v1, v2 = c1.get(key, 0.0), c2.get(key, 0.0)
        if isinstance(v1, (int, float)) and isinstance(v2, (int, float)):
            out[key] = v1 + k * (v2 - v1)
    return out
