"""End-to-end fault-tolerant training driver.

The full §6.1 stack around a real JAX training loop: sharded train step,
deterministic resumable data pipeline, asynchronous checkpointing, loss-spike
detection with rollback + data-skip, failure diagnosis and the auto-restart
supervisor. Scales from the CPU example (reduced config) to the production
mesh (same code path — only the mesh/config change).

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
      --steps 120 --ckpt-every 20 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import numpy as np

from repro.config import (ParallelConfig, TrainConfig, get_arch, get_smoke)
from repro.core.ft.checkpoint import CheckpointManager
from repro.core.ft.diagnosis import FailureDiagnosisSystem
from repro.core.ft.detection import SimulatedFleet, StragglerMonitor
from repro.core.ft.spike import SpikeDetector
from repro.core.ft.supervisor import (JobContext, JobFailure, SpikeInterrupt,
                                      Supervisor)
from repro.data import DataConfig, DataLoader, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models import Model
from repro.sharding import make_rules
from repro.train.optimizer import adamw_init
from repro.train.train_step import compile_train_step
from repro.utils import logger


@dataclasses.dataclass
class TrainerState:
    params: object
    opt: object
    loader: DataLoader


class Trainer:
    """Restartable training job body driven by the Supervisor."""

    def __init__(self, model: Model, tcfg: TrainConfig, mesh, parallel,
                 ckpt: CheckpointManager, *, total_steps: int,
                 ckpt_every: int = 20,
                 fault_schedule: Optional[dict] = None,
                 spike_schedule: Optional[dict] = None,
                 log_every: int = 10,
                 fleet: Optional[SimulatedFleet] = None,
                 host_time_fn=None):
        self.model, self.tcfg = model, tcfg
        self.mesh, self.parallel = mesh, parallel
        self.ckpt = ckpt
        self.total_steps = total_steps
        self.ckpt_every = ckpt_every
        self.fault_schedule = dict(fault_schedule or {})  # step -> FailureType
        self.spike_schedule = dict(spike_schedule or {})  # step -> delta loss
        self.log_every = log_every
        self.detector = SpikeDetector(min_history=8, patience=3,
                                      z_threshold=6.0)
        # straggler mitigation: per-host step times feed the same cordon
        # list the detection kit uses; persistently slow hosts are removed
        # at the next elastic restart. host_time_fn(step) -> {host: seconds}
        # supplies the measurements (real deployments read them from the
        # multihost heartbeat; tests/sims inject them).
        self.fleet = fleet
        self.host_time_fn = host_time_fn
        self.straggler = StragglerMonitor(
            range(fleet.num_nodes) if fleet else [])
        self.history: list[tuple[int, float]] = []
        self.step_fn, self.p_sh, self.o_sh, _ = compile_train_step(
            model, tcfg, mesh, parallel, donate=False)
        data_cfg = DataConfig(vocab_size=model.cfg.vocab_size,
                              seq_len=tcfg.seq_len,
                              global_batch=tcfg.global_batch,
                              seed=tcfg.seed)
        self.dataset = SyntheticLM(data_cfg)
        self._fired: set[int] = set()

    def init_state(self) -> TrainerState:
        params = self.model.init(jax.random.PRNGKey(self.tcfg.seed))
        return TrainerState(params, adamw_init(params), DataLoader(self.dataset))

    def _restore(self, step: int, skip_ranges) -> TrainerState:
        template = self.init_state()
        (params, opt), extra = self.ckpt.restore(
            step, (template.params, template.opt))
        loader = DataLoader(self.dataset,
                            start_step=int(extra.get("data_step", step)),
                            skip_ranges=[tuple(r) for r in
                                         extra.get("skip_ranges", [])])
        for lo, hi in skip_ranges:
            loader.skip(lo, hi)
        return TrainerState(params, opt, loader)

    def job(self, ctx: JobContext) -> int:
        if ctx.start_step == 0 and self.ckpt.latest_restorable() is None:
            state = self.init_state()
        else:
            state = self._restore(ctx.start_step, ctx.skip_ranges)
        self.detector.reset_after_rollback(ctx.start_step)
        step = ctx.start_step
        while step < self.total_steps:
            data_step, batch = state.loader.next()
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            state.params, state.opt, metrics = self.step_fn(
                state.params, state.opt, batch)
            loss = float(metrics["loss"])
            # injected anomalies (benchmarks / example demos)
            if data_step in self.spike_schedule and not self._skipped(state, data_step):
                loss += self.spike_schedule[data_step]
            step += 1
            self.history.append((step, loss))
            if step % self.log_every == 0:
                logger.info("step %d loss %.4f lr %.2e", step, loss,
                            float(metrics["lr"]))
            ev = self.detector.update(step, loss,
                                      self.ckpt.available_steps() or
                                      list(self.ckpt.ram_cache))
            if ev is not None:
                raise SpikeInterrupt(ev)
            if step % self.ckpt_every == 0:
                stall = self.ckpt.save_async(
                    step, (state.params, state.opt),
                    extra={"data_step": state.loader.step,
                           "skip_ranges": state.loader.skip_ranges})
                logger.debug("ckpt %d stall %.1fms", step, stall * 1e3)
            if self.host_time_fn is not None and self.fleet is not None:
                for host, t in self.host_time_fn(step).items():
                    self.straggler.record(host, t)
                slow = [h for h in self.straggler.stragglers()
                        if h not in self.fleet.cordoned]
                if slow:
                    self.fleet.cordon(slow)
                    logger.info("stragglers cordoned at step %d: %s",
                                step, slow)
            if step in self.fault_schedule and step not in self._fired:
                self._fired.add(step)
                from repro.core.ft.events import generate_log
                ft = self.fault_schedule[step]
                raise JobFailure(step, generate_log(ft, seed=step), truth=ft.name)
        return step

    def _skipped(self, state: TrainerState, data_step: int) -> bool:
        return any(lo <= data_step < hi for lo, hi in state.loader.skip_ranges)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--model-axis", type=int, default=1)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    mesh = make_host_mesh(args.model_axis)
    parallel = ParallelConfig(remat="none", moe_impl="dense",
                              shard_model_axes=args.model_axis > 1)
    tcfg = TrainConfig(global_batch=args.global_batch, seq_len=args.seq_len,
                       total_steps=args.steps, warmup_steps=args.steps // 10)
    model = Model(cfg, parallel, make_rules(mesh, parallel))
    ckpt = CheckpointManager(args.ckpt_dir, keep=4)
    trainer = Trainer(model, tcfg, mesh, parallel, ckpt,
                      total_steps=args.steps, ckpt_every=args.ckpt_every)
    sup = Supervisor(ckpt, FailureDiagnosisSystem(), SimulatedFleet(8))
    t0 = time.time()
    report = sup.run(trainer.job)
    ckpt.wait()
    losses = [l for _, l in trainer.history]
    logger.info("done: completed=%s final_step=%d attempts=%d "
                "loss %.3f -> %.3f (%.1fs)", report.completed,
                report.final_step, report.attempts, losses[0], losses[-1],
                time.time() - t0)


if __name__ == "__main__":
    main()
