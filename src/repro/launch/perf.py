"""§Perf hillclimb driver: lower one cell under a named ParallelConfig
variant and print its calibrated roofline terms.

  PYTHONPATH=src python -m repro.launch.perf --arch gemma3-27b \
      --shape train_4k --variant fsdp2d
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse
import dataclasses
import json

from repro.config import ParallelConfig, get_arch
from repro.launch.calibrate import depth_variants, extrapolate
from repro.launch.dryrun import default_parallel, lower_cell
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS, \
    model_flops_per_device
from repro.launch.shapes import SHAPES
from repro.utils import human_bytes, logger


def variant_parallel(name: str, base: ParallelConfig, cfg, mesh
                     ) -> ParallelConfig:
    M = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    if name == "baseline":
        return base
    if name == "fsdp2d":          # drop TP/SP; 2-D FSDP + full data-parallel
        return dataclasses.replace(base, shard_model_axes=False,
                                   sequence_parallel=False)
    if name == "fsdp2d_remat_full":
        return dataclasses.replace(base, shard_model_axes=False,
                                   sequence_parallel=False, remat="full")
    if name == "remat_full":
        return dataclasses.replace(base, remat="full")
    if name == "no_sp":           # TP without sequence parallelism
        return dataclasses.replace(base, sequence_parallel=False)
    if name == "ep_align":        # expert-parallel only when E % M == 0
        ep = cfg.moe.num_experts > 0 and cfg.moe.num_experts % M == 0
        return dataclasses.replace(base, expert_parallel=ep)
    if name == "ep_align_fsdp2d":
        ep = cfg.moe.num_experts > 0 and cfg.moe.num_experts % M == 0
        return dataclasses.replace(base, expert_parallel=ep,
                                   shard_model_axes=False,
                                   sequence_parallel=False)
    if name == "zero1":           # params replicated, opt sharded
        return dataclasses.replace(base, zero="zero1")
    if name == "bf16_grads":      # bf16 gradient flow + reductions
        return dataclasses.replace(base, grad_dtype="bfloat16")
    if name == "bf16_grads_mb8":
        return dataclasses.replace(base, grad_dtype="bfloat16")
    if name == "ep_bf16":         # aligned expert sharding + bf16 grads
        ep = cfg.moe.num_experts > 0 and cfg.moe.num_experts % M == 0
        return dataclasses.replace(base, expert_parallel=ep,
                                   grad_dtype="bfloat16")
    if name == "fsdp2d_bf16":     # pure-DP FSDP + bf16 grads
        return dataclasses.replace(base, shard_model_axes=False,
                                   sequence_parallel=False,
                                   grad_dtype="bfloat16")
    if name == "fsdp2d_bf16_noremat":   # + skip recompute (small models)
        return dataclasses.replace(base, shard_model_axes=False,
                                   sequence_parallel=False,
                                   grad_dtype="bfloat16", remat="none")
    raise ValueError(f"unknown variant {name!r}")


def measure(arch: str, shape_name: str, variant: str,
            ssm_overrides: dict | None = None,
            microbatches: int = 1) -> dict:
    import jax
    from repro.config import TrainConfig
    shape = SHAPES[shape_name]
    if variant.endswith("_tp8"):
        # same 256 chips, deeper data parallelism: TP activation collectives
        # scale with tokens-in-flight per device, param gathers barely move
        mesh = jax.make_mesh((32, 8), ("data", "model"))
        variant_base = variant[:-4]
    else:
        mesh = make_production_mesh()
        variant_base = variant
    cfg = get_arch(arch)
    if ssm_overrides and cfg.ssm is not None:
        cfg = dataclasses.replace(
            cfg, ssm=dataclasses.replace(cfg.ssm, **ssm_overrides))
    par = variant_parallel(variant_base, default_parallel(arch, mesh), cfg,
                           mesh)
    tcfg = TrainConfig(global_batch=shape.global_batch,
                       seq_len=shape.seq_len, microbatches=microbatches)

    # full compile: memory + proof
    lowered = lower_cell(arch, shape, mesh, parallel=par, cfg_override=cfg,
                         tcfg=tcfg)
    full = analyze(lowered.compile())

    # calibrated costs via unrolled depth variants
    dv = depth_variants(cfg)
    par_u = dataclasses.replace(par, scan_layers=False)
    keep = ("flops", "bytes_accessed")
    recs = []
    for c in (dv.cfg_n1, dv.cfg_n2):
        a = analyze(lower_cell(arch, shape, mesh, parallel=par_u,
                               cfg_override=c, tcfg=tcfg).compile())
        flat = {k: v for k, v in a["cost"].items() if k in keep}
        flat["coll_total"] = a["collectives"]["total_bytes_per_device"]
        for op, b in a["collectives"]["bytes_by_op"].items():
            flat[f"coll_{op}"] = b
        recs.append(flat)
    cal = extrapolate(recs[0], recs[1], dv.k)

    compute_s = cal["flops"] / PEAK_FLOPS
    memory_s = cal["bytes_accessed"] / HBM_BW
    coll_s = cal["coll_total"] / ICI_BW
    mf = model_flops_per_device(cfg, shape.kind, shape.seq_len,
                                shape.global_batch, mesh.devices.size)
    out = {
        "arch": arch, "shape": shape_name, "variant": variant,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": max(("compute", compute_s), ("memory", memory_s),
                        ("collective", coll_s), key=lambda kv: kv[1])[0],
        "roofline_frac": compute_s / max(compute_s, memory_s, coll_s),
        "useful_ratio": mf / max(cal["flops"], 1.0),
        "coll_by_op_gib": {k.replace("coll_", ""): v / 2 ** 30
                           for k, v in cal.items()
                           if k.startswith("coll_") and k != "coll_total"},
        "args_gib": full["memory"].get("argument_size_in_bytes", 0) / 2 ** 30,
        "temp_gib": full["memory"].get("temp_size_in_bytes", 0) / 2 ** 30,
    }
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--ssm-chunk", type=int, default=0)
    ap.add_argument("--ssm-head-block", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--out", default="artifacts/perf")
    args = ap.parse_args()
    ov = {}
    if args.ssm_chunk:
        ov["chunk_size"] = args.ssm_chunk
    if args.ssm_head_block:
        ov["head_block"] = args.ssm_head_block
    rec = measure(args.arch, args.shape, args.variant, ov or None,
                  microbatches=args.microbatches)
    os.makedirs(args.out, exist_ok=True)
    tag = f"{args.arch}_{args.shape}_{args.variant}"
    if args.microbatches > 1:
        tag += f"_mb{args.microbatches}"
    if ov:
        tag += "_" + "_".join(f"{k}{v}" for k, v in ov.items())
    with open(os.path.join(args.out, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    logger.info("%s: compute %.3fs memory %.3fs collective %.3fs "
                "dominant=%s frac=%.3f useful=%.3f temp=%.1fGiB",
                tag, rec["compute_s"], rec["memory_s"], rec["collective_s"],
                rec["dominant"], rec["roofline_frac"], rec["useful_ratio"],
                rec["temp_gib"])
    logger.info("collectives: %s",
                {k: round(v, 2) for k, v in rec["coll_by_op_gib"].items()})


if __name__ == "__main__":
    main()
