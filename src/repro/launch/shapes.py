"""The assigned input-shape set and per-arch cell plan.

Four shapes per LM-family arch (40 cells total):
  train_4k      seq 4096,    global_batch 256   -> lowers train_step
  prefill_32k   seq 32768,   global_batch 32    -> lowers prefill
  decode_32k    seq 32768,   global_batch 128   -> lowers serve_step
  long_500k     seq 524288,  global_batch 1     -> lowers serve_step

``long_500k`` needs sub-quadratic decode state: it runs for the SWA-bounded,
SSM and hybrid archs and is recorded as SKIP for pure full-attention archs
(DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses

from repro.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# archs whose decode state stays bounded (SWA window / SSM state / hybrid)
LONG_CONTEXT_OK = {
    "gemma3-27b",            # 5/6 layers SWA-1024; global layers linear decode
    "h2o-danube-1.8b",       # SWA 4096
    "mamba2-1.3b",           # O(1) SSM state
    "mixtral-8x22b",         # SWA 4096
    "jamba-1.5-large-398b",  # 7/8 layers SSM
}


def cell_plan(arch: str, cfg: ModelConfig) -> list[tuple[ShapeSpec, str]]:
    """[(shape, "run"|"skip:<reason>")] for one architecture."""
    plan = []
    for shape in SHAPES.values():
        verdict = "run"
        if shape.name == "long_500k" and arch not in LONG_CONTEXT_OK:
            verdict = "skip:full-attention decode state at 500k is unbounded"
        plan.append((shape, verdict))
    return plan


def effective_batch(shape: ShapeSpec, cfg: ModelConfig) -> int:
    return shape.global_batch
