import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init). 512 placeholder host devices let ``jax.make_mesh``
build the production meshes: 16x16 (one v5e pod) and 2x16x16 (two pods).

For every runnable cell this driver:
  1. builds the model + sharding rules,
  2. lowers the right program (train_step / prefill / serve_step),
  3. ``.compile()``s it — sharding mismatches, unsupported collectives and
     shape errors surface here, exactly what the dry-run must prove out,
  4. records memory_analysis / cost_analysis / parsed collective bytes to
     ``artifacts/dryrun/<mesh>/<arch>/<shape>.json`` for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch smollm-360m --shape train_4k --mesh single
  python -m repro.launch.dryrun --mesh both          # the full 40-cell matrix
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.config import ParallelConfig, TrainConfig, get_arch
from repro.configs import ASSIGNED
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, ShapeSpec, cell_plan
from repro.models import Model
from repro.serve import compile_prefill, compile_serve_step
from repro.train.train_step import compile_train_step
from repro.utils import human_bytes, logger


def default_parallel(arch: str, mesh) -> ParallelConfig:
    multi_pod = "pod" in mesh.axis_names
    return ParallelConfig(
        zero="zero3_hier" if multi_pod else "zero3",
        shard_model_axes=True, sequence_parallel=True, expert_parallel=True,
        remat="dots", scan_layers=True, moe_impl="gshard")


def lower_cell(arch: str, shape: ShapeSpec, mesh,
               parallel: ParallelConfig | None = None,
               tcfg: TrainConfig | None = None,
               cfg_override=None):
    cfg = cfg_override if cfg_override is not None else get_arch(arch)
    parallel = parallel or default_parallel(arch, mesh)
    model = Model(cfg, parallel, rules=None)
    # rules bound inside train/serve compile via make_rules(mesh, parallel)
    from repro.sharding import make_rules
    model.rules = make_rules(mesh, parallel)
    if shape.kind == "train":
        tcfg = tcfg or TrainConfig(global_batch=shape.global_batch,
                                   seq_len=shape.seq_len)
        lowered, *_ = compile_train_step(model, tcfg, mesh, parallel,
                                         batch_size=shape.global_batch,
                                         seq_len=shape.seq_len,
                                         lower_only=True)
        return lowered
    if shape.kind == "prefill":
        return compile_prefill(model, mesh, parallel,
                               batch=shape.global_batch,
                               seq_len=shape.seq_len)
    return compile_serve_step(model, mesh, parallel,
                              batch=shape.global_batch,
                              kv_len=shape.seq_len)


def _calibrated_costs(arch: str, shape: ShapeSpec, mesh) -> dict:
    """True per-device totals via unrolled layer-delta extrapolation
    (cost_analysis counts scan bodies once — see launch/calibrate.py)."""
    from repro.launch.calibrate import depth_variants, extrapolate
    dv = depth_variants(get_arch(arch))
    par = dataclasses.replace(default_parallel(arch, mesh),
                              scan_layers=False)
    recs = []
    keep = ("flops", "bytes_accessed", "transcendentals")
    for c in (dv.cfg_n1, dv.cfg_n2):
        lowered = lower_cell(arch, shape, mesh, parallel=par, cfg_override=c)
        a = analyze(lowered.compile())
        flat = {k: v for k, v in a["cost"].items() if k in keep}
        for op, b in a["collectives"]["bytes_by_op"].items():
            flat[f"coll_{op}"] = b
        flat["coll_total"] = a["collectives"]["total_bytes_per_device"]
        recs.append(flat)
    out = extrapolate(recs[0], recs[1], dv.k)
    out["calib_k"] = dv.k
    out["calib_n"] = (dv.cfg_n1.num_layers, dv.cfg_n2.num_layers)
    return out


def run_cell(arch: str, shape: ShapeSpec, mesh_name: str, mesh,
             out_dir: str, calibrate: bool = False) -> dict:
    rec: dict = {"arch": arch, "shape": shape.name, "mesh": mesh_name,
                 "kind": shape.kind, "seq_len": shape.seq_len,
                 "global_batch": shape.global_batch,
                 "n_devices": mesh.devices.size}
    t0 = time.time()
    try:
        lowered = lower_cell(arch, shape, mesh)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)
        rec.update(analyze(compiled))
        if calibrate:
            t2 = time.time()
            rec["calibrated"] = _calibrated_costs(arch, shape, mesh)
            rec["calibrate_s"] = round(time.time() - t2, 2)
        rec["status"] = "ok"
        mem = rec.get("memory", {})
        per_dev = (mem.get("argument_size_in_bytes", 0)
                   + mem.get("temp_size_in_bytes", 0))
        logger.info("%-24s %-12s %-7s ok  lower %5.1fs compile %6.1fs "
                    "args+temp/dev %s  flops/dev %.3e  coll/dev %s",
                    arch, shape.name, mesh_name, rec["lower_s"],
                    rec["compile_s"], human_bytes(per_dev),
                    rec.get("cost", {}).get("flops", float("nan")),
                    human_bytes(rec["collectives"]["total_bytes_per_device"]))
    except Exception as e:  # noqa: BLE001 — a failed cell is a result
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        logger.error("%-24s %-12s %-7s FAILED: %s", arch, shape.name,
                     mesh_name, rec["error"])
    path = os.path.join(out_dir, mesh_name, arch)
    os.makedirs(path, exist_ok=True)
    with open(os.path.join(path, f"{shape.name}.json"), "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--calibrate", action="store_true",
                    help="also compile unrolled depth variants for true "
                         "per-device cost totals (single-pod roofline)")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ASSIGNED)
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi", make_production_mesh(multi_pod=True)))

    results = []
    for mesh_name, mesh in meshes:
        for arch in archs:
            cfg = get_arch(arch)
            for shape, verdict in cell_plan(arch, cfg):
                if args.shape and shape.name != args.shape:
                    continue
                if verdict != "run":
                    results.append({"arch": arch, "shape": shape.name,
                                    "mesh": mesh_name, "status": verdict})
                    logger.info("%-24s %-12s %-7s %s", arch, shape.name,
                                mesh_name, verdict)
                    continue
                results.append(run_cell(arch, shape, mesh_name, mesh,
                                        args.out,
                                        calibrate=args.calibrate))
    ok = sum(1 for r in results if r["status"] == "ok")
    skip = sum(1 for r in results if r["status"].startswith("skip"))
    err = sum(1 for r in results if r["status"] == "error")
    logger.info("dry-run done: %d ok, %d skipped, %d failed", ok, skip, err)
    if err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
