"""Per-arch step-time cost model: dry-run artifacts -> width-scaling curves.

This is the layer that makes the jax_pallas half load-bearing for the
cluster simulation (ROADMAP "cost-model-grounded replay", MoFa-style):

  dryrun --calibrate  ->  artifacts/dryrun/<mesh>/<arch>/<shape>.json
  roofline.cell_roofline  ->  three-term seconds-per-step (compute / memory
                              / collective) at the recorded mesh width
  CostModel               ->  per-(arch, shape) ``CostCell`` table with a
                              deterministic *analytic* fallback for archs
                              without artifacts (tier-1 stays hermetic)
  WidthCurve              ->  T(w) = work_s / w + coll_s, the repricing
                              curve the replay engine consults on elastic
                              shrink/regrow instead of linear stretching

The width model splits a cell's step time into *divisible work* (the
larger of the compute and memory terms, which shards with width) and the
*collective* term (per-device ring/all-to-all traffic, to first order
width-invariant under ZeRO-style sharding — halving the width halves the
gathered bytes but also halves the links moving them). That yields the
MegaScale-flavored behavior the paper motivates: shrinking a job hurts
*less* than linearly (rate(w) > w/W0 for w < W0, the collective share
doesn't grow), and regrowing gains less than linearly.

The analytic fallback is NOT magnitude-faithful to the calibrated cells
(XLA's HLO byte accounting inflates collective totals vs the naive
estimate); it exists to give *deterministic, correctly ordered* cells —
MoE archs several times more collective-heavy per useful FLOP than dense
— when ``artifacts/dryrun/**`` is absent, so golden tests and benches are
reproducible on a bare checkout.
"""
from __future__ import annotations

import dataclasses
import json
import zlib
from typing import Optional

from repro.launch.roofline import (HBM_BW, ICI_BW, PEAK_FLOPS, active_params,
                                   cell_roofline, load_cells,
                                   model_flops_per_device)
from repro.launch.shapes import SHAPES

DEFAULT_ART_DIR = "artifacts/dryrun/single"
#: single-pod mesh width every dry-run cell is recorded at
NOMINAL_DEVICES = 256

# analytic-fallback constants (documented, deterministic; see module doc)
_ANALYTIC_HLO_EFFICIENCY = 0.85   # model FLOPs / HLO FLOPs (remat waste)
#: fusion-level arithmetic intensity assumed by the analytic cells; public
#: because repro.quality.pallas_cost cross-checks it against the envelope
#: of statically-derived per-kernel intensities
ANALYTIC_FLOPS_PER_BYTE = 12.0
_ANALYTIC_ZERO_BYTES_PER_PARAM = 12.0   # fwd/bwd gathers + grad reduce
_ANALYTIC_TP_BYTES_PER_ACT = 8.0        # per token*d_model*layer element

# serving-rate decomposition: share of a decode step's divisible work that
# is batch-invariant (weight streaming — every step reads the whole sharded
# parameter set once regardless of how many sequences ride the step) vs
# per-sequence (KV reads + per-token FLOPs) at the cell's recorded global
# batch. Order-faithful, not magnitude-faithful, like the analytic cells.
_SERVE_DECODE_FIXED_FRAC = 0.6


@dataclasses.dataclass(frozen=True, slots=True)
class CostCell:
    """One (arch, shape) step-time observation at the nominal mesh width."""
    arch: str
    shape: str
    kind: str
    n_devices: int
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float
    model_flops: float
    collective_bytes: float
    a2a_bytes: float
    source: str                  # "calibrated" | "dryrun" | "analytic"

    @property
    def step_time_s(self) -> float:
        return max(self.compute_s, self.memory_s) + self.collective_s


class WidthCurve:
    """Step-time vs width for one arch: ``T(w) = work_s / w + coll_s``.

    ``work_s`` is the cell's divisible work in device-seconds
    (max(compute, memory) * n_devices); ``coll_s`` is the width-invariant
    per-device collective term. ``rate(w)`` is the progress rate relative
    to the nominal width — the quantity the replay engine multiplies wall
    minutes by. ``rate(n_devices)`` is *exactly* 1.0 (same float expression
    divided by itself), which is what keeps full-width replays bit-exact.
    """
    __slots__ = ("arch", "n_devices", "work_s", "coll_s", "t_nom")

    def __init__(self, arch: str, n_devices: int, work_s: float,
                 coll_s: float) -> None:
        self.arch = arch
        self.n_devices = n_devices
        self.work_s = work_s
        self.coll_s = coll_s
        self.t_nom = work_s / n_devices + coll_s

    @classmethod
    def from_cell(cls, cell: CostCell) -> "WidthCurve":
        return cls(cell.arch, cell.n_devices,
                   max(cell.compute_s, cell.memory_s) * cell.n_devices,
                   cell.collective_s)

    def step_time(self, width: float) -> float:
        return self.work_s / width + self.coll_s

    def rate(self, width: float) -> float:
        """Nominal-minutes of progress per wall minute at ``width`` GPUs."""
        return self.t_nom / (self.work_s / width + self.coll_s)

    def efficiency(self, width: float) -> float:
        """Parallel efficiency T(1) / (w * T(w)); 1.0 at w=1, <= 1,
        monotone non-increasing in width."""
        return (self.work_s + self.coll_s) / (self.work_s
                                              + width * self.coll_s)

    def __repr__(self) -> str:
        return (f"WidthCurve({self.arch!r}, n={self.n_devices}, "
                f"work={self.work_s:.3e}s, coll={self.coll_s:.3e}s)")


@dataclasses.dataclass(frozen=True, slots=True)
class ServeRates:
    """Serving-side pricing derived from one arch's prefill/decode cells.

    The serving replay (``repro.cluster.serve_replay``) consults exactly
    two quantities:

      * ``prefill_s(tokens)`` — seconds one ``gpus``-wide prefill instance
        takes to run a prompt (or a KV-recompute pass) of ``tokens``
        tokens, from the ``prefill_32k`` cell's token throughput scaled
        linearly from the cell's recorded width to the instance width;
      * ``step_time_s(batch)`` — seconds per continuous-batching decode
        step at occupancy ``batch``: an affine ``fixed + batch * per_seq``
        decomposition of the ``decode_32k`` cell (weight streaming +
        collectives are batch-invariant, KV reads and token FLOPs scale
        per sequence), so TPOT improves as batches fill and the engine's
        admission policy has a real throughput/latency trade to make.

    ``source`` records the provenance of each cell ("calibrated" /
    "dryrun" / "analytic"), mirroring ``CostCell.source``.
    """
    arch: str
    gpus: int
    prefill_tok_s: float
    decode_fixed_s: float
    decode_per_seq_s: float
    source: str               # "<prefill cell source>/<decode cell source>"

    def prefill_s(self, tokens: float) -> float:
        return tokens / self.prefill_tok_s

    def step_time_s(self, batch: int) -> float:
        return self.decode_fixed_s + batch * self.decode_per_seq_s


def _analytic_cell(arch: str, shape_name: str = "train_4k",
                   n_devices: int = NOMINAL_DEVICES) -> CostCell:
    """Deterministic closed-form cell from the arch config alone."""
    from repro.config import get_arch
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    total, _active = active_params(cfg)
    mf = model_flops_per_device(cfg, shape.kind, shape.seq_len,
                                shape.global_batch, n_devices)
    hlo_flops = mf / _ANALYTIC_HLO_EFFICIENCY
    byts = hlo_flops / ANALYTIC_FLOPS_PER_BYTE
    if shape.kind == "decode":
        tokens_dev = shape.global_batch / n_devices
    else:
        tokens_dev = shape.seq_len * shape.global_batch / n_devices
    n_layers = cfg.num_layers + cfg.encoder_layers
    if shape.kind == "train":
        # training step: ZeRO-style parameter gathers + gradient reduce
        zero_bytes = _ANALYTIC_ZERO_BYTES_PER_PARAM * total
    else:
        # serving step: weights are resident (tensor-parallel sharded), no
        # per-step parameter movement over the interconnect — only the TP
        # activation reductions (and MoE a2a) below remain
        zero_bytes = 0.0
    coll = (zero_bytes
            + _ANALYTIC_TP_BYTES_PER_ACT * tokens_dev * cfg.d_model
            * n_layers)
    if shape.kind == "decode":
        # the flops-intensity heuristic misses what bounds decode: each
        # step streams the full sharded weight set plus every live
        # sequence's KV cache through HBM while doing ~2 flops/param of
        # work. Price those reads explicitly (bf16 weights, K+V bf16 at
        # the full context) and keep whichever bound is tighter... i.e.
        # larger, since these are times, not rates.
        att = getattr(cfg, "attention", None)
        kv_dim = cfg.d_model
        if att is not None and att.num_kv_heads and att.head_dim:
            kv_dim = att.num_kv_heads * att.head_dim
        weight_b = 2.0 * total / n_devices
        kv_b = (4.0 * shape.global_batch * shape.seq_len * kv_dim
                * n_layers / n_devices)
        byts = max(byts, weight_b + kv_b)
    a2a = 0.0
    if cfg.moe.num_experts:
        n_moe = sum(cfg.moe.is_moe_layer(i) for i in range(cfg.num_layers))
        a2a = (_ANALYTIC_TP_BYTES_PER_ACT * tokens_dev * cfg.d_model
               * cfg.moe.top_k * n_moe)
        coll += a2a
    return CostCell(
        arch=arch, shape=shape_name, kind=shape.kind, n_devices=n_devices,
        compute_s=hlo_flops / PEAK_FLOPS, memory_s=byts / HBM_BW,
        collective_s=coll / ICI_BW, hlo_flops=hlo_flops, model_flops=mf,
        collective_bytes=coll, a2a_bytes=a2a, source="analytic")


def _cell_from_record(rec: dict, skipped: Optional[dict] = None
                      ) -> Optional[CostCell]:
    r = cell_roofline(rec, skipped=skipped)
    if r is None:
        return None
    cal = rec.get("calibrated")
    if not isinstance(cal, dict):
        cal = {}
    try:
        a2a = float(cal.get("coll_all-to-all", 0.0))
    except (TypeError, ValueError):
        a2a = 0.0
    return CostCell(
        arch=r.arch, shape=r.shape, kind=r.kind,
        n_devices=int(rec["n_devices"]),
        compute_s=r.compute_s, memory_s=r.memory_s,
        collective_s=r.collective_s, hlo_flops=r.hlo_flops,
        model_flops=r.model_flops, collective_bytes=r.collective_bytes,
        a2a_bytes=a2a, source="calibrated" if r.calibrated else "dryrun")


class CostModel:
    """Per-(arch, shape) ``CostCell`` table + per-arch ``WidthCurve``s."""
    __slots__ = ("cells", "skipped", "art_dir", "_curves", "_job_curves",
                 "_serve_rates")

    def __init__(self, cells: dict, skipped: dict,
                 art_dir: Optional[str]) -> None:
        self.cells = cells            # (arch, shape) -> CostCell
        self.skipped = skipped        # reason -> count (malformed records)
        self.art_dir = art_dir        # None for a purely analytic model
        self._curves: dict = {}       # arch -> Optional[WidthCurve]
        self._job_curves: dict = {}   # (arch, gpus) -> Optional[WidthCurve]
        self._serve_rates: dict = {}  # (arch, gpus) -> ServeRates

    @classmethod
    def load(cls, art_dir: str = DEFAULT_ART_DIR,
             archs: tuple = (), analytic_fallback: bool = True
             ) -> "CostModel":
        """Cells from the artifact tree; ``archs`` lists architectures that
        must be present — any without a train cell on disk get an analytic
        fallback cell (counted in ``skipped['analytic_fallback']``)."""
        skipped: dict = {}
        cells: dict = {}
        for rec in load_cells(art_dir, skipped=skipped):
            cell = _cell_from_record(rec, skipped=skipped)
            if cell is not None:
                cells[(cell.arch, cell.shape)] = cell
        if analytic_fallback:
            for arch in archs:
                if (arch, "train_4k") not in cells:
                    try:
                        cells[(arch, "train_4k")] = _analytic_cell(arch)
                    except (KeyError, ValueError):
                        skipped["unknown_arch"] = (
                            skipped.get("unknown_arch", 0) + 1)
                        continue
                    skipped["analytic_fallback"] = (
                        skipped.get("analytic_fallback", 0) + 1)
        return cls(cells, skipped, art_dir)

    @classmethod
    def analytic(cls, archs: tuple) -> "CostModel":
        """Hermetic model: every cell closed-form, no artifacts read."""
        skipped: dict = {}
        cells: dict = {}
        for arch in archs:
            try:
                cells[(arch, "train_4k")] = _analytic_cell(arch)
            except (KeyError, ValueError):
                skipped["unknown_arch"] = skipped.get("unknown_arch", 0) + 1
        return cls(cells, skipped, None)

    def cell(self, arch: str, shape: str = "train_4k"
             ) -> Optional[CostCell]:
        return self.cells.get((arch, shape))

    def curve(self, arch: str) -> Optional[WidthCurve]:
        """Width-scaling curve from the arch's train cell (cached);
        ``None`` when the arch has no cell (job falls back to nominal)."""
        if arch in self._curves:
            return self._curves[arch]
        cell = self.cells.get((arch, "train_4k"))
        curve = WidthCurve.from_cell(cell) if cell is not None else None
        self._curves[arch] = curve
        return curve

    def job_curve(self, arch: str, gpus: int) -> Optional[WidthCurve]:
        """Width curve *re-anchored at the job's nominal width*: the
        replay's progress accounting needs ``rate(gpus) == 1.0`` exactly
        (a full-width job advances one nominal minute per wall minute by
        definition), so the curve's reference step time is evaluated at
        the job's own GPU count. The curve *shape* is unchanged —
        ``rate`` only ever uses step-time ratios. Cached per
        (arch, gpus): the replay resolves one per job arrival."""
        key = (arch, gpus)
        if key in self._job_curves:
            return self._job_curves[key]
        cell = self.cells.get((arch, "train_4k"))
        if cell is None:
            curve = None
        else:
            curve = WidthCurve(arch, gpus,
                               max(cell.compute_s, cell.memory_s)
                               * cell.n_devices, cell.collective_s)
        self._job_curves[key] = curve
        return curve

    def _serve_cell(self, arch: str, shape: str) -> CostCell:
        """The (arch, shape) serving cell, closed-form when absent.

        ``load()``'s fallback only guarantees train cells; the serving
        shapes fall back here on demand so a serving replay works for any
        registry arch on a bare checkout (counted in
        ``skipped['analytic_fallback_serve']``). Raises ``KeyError`` for
        an arch the registry does not know."""
        cell = self.cells.get((arch, shape))
        if cell is None:
            cell = _analytic_cell(arch, shape)
            self.cells[(arch, shape)] = cell
            self.skipped["analytic_fallback_serve"] = (
                self.skipped.get("analytic_fallback_serve", 0) + 1)
        return cell

    def serve_rates(self, arch: str, gpus: int) -> ServeRates:
        """Per-instance serving rates from the prefill/decode cells.

        Both cells are recorded at the nominal mesh width; a serving
        instance is ``gpus`` wide, so the divisible terms (compute/memory)
        scale by ``n_devices / gpus`` while the collective term stays —
        the same width model as :class:`WidthCurve`. The decode step is
        then split batch-invariant vs per-sequence with
        ``_SERVE_DECODE_FIXED_FRAC`` at the cell's recorded batch. Cached
        per (arch, gpus); the serving replay resolves one per run."""
        key = (arch, gpus)
        rates = self._serve_rates.get(key)
        if rates is not None:
            return rates
        pcell = self._serve_cell(arch, "prefill_32k")
        dcell = self._serve_cell(arch, "decode_32k")
        pshape = SHAPES["prefill_32k"]
        p_work = max(pcell.compute_s, pcell.memory_s)
        p_step = p_work * (pcell.n_devices / gpus) + pcell.collective_s
        prefill_tok_s = pshape.seq_len * pshape.global_batch / p_step
        d_work = max(dcell.compute_s, dcell.memory_s) \
            * (dcell.n_devices / gpus)
        b0 = SHAPES["decode_32k"].global_batch
        fixed = d_work * _SERVE_DECODE_FIXED_FRAC + dcell.collective_s
        per_seq = d_work * (1.0 - _SERVE_DECODE_FIXED_FRAC) / b0
        rates = ServeRates(arch=arch, gpus=gpus,
                           prefill_tok_s=prefill_tok_s,
                           decode_fixed_s=fixed, decode_per_seq_s=per_seq,
                           source=f"{pcell.source}/{dcell.source}")
        self._serve_rates[key] = rates
        return rates

    def archs(self) -> list[str]:
        return sorted({a for a, _ in self.cells})


def dryrun_provenance(art_dir: str = DEFAULT_ART_DIR) -> dict:
    """Identity of the artifact cells a bench run consumed.

    ``benchmarks.run`` stamps this next to the bench rows so
    ``check_regression`` can refuse to compare roofline/moe_comm numbers
    against a baseline built from a different cell set (different archs,
    or calibrated vs raw-HLO records)."""
    skipped: dict = {}
    ids = []
    for rec in load_cells(art_dir, skipped=skipped):
        if rec.get("status") != "ok":
            continue
        cal = rec.get("calibrated")
        calibrated = isinstance(cal, dict) and bool(cal)
        try:
            n_dev = int(rec.get("n_devices") or 0)
        except (TypeError, ValueError):
            n_dev = 0
        # identity is the *cell set* — which (arch, shape) cells exist, at
        # what width, calibrated or raw — not the measured numbers: the
        # gates' tolerance bands judge the numbers, the fingerprint only
        # refuses structurally different tables (and must stay stable
        # across XLA versions whose cost analysis drifts slightly)
        ids.append((str(rec.get("arch")), str(rec.get("shape")),
                    int(calibrated), n_dev))
    ids.sort()
    fp = zlib.crc32(json.dumps(ids).encode("utf-8")) & 0xFFFFFFFF
    return {
        "archs": sorted({i[0] for i in ids}),
        "n_cells": len(ids),
        "n_calibrated": sum(i[2] for i in ids),
        "fingerprint": f"{fp:08x}",
    }
