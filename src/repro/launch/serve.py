"""Batched serving driver: prefill + greedy decode with sharded KV caches.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ParallelConfig, get_arch, get_smoke
from repro.launch.mesh import make_host_mesh
from repro.models import Model
from repro.serve import make_serve_step
from repro.sharding import make_rules
from repro.utils import logger


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--model-axis", type=int, default=1)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    mesh = make_host_mesh(args.model_axis)
    parallel = ParallelConfig(remat="none", moe_impl="dense",
                              shard_model_axes=args.model_axis > 1)
    model = Model(cfg, parallel, make_rules(mesh, parallel))
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                      (args.batch, args.prompt_len),
                                      dtype=np.int32))
    batch = {"tokens": prompt}
    if cfg.frontend == "patch_stub":
        batch["patches"] = jnp.zeros((args.batch, cfg.num_patches,
                                      cfg.d_model), jnp.float32)
    if cfg.frontend == "audio_stub":
        batch["frames"] = jnp.zeros((args.batch, cfg.encoder_seq,
                                     cfg.d_model), jnp.float32)

    t0 = time.time()
    logits, caches = jax.jit(model.prefill)(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    step_fn = jax.jit(make_serve_step(model))
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    t1 = time.time()
    for t in range(args.prompt_len, args.prompt_len + args.gen - 1):
        logits, caches = step_fn(params, caches, tok, jnp.int32(t))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(out[-1])
    t_decode = time.time() - t1
    gen = jnp.stack(out, axis=1)
    toks_s = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    logger.info("prefill %.2fs; decode %d x %d tokens in %.2fs "
                "(%.1f tok/s incl. first-step compile)",
                t_prefill, args.batch, args.gen, t_decode, toks_s)
    logger.info("sample generation: %s", np.asarray(gen[0][:16]))


if __name__ == "__main__":
    main()
