"""Batched serving driver: prefill + greedy decode with sharded KV caches.

The importable surface is :class:`ServeSession` — build the model, mesh
and parameters once, then drive `prefill()` / `decode_step()` (or the
convenience `generate()`) as many times as needed; each call returns a
structured :class:`ServeTimings`. These two phases are exactly the ones
the cost model prices for the serving replay (``SHAPES['prefill_32k']``
and ``SHAPES['decode_32k']`` in ``launch/cost_model.py``), so a
calibrated dry-run of this driver and ``cluster/serve_replay.py``'s
analytic fallback describe the same work.

CLI (thin argparse wrapper over ServeSession):

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ParallelConfig, get_arch, get_smoke
from repro.launch.mesh import make_host_mesh
from repro.models import Model
from repro.serve import make_serve_step
from repro.sharding import make_rules
from repro.utils import logger


@dataclasses.dataclass(frozen=True, slots=True)
class ServeTimings:
    """Wall-clock accounting for one serving phase.

    ``seconds`` includes compile on the first call of each jitted
    function; ``tokens`` is the number of tokens the phase produced
    (batch * prompt for prefill, batch * steps for decode)."""
    phase: str
    seconds: float
    batch: int
    tokens: int

    @property
    def tokens_per_s(self) -> float:
        return self.tokens / max(self.seconds, 1e-9)


class ServeSession:
    """One resident serving instance: model + mesh + params built once.

    ``prefill(batch)`` runs the prompt pass and retains the KV caches and
    last-step logits as session state; ``decode_step()`` appends one
    greedy token per sequence. ``generate(prompt, n)`` chains the two.
    """

    def __init__(self, arch: str = "smollm-360m", *, smoke: bool = False,
                 model_axis: int = 1, seed: int = 0) -> None:
        self.cfg = get_smoke(arch) if smoke else get_arch(arch)
        self.mesh = make_host_mesh(model_axis)
        self.parallel = ParallelConfig(remat="none", moe_impl="dense",
                                       shard_model_axes=model_axis > 1)
        self.model = Model(self.cfg, self.parallel,
                           make_rules(self.mesh, self.parallel))
        self._seed = seed
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self._prefill_fn = jax.jit(self.model.prefill)
        self._step_fn = jax.jit(make_serve_step(self.model))
        self._caches = None
        self._tok = None
        self._pos = 0

    def make_batch(self, batch: int, prompt_len: int,
                   seed: int = 0) -> dict:
        """Random token batch shaped for this arch (stub frontends too)."""
        cfg = self.cfg
        rng = np.random.default_rng(seed)
        prompt = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                          (batch, prompt_len),
                                          dtype=np.int32))
        out = {"tokens": prompt}
        if cfg.frontend == "patch_stub":
            out["patches"] = jnp.zeros((batch, cfg.num_patches,
                                        cfg.d_model), jnp.float32)
        if cfg.frontend == "audio_stub":
            out["frames"] = jnp.zeros((batch, cfg.encoder_seq,
                                       cfg.d_model), jnp.float32)
        return out

    def prefill(self, batch: dict) -> ServeTimings:
        """Prompt pass; stores caches + first greedy token on the session."""
        tokens = batch["tokens"]
        t0 = time.time()
        logits, caches = self._prefill_fn(self.params, batch)
        logits.block_until_ready()
        dt = time.time() - t0
        self._caches = caches
        self._tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._pos = int(tokens.shape[1])
        return ServeTimings("prefill", dt, int(tokens.shape[0]),
                            int(tokens.shape[0] * tokens.shape[1]))

    def decode_step(self, n_steps: int = 1) -> tuple[jnp.ndarray,
                                                     ServeTimings]:
        """Greedy-decode ``n_steps`` tokens per sequence.

        Returns the generated tokens ``[batch, n_steps]`` and the phase
        timings. The session always holds one generated-but-unreturned
        token (prefill's argmax at first), so consecutive calls emit a
        contiguous, non-overlapping token stream."""
        if self._caches is None:
            raise RuntimeError("decode_step before prefill")
        tok = self._tok
        out = []
        t0 = time.time()
        for t in range(self._pos, self._pos + n_steps):
            out.append(tok)
            logits, self._caches = self._step_fn(self.params, self._caches,
                                                 tok, jnp.int32(t))
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        jax.block_until_ready(tok)
        dt = time.time() - t0
        self._pos += n_steps
        self._tok = tok
        gen = jnp.stack(out, axis=1)
        return gen, ServeTimings("decode", dt, int(tok.shape[0]),
                                 int(tok.shape[0] * n_steps))

    def generate(self, batch: dict, n_tokens: int
                 ) -> tuple[jnp.ndarray, ServeTimings, ServeTimings]:
        """Prefill then greedy-decode ``n_tokens``; returns
        (tokens ``[batch, n_tokens]``, prefill timings, decode timings)."""
        tp = self.prefill(batch)
        gen, td = self.decode_step(n_tokens)
        return gen, tp, td

    def restart(self) -> ServeTimings:
        """In-place restart: the recovery primitive the serving replay's
        transient-infra verdict models (``cluster/serve_replay.py``). All
        session state an instance failure would destroy — KV caches, the
        pending greedy token, the position cursor — is dropped and the
        parameters are re-initialized from the session seed; resident
        requests must re-enter through :meth:`prefill` (the replay's
        recompute pass). Returns the restart's wall-clock timings so
        dry-runs can calibrate the taxonomy's ``restart_overhead_min``."""
        self._caches = None
        self._tok = None
        self._pos = 0
        t0 = time.time()
        self.params = self.model.init(jax.random.PRNGKey(self._seed))
        jax.block_until_ready(self.params)
        dt = time.time() - t0
        return ServeTimings("restart", dt, 0, 0)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--restarts", type=int, default=0,
                    help="in-place restarts between generations (exercises "
                         "the fault-recovery primitive the serving replay "
                         "models for transient-infra verdicts)")
    args = ap.parse_args()

    sess = ServeSession(args.arch, smoke=args.smoke,
                        model_axis=args.model_axis)
    for i in range(args.restarts + 1):
        gen, tp, td = sess.generate(
            sess.make_batch(args.batch, args.prompt_len), args.gen)
        logger.info("prefill %.2fs; decode %d x %d tokens in %.2fs "
                    "(%.1f tok/s incl. first-step compile)",
                    tp.seconds, td.batch, args.gen, td.seconds,
                    td.tokens_per_s)
        if i < args.restarts:
            tr = sess.restart()
            logger.info("in-place restart %d/%d: %.2fs (KV + session state "
                        "dropped)", i + 1, args.restarts, tr.seconds)
    logger.info("sample generation: %s", np.asarray(gen[0][:16]))


if __name__ == "__main__":
    main()
