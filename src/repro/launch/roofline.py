"""Three-term roofline model from dry-run artifacts (§Roofline).

Target hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI per chip. The terms are seconds-per-step on the single-pod (256-chip)
mesh, derived from the *calibrated* per-device totals (scan bodies
extrapolated to full depth — launch/calibrate.py):

  compute    = HLO_FLOPs / peak_FLOPs
  memory     = HLO_bytes_accessed / HBM_bw   (upper bound: XLA counts every
               fusion's operand/result bytes; on-chip reuse isn't modeled)
  collective = collective_bytes / ICI_bw     (per-device parsed HLO traffic)

MODEL_FLOPS uses 6*N*D (train), 2*N*D (prefill), 2*N_active*B (decode) per
device; the ratio MODEL_FLOPS/HLO_FLOPs exposes remat/redundancy waste.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def active_params(cfg) -> tuple[int, int]:
    """(total_params, active_params) from the config (MoE-aware)."""
    from repro.models import Model
    from repro.models.spec import num_params
    model = Model(cfg)
    total = num_params(model.specs())
    if cfg.moe.num_experts == 0:
        return total, total
    # subtract the inactive routed-expert fraction per MoE layer
    from repro.models import moe as moe_lib
    expert_specs = moe_lib.moe_specs(cfg.d_model, cfg.moe, cfg.mlp_act)
    routed = num_params({k: v for k, v in expert_specs.items()
                         if k in ("w1", "w2", "w3")})
    n_moe_layers = sum(cfg.moe.is_moe_layer(i) for i in range(cfg.num_layers))
    inactive_frac = 1.0 - cfg.moe.top_k / cfg.moe.num_experts
    active = total - int(n_moe_layers * routed * inactive_frac)
    return total, active


def model_flops_per_device(cfg, kind: str, seq_len: int, global_batch: int,
                           n_devices: int) -> float:
    total, active = active_params(cfg)
    if kind == "train":
        return 6.0 * active * seq_len * global_batch / n_devices
    if kind == "prefill":
        return 2.0 * active * seq_len * global_batch / n_devices
    return 2.0 * active * global_batch / n_devices      # decode: 1 token


@dataclasses.dataclass
class CellRoofline:
    arch: str
    shape: str
    kind: str
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    hlo_flops: float
    model_flops: float
    useful_ratio: float          # MODEL/HLO
    roofline_frac: float         # compute_s / max(term)
    mem_args_gib: float
    mem_temp_gib: float
    collective_bytes: float

    def row(self) -> str:
        return (f"{self.arch},{self.shape},{self.kind},"
                f"{self.compute_s:.4e},{self.memory_s:.4e},"
                f"{self.collective_s:.4e},{self.dominant},"
                f"{self.useful_ratio:.3f},{self.roofline_frac:.3f},"
                f"{self.mem_args_gib:.2f},{self.mem_temp_gib:.2f}")


def cell_roofline(rec: dict, cfg=None) -> Optional[CellRoofline]:
    if rec.get("status") != "ok":
        return None
    cal = rec.get("calibrated") or {}
    flops = cal.get("flops") or rec.get("cost", {}).get("flops", 0.0)
    byts = cal.get("bytes_accessed") or rec.get("cost", {}).get(
        "bytes_accessed", 0.0)
    coll = (cal.get("coll_total")
            if cal.get("coll_total") is not None
            else rec.get("collectives", {}).get("total_bytes_per_device",
                                                0.0))
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    coll_s = coll / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    if cfg is None:
        from repro.config import get_arch
        cfg = get_arch(rec["arch"])
    mf = model_flops_per_device(cfg, rec["kind"], rec["seq_len"],
                                rec["global_batch"], rec["n_devices"])
    mem = rec.get("memory", {})
    return CellRoofline(
        arch=rec["arch"], shape=rec["shape"], kind=rec["kind"],
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        dominant=dominant, hlo_flops=flops, model_flops=mf,
        useful_ratio=mf / flops if flops else 0.0,
        roofline_frac=compute_s / max(max(terms.values()), 1e-30),
        mem_args_gib=mem.get("argument_size_in_bytes", 0.0) / 2 ** 30,
        mem_temp_gib=mem.get("temp_size_in_bytes", 0.0) / 2 ** 30,
        collective_bytes=coll)


def load_cells(art_dir: str = "artifacts/dryrun/single") -> list[dict]:
    out = []
    if not os.path.isdir(art_dir):
        return out
    for arch in sorted(os.listdir(art_dir)):
        d = os.path.join(art_dir, arch)
        for f in sorted(os.listdir(d)):
            if f.endswith(".json"):
                with open(os.path.join(d, f)) as fh:
                    out.append(json.load(fh))
    return out


def full_table(art_dir: str = "artifacts/dryrun/single") -> list[CellRoofline]:
    rows = []
    for rec in load_cells(art_dir):
        r = cell_roofline(rec)
        if r is not None:
            rows.append(r)
    return rows


HEADER = ("arch,shape,kind,compute_s,memory_s,collective_s,dominant,"
          "useful_ratio,roofline_frac,args_gib,temp_gib")
