"""Three-term roofline model from dry-run artifacts (§Roofline).

Target hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI per chip. The terms are seconds-per-step on the single-pod (256-chip)
mesh, derived from the *calibrated* per-device totals (scan bodies
extrapolated to full depth — launch/calibrate.py):

  compute    = HLO_FLOPs / peak_FLOPs
  memory     = HLO_bytes_accessed / HBM_bw   (upper bound: XLA counts every
               fusion's operand/result bytes; on-chip reuse isn't modeled)
  collective = collective_bytes / ICI_bw     (per-device parsed HLO traffic)

MODEL_FLOPS uses 6*N*D (train), 2*N*D (prefill), 2*N_active*B (decode) per
device; the ratio MODEL_FLOPS/HLO_FLOPs exposes remat/redundancy waste.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def active_params(cfg) -> tuple[int, int]:
    """(total_params, active_params) from the config (MoE-aware)."""
    from repro.models import Model
    from repro.models.spec import num_params
    model = Model(cfg)
    total = num_params(model.specs())
    if cfg.moe.num_experts == 0:
        return total, total
    # subtract the inactive routed-expert fraction per MoE layer
    from repro.models import moe as moe_lib
    expert_specs = moe_lib.moe_specs(cfg.d_model, cfg.moe, cfg.mlp_act)
    routed = num_params({k: v for k, v in expert_specs.items()
                         if k in ("w1", "w2", "w3")})
    n_moe_layers = sum(cfg.moe.is_moe_layer(i) for i in range(cfg.num_layers))
    inactive_frac = 1.0 - cfg.moe.top_k / cfg.moe.num_experts
    active = total - int(n_moe_layers * routed * inactive_frac)
    return total, active


def model_flops_per_device(cfg, kind: str, seq_len: int, global_batch: int,
                           n_devices: int) -> float:
    total, active = active_params(cfg)
    if kind == "train":
        return 6.0 * active * seq_len * global_batch / n_devices
    if kind == "prefill":
        return 2.0 * active * seq_len * global_batch / n_devices
    return 2.0 * active * global_batch / n_devices      # decode: 1 token


@dataclasses.dataclass
class CellRoofline:
    arch: str
    shape: str
    kind: str
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    hlo_flops: float
    model_flops: float
    useful_ratio: float          # MODEL/HLO
    roofline_frac: float         # compute_s / max(term)
    mem_args_gib: float
    mem_temp_gib: float
    collective_bytes: float
    calibrated: bool = False     # record carried depth-extrapolated totals

    def row(self) -> str:
        return (f"{self.arch},{self.shape},{self.kind},"
                f"{self.compute_s:.4e},{self.memory_s:.4e},"
                f"{self.collective_s:.4e},{self.dominant},"
                f"{self.useful_ratio:.3f},{self.roofline_frac:.3f},"
                f"{self.mem_args_gib:.2f},{self.mem_temp_gib:.2f}")


def _count(skipped: Optional[dict], reason: str) -> None:
    if skipped is not None:
        skipped[reason] = skipped.get(reason, 0) + 1


def cell_roofline(rec: dict, cfg=None,
                  skipped: Optional[dict] = None) -> Optional[CellRoofline]:
    """Three-term roofline for one dry-run record, or ``None``.

    Partial or malformed records — a cell that failed to compile, a
    ``calibrated`` blob that is not a dict, missing/garbled identity or
    cost fields — are *skipped* (with a counted reason in ``skipped``)
    rather than raised on: one corrupt artifact must not take down a
    bench run or a replay that prices jobs off the table."""
    if not isinstance(rec, dict):
        _count(skipped, "not_a_record")
        return None
    if rec.get("status") != "ok":
        _count(skipped, f"status_{rec.get('status', 'missing')}")
        return None
    cal = rec.get("calibrated")
    if not isinstance(cal, dict):
        cal = {}
    cost = rec.get("cost")
    if not isinstance(cost, dict):
        cost = {}
    colls = rec.get("collectives")
    if not isinstance(colls, dict):
        colls = {}
    try:
        flops = float(cal.get("flops") or cost.get("flops", 0.0))
        byts = float(cal.get("bytes_accessed")
                     or cost.get("bytes_accessed", 0.0))
        coll = float(cal["coll_total"] if cal.get("coll_total") is not None
                     else colls.get("total_bytes_per_device", 0.0))
        arch, shape, kind = rec["arch"], rec["shape"], rec["kind"]
        seq_len = int(rec["seq_len"])
        global_batch = int(rec["global_batch"])
        n_devices = int(rec["n_devices"])
    except (KeyError, TypeError, ValueError):
        _count(skipped, "malformed_record")
        return None
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    coll_s = coll / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    try:
        if cfg is None:
            from repro.config import get_arch
            cfg = get_arch(arch)
        mf = model_flops_per_device(cfg, kind, seq_len, global_batch,
                                    n_devices)
    except (KeyError, ValueError, TypeError):
        _count(skipped, "unknown_arch")
        return None
    mem = rec.get("memory")
    if not isinstance(mem, dict):
        mem = {}
    return CellRoofline(
        arch=arch, shape=shape, kind=kind,
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        dominant=dominant, hlo_flops=flops, model_flops=mf,
        useful_ratio=mf / flops if flops else 0.0,
        roofline_frac=compute_s / max(max(terms.values()), 1e-30),
        mem_args_gib=mem.get("argument_size_in_bytes", 0.0) / 2 ** 30,
        mem_temp_gib=mem.get("temp_size_in_bytes", 0.0) / 2 ** 30,
        collective_bytes=coll,
        calibrated=bool(cal))


def load_cells(art_dir: str = "artifacts/dryrun/single",
               skipped: Optional[dict] = None) -> list[dict]:
    """Raw dry-run records under ``art_dir``. Truncated or unreadable
    JSON files are skipped (reason counted into ``skipped``), never
    raised — a partially written artifact tree must stay loadable."""
    out = []
    if not os.path.isdir(art_dir):
        return out
    for arch in sorted(os.listdir(art_dir)):
        d = os.path.join(art_dir, arch)
        if not os.path.isdir(d):
            continue
        for f in sorted(os.listdir(d)):
            if not f.endswith(".json"):
                continue
            try:
                with open(os.path.join(d, f)) as fh:
                    rec = json.load(fh)
            except (OSError, ValueError):
                _count(skipped, "unreadable_json")
                continue
            if not isinstance(rec, dict):
                _count(skipped, "not_a_record")
                continue
            out.append(rec)
    return out


def full_table(art_dir: str = "artifacts/dryrun/single",
               skipped: Optional[dict] = None) -> list[CellRoofline]:
    rows = []
    for rec in load_cells(art_dir, skipped=skipped):
        r = cell_roofline(rec, skipped=skipped)
        if r is not None:
            rows.append(r)
    return rows


HEADER = ("arch,shape,kind,compute_s,memory_s,collective_s,dominant,"
          "useful_ratio,roofline_frac,args_gib,temp_gib")
