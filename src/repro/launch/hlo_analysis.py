"""Compiled-HLO analysis: cost, memory, and collective-byte extraction.

This is the dry-run "profiler" (no real TPU): ``cost_analysis()`` gives
per-device HLO FLOPs and bytes accessed; collective bytes are NOT in
cost_analysis, so we parse the post-SPMD optimized HLO and sum the result
sizes of every collective op (shapes in partitioned HLO are per-device).

Per-op traffic model (ring algorithms, (n-1)/n ~ 1):
  all-gather          result bytes          (received per device)
  all-reduce          2x result bytes       (reduce-scatter + all-gather)
  reduce-scatter      result bytes x ~n     -> operand bytes ~ result*n; we
                      count result bytes * (group-1) when parseable else 1x
  all-to-all          result bytes
  collective-permute  result bytes
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

# numpy dtype name -> the HLO short name used in _DTYPE_BYTES
_NP_TO_HLO = {
    "float64": "f64", "float32": "f32", "float16": "f16",
    "bfloat16": "bf16", "float8_e4m3fn": "f8e4m3fn",
    "float8_e5m2": "f8e5m2", "int64": "s64", "uint64": "u64",
    "int32": "s32", "uint32": "u32", "int16": "s16", "uint16": "u16",
    "int8": "s8", "uint8": "u8", "bool": "pred", "complex64": "c64",
    "complex128": "c128",
}


def dtype_bytes(dtype) -> int:
    """Bytes per element for a numpy/jax dtype, priced off the same
    ``_DTYPE_BYTES`` table the HLO shape parser uses — so the static
    kernel analyzer (``repro.quality.pallas_cost``) and the HLO
    collective parser count bytes with one set of constants. Unknown
    dtypes fall back to numpy's ``itemsize``."""
    import numpy as np
    dt = np.dtype(dtype)
    short = _NP_TO_HLO.get(dt.name)
    if short is None:
        return int(dt.itemsize)
    return _DTYPE_BYTES[short]

_COLL = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?P<result>.*?)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(", re.M)
_SHAPE = re.compile(r"(?P<dt>[a-z]\d*[a-z]*\d*(?:e\dm\d\w*)?)\[(?P<dims>[\d,]*)\]")
_GROUPS = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(text: str) -> int:
    """Max tensor size among the dtype[dims] shapes in ``text``."""
    best = 0
    for m in _SHAPE.finditer(text):
        bs = _DTYPE_BYTES.get(m.group("dt"))
        if bs is None:
            continue
        n = 1
        dims = m.group("dims")
        if dims:
            for d in dims.split(","):
                n *= int(d)
        best = max(best, n * bs)
    return best


@dataclasses.dataclass(slots=True)
class CollectiveStats:
    counts: dict
    bytes_by_op: dict

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_op.values()))


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: dict[str, int] = {}
    bytes_by_op: dict[str, float] = {}
    for m in _COLL.finditer(hlo_text):
        op = m.group("op")
        if m.group(0).rstrip().endswith("-done("):
            continue  # count start/untagged once, not the -done half
        size = _shape_bytes(m.group("result"))
        mult = 2.0 if op == "all-reduce" else 1.0
        line_end = hlo_text.find("\n", m.end())
        line = hlo_text[m.start(): line_end if line_end > 0 else None]
        if op == "reduce-scatter":
            g = _GROUPS.search(line)
            if g:
                mult = max(len(g.group(1).split(",")) - 1, 1)
        counts[op] = counts.get(op, 0) + 1
        bytes_by_op[op] = bytes_by_op.get(op, 0.0) + size * mult
    return CollectiveStats(counts, bytes_by_op)


_IOTA_RG = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_EXPL_RG = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _group_crosses(line: str, boundary: int) -> Optional[bool]:
    """Does this collective's replica group span the pod boundary?"""
    m = _IOTA_RG.search(line)
    if m:
        import numpy as np
        ng, gs = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            ids = ids.transpose([int(p) for p in m.group(4).split(",")])
        rows = ids.reshape(ng, gs)
        side = rows < boundary
        return bool(np.any(side.any(axis=1) & (~side).any(axis=1)))
    m = _EXPL_RG.search(line)
    if m:
        ids = [int(x) for x in m.group(1).split(",")]
        return any(i < boundary for i in ids) and any(i >= boundary
                                                      for i in ids)
    return None


def classify_collectives(hlo_text: str, pod_boundary: int) -> dict:
    """Split per-device collective bytes into cross-pod (DCN) vs pod-local
    (ICI) traffic — the lens for the hierarchical-ZeRO comparison."""
    cross = intra = unknown = 0.0
    for m in _COLL.finditer(hlo_text):
        if m.group(0).rstrip().endswith("-done("):
            continue
        size = _shape_bytes(m.group("result"))
        mult = 2.0 if m.group("op") == "all-reduce" else 1.0
        line_end = hlo_text.find("\n", m.end())
        line = hlo_text[m.start(): line_end if line_end > 0 else None]
        c = _group_crosses(line, pod_boundary)
        if c is None:
            unknown += size * mult
        elif c:
            cross += size * mult
        else:
            intra += size * mult
    return {"cross_pod_bytes": cross, "pod_local_bytes": intra,
            "unknown_bytes": unknown}


def memory_stats(compiled) -> dict:
    out: dict[str, float] = {}
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return out
    if ma is None:
        return out
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    return out


def cost_stats(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    out = {}
    for k in ("flops", "bytes accessed", "transcendentals", "utilization"):
        if k in ca:
            out[k.replace(" ", "_")] = float(ca[k])
    # per-memory-space bytes when present
    for k, v in ca.items():
        if isinstance(k, str) and k.startswith("bytes accessed"):
            out[k.replace(" ", "_")] = float(v)
    return out


def analyze(compiled) -> dict:
    """Everything §Roofline needs from one compiled executable."""
    hlo = compiled.as_text()
    colls = parse_collectives(hlo)
    return {
        "cost": cost_stats(compiled),
        "memory": memory_stats(compiled),
        "collectives": {
            "counts": colls.counts,
            "bytes_by_op": colls.bytes_by_op,
            "total_bytes_per_device": colls.total_bytes,
        },
    }
