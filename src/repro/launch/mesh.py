"""Production mesh construction.

Kept as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
initialization, and smoke tests must keep seeing 1 device.

Target hardware: TPU v5e pods — 16x16 (256 chips) per pod; the multi-pod
mesh prepends a DCN "pod" axis (2 pods = 512 chips).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1) -> Mesh:
    """Tiny mesh over whatever devices exist (CPU tests / examples)."""
    n = len(jax.devices())
    data = max(n // model_axis, 1)
    return jax.make_mesh((data, model_axis), ("data", "model"))
