"""Sharding rule engine: logical axis names -> mesh PartitionSpecs.

Every parameter/activation dimension carries a *logical* axis name. A rule
table maps logical names to (tuples of) mesh axis names; ``best_effort_spec``
drops mesh axes whose size does not divide the dimension, mirroring what
production frameworks (MaxText, T5X) do, so e.g. smollm's 5 KV heads simply
stay replicated on a model=16 mesh instead of failing to lower.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ParallelConfig
from repro.utils import logger

# Mesh axis names used throughout.
POD, DATA, MODEL = "pod", "data", "model"


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    """Axes that carry the batch dimension (all non-model axes)."""
    return tuple(a for a in mesh.axis_names if a != MODEL)


def fsdp_axes(mesh: Mesh, parallel: ParallelConfig) -> tuple[str, ...]:
    """Axes over which ZeRO-3 shards parameters."""
    if parallel.zero == "zero3_hier":
        # Hierarchical ZeRO (paper §2.2 / InternEvo): bound the parameter
        # gather group to a pod -> shard over the pod-local data axis only,
        # keeping the all-gather on fast intra-pod links.
        return (DATA,)
    if parallel.zero == "zero3":
        if not parallel.shard_model_axes and MODEL in mesh.axis_names:
            # no tensor parallelism -> the model axis is free; fold it into
            # FSDP (2-D FSDP: params shard over every axis, batch too)
            return data_axes(mesh) + (MODEL,)
        return data_axes(mesh)
    return ()


@dataclasses.dataclass(frozen=True)
class Rules:
    """Logical-axis -> mesh-axes mapping for one (mesh, parallel) setting."""
    table: dict[str, tuple[str, ...]]
    mesh: Mesh

    def spec(self, axes: Sequence[Optional[str]]) -> P:
        """Best-effort PartitionSpec for a dim-name tuple."""
        used: set[str] = set()
        entries: list[Any] = []
        for name in axes:
            if name is None:
                entries.append(None)
                continue
            mesh_axes = tuple(a for a in self.table.get(name, ()) if a in self.mesh.axis_names)
            mesh_axes = tuple(a for a in mesh_axes if a not in used)
            entries.append(mesh_axes if mesh_axes else None)
            used.update(mesh_axes)
        return P(*entries)

    def shard_spec(self, shape: Sequence[int], axes: Sequence[Optional[str]]) -> P:
        """Like ``spec`` but drops mesh axes that don't divide the dim size."""
        assert len(shape) == len(axes), (shape, axes)
        used: set[str] = set()
        entries: list[Any] = []
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        for dim, name in zip(shape, axes):
            if name is None:
                entries.append(None)
                continue
            mesh_axes = tuple(a for a in self.table.get(name, ())
                              if a in self.mesh.axis_names and a not in used)
            keep: list[str] = []
            extent = 1
            for a in mesh_axes:
                if dim % (extent * sizes[a]) == 0:
                    keep.append(a)
                    extent *= sizes[a]
            entries.append(tuple(keep) if keep else None)
            used.update(keep)
        return P(*entries)

    def sharding(self, shape: Sequence[int], axes: Sequence[Optional[str]]) -> NamedSharding:
        return NamedSharding(self.mesh, self.shard_spec(shape, axes))


def make_rules(mesh: Mesh, parallel: ParallelConfig) -> Rules:
    """Build the rule table for a mesh + parallelism config.

    Logical axes:
      batch        activation batch                     -> all data axes
      seq          activation sequence (seq-parallel)   -> model axis
      embed        d_model dim of params (FSDP dim)     -> fsdp axes
      mlp          FFN hidden dim                       -> model (TP)
      heads        attention query heads                -> model (TP)
      kv_heads     attention KV heads                   -> model (TP, best-effort)
      vocab        embedding/output vocab               -> model (TP)
      experts      MoE expert dim                       -> model (EP)
      expert_mlp   per-expert hidden dim                -> model when EP off
      kv_seq       decode KV-cache sequence dim         -> data axes (cache spread)
      stacked      scanned-layer leading dim            -> never sharded
    """
    dax = data_axes(mesh)
    fax = fsdp_axes(mesh, parallel)
    model = (MODEL,) if parallel.shard_model_axes else ()
    # with TP off, the model axis carries extra data parallelism instead
    batch_axes = dax if parallel.shard_model_axes else dax + (
        (MODEL,) if MODEL in mesh.axis_names else ())
    table: dict[str, tuple[str, ...]] = {
        "batch": batch_axes,
        "seq": model if parallel.sequence_parallel else (),
        "embed": fax,
        "mlp": model,
        "heads": model,
        "kv_heads": model,
        "vocab": model,
        "experts": model if parallel.expert_parallel else (),
        "expert_mlp": () if parallel.expert_parallel else model,
        # decode KV caches: batch takes the data axes first (dim order);
        # the cache sequence dim then spreads over whatever remains — for
        # batched decode that's the model axis (flash-decode style seq
        # partitioning), for batch-1 long-context decode it's data+model.
        "kv_seq": dax + model,
        "stacked": (),
        "ssm_state": (),
        "ssm_heads": model,
        "ssm_inner": model,
    }
    return Rules(table=table, mesh=mesh)


# ---------------------------------------------------------------------------
# helpers for whole-pytree shardings
# ---------------------------------------------------------------------------

def tree_shardings(rules: Rules, spec_tree: Any) -> Any:
    """Map a tree of ParamSpec (shape+axes) to NamedShardings."""
    from repro.models.spec import ParamSpec  # local import to avoid cycle

    def _one(ps: ParamSpec) -> NamedSharding:
        return rules.sharding(ps.shape, ps.axes)

    return jax.tree_util.tree_map(_one, spec_tree,
                                  is_leaf=lambda x: isinstance(x, ParamSpec))


def constrain(x: jax.Array, rules: Rules, *axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axis names (best-effort)."""
    spec = rules.shard_spec(x.shape, axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


def log_sharding_summary(rules: Rules, spec_tree: Any, max_rows: int = 0) -> None:
    from repro.models.spec import ParamSpec
    from repro.utils import tree_flatten_with_paths
    rows = []
    for path, ps in tree_flatten_with_paths(spec_tree):
        if isinstance(ps, ParamSpec):
            rows.append((path, ps.shape, rules.shard_spec(ps.shape, ps.axes)))
    for path, shape, spec in (rows[:max_rows] if max_rows else rows):
        logger.info("%-60s %-24s %s", path, str(shape), spec)


def device_put_tree(tree: Any, shardings: Any) -> Any:
    return jax.tree_util.tree_map(lambda x, s: jax.device_put(x, s), tree, shardings)


def mesh_size_bytes_per_device(tree: Any, rules: Rules, spec_tree: Any) -> float:
    """Bytes/device for a tree of arrays under its shardings (analytic)."""
    from repro.models.spec import ParamSpec
    sizes = dict(zip(rules.mesh.axis_names, rules.mesh.devices.shape))
    total = 0.0
    flat_specs = jax.tree_util.tree_leaves(
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))
    for ps in flat_specs:
        spec = rules.shard_spec(ps.shape, ps.axes)
        denom = 1
        for entry in spec:
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for a in axes:
                denom *= sizes[a]
        total += int(np.prod(ps.shape)) * np.dtype(ps.dtype).itemsize / denom
    return total
