"""Deterministic, resumable data pipeline.

Every batch is a pure function of (seed, step); resuming a job at step N —
or *skipping* a bad range of batches after a loss-spike rollback (paper
§6.1: "opt to an earlier healthy checkpoint and bypass subsequent data
batches") — needs no iterator state beyond the step counter and a skip set.

The synthetic corpus is a Zipf-distributed token stream with injected
structure (periodic motifs) so small models can actually learn (loss drops),
giving the end-to-end example a real training signal.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    motif_len: int = 8
    motif_prob: float = 0.5


class SyntheticLM:
    """Deterministic synthetic LM dataset: batch(step) is pure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # token unigram distribution (Zipf over the real vocab)
        ranks = np.arange(1, cfg.vocab_size + 1)
        p = ranks ** (-cfg.zipf_a)
        self._p = p / p.sum()
        # a bank of motifs the model can learn to predict
        self._motifs = rng.integers(
            0, cfg.vocab_size, size=(64, cfg.motif_len)).astype(np.int32)

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S = cfg.global_batch, cfg.seq_len
        toks = rng.choice(cfg.vocab_size, size=(B, S + 1),
                          p=self._p).astype(np.int32)
        # paste motifs at random offsets: learnable structure
        n_paste = int(cfg.motif_prob * B * S / cfg.motif_len)
        if n_paste:
            rows = rng.integers(0, B, n_paste)
            cols = rng.integers(0, S + 1 - cfg.motif_len, n_paste)
            ids = rng.integers(0, len(self._motifs), n_paste)
            for r, c, i in zip(rows, cols, ids):
                toks[r, c:c + cfg.motif_len] = self._motifs[i]
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
            "weights": np.ones((B, S), np.float32),
        }


class DataLoader:
    """Stateful wrapper: step counter + skip set (for spike rollbacks).

    State is two integers and a list — trivially checkpointable.
    """

    def __init__(self, dataset: SyntheticLM, start_step: int = 0,
                 skip_ranges: Optional[list[tuple[int, int]]] = None):
        self.dataset = dataset
        self.step = start_step
        self.skip_ranges = list(skip_ranges or [])

    def _skipped(self, step: int) -> bool:
        return any(lo <= step < hi for lo, hi in self.skip_ranges)

    def next(self) -> tuple[int, dict]:
        while self._skipped(self.step):
            self.step += 1
        step = self.step
        self.step += 1
        return step, self.dataset.batch(step)

    def skip(self, lo: int, hi: int) -> None:
        """Mark data steps [lo, hi) as poisoned (loss-spike mitigation)."""
        self.skip_ranges.append((lo, hi))

    def state_dict(self) -> dict:
        return {"step": self.step, "skip_ranges": self.skip_ranges}

    def load_state_dict(self, d: dict) -> None:
        self.step = int(d["step"])
        self.skip_ranges = [tuple(x) for x in d["skip_ranges"]]
