"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracles,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.rmsnorm import rmsnorm, rmsnorm_ref
from repro.kernels.ssd import ssd, ssd_ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("B,Sq,Skv,H,KV,D", [
    (2, 64, 64, 4, 2, 32),
    (1, 96, 96, 8, 8, 16),
    (2, 33, 128, 4, 1, 64),     # ragged Sq, MQA
    (1, 128, 48, 6, 3, 24),     # ragged Skv
])
@pytest.mark.parametrize("causal,window,softcap", [
    (True, 0, 0.0), (True, 24, 0.0), (False, 0, 0.0), (True, 0, 30.0),
])
def test_flash_attention_matches_ref(B, Sq, Skv, H, KV, D, causal, window,
                                     softcap):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Skv, KV, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Skv, KV, D), jnp.float32)
    # causal: align q to the TAIL of kv when the prompt is longer, else
    # plain positions (q beyond kv attends to everything available)
    off = max(Skv - Sq, 0)
    qp = jnp.arange(off, off + Sq, dtype=jnp.int32)
    kp = jnp.arange(Skv, dtype=jnp.int32)
    out = flash_attention(q, k, v, qp, kp, causal=causal, window=window,
                          softcap=softcap, block_q=32, block_kv=32)
    ref = flash_attention_ref(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        jnp.broadcast_to(qp, (B, Sq)), jnp.broadcast_to(kp, (B, Skv)),
        causal=causal, window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.swapaxes(ref, 1, 2)),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 3e-2)])
def test_flash_attention_dtypes(dtype, tol):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 32), dtype)
    k = jax.random.normal(ks[1], (1, 64, 2, 32), dtype)
    v = jax.random.normal(ks[2], (1, 64, 2, 32), dtype)
    pos = jnp.arange(64, dtype=jnp.int32)
    out = flash_attention(q, k, v, pos, pos, block_q=32, block_kv=32)
    ref = flash_attention_ref(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                              jnp.swapaxes(v, 1, 2),
                              pos[None], pos[None])
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(jnp.swapaxes(ref, 1, 2), np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("shape", [(4, 17, 96), (2, 100), (3, 5, 7, 32)])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5), (jnp.bfloat16, 2e-2)])
def test_rmsnorm_matches_ref(shape, dtype, tol):
    x = jax.random.normal(KEY, shape, dtype)
    s = jax.random.normal(jax.random.fold_in(KEY, 1), shape[-1:], jnp.float32)
    out = rmsnorm(x, s)
    ref = rmsnorm_ref(x, s)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("B,L,H,P,G,N,chunk", [
    (2, 64, 4, 16, 2, 32, 16),
    (1, 100, 2, 8, 1, 16, 32),   # ragged L
    (2, 128, 8, 32, 8, 64, 64),  # G == H
])
def test_ssd_matches_ref(B, L, H, P, G, N, chunk):
    ks = jax.random.split(jax.random.fold_in(KEY, L), 5)
    x = jax.random.normal(ks[0], (B, L, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32) * 0.5)
    Bm = jax.random.normal(ks[3], (B, L, G, N), jnp.float32) * 0.5
    Cm = jax.random.normal(ks[4], (B, L, G, N), jnp.float32) * 0.5
    y, st = ssd(x, dt, A, Bm, Cm, chunk=chunk)
    yr, sr = ssd_ref(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(sr),
                               rtol=3e-4, atol=3e-4)


def test_ssd_head_blocked_equals_unblocked():
    from repro.models.mamba import ssd_chunked
    ks = jax.random.split(KEY, 5)
    B, L, H, P, G, N = 2, 64, 32, 4, 8, 8
    x = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, L, G, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, L, G, N)) * 0.5
    y0 = ssd_chunked(x, dt, A, Bm, Cm, chunk=16)
    for hb in (2, 4, 8, 16):
        y1 = ssd_chunked(x, dt, A, Bm, Cm, chunk=16, head_block=hb)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   rtol=1e-5, atol=1e-5)
