"""The summary-schema contract shared by ``ReplayResult.summary()`` and
``ServeReplayResult.summary()`` (README "Result schemas"):

  1. stable top-level keys — additive evolution only, so downstream
     notebooks/benches can index without defensive ``.get`` chains;
  2. plain-scalar leaves (int/float/str/bool/None) reachable through
     dicts and lists only — the tree must survive ``json.dumps`` without
     a custom encoder;
  3. side-effect-free repeated calls — mutating a returned tree must not
     leak into later calls, and every call returns an equal tree.
"""
import json

from repro.cluster import (KALOS, SERVING_TAXONOMY, FailureInjector,
                           ReplayConfig, ServeReplayConfig, generate_jobs,
                           generate_requests, replay_requests, replay_trace)
from repro.core.ft.diagnosis import VERDICT_HARDWARE, VERDICT_TRANSIENT
from repro.launch.cost_model import CostModel

REPLAY_TOP_KEYS = {
    "n_jobs", "events_processed", "queue_delay_quantiles", "restart_counts",
    "total_restarts", "total_lost_gpu_hours", "lost_gpu_hours_by_class",
    "lost_gpu_hours_by_jtype", "killed_jobs", "rejected_jobs",
    "cordon_events", "detection_probes", "recovery", "pool", "placement",
    "head_delay",
}

SERVE_TOP_KEYS = {
    "n_requests", "completed", "rejected", "events_processed",
    "stale_events", "horizon_min", "ttft", "tpot", "slo", "throughput",
    "batch", "kv", "fleet", "cost_model",
}

# the injected-replay-only "faults" section (README "Result schemas"):
# top-level scalar counters plus a per-class attribution tree
FAULTS_KEYS = {
    "injected", "retries", "drops", "shed", "hol_skips", "killed_tokens",
    "lost_goodput_tokens", "degraded_min", "respawns", "inplace_restarts",
    "cordoned_nodes", "by_class",
}
FAULTS_CLASS_KEYS = {
    "failures", "prefill", "decode", "retries", "drops", "shed",
    "killed_tokens", "lost_goodput_tokens", "slo_ttft_violations",
    "slo_tpot_violations", "downtime_min", "verdicts",
}

_SCALARS = (int, float, str, bool, type(None))


def _walk(path, node, problems):
    if isinstance(node, dict):
        for k, v in node.items():
            if not isinstance(k, (str, int)):
                problems.append(f"{path}: non-str/int key {k!r}")
            _walk(f"{path}.{k}", v, problems)
    elif isinstance(node, list):
        for i, v in enumerate(node):
            _walk(f"{path}[{i}]", v, problems)
    elif not isinstance(node, _SCALARS):
        problems.append(f"{path}: non-scalar leaf {type(node).__name__}")


def _replay_result():
    jobs = generate_jobs(KALOS, seed=2, n_jobs=3_000, best_effort_frac=0.2)
    return replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.97,
                        config=ReplayConfig(elastic=True, placement=True))


def _serve_result():
    reqs = generate_requests(3_000, seed=2, horizon_min=10.0)
    cfg = ServeReplayConfig(cost_model=CostModel.analytic(("internlm-7b",)))
    return replay_requests(reqs, cfg)


def _check_contract(result, expected_top):
    s = result.summary()
    assert set(s) >= expected_top, (
        f"missing top-level keys: {expected_top - set(s)}")
    problems: list = []
    _walk("summary", s, problems)
    assert not problems, "\n".join(problems)
    json.dumps(s)   # no custom encoder needed
    # repeated calls are side-effect-free: deep-mutate the first tree and
    # demand the second is pristine and equal to the original
    pristine = json.loads(json.dumps(s))
    _clobber(s)
    s2 = result.summary()
    assert json.loads(json.dumps(s2)) == pristine


def _clobber(node):
    if isinstance(node, dict):
        for k in list(node):
            _clobber(node[k])
            node[k] = "clobbered"
    elif isinstance(node, list):
        node.clear()


class _StubDiagnosis:
    def verdict(self, cls):
        return (VERDICT_HARDWARE if cls.needs_cordon
                else VERDICT_TRANSIENT), None, None


def _serve_faults_result():
    reqs = generate_requests(3_000, seed=2, horizon_min=10.0)
    cfg = ServeReplayConfig(
        cost_model=CostModel.analytic(("internlm-7b",)),
        injector=FailureInjector(SERVING_TAXONOMY, seed=1,
                                 rate_scale=3_000.0),
        diagnosis=_StubDiagnosis())
    return replay_requests(reqs, cfg)


def test_replay_summary_schema():
    _check_contract(_replay_result(), REPLAY_TOP_KEYS)


def test_serve_summary_schema():
    # the no-injection tree must NOT grow the faults section — it is
    # additive and injection-gated, so existing consumers see no change
    res = _serve_result()
    _check_contract(res, SERVE_TOP_KEYS)
    assert "faults" not in res.summary()


def test_serve_faults_summary_schema():
    """Injected replays grow exactly one additional top-level section,
    ``"faults"``, holding the per-class §5 attribution tree — same
    scalar-leaf contract as the rest of the summary."""
    res = _serve_faults_result()
    _check_contract(res, SERVE_TOP_KEYS | {"faults"})
    faults = res.summary()["faults"]
    assert set(faults) == FAULTS_KEYS
    assert faults["injected"] > 0
    for name, cls in faults["by_class"].items():
        assert isinstance(name, str)
        assert set(cls) == FAULTS_CLASS_KEYS
