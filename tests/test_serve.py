"""Prefill + decode must reproduce full-forward (teacher-forced) logits —
the strongest cache-correctness check, run per attention family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import (AttentionConfig, ModelConfig, MoEConfig,
                          ParallelConfig, SSMConfig)
from repro.models import Model


def _decode_parity(cfg: ModelConfig, atol: float = 1e-4):
    """prefill(prompt[:k]) + decode steps == forward(prompt) logits.

    Run in fp32: the full-forward (blockwise flash) and decode
    (cache-attention) paths are then numerically equivalent to ~1e-6;
    bf16 accumulation-order noise would need sloppy tolerances."""
    cfg = dataclasses.replace(cfg, dtype="float32")
    parallel = ParallelConfig(remat="none", moe_impl="dense",
                              decode_moe_impl="dense")
    model = Model(cfg, parallel)
    params = model.init(jax.random.PRNGKey(0))
    B, S, k = 2, 24, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size, dtype=jnp.int32)
    batch = {"tokens": toks}
    if cfg.frontend == "audio_stub":
        batch["frames"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.encoder_seq, cfg.d_model))
    full = model.forward_logits(params, batch)          # (B, S, V)
    pre_batch = dict(batch, tokens=toks[:, :k])
    logits, caches = model.prefill(params, pre_batch)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full[:, k - 1]),
                               rtol=atol, atol=atol)
    for t in range(k, S):
        logits, caches = model.decode_step(params, caches, toks[:, t],
                                           jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full[:, t]),
            rtol=atol, atol=atol,
            err_msg=f"{cfg.name}: decode step {t}")


def test_decode_parity_gqa(tiny_cfg):
    _decode_parity(tiny_cfg)


def test_decode_parity_swa(tiny_cfg):
    cfg = dataclasses.replace(
        tiny_cfg, name="swa",
        attention=dataclasses.replace(tiny_cfg.attention, sliding_window=8))
    _decode_parity(cfg)


def test_decode_parity_local_global(tiny_cfg):
    cfg = dataclasses.replace(
        tiny_cfg, name="lg", num_layers=4,
        attention=dataclasses.replace(tiny_cfg.attention, global_every=2,
                                      local_window=8))
    _decode_parity(cfg)


def test_decode_parity_mla():
    cfg = ModelConfig(
        name="mla", num_layers=2, d_model=64, d_ff=128, vocab_size=256,
        max_seq_len=128, vocab_pad_multiple=64,
        attention=AttentionConfig(kind="mla", num_heads=4, num_kv_heads=4,
                                  kv_lora_rank=32, qk_nope_dim=16,
                                  qk_rope_dim=8, v_head_dim=16))
    _decode_parity(cfg)


def test_decode_parity_ssm():
    cfg = ModelConfig(
        name="ssm", family="ssm", num_layers=2, d_model=64, d_ff=0,
        vocab_size=256, max_seq_len=128, vocab_pad_multiple=64,
        ssm=SSMConfig(state_dim=16, head_dim=16, n_groups=1, chunk_size=8))
    _decode_parity(cfg, atol=1e-3)


def test_decode_parity_hybrid_moe():
    cfg = ModelConfig(
        name="hy", family="hybrid", num_layers=4, d_model=64, d_ff=128,
        vocab_size=256, max_seq_len=128, vocab_pad_multiple=64,
        attn_every=4, attn_index=1,
        attention=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=16),
        ssm=SSMConfig(state_dim=16, head_dim=16, n_groups=1, chunk_size=8),
        moe=MoEConfig(num_experts=4, top_k=2, expert_ff=64, moe_every=2,
                      moe_offset=1))
    _decode_parity(cfg, atol=1e-3)


def test_decode_parity_encdec():
    cfg = ModelConfig(
        name="ed", family="audio", num_layers=2, d_model=64, d_ff=128,
        vocab_size=256, max_seq_len=64, vocab_pad_multiple=64,
        encoder_layers=2, encoder_seq=12, frontend="audio_stub",
        mlp_act="gelu",
        attention=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=16,
                                  use_rope=False))
    _decode_parity(cfg)


def test_ring_buffer_rolls_past_window(tiny_cfg):
    """Decoding far past the SWA window must equal the windowed forward."""
    cfg = dataclasses.replace(
        tiny_cfg, name="roll", max_seq_len=8, dtype="float32",
        attention=dataclasses.replace(tiny_cfg.attention, sliding_window=8))
    parallel = ParallelConfig(remat="none")
    model = Model(cfg, parallel)
    params = model.init(jax.random.PRNGKey(0))
    S = 24    # 3x the window/cache
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0,
                              cfg.vocab_size, dtype=jnp.int32)
    full = model.forward_logits(params, {"tokens": toks})
    logits, caches = model.prefill(params, {"tokens": toks[:, :8]})
    for t in range(8, S):
        logits, caches = model.decode_step(params, caches, toks[:, t],
                                           jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full[:, t]),
                                   rtol=1e-4, atol=1e-4)


def test_serve_session_stream_is_contiguous():
    """ServeSession emits a non-overlapping greedy token stream: two
    chained decode_step calls must equal one generate of the same total."""
    from repro.launch.serve import ServeSession

    sess = ServeSession("smollm-360m", smoke=True)
    batch = sess.make_batch(2, 8, seed=3)
    gen, tp, td = sess.generate(batch, 6)
    assert gen.shape == (2, 6)
    assert (tp.phase, tp.batch, tp.tokens) == ("prefill", 2, 16)
    assert (td.phase, td.batch, td.tokens) == ("decode", 2, 12)
    assert tp.seconds >= 0.0 and td.tokens_per_s > 0.0

    sess2 = ServeSession("smollm-360m", smoke=True)
    sess2.prefill(batch)
    a, _ = sess2.decode_step(2)
    b, _ = sess2.decode_step(4)
    chained = np.concatenate([np.asarray(a), np.asarray(b)], axis=1)
    np.testing.assert_array_equal(chained, np.asarray(gen))
