"""Per-architecture smoke tests: a reduced same-family config runs one
forward + one train step on CPU with finite outputs and correct shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro.config import (ParallelConfig, TrainConfig, get_arch, get_smoke,
                          list_archs)
from repro.models import Model
from repro.models.spec import num_params
from repro.train import make_train_step
from repro.train.optimizer import adamw_init

ARCHS = list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke(arch)
    model = Model(cfg, ParallelConfig(remat="none", moe_impl="dense"))
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = make_batch(cfg, B, S)
    logits = model.forward_logits(params, batch)
    exp_s = S + (cfg.num_patches if cfg.frontend == "patch_stub" else 0)
    assert logits.shape == (B, exp_s, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    step = jax.jit(make_train_step(model, TrainConfig(global_batch=B,
                                                      seq_len=S)))
    p2, opt2, metrics = step(params, adamw_init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))


@pytest.mark.parametrize("arch,expected_b", [
    ("gemma3-27b", 27.0), ("smollm-360m", 0.36), ("h2o-danube-1.8b", 1.83),
    ("nemotron-4-15b", 15.6), ("internvl2-2b", 1.9), ("mamba2-1.3b", 1.34),
    ("whisper-large-v3", 1.64), ("mixtral-8x22b", 140.6),
    ("deepseek-v2-lite-16b", 15.7), ("jamba-1.5-large-398b", 398.6),
    ("internlm-7b", 7.3), ("internlm-123b", 123.9),
])
def test_full_config_param_counts(arch, expected_b):
    """The full configs match published parameter counts (no allocation)."""
    n = num_params(Model(get_arch(arch)).specs()) / 1e9
    assert abs(n - expected_b) / expected_b < 0.06, f"{arch}: {n:.2f}B"


def test_segmentation_periods():
    """Layer-pattern segmentation matches each arch's published structure."""
    m = Model(get_arch("gemma3-27b"))
    assert [(len(s.pattern), s.repeat) for s in m.segments] == [(6, 10), (1, 2)]
    m = Model(get_arch("jamba-1.5-large-398b"))
    assert [(len(s.pattern), s.repeat) for s in m.segments] == [(8, 9)]
    m = Model(get_arch("deepseek-v2-lite-16b"))
    assert [(len(s.pattern), s.repeat) for s in m.segments] == [(1, 1), (1, 26)]
    plans = m.plans
    assert plans[0].mlp == "dense" and all(p.mlp == "moe" for p in plans[1:])
