"""Dry-run machinery: HLO collective parsing, depth-variant calibration,
mesh construction, and the cell plan (35 runnable of 40)."""
import pytest

from repro.config import get_arch, list_archs
from repro.configs import ASSIGNED
from repro.launch.calibrate import depth_variants, extrapolate
from repro.launch.hlo_analysis import parse_collectives, _shape_bytes
from repro.launch.shapes import LONG_CONTEXT_OK, SHAPES, cell_plan

HLO = """
ENTRY %main {
  %ag = bf16[8,512,2688]{2,1,0} all-gather(%x), replica_groups={{0,1,2,3}}
  %ar = f32[1024]{0} all-reduce(%y), to_apply=%sum
  %rs = f32[64,128]{1,0} reduce-scatter(%z), replica_groups={{0,1,2,3,4,5,6,7}}
  %a2a = bf16[16,640,8192]{2,1,0} all-to-all(%w)
  %cp = f32[32]{0} collective-permute(%v)
  %ags = (bf16[4,4]{1,0}, bf16[8,4]{1,0}) all-gather-start(%q)
  %agd = bf16[8,4]{1,0} all-gather-done(%ags)
}
"""


def test_parse_collectives_counts_and_bytes():
    st = parse_collectives(HLO)
    assert st.counts == {"all-gather": 2, "all-reduce": 1,
                         "reduce-scatter": 1, "all-to-all": 1,
                         "collective-permute": 1}
    ag = 8 * 512 * 2688 * 2 + 8 * 4 * 2          # incl. -start tuple result
    assert st.bytes_by_op["all-gather"] == ag
    assert st.bytes_by_op["all-reduce"] == 1024 * 4 * 2      # 2x model
    assert st.bytes_by_op["reduce-scatter"] == 64 * 128 * 4 * 7  # (group-1)x
    assert st.bytes_by_op["all-to-all"] == 16 * 640 * 8192 * 2


def test_shape_bytes_picks_largest():
    assert _shape_bytes("(f32[4,4], bf16[128,128])") == 128 * 128 * 2


@pytest.mark.parametrize("arch", list_archs())
def test_depth_variants_cover_total_depth(arch):
    cfg = get_arch(arch)
    dv = depth_variants(cfg)
    n1, n2 = dv.cfg_n1.num_layers, dv.cfg_n2.num_layers
    # extrapolating layer COUNT must land exactly on the full depth
    assert n1 + dv.k * (n2 - n1) == cfg.num_layers
    assert dv.cfg_n1.validate() is None  # still a valid config


def test_extrapolate_linear():
    out = extrapolate({"flops": 10.0, "x": 1.0}, {"flops": 14.0, "x": 2.0}, 5)
    assert out["flops"] == 30.0 and out["x"] == 6.0


def test_cell_plan_counts():
    """40 cells total; long_500k runs only for bounded-state archs -> 35."""
    run = skip = 0
    for arch in ASSIGNED:
        cfg = get_arch(arch)
        for shape, verdict in cell_plan(arch, cfg):
            if verdict == "run":
                run += 1
            else:
                skip += 1
                assert shape.name == "long_500k"
                assert arch not in LONG_CONTEXT_OK
    assert run + skip == 40
    assert run == 35 and skip == 5


def test_mesh_shapes():
    # constructing the production meshes requires 512 forced host devices;
    # here we only verify the requested geometry logic
    from repro.launch import mesh as mesh_mod
    import inspect
    src = inspect.getsource(mesh_mod.make_production_mesh)
    assert "(2, 16, 16)" in src and "(16, 16)" in src
    assert '("pod", "data", "model")' in src
