"""Training substrate: data determinism + skip semantics, microbatch
equivalence, optimizer behavior, gradient compression, loss decrease."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import make_batch
from repro.config import ParallelConfig, TrainConfig
from repro.data import DataConfig, DataLoader, SyntheticLM
from repro.models import Model
from repro.train import make_train_step
from repro.train.optimizer import (adamw_init, adamw_update,
                                   clip_by_global_norm, compress_grads,
                                   compressor_init, global_norm)


# --- data --------------------------------------------------------------------

def _dataset():
    return SyntheticLM(DataConfig(vocab_size=128, seq_len=16, global_batch=2))


def test_data_is_pure_function_of_step():
    ds = _dataset()
    a, b = ds.batch(7), ds.batch(7)
    assert np.array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(ds.batch(7)["tokens"], ds.batch(8)["tokens"])


@settings(max_examples=30, deadline=None)
@given(lo=st.integers(0, 30), width=st.integers(1, 10))
def test_loader_skip_ranges(lo, width):
    loader = DataLoader(_dataset())
    loader.skip(lo, lo + width)
    steps = [loader.next()[0] for _ in range(40)]
    assert all(not (lo <= s < lo + width) for s in steps)
    assert steps == sorted(steps)


def test_loader_state_roundtrip():
    loader = DataLoader(_dataset())
    loader.skip(3, 5)
    for _ in range(4):
        loader.next()
    clone = DataLoader(_dataset())
    clone.load_state_dict(loader.state_dict())
    assert clone.next()[0] == loader.next()[0]


# --- optimizer ---------------------------------------------------------------

def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert np.isclose(float(norm), 10.0)
    assert np.isclose(float(global_norm(clipped)), 1.0, atol=1e-5)


def test_adamw_moves_params_toward_grad():
    params = {"w": jnp.ones((8,))}
    grads = {"w": jnp.ones((8,))}
    state = adamw_init(params)
    cfg = TrainConfig(learning_rate=0.1, warmup_steps=0, total_steps=10,
                      weight_decay=0.0)
    p2, state2, _ = adamw_update(grads, state, params, cfg)
    assert float(p2["w"][0]) < 1.0
    assert int(state2.step) == 1


def test_gradient_compression_error_feedback():
    """int8 EF compression: single-shot error shrinks over repeated rounds
    of the SAME gradient (error feedback re-injects the residual)."""
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal(256), jnp.float32)}
    state = compressor_init(g)
    total = jnp.zeros_like(g["w"])
    for _ in range(16):
        deq, state = compress_grads(g, state)
        total = total + deq["w"]
    avg = total / 16
    err = float(jnp.abs(avg - g["w"]).max())
    one, _ = compress_grads(g, compressor_init(g))
    err_one = float(jnp.abs(one["w"] - g["w"]).max())
    assert err < err_one / 2      # EF averages out the quantization bias


# --- train step --------------------------------------------------------------

def test_microbatch_accumulation_matches_full_batch(tiny_cfg):
    import dataclasses
    cfg = dataclasses.replace(tiny_cfg, dtype="float32")
    model = Model(cfg, ParallelConfig(remat="none"))
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 4, 16)
    opt = adamw_init(params)
    t1 = TrainConfig(global_batch=4, seq_len=16, microbatches=1)
    t2 = TrainConfig(global_batch=4, seq_len=16, microbatches=2)
    p1, _, m1 = jax.jit(make_train_step(model, t1))(params, opt, batch)
    p2, _, m2 = jax.jit(make_train_step(model, t2))(params, opt, batch)
    # same data, same update (averaged grads) up to accumulation-order noise
    flat1 = jax.tree_util.tree_leaves(p1)
    flat2 = jax.tree_util.tree_leaves(p2)
    for a, b in zip(flat1, flat2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


def test_loss_decreases_on_learnable_data(tiny_cfg):
    model = Model(tiny_cfg, ParallelConfig(remat="none"))
    params = model.init(jax.random.PRNGKey(0))
    ds = SyntheticLM(DataConfig(vocab_size=tiny_cfg.vocab_size, seq_len=32,
                                global_batch=4, motif_prob=0.8))
    tcfg = TrainConfig(global_batch=4, seq_len=32, learning_rate=3e-3,
                       warmup_steps=5, total_steps=60)
    step = jax.jit(make_train_step(model, tcfg))
    opt = adamw_init(params)
    losses = []
    for s in range(45):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(s).items()}
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.25, losses[::10]
