import jax
import jax.numpy as jnp
import pytest

# Hermetic offline runs: several test modules property-test with
# ``hypothesis``; when the real package is missing, install the
# deterministic shim (see repro.utils.hypothesis_shim for the policy)
# BEFORE those modules are collected.
try:
    import hypothesis  # noqa: F401
except ImportError:
    from repro.utils.hypothesis_shim import install as _install_hyp_shim
    _install_hyp_shim()

from repro.config import AttentionConfig, ModelConfig, ParallelConfig


@pytest.fixture(scope="session")
def tiny_cfg() -> ModelConfig:
    return ModelConfig(
        name="tiny", num_layers=2, d_model=64, d_ff=128, vocab_size=256,
        max_seq_len=128, vocab_pad_multiple=64,
        attention=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=16))


@pytest.fixture(scope="session")
def tiny_parallel() -> ParallelConfig:
    return ParallelConfig(remat="none", moe_impl="dense")


def make_batch(cfg: ModelConfig, batch: int, seq: int, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (batch, seq + 1), 0, cfg.vocab_size,
                              dtype=jnp.int32)
    b = {"tokens": toks[:, :-1], "labels": toks[:, 1:],
         "weights": jnp.ones((batch, seq), jnp.float32)}
    if cfg.frontend == "patch_stub":
        b["patches"] = 0.02 * jax.random.normal(
            jax.random.fold_in(key, 1),
            (batch, cfg.num_patches, cfg.d_model), jnp.float32)
    if cfg.frontend == "audio_stub":
        b["frames"] = 0.02 * jax.random.normal(
            jax.random.fold_in(key, 2),
            (batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return b
