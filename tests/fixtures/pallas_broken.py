"""Deliberately broken Pallas kernels for the static analyzers.

Each ``bad_*`` thunk makes exactly one ``pl.pallas_call`` violating exactly
one contract/resource rule the analyzers must flag (the code in the name's
comment); ``good_control`` is a correct call both must pass. RPL1xx
fixtures (``repro.quality.pallas_check``) are only ever traced under
``capture_pallas_calls()`` — their bodies never execute, so they are
minimal no-ops. RPL2xx fixtures (``repro.quality.pallas_cost``) have
their bodies *abstract-interpreted* through ``jax.make_jaxpr``, so each
body genuinely reads its input and writes its output (except the RPL204
fixture, whose dead ref is the point).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_X = (256, 256)          # operand shape shared by the fixtures


def _noop2(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def _call(in_spec, out_spec, grid, kernel=_noop2, scratch=()):
    x = jnp.zeros(_X, jnp.float32)
    return pl.pallas_call(
        kernel, grid=grid, in_specs=[in_spec], out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(_X, jnp.float32),
        scratch_shapes=list(scratch), interpret=True)(x)


def good_control():
    spec = pl.BlockSpec((128, 256), lambda i: (i, 0))
    _call(spec, spec, grid=(2,))


def bad_index_map_arity():     # RPL101: 2D grid, 1-arg index_map
    spec = pl.BlockSpec((128, 128), lambda i: (i, 0))
    good = pl.BlockSpec((128, 128), lambda i, j: (i, j))
    _call(spec, good, grid=(2, 2))


def bad_index_map_rank():      # RPL101: map yields 1 index for a 2D block
    spec = pl.BlockSpec((128, 256), lambda i: (i,))
    good = pl.BlockSpec((128, 256), lambda i: (i, 0))
    _call(spec, good, grid=(2,))


def bad_block_rank():          # RPL102: 1D block over a 2D operand
    spec = pl.BlockSpec((128,), lambda i: (i,))
    good = pl.BlockSpec((128, 256), lambda i: (i, 0))
    _call(spec, good, grid=(2,))


def bad_divisibility():        # RPL103: 100 does not divide 256
    spec = pl.BlockSpec((100, 256), lambda i: (i, 0))
    good = pl.BlockSpec((128, 256), lambda i: (i, 0))
    _call(spec, good, grid=(2,))


def bad_alignment():           # RPL104: trailing 32: not 1/128k/whole-dim
    spec = pl.BlockSpec((256, 32), lambda i: (0, i))
    good = pl.BlockSpec((256, 128), lambda i: (0, i))
    _call(spec, good, grid=(2,))


def bad_kernel_arity():        # RPL105: scratch wired but no scratch ref
    spec = pl.BlockSpec((128, 256), lambda i: (i, 0))
    _call(spec, spec, grid=(2,),
          scratch=[pltpu.VMEM((128, 128), jnp.float32)])


def bad_index_map_corner():    # RPL101: right rank at origin, wrong off it
    spec = pl.BlockSpec((128, 256),
                        lambda i: (i, 0) if i == 0 else (i,))
    good = pl.BlockSpec((128, 256), lambda i: (i, 0))
    _call(spec, good, grid=(2,))


def good_grid_spec():          # valid call through the grid_spec= bundle
    spec = pl.BlockSpec((128, 256), lambda i: (i, 0))
    x = jnp.zeros(_X, jnp.float32)
    pl.pallas_call(
        _noop2,
        grid_spec=pl.GridSpec(grid=(2,), in_specs=[spec], out_specs=spec),
        out_shape=jax.ShapeDtypeStruct(_X, jnp.float32),
        interpret=True)(x)


# --------------------------------------------------------------------------
# RPL2xx resource fixtures (pallas_cost) — bodies are abstract-interpreted
# --------------------------------------------------------------------------

def _copy2(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def bad_vmem_budget():         # RPL201: two 64 MiB whole-operand blocks
    big = (4096, 4096)
    spec = pl.BlockSpec(big, lambda i: (0, 0))
    pl.pallas_call(
        _copy2, grid=(1,), in_specs=[spec], out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(big, jnp.float32),
        interpret=True)(jnp.zeros(big, jnp.float32))


def bad_revisit():             # RPL202: input re-fetched across axis i
    spec = pl.BlockSpec((128, 128), lambda i, j: (j, 0))
    good = pl.BlockSpec((128, 128), lambda i, j: (i, j))
    _call(spec, good, grid=(2, 2), kernel=_copy2)


def bad_output_gap():          # RPL203: both steps write tile (0, 0)
    spec = pl.BlockSpec((128, 256), lambda i: (i, 0))
    gap = pl.BlockSpec((128, 256), lambda i: (0, 0))
    _call(spec, gap, grid=(2,), kernel=_copy2)


def bad_output_overlap():      # RPL203: output blocks in 2 runs each
    def body(x_ref, o_ref):
        o_ref[...] = jnp.full(o_ref.shape, jnp.sum(x_ref[...]),
                              o_ref.dtype)
    spec = pl.BlockSpec((128, 128), lambda i, j: (i, j))
    over = pl.BlockSpec((128, 256), lambda i, j: (j, 0))
    _call(spec, over, grid=(2, 2), kernel=body)


def bad_unused_ref():          # RPL204: x_ref wired but never touched
    def body(x_ref, o_ref):
        o_ref[...] = jnp.zeros(o_ref.shape, o_ref.dtype)
    spec = pl.BlockSpec((128, 256), lambda i: (i, 0))
    _call(spec, spec, grid=(2,), kernel=body)
