"""Deliberately broken Pallas kernels for ``repro.quality.pallas_check``.

Each ``bad_*`` thunk makes exactly one ``pl.pallas_call`` violating exactly
one contract the checker must flag (the code in the name's comment);
``good_control`` is a correct call the checker must pass. The thunks are
only ever traced under ``capture_pallas_calls()`` — the kernel bodies
never execute, so they are minimal no-ops.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_X = (256, 256)          # operand shape shared by the fixtures


def _noop2(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def _call(in_spec, out_spec, grid, kernel=_noop2, scratch=()):
    x = jnp.zeros(_X, jnp.float32)
    return pl.pallas_call(
        kernel, grid=grid, in_specs=[in_spec], out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(_X, jnp.float32),
        scratch_shapes=list(scratch), interpret=True)(x)


def good_control():
    spec = pl.BlockSpec((128, 256), lambda i: (i, 0))
    _call(spec, spec, grid=(2,))


def bad_index_map_arity():     # RPL101: 2D grid, 1-arg index_map
    spec = pl.BlockSpec((128, 128), lambda i: (i, 0))
    good = pl.BlockSpec((128, 128), lambda i, j: (i, j))
    _call(spec, good, grid=(2, 2))


def bad_index_map_rank():      # RPL101: map yields 1 index for a 2D block
    spec = pl.BlockSpec((128, 256), lambda i: (i,))
    good = pl.BlockSpec((128, 256), lambda i: (i, 0))
    _call(spec, good, grid=(2,))


def bad_block_rank():          # RPL102: 1D block over a 2D operand
    spec = pl.BlockSpec((128,), lambda i: (i,))
    good = pl.BlockSpec((128, 256), lambda i: (i, 0))
    _call(spec, good, grid=(2,))


def bad_divisibility():        # RPL103: 100 does not divide 256
    spec = pl.BlockSpec((100, 256), lambda i: (i, 0))
    good = pl.BlockSpec((128, 256), lambda i: (i, 0))
    _call(spec, good, grid=(2,))


def bad_alignment():           # RPL104: trailing 32: not 1/128k/whole-dim
    spec = pl.BlockSpec((256, 32), lambda i: (0, i))
    good = pl.BlockSpec((256, 128), lambda i: (0, i))
    _call(spec, good, grid=(2,))


def bad_kernel_arity():        # RPL105: scratch wired but no scratch ref
    spec = pl.BlockSpec((128, 256), lambda i: (i, 0))
    _call(spec, spec, grid=(2,),
          scratch=[pltpu.VMEM((128, 128), jnp.float32)])
