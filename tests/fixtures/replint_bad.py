"""Known-bad corpus for ``replint`` (never imported — linted by path).

``tests/test_replint.py::test_bad_corpus_fails_cli`` runs the CLI over
this file with a fake engine path and asserts a non-zero exit plus one
finding per EXPECT comment. CI's lint job does NOT lint ``tests/``, so
this corpus cannot trip the build it exists to protect.
"""
import heapq
import random
import time

import numpy as np


def unseeded_draws(xs):
    a = random.random()                       # EXPECT RPL001
    random.shuffle(xs)                        # EXPECT RPL001
    b = np.random.randint(0, 5)               # EXPECT RPL001
    rng = random.Random()                     # EXPECT RPL001
    gen = np.random.default_rng()             # EXPECT RPL001
    return a, b, rng, gen


def order_leaks(h, ys):
    for x in {1, 2, 3}:                       # EXPECT RPL002
        pass
    xs = list(set(ys))                        # EXPECT RPL002
    heapq.heappush(h, (0.0, frozenset(ys)))   # EXPECT RPL002
    return xs


def wall_clock_ordering(events):
    t = time.perf_counter()                   # EXPECT RPL003
    events.sort(key=lambda e: id(e))          # EXPECT RPL003
    print("tick", t)                          # EXPECT RPL004
    return events


class EventRecord:                            # EXPECT RPL005
    def __init__(self, t):
        self.t = t


def suppressed_is_not_counted():
    return random.random()  # replint: disable=RPL001
