"""Tests for ``repro.quality.pallas_cost`` — the static resource analyzer
must derive hand-checkable costs for the shipped kernels, pass all three
clean, flag every RPL2xx fixture with exactly its code, and agree with
``CostModel``'s analytic kernel constant within the stated slack.

Everything runs on CPU: kernel bodies are abstract-interpreted through
``jax.make_jaxpr``; nothing is lowered or executed.
"""
import json
import sys
from pathlib import Path

import pytest

jax = pytest.importorskip("jax")

from repro.quality import pallas_cost as pcost  # noqa: E402
from repro.quality.pallas_check import capture_pallas_calls  # noqa: E402

FIXTURES = Path(__file__).resolve().parent / "fixtures"
REPO = Path(__file__).resolve().parent.parent


def _fixtures():
    if str(FIXTURES) not in sys.path:
        sys.path.insert(0, str(FIXTURES))
    import pallas_broken
    return pallas_broken


def _flash_cost():
    costs, findings = pcost.analyze_traced(
        pcost.KERNEL_CASES[0].trace, "flash",
        streaming=pcost._streaming_for(pcost.KERNEL_CASES[0].module),
        label="trace")
    assert findings == []
    (cost,) = costs
    return cost


# ---------------------------------------------------------------------------
# golden static cost table: flash_attention at the pallas_check trace shape
# (B, H, KV, S, D) = (1, 4, 2, 256, 128), block_q = block_kv = 128
# ---------------------------------------------------------------------------

def test_flash_golden_hbm_bytes_exact():
    # hand-computed, walking the (1, 4, 2, 2) grid innermost-fastest:
    #   q_pos  (1,128) i32, map (b,iq):   8 fetches x    512 B =     4096
    #   kv_pos (1,128) i32, map (b,ik):  16 fetches x    512 B =     8192
    #   q  (1,1,128,128) f32, (b,h,iq):   8 fetches x  65536 B =   524288
    #   k  (1,1,128,128) f32, streamed:  16 fetches x  65536 B =  1048576
    #   v  same as k:                    16 fetches x  65536 B =  1048576
    #   o  (1,1,128,128) f32:             8 runs    x  65536 B =   524288
    cost = _flash_cost()
    assert cost["hbm_bytes"] == 3_158_016
    fetches = {o["name"]: o["fetches"] for o in cost["operands"]}
    assert fetches == {"in[0]": 8, "in[1]": 16, "in[2]": 8,
                       "in[3]": 16, "in[4]": 16, "out[0]": 8}


def test_flash_golden_flops_within_tolerance():
    # the two MXU matmuls dominate: qk^T and pv are each
    # 2*128*128*128 = 4,194,304 flops/step -> 8,388,608/step. The static
    # count adds elementwise/softmax work and charges @pl.when bodies on
    # every step (documented upper bound), so it must land within 5%
    # above the matmul floor — never below it.
    cost = _flash_cost()
    dot_floor = 2 * (2 * 128 * 128 * 128)
    assert dot_floor <= cost["flops_per_step"] <= dot_floor * 1.05
    assert cost["flops"] == cost["flops_per_step"] * 16
    assert cost["steps"] == 16


def test_flash_golden_vmem_exact():
    # 2x double-buffered blocks (2x512 + 4x65536 in + 65536 out)
    # + 3 scratch buffers (m, l: (128,128) f32; acc: (128,128) f32)
    cost = _flash_cost()
    blocks = 2 * (512 + 512 + 4 * 65536)   # qp + kp + (q, k, v, o)
    scratch = 3 * 65536                    # m, l, acc — single-instance
    assert cost["vmem_bytes"] == blocks + scratch == 722_944


def test_flash_transcendentals_counted():
    # softcap tanh + online-softmax exps: transcendental work must be
    # visible (it is what distinguishes this body from a pure matmul)
    cost = _flash_cost()
    assert cost["transcendentals_per_step"] > 0


def test_flash_is_memory_bound_at_trace_shape():
    cost = _flash_cost()
    assert cost["bound"] == "memory"
    assert 40 < cost["arithmetic_intensity"] < 50
    assert 0 < cost["roofline_frac"] < 1


# ---------------------------------------------------------------------------
# the full shipped table
# ---------------------------------------------------------------------------

def test_shipped_kernels_are_clean():
    costs, findings = pcost.analyze_shipped()
    assert findings == [], [f"{f.path}: {f.code} {f.message}"
                            for f in findings]
    assert len(costs) == len(pcost.KERNEL_CASES)


def test_rmsnorm_intensity_is_memory_bound_constant():
    # rmsnorm moves every element twice (read + write) for ~2 flops/elem:
    # intensity ~0.5 regardless of shape — the memory-bound floor of the
    # envelope
    costs, _ = pcost.analyze_shipped()
    rms = [c for c in costs if "rmsnorm" in c["kernel"]]
    assert len(rms) == 2
    for c in rms:
        assert 0.3 < c["arithmetic_intensity"] < 0.8
        assert c["bound"] == "memory"


def test_every_shipped_row_fits_vmem():
    costs, _ = pcost.analyze_shipped()
    for c in costs:
        assert c["vmem_bytes"] <= pcost.VMEM_BUDGET_BYTES, c["shape"]


# ---------------------------------------------------------------------------
# RPL2xx fixtures flag exactly their codes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,code", [
    ("bad_vmem_budget", "RPL201"),
    ("bad_revisit", "RPL202"),
    ("bad_output_gap", "RPL203"),
    ("bad_output_overlap", "RPL203"),
    ("bad_unused_ref", "RPL204"),
])
def test_broken_fixture_flags_exactly_its_code(name, code):
    mod = _fixtures()
    _, findings = pcost.analyze_traced(getattr(mod, name), name)
    assert sorted(f.code for f in findings) == [code]


def test_good_fixtures_are_cost_clean():
    mod = _fixtures()
    for name in ("good_control", "good_grid_spec"):
        costs, findings = pcost.analyze_traced(getattr(mod, name), name)
        assert findings == [], name
        assert len(costs) == 1


def test_unused_ref_finding_names_the_ref():
    mod = _fixtures()
    _, findings = pcost.analyze_traced(mod.bad_unused_ref, "f")
    (f,) = findings
    assert "in[0]" in f.message


def test_contract_violation_short_circuits_costs():
    # a malformed spec (RPL1xx) must not produce a cost row — resource
    # numbers derived from a broken contract would be noise
    mod = _fixtures()
    costs, findings = pcost.analyze_traced(mod.bad_divisibility, "f")
    assert costs == []
    assert any(f.code == "RPL103" for f in findings)


def test_streaming_allowance_suppresses_rpl202():
    mod = _fixtures()
    _, findings = pcost.analyze_traced(
        mod.bad_revisit, "f", streaming={0: "declared for the test"})
    assert findings == []


# ---------------------------------------------------------------------------
# the abstract interpreter
# ---------------------------------------------------------------------------

def test_refbox_counts_reads_and_writes():
    mod = _fixtures()
    with capture_pallas_calls() as stub:
        mod.good_control()
    (call,) = stub.calls
    _, refs = pcost.trace_body(call)
    assert [r.name for r in refs] == ["in[0]", "out[0]"]
    assert refs[0].reads == 1 and refs[0].writes == 0
    assert refs[1].reads == 0 and refs[1].writes == 1


def test_trace_body_handles_pl_when_and_program_id():
    # the flash body uses both; tracing must succeed and touch every ref
    with capture_pallas_calls() as stub:
        pcost.KERNEL_CASES[0].trace()
    (call,) = stub.calls
    _, refs = pcost.trace_body(call)
    assert len(refs) == 9            # 5 in + 1 out + 3 scratch
    for r in refs:
        assert r.reads + r.writes > 0, r.name


def test_jaxpr_flops_dot_general():
    import jax.numpy as jnp

    def f(a, b):
        return a @ b

    jaxpr = jax.make_jaxpr(f)(jnp.zeros((8, 16)), jnp.zeros((16, 4)))
    flops, transc = pcost.jaxpr_flops(jaxpr.jaxpr)
    assert flops == 2 * 8 * 16 * 4
    assert transc == 0


def test_jaxpr_flops_bool_ops_are_free():
    import jax.numpy as jnp

    def f(a, b):
        return jnp.where(a > b, a, b)

    jaxpr = jax.make_jaxpr(f)(jnp.zeros((32,)), jnp.zeros((32,)))
    flops, _ = pcost.jaxpr_flops(jaxpr.jaxpr)
    # the comparison is free; only select_n pays
    assert flops == 32


# ---------------------------------------------------------------------------
# cost-model cross-check + verdict + committed report agreement
# ---------------------------------------------------------------------------

def test_cost_model_crosscheck_holds():
    costs, _ = pcost.analyze_shipped()
    check = pcost.crosscheck_cost_model(costs)
    assert check["ok"], check
    lo, hi = check["envelope"]
    assert lo <= check["analytic_flops_per_byte"] <= hi


def test_cost_model_crosscheck_fails_outside_envelope():
    fake = [{"kernel": "k", "shape": "s", "arithmetic_intensity": 100.0},
            {"kernel": "k", "shape": "t", "arithmetic_intensity": 200.0}]
    assert not pcost.crosscheck_cost_model(fake)["ok"]
    assert not pcost.crosscheck_cost_model([])["ok"]


def test_verdict_is_clean():
    v = pcost.verdict()
    assert v["clean"] and v["cost_model_ok"]
    assert v["n_findings"] == 0
    assert v["n_cost_rows"] == len(pcost.KERNEL_CASES)


def test_committed_report_matches_fresh_analysis():
    # the committed artifact is documentation (README renders it); it must
    # not drift from what the analyzer derives at head
    path = REPO / "artifacts" / "lint" / "pallas_cost.json"
    committed = json.loads(path.read_text())
    assert committed["clean"] is True
    costs, _ = pcost.analyze_shipped()
    fresh = json.loads(json.dumps(costs))    # normalize tuples/ints
    committed_rows = {(c["kernel"], c["shape"]):
                      (c["flops"], c["hbm_bytes"], c["vmem_bytes"])
                      for c in committed["cost_table"]}
    fresh_rows = {(c["kernel"], c["shape"]):
                  (c["flops"], c["hbm_bytes"], c["vmem_bytes"])
                  for c in fresh}
    assert committed_rows == fresh_rows
