"""Serving replay: determinism, admission/KV conservation, eviction
accounting, and fault-tolerant serving (§5 taxonomy injection). The
conservation laws here are the engine's ground truth — every decode token
is produced exactly once, every evicted *or failure-killed* KV token is
recomputed through the prefill fleet
(``evicted + killed == recomputed``), and the conservative page bound
never exceeds capacity."""
import json
import math

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.cluster import (SERVING_TAXONOMY, FailureInjector,
                           ServeReplayConfig, generate_requests,
                           replay_requests)
from repro.core.ft.diagnosis import VERDICT_HARDWARE, VERDICT_TRANSIENT
from repro.launch.cost_model import ServeRates


class _StubCostModel:
    """Duck-typed cost model: fast fixed rates, no artifact loading."""

    def serve_rates(self, arch, gpus):
        return ServeRates(arch=arch, gpus=gpus, prefill_tok_s=50_000.0,
                          decode_fixed_s=0.05, decode_per_seq_s=0.002,
                          source="stub/stub")


class _StubDiagnosis:
    """Duck-typed DiagnosisLoop: ground-truth verdicts, no pipeline cost."""

    def verdict(self, cls):
        v = VERDICT_HARDWARE if cls.needs_cordon else VERDICT_TRANSIENT
        return v, None, None


def _cfg(**kw):
    kw.setdefault("cost_model", _StubCostModel())
    if kw.get("injector") is not None:
        kw.setdefault("diagnosis", _StubDiagnosis())
    return ServeReplayConfig(**kw)


def _check_conservation(reqs, res, cfg):
    """The invariants every serving replay must satisfy, any config —
    fault injection included (the no-fault run is the special case with
    empty dropped/shed sets and ``killed_tokens == 0``)."""
    rejected = set(res.rejected_ids)
    dropped = set(res.dropped_ids)
    shed = set(res.shed_ids)
    gone = rejected | dropped | shed
    assert len(gone) == len(rejected) + len(dropped) + len(shed)
    finished = [r for r in reqs if r.req_id not in gone]
    # every request is finished, rejected, dropped, or shed — nothing lost
    assert res.completed == len(finished)
    for r in finished:
        assert math.isfinite(r.done_min) and math.isfinite(r.ttft_min)
        assert 0.0 <= r.ttft_min <= r.done_min + 1e-9
        assert r.decoded == r.out_tokens - 1
    for r in reqs:
        if r.req_id in gone:
            assert not math.isfinite(r.done_min)
        if r.req_id in dropped:
            # only a spent retry budget may drop a request
            assert r.retries == cfg.retry_budget
        assert r.retries <= cfg.retry_budget
    # token conservation: decode side produces each token exactly once
    # (dropped requests keep the partial progress they streamed out)...
    assert res.decoded_tokens == sum(r.decoded for r in reqs)
    # ...and every evicted or failure-killed KV token is recomputed
    # through the prefill fleet — the extended conservation law
    assert (res.evicted_tokens + res.killed_tokens
            == res.recompute_prefill_tokens)
    # prefill side: one prompt pass per request that entered the fleet
    # (shed/rejected never prefill), plus the recompute traffic
    started = [r for r in reqs
               if r.req_id not in rejected and r.req_id not in shed]
    assert res.prefill_tokens == (sum(r.prompt_tokens for r in started)
                                  + res.recompute_prefill_tokens)
    # conservative page bound stays within capacity (up to float round-off
    # at the eviction-crossing instant)
    assert res.kv_peak_pages <= cfg.kv_pages + 1e-6
    assert res.peak_batch <= cfg.max_batch
    assert sum(r.evictions for r in reqs) == res.evictions


def test_replay_is_bit_deterministic():
    reqs_a = generate_requests(5_000, seed=7, horizon_min=20.0)
    reqs_b = generate_requests(5_000, seed=7, horizon_min=20.0)
    sa = replay_requests(reqs_a, _cfg()).summary()
    sb = replay_requests(reqs_b, _cfg()).summary()
    assert json.dumps(sa, sort_keys=True) == json.dumps(sb, sort_keys=True)


def test_conservation_default_config():
    reqs = generate_requests(8_000, seed=1, horizon_min=20.0)
    cfg = _cfg()
    _check_conservation(reqs, replay_requests(reqs, cfg), cfg)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000),
       n=st.integers(50, 600),
       kv_pages=st.integers(48, 512),
       max_batch=st.integers(2, 32),
       n_decode=st.integers(1, 4),
       n_prefill=st.integers(1, 3),
       burst_frac=st.floats(0.0, 0.6))
def test_conservation_property(seed, n, kv_pages, max_batch, n_decode,
                               n_prefill, burst_frac):
    """Admission/KV conservation under randomized fleet + trace shapes,
    including KV-starved configs that force heavy eviction churn."""
    reqs = generate_requests(n, seed=seed, horizon_min=10.0,
                             max_prompt=512, max_out=64,
                             burst_frac=burst_frac, n_bursts=4)
    cfg = _cfg(n_prefill=n_prefill, n_decode=n_decode,
               max_batch=max_batch, kv_pages=kv_pages, page_tokens=16,
               admit_headroom_tokens=32, evict_headroom_tokens=64,
               total_gpus=256)
    _check_conservation(reqs, replay_requests(reqs, cfg), cfg)


def test_forced_evictions_recompute_through_prefill():
    """A KV-starved fleet must evict, recompute, and still finish
    everything it admitted."""
    reqs = generate_requests(1_500, seed=3, horizon_min=5.0,
                             max_prompt=400, max_out=64)
    cfg = _cfg(n_decode=1, n_prefill=1, max_batch=16, kv_pages=96,
               page_tokens=16, admit_headroom_tokens=32,
               evict_headroom_tokens=64)
    res = replay_requests(reqs, cfg)
    assert res.evictions > 0
    assert any(r.evictions > 0 and math.isfinite(r.done_min) for r in reqs)
    _check_conservation(reqs, res, cfg)


def test_oversized_requests_rejected():
    reqs = generate_requests(50, seed=0, horizon_min=1.0)
    big = reqs[10]
    big.prompt_tokens = 10**6
    cfg = _cfg()
    res = replay_requests(reqs, cfg)
    assert res.rejected_ids == [big.req_id]
    _check_conservation(reqs, res, cfg)


def test_config_validation():
    with pytest.raises(ValueError):
        replay_requests([], _cfg(n_decode=0))
    with pytest.raises(ValueError):
        replay_requests([], _cfg(total_gpus=64, n_prefill=4, n_decode=16,
                                 gpus_per_instance=8))


def test_generate_requests_stream_separation():
    """Burst/diurnal knobs reshuffle arrivals but must not perturb the
    token draws — separate RNG streams, same idiom as generate_jobs."""
    a = generate_requests(2_000, seed=5, burst_frac=0.0, diurnal=False)
    b = generate_requests(2_000, seed=5, burst_frac=0.4, diurnal=True)
    toks_a = sorted((r.prompt_tokens, r.out_tokens) for r in a)
    toks_b = sorted((r.prompt_tokens, r.out_tokens) for r in b)
    assert toks_a == toks_b
    arr_a = [r.arrival_min for r in a]
    assert arr_a == sorted(arr_a)
    assert [r.req_id for r in a] == list(range(2_000))
    assert arr_a != [r.arrival_min for r in b]


def _inj_cfg(seed=1, rate_scale=3_000.0, **kw):
    """Fault-injected config: hot enough hazard rates that a short trace
    reliably sees failures, ground-truth stub diagnosis for speed."""
    kw.setdefault("injector",
                  FailureInjector(SERVING_TAXONOMY, seed=seed,
                                  rate_scale=rate_scale))
    return _cfg(**kw)


def test_fault_injection_conservation_and_recovery():
    """The tentpole end-to-end: §5 failures strike the fleet, diagnosis
    routes recovery (hardware -> cordon + respawn, transient -> in-place
    restart), killed residents retry through prefill, and the extended
    conservation law holds exactly."""
    reqs = generate_requests(6_000, seed=4, horizon_min=30.0)
    cfg = _inj_cfg()
    res = replay_requests(reqs, cfg)
    assert res.faults_injected > 0
    # every failure was recovered one way — and with the stub's
    # ground-truth verdicts, the split matches the taxonomy's cordon flag
    assert res.respawns + res.inplace_restarts == res.faults_injected
    assert res.cordoned_nodes > 0 or res.respawns == 0
    assert res.retries_total > 0        # in-flight residents were killed
    assert res.killed_tokens > 0
    _check_conservation(reqs, res, cfg)


def test_fault_injection_is_deterministic():
    reqs_a = generate_requests(4_000, seed=9, horizon_min=20.0)
    reqs_b = generate_requests(4_000, seed=9, horizon_min=20.0)
    sa = replay_requests(reqs_a, _inj_cfg(seed=3)).summary()
    sb = replay_requests(reqs_b, _inj_cfg(seed=3)).summary()
    assert json.dumps(sa, sort_keys=True) == json.dumps(sb, sort_keys=True)


def test_faults_summary_section():
    """``summary()["faults"]`` attributes per-class; the no-injection
    summary must not grow the section (schema stability)."""
    reqs = generate_requests(6_000, seed=4, horizon_min=30.0)
    s = replay_requests(reqs, _inj_cfg()).summary()
    faults = s["faults"]
    assert faults["injected"] > 0
    by_class = faults["by_class"]
    assert sum(c["failures"] for c in by_class.values()) == faults["injected"]
    assert sum(c["retries"] for c in by_class.values()) == faults["retries"]
    assert sum(c["drops"] for c in by_class.values()) == faults["drops"]
    for c in by_class.values():
        assert c["downtime_min"] >= 0.0
        assert sum(c["verdicts"].values()) == c["failures"]
    clean = replay_requests(generate_requests(500, seed=4, horizon_min=5.0),
                            _cfg()).summary()
    assert "faults" not in clean


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000),
       inj_seed=st.integers(0, 1_000),
       rate_scale=st.floats(100.0, 20_000.0),
       n=st.integers(50, 500),
       kv_pages=st.integers(48, 512),
       retry_budget=st.integers(0, 4),
       n_decode=st.integers(1, 4),
       n_prefill=st.integers(1, 3))
def test_fault_conservation_property(seed, inj_seed, rate_scale, n, kv_pages,
                                     retry_budget, n_decode, n_prefill):
    """Extended conservation law under randomized failure schedules:
    ``evicted + killed == recomputed`` must hold exactly whatever the
    taxonomy does to the fleet."""
    reqs = generate_requests(n, seed=seed, horizon_min=10.0,
                             max_prompt=512, max_out=64)
    cfg = _inj_cfg(seed=inj_seed, rate_scale=rate_scale,
                   n_prefill=n_prefill, n_decode=n_decode,
                   max_batch=16, kv_pages=kv_pages, page_tokens=16,
                   admit_headroom_tokens=32, evict_headroom_tokens=64,
                   retry_budget=retry_budget, total_gpus=256)
    _check_conservation(reqs, replay_requests(reqs, cfg), cfg)


def test_retry_budget_exhaustion_drops():
    """With a zero retry budget every failure-killed request drops
    immediately: drops accrue, no retry recompute is ever charged
    (``killed_tokens`` counts only *retried* kills), and dropped
    requests' partial decode progress is still conserved."""
    reqs = generate_requests(6_000, seed=4, horizon_min=30.0)
    cfg = _inj_cfg(retry_budget=0)
    res = replay_requests(reqs, cfg)
    assert res.faults_injected > 0
    assert len(res.dropped_ids) > 0
    assert res.retries_total == 0
    assert res.killed_tokens == 0
    assert res.evicted_tokens == res.recompute_prefill_tokens
    _check_conservation(reqs, res, cfg)


def test_degraded_shedding_accounts_load():
    """A tiny degraded shed queue forces load shedding while instances
    are down; shed requests never touch the prefill fleet."""
    reqs = generate_requests(6_000, seed=4, horizon_min=30.0)
    cfg = _inj_cfg(degraded_shed_queue=1, n_decode=2, n_prefill=1,
                   max_batch=16)
    res = replay_requests(reqs, cfg)
    assert res.faults_injected > 0
    assert len(res.shed_ids) > 0
    assert res.degraded_min > 0.0
    _check_conservation(reqs, res, cfg)


def test_hol_skip_window():
    """Satellite: with a KV-starved head blocking the queue, a non-zero
    ``hol_skip_window`` admits small requests past it; the default stays
    strict FIFO (zero skips). Both must conserve."""
    reqs = generate_requests(1_500, seed=3, horizon_min=5.0,
                             max_prompt=400, max_out=64)
    base = dict(n_decode=1, n_prefill=1, max_batch=16, kv_pages=96,
                page_tokens=16, admit_headroom_tokens=32,
                evict_headroom_tokens=64)
    cfg_fifo = _cfg(**base)
    res_fifo = replay_requests(reqs, cfg_fifo)
    assert res_fifo.hol_skips == 0
    _check_conservation(reqs, res_fifo, cfg_fifo)
    reqs2 = generate_requests(1_500, seed=3, horizon_min=5.0,
                              max_prompt=400, max_out=64)
    cfg_skip = _cfg(hol_skip_window=8, **base)
    res_skip = replay_requests(reqs2, cfg_skip)
    assert res_skip.hol_skips > 0
    assert all(r.retries == 0 for r in reqs2)
    _check_conservation(reqs2, res_skip, cfg_skip)


def test_fault_config_validation():
    with pytest.raises(ValueError):
        replay_requests([], _cfg(retry_budget=-1))
    with pytest.raises(ValueError):
        replay_requests([], _cfg(hol_skip_window=-1))
    with pytest.raises(ValueError):
        replay_requests([], _cfg(degraded_max_batch_frac=0.0))
    with pytest.raises(ValueError):
        replay_requests([], _cfg(degraded_headroom_mult=0.5))


def test_slo_and_tails_respond_to_load():
    """Doubling the arrival rate into the same fleet cannot improve the
    TTFT tail or the joint SLO."""
    light = generate_requests(2_000, seed=11, horizon_min=40.0)
    heavy = generate_requests(20_000, seed=11, horizon_min=40.0)
    s_light = replay_requests(light, _cfg(n_decode=2, n_prefill=1)).summary()
    s_heavy = replay_requests(heavy, _cfg(n_decode=2, n_prefill=1)).summary()
    assert s_heavy["ttft"]["p99_s"] >= s_light["ttft"]["p99_s"]
    assert (s_heavy["slo"]["joint_attainment"]
            <= s_light["slo"]["joint_attainment"] + 1e-9)
