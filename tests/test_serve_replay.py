"""Serving replay: determinism, admission/KV conservation, eviction
accounting. The conservation laws here are the engine's ground truth —
every decode token is produced exactly once, every evicted KV token is
recomputed through the prefill fleet, and the conservative page bound
never exceeds capacity."""
import json
import math

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.cluster import (ServeReplayConfig, generate_requests,
                           replay_requests)
from repro.launch.cost_model import ServeRates


class _StubCostModel:
    """Duck-typed cost model: fast fixed rates, no artifact loading."""

    def serve_rates(self, arch, gpus):
        return ServeRates(arch=arch, gpus=gpus, prefill_tok_s=50_000.0,
                          decode_fixed_s=0.05, decode_per_seq_s=0.002,
                          source="stub/stub")


def _cfg(**kw):
    kw.setdefault("cost_model", _StubCostModel())
    return ServeReplayConfig(**kw)


def _check_conservation(reqs, res, cfg):
    """The invariants every serving replay must satisfy, any config."""
    rejected = set(res.rejected_ids)
    finished = [r for r in reqs if r.req_id not in rejected]
    # every admitted request runs to completion
    assert res.completed == len(finished)
    for r in finished:
        assert math.isfinite(r.done_min) and math.isfinite(r.ttft_min)
        assert 0.0 <= r.ttft_min <= r.done_min + 1e-9
        assert r.decoded == r.out_tokens - 1
    for r in reqs:
        if r.req_id in rejected:
            assert not math.isfinite(r.done_min)
    # token conservation: decode side produces each token exactly once...
    assert res.decoded_tokens == sum(r.out_tokens - 1 for r in finished)
    # ...and every evicted KV token is recomputed through the prefill fleet
    assert res.evicted_tokens == res.recompute_prefill_tokens
    assert res.prefill_tokens == (sum(r.prompt_tokens for r in finished)
                                  + res.recompute_prefill_tokens)
    # conservative page bound stays within capacity (up to float round-off
    # at the eviction-crossing instant)
    assert res.kv_peak_pages <= cfg.kv_pages + 1e-6
    assert res.peak_batch <= cfg.max_batch
    assert sum(r.evictions for r in reqs) == res.evictions


def test_replay_is_bit_deterministic():
    reqs_a = generate_requests(5_000, seed=7, horizon_min=20.0)
    reqs_b = generate_requests(5_000, seed=7, horizon_min=20.0)
    sa = replay_requests(reqs_a, _cfg()).summary()
    sb = replay_requests(reqs_b, _cfg()).summary()
    assert json.dumps(sa, sort_keys=True) == json.dumps(sb, sort_keys=True)


def test_conservation_default_config():
    reqs = generate_requests(8_000, seed=1, horizon_min=20.0)
    cfg = _cfg()
    _check_conservation(reqs, replay_requests(reqs, cfg), cfg)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000),
       n=st.integers(50, 600),
       kv_pages=st.integers(48, 512),
       max_batch=st.integers(2, 32),
       n_decode=st.integers(1, 4),
       n_prefill=st.integers(1, 3),
       burst_frac=st.floats(0.0, 0.6))
def test_conservation_property(seed, n, kv_pages, max_batch, n_decode,
                               n_prefill, burst_frac):
    """Admission/KV conservation under randomized fleet + trace shapes,
    including KV-starved configs that force heavy eviction churn."""
    reqs = generate_requests(n, seed=seed, horizon_min=10.0,
                             max_prompt=512, max_out=64,
                             burst_frac=burst_frac, n_bursts=4)
    cfg = _cfg(n_prefill=n_prefill, n_decode=n_decode,
               max_batch=max_batch, kv_pages=kv_pages, page_tokens=16,
               admit_headroom_tokens=32, evict_headroom_tokens=64,
               total_gpus=256)
    _check_conservation(reqs, replay_requests(reqs, cfg), cfg)


def test_forced_evictions_recompute_through_prefill():
    """A KV-starved fleet must evict, recompute, and still finish
    everything it admitted."""
    reqs = generate_requests(1_500, seed=3, horizon_min=5.0,
                             max_prompt=400, max_out=64)
    cfg = _cfg(n_decode=1, n_prefill=1, max_batch=16, kv_pages=96,
               page_tokens=16, admit_headroom_tokens=32,
               evict_headroom_tokens=64)
    res = replay_requests(reqs, cfg)
    assert res.evictions > 0
    assert any(r.evictions > 0 and math.isfinite(r.done_min) for r in reqs)
    _check_conservation(reqs, res, cfg)


def test_oversized_requests_rejected():
    reqs = generate_requests(50, seed=0, horizon_min=1.0)
    big = reqs[10]
    big.prompt_tokens = 10**6
    cfg = _cfg()
    res = replay_requests(reqs, cfg)
    assert res.rejected_ids == [big.req_id]
    _check_conservation(reqs, res, cfg)


def test_config_validation():
    with pytest.raises(ValueError):
        replay_requests([], _cfg(n_decode=0))
    with pytest.raises(ValueError):
        replay_requests([], _cfg(total_gpus=64, n_prefill=4, n_decode=16,
                                 gpus_per_instance=8))


def test_generate_requests_stream_separation():
    """Burst/diurnal knobs reshuffle arrivals but must not perturb the
    token draws — separate RNG streams, same idiom as generate_jobs."""
    a = generate_requests(2_000, seed=5, burst_frac=0.0, diurnal=False)
    b = generate_requests(2_000, seed=5, burst_frac=0.4, diurnal=True)
    toks_a = sorted((r.prompt_tokens, r.out_tokens) for r in a)
    toks_b = sorted((r.prompt_tokens, r.out_tokens) for r in b)
    assert toks_a == toks_b
    arr_a = [r.arrival_min for r in a]
    assert arr_a == sorted(arr_a)
    assert [r.req_id for r in a] == list(range(2_000))
    assert arr_a != [r.arrival_min for r in b]


def test_slo_and_tails_respond_to_load():
    """Doubling the arrival rate into the same fleet cannot improve the
    TTFT tail or the joint SLO."""
    light = generate_requests(2_000, seed=11, horizon_min=40.0)
    heavy = generate_requests(20_000, seed=11, horizon_min=40.0)
    s_light = replay_requests(light, _cfg(n_decode=2, n_prefill=1)).summary()
    s_heavy = replay_requests(heavy, _cfg(n_decode=2, n_prefill=1)).summary()
    assert s_heavy["ttft"]["p99_s"] >= s_light["ttft"]["p99_s"]
    assert (s_heavy["slo"]["joint_attainment"]
            <= s_light["slo"]["joint_attainment"] + 1e-9)
