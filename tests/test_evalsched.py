"""Decoupled evaluation scheduling: simulator invariants, plan conservation,
the paper's makespan claims, and the real threaded runner."""
import dataclasses

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.evalsched import (ClusterSpec, schedule_baseline,
                                  schedule_decoupled, standard_suite)
from repro.core.evalsched.trial import EvalDataset, plan_work_items


def test_plan_conserves_work():
    suite = standard_suite(63)
    items = plan_work_items(suite, 32)
    assert abs(sum(w.gpu_minutes for w in items)
               - sum(d.gpu_minutes for d in suite)) < 1e-6
    assert abs(sum(w.cpu_metric_minutes for w in items)
               - sum(d.cpu_metric_minutes for d in suite)) < 1e-6
    covered = set()
    for w in items:
        covered.update(w.datasets)
    assert covered == {d.name for d in suite}


def test_plan_sorted_long_cpu_tails_first():
    items = plan_work_items(standard_suite(63), 8)
    tails = [w.cpu_metric_minutes for w in items]
    assert tails[0] == max(tails)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(4, 40), nodes=st.integers(1, 4), seed=st.integers(0, 5))
def test_decoupled_never_slower(n, nodes, seed):
    """Property: decoupling never hurts makespan (same work, fewer stalls)."""
    suite = standard_suite(n, seed=seed)
    spec = ClusterSpec(n_nodes=nodes)
    b = schedule_baseline(suite, spec)
    d = schedule_decoupled(suite, spec)
    assert d.makespan <= b.makespan * 1.02
    assert d.gpu_utilization >= b.gpu_utilization - 0.02


def test_paper_claim_makespan_reduction():
    """Paper §6.2: makespan reduced ~1.3x (1 node) and ~1.8x (4 nodes)."""
    suite = standard_suite(63)
    r1 = (schedule_baseline(suite, ClusterSpec(n_nodes=1)).makespan /
          schedule_decoupled(suite, ClusterSpec(n_nodes=1)).makespan)
    r4 = (schedule_baseline(suite, ClusterSpec(n_nodes=4)).makespan /
          schedule_decoupled(suite, ClusterSpec(n_nodes=4)).makespan)
    assert 1.1 <= r1 <= 1.6, r1
    assert 1.5 <= r4 <= 2.3, r4
    assert r4 > r1     # more nodes -> more contention relief


def test_loading_speed_collapse():
    """Fig. 16 left: per-trial load speed collapses 1 -> 8 trials/node,
    then stabilizes."""
    from repro.core.evalsched.coordinator import loading_speed_curve
    spec = ClusterSpec(n_nodes=4)
    curve = dict(loading_speed_curve(spec, [1, 2, 4, 8, 64, 256]))
    assert curve[1] > curve[8] * 2
    assert curve[8] == curve[64] == curve[256]


def test_decoupled_gpu_utilization_high():
    suite = standard_suite(63)
    d = schedule_decoupled(suite, ClusterSpec(n_nodes=4))
    assert d.gpu_utilization > 0.9     # GPUs no longer idle on load/metric


def test_real_runner_decoupled_faster():
    import jax
    from repro.config import AttentionConfig, ModelConfig
    from repro.core.evalsched.runner import (RemoteStore, make_suite,
                                             run_baseline, run_decoupled)
    from repro.models import Model
    cfg = ModelConfig(name="t", num_layers=2, d_model=64, d_ff=128,
                      vocab_size=256, max_seq_len=64, vocab_pad_multiple=64,
                      attention=AttentionConfig(num_heads=4, num_kv_heads=2,
                                                head_dim=16))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    store = RemoteStore(params, bandwidth_mbps=4.0)
    suite = make_suite(model, n_datasets=8, heavy_tail=0.5)
    try:
        base = run_baseline(model, store, suite, n_workers=2,
                            warm_params=params)
        dec = run_decoupled(model, store, suite, n_workers=2,
                            warm_params=params)
    finally:
        store.close()
    assert dec.makespan_s < base.makespan_s / 1.25
