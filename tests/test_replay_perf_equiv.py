"""Equivalence and invariant tests for the PR 5 hot-path rewrite.

The golden-summary suite (``tests/test_golden_summary.py``) pins the
end-to-end output; these tests pin the *mechanisms* the rewrite touched:

  * ``NodeLedger``'s incrementally-maintained free-bucket index and the
    ``missing`` counter (which replaced the per-node ``used`` array so the
    alloc/release hot path stops maintaining it) stay exactly equal to a
    brute-force recomputation across randomized
    alloc/release/cordon/lease/detach/attach/repair sequences;
  * the dirty-flag borrower-reconcile trigger is a pure optimization: a
    duck-typed borrower without the ``_min_done`` watermark is reconciled
    after every event (the old behavior), and both paths produce
    bit-identical borrowing stats and summaries;
  * ``ReplayResult.summary()`` is memoized, repeat calls are
    side-effect-free, and mutating a returned tree cannot leak into the
    next call;
  * the lease-revocation fast paths (``ensure_free`` victim simulation,
    cordon accounting) preserve the exact counts the old full-rescan
    implementation produced.
"""
from __future__ import annotations

import random

from hypothesis import given, settings, strategies as st

from repro.cluster import (KALOS, FailureInjector, ReplayConfig,
                           generate_jobs, replay_trace)
from repro.cluster.replay import NodeLedger
from repro.core.evalsched import STORAGE_SPEC, TrialBorrower


# ---------------------------------------------------------------------------
# NodeLedger: incremental bucket index == brute force
# ---------------------------------------------------------------------------

def _check_ledger(led: NodeLedger, alloc_model: list, expect_free: int):
    """The incremental state must equal a from-scratch recomputation."""
    # bucket index: exactly the non-cordoned nodes at each free level
    for b in range(led.node_gpus + 1):
        want = {n for n in range(led.n_nodes)
                if n not in led.cordoned and led.free[n] == b}
        assert led._buckets[b] == want, f"bucket {b}"
    # cordoned nodes hold no free GPUs and sit in no bucket
    for n in led.cordoned:
        assert led.free[n] == 0
    # per-node conservation: free + missing + allocated == node capacity
    for n in range(led.n_nodes):
        assert led.free[n] + led.missing[n] + alloc_model[n] \
            == led.node_gpus, f"node {n}"
        assert led.missing[n] >= 0 and led.free[n] >= 0
    # the summed free pool tracks the op-by-op expectation exactly
    assert led.free_total() == expect_free


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 500))
def test_node_ledger_bucket_index_matches_brute_force(seed):
    rng = random.Random(seed)
    n_nodes = rng.randint(2, 12)
    node_gpus = rng.randint(1, 8)
    total = n_nodes * node_gpus + rng.randint(0, 5)   # + unplaced remainder
    led = NodeLedger(n_nodes, node_gpus, total)
    alloc_model = [0] * n_nodes        # allocated GPUs per node (model)
    expect_free = total
    jobs: list = []                    # live allocations ({node: k} dicts)
    drained = 0                        # free GPUs drained by cordons
    leases: dict = {}                  # node -> borrowed-lease cover

    for _ in range(rng.randint(20, 120)):
        op = rng.randrange(7)
        if op == 0:                                   # alloc
            g = rng.randint(1, max(1, expect_free))
            if g > expect_free:
                continue
            nodes = led.alloc(g)
            assert sum(nodes.values()) == g
            for n, k in nodes.items():
                if n >= 0:
                    alloc_model[n] += k
            jobs.append(nodes)
            expect_free -= g
        elif op == 1 and jobs:                        # release
            nodes = jobs.pop(rng.randrange(len(jobs)))
            for n, k in nodes.items():
                if n >= 0:
                    alloc_model[n] -= k
            expect_free += sum(nodes.values())
            led.release(nodes)
        elif op == 2:                                 # cordon a node
            n = rng.randrange(n_nodes)
            k = led.cordon_node(n)
            drained += k
            expect_free -= k
        elif op == 3 and led.cordoned:                # repair + hand back
            n = rng.choice(sorted(led.cordoned))
            led.repair_nodes([n])
            give = rng.randint(0, drained)
            led.add_free(give, prefer=[n])
            drained -= give
            expect_free += give
        elif op == 4 and jobs:                        # elastic detach
            nodes = rng.choice(jobs)
            picks = [n for n in nodes if n >= 0]
            if picks:
                n = rng.choice(picks)
                k = led.detach(nodes, n)
                alloc_model[n] -= k
                # the job sheds the GPUs; they are neither free nor
                # allocated until attach — tracked as missing
        elif op == 5 and jobs:                        # attach at repair
            nodes = rng.choice(jobs)
            n = rng.randrange(n_nodes)
            if n in led.cordoned:
                continue
            give = rng.randint(0, led.missing[n])
            before = dict(nodes)
            led.attach(nodes, [n], give)
            got = nodes.get(n, 0) - before.get(n, 0)
            alloc_model[n] += got
        else:                                         # lease placement
            node = led.lease_node(leases)
            if node >= 0:
                assert node not in led.cordoned
                assert led.free[node] > leases.get(node, 0)
                leases[node] = leases.get(node, 0) + 1
            if leases and rng.random() < 0.5:
                n = rng.choice(sorted(leases))
                leases[n] -= 1
                if not leases[n]:
                    del leases[n]
        _check_ledger(led, alloc_model, expect_free)


def test_node_ledger_lease_node_fast_path_matches_scan():
    """With no live leases, the fast path must pick exactly the node the
    full headroom scan would pick (first node of the smallest nonempty
    bucket, h==1 early-return included)."""
    rng = random.Random(7)
    for _ in range(50):
        led = NodeLedger(rng.randint(2, 10), rng.randint(1, 8), 200)
        for _ in range(rng.randint(0, 6)):
            free = led.free_total() - led.float_free
            if free > 0:
                led.alloc(rng.randint(1, free))
        fast = led.lease_node({})
        # reference: the original scan, leases empty
        best, best_h = -1, 0
        for b in range(1, led.node_gpus + 1):
            for n in led._buckets[b]:
                h = b
                if h == 1:
                    best = n
                    break
                if best < 0 or h < best_h:
                    best, best_h = n, h
            if best >= 0:
                break
        assert fast == best


# ---------------------------------------------------------------------------
# dirty-flag reconcile trigger: skip == no-op
# ---------------------------------------------------------------------------

class _EveryEventBorrower:
    """Duck-typed borrower without the ``_min_done`` watermark: the engine
    cannot prove a reconcile skippable, so it reconciles after every event
    — the pre-optimization behavior — while delegating to a real
    TrialBorrower."""

    def __init__(self, inner: TrialBorrower):
        self.inner = inner
        self.calls = 0

    def reconcile(self, now, free, nodes=None):
        self.calls += 1
        return self.inner.reconcile(now, free, nodes)

    def close(self, now):
        return self.inner.close(now)

    def stats(self):
        return self.inner.stats()


def _borrow_world(borrower):
    jobs = generate_jobs(KALOS, seed=11, n_jobs=4_000, best_effort_frac=0.3)
    cfg = ReplayConfig(injector=FailureInjector(seed=1, rate_scale=2.0),
                       diagnose=True, elastic=True, placement=True,
                       reshard_cost_min=1.0, borrower=borrower)
    res = replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.97, config=cfg)
    return res.summary()


def test_reconcile_skip_guard_is_a_pure_optimization():
    fast = TrialBorrower.from_suite(16, repeat=30, spec=STORAGE_SPEC)
    slow = _EveryEventBorrower(
        TrialBorrower.from_suite(16, repeat=30, spec=STORAGE_SPEC))
    s_fast = _borrow_world(fast)
    s_slow = _borrow_world(slow)
    # the skipped reconciles were provably no-ops: identical stats, leases,
    # preemptions, NIC bins — and identical everything else
    assert s_fast == s_slow
    assert slow.calls > 0


# ---------------------------------------------------------------------------
# summary(): memoized, side-effect-free
# ---------------------------------------------------------------------------

def test_summary_memoized_and_side_effect_free():
    jobs = generate_jobs(KALOS, seed=3, n_jobs=3_000, best_effort_frac=0.2)
    res = replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.97,
                       config=ReplayConfig(
                           injector=FailureInjector(seed=1, rate_scale=2.0),
                           diagnose=True, elastic=True, placement=True,
                           borrower=TrialBorrower.from_suite(
                               8, repeat=5, spec=STORAGE_SPEC)))
    first = res.summary()
    # repeated calls: equal trees, built once (memoized)
    assert res.summary() == first
    assert res._summary is not None
    # mutating a returned tree must not leak into the next call — the old
    # implementation shared result.borrow/placement dict references with
    # the caller
    mangled = res.summary()
    mangled["pool"]["borrow"]["leases"] = -999
    mangled["queue_delay_quantiles"].clear()
    mangled["recovery"]["policies"]["bogus"] = 1
    assert res.summary() == first
    # and the memo itself is not the returned object
    assert res.summary() is not res.summary()


# ---------------------------------------------------------------------------
# lease-revocation fast paths: accounting unchanged
# ---------------------------------------------------------------------------

def test_cordon_and_revocation_accounting_pinned():
    """Regression pin for the ensure_free victim *simulation* (which
    replaced the per-candidate can_start rescan of the whole be_running
    dict) and the cordon paths. The literal values below were produced by
    the pre-optimization (PR 4) engine on this exact trace/config — the
    fast path must revoke the same leases, cordon the same nodes and
    restart the same jobs."""
    jobs = generate_jobs(KALOS, seed=9, n_jobs=30_000, best_effort_frac=0.4)
    res = replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.9,
                       config=ReplayConfig(
                           injector=FailureInjector(seed=2, rate_scale=3.0),
                           diagnose=True, elastic=True, placement=True,
                           reshard_cost_min=1.0))
    s = res.summary()
    be = s["pool"]["best_effort"]
    # pinned from the PR 4 engine (pre-rewrite), verbatim:
    assert s["cordon_events"] == 65
    assert be["revocations"] == 11
    assert be["lease_starts"] == 478
    assert s["total_restarts"] == 165
    assert s["killed_jobs"] == 0
    # structural balance: revocations land in the quota_reclaim class and
    # the ledger drained at most one node per cordon event
    reclaim = s["lost_gpu_hours_by_class"]["quota_reclaim"]
    assert reclaim["failures"] == be["revocations"]
    assert s["placement"]["cordoned_nodes"] <= s["cordon_events"]
    # and the whole tree is deterministic across replays of the same list
    res2 = replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.9,
                        config=ReplayConfig(
                            injector=FailureInjector(seed=2, rate_scale=3.0),
                            diagnose=True, elastic=True, placement=True,
                            reshard_cost_min=1.0))
    assert res2.summary() == s
