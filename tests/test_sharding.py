"""Sharding rules engine: divisibility, axis-reuse, and best-effort specs —
property-tested over random shapes."""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.config import ParallelConfig
from repro.models.spec import ParamSpec
from repro.sharding import data_axes, fsdp_axes, make_rules, tree_shardings

AXES = ["batch", "seq", "embed", "mlp", "heads", "kv_heads", "vocab",
        "experts", "kv_seq", "stacked", None]


@pytest.fixture(scope="module")
def mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def _flat_axes(spec: P) -> list:
    out = []
    for e in spec:
        if e is None:
            continue
        out.extend(e if isinstance(e, tuple) else [e])
    return out


@settings(max_examples=200, deadline=None)
@given(dims=st.lists(st.tuples(st.integers(1, 64),
                               st.sampled_from(AXES)), min_size=1,
                     max_size=4))
def test_shard_spec_properties(mesh11, dims):
    """For ANY shape/axes: mesh axes divide their dims and never repeat."""
    rules = make_rules(mesh11, ParallelConfig())
    shape = tuple(d for d, _ in dims)
    axes = tuple(a for _, a in dims)
    spec = rules.shard_spec(shape, axes)
    sizes = dict(zip(rules.mesh.axis_names, rules.mesh.devices.shape))
    seen = []
    for dim, entry in zip(shape, spec):
        if entry is None:
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        extent = 1
        for a in names:
            extent *= sizes[a]
        assert dim % extent == 0
        seen.extend(names)
    assert len(seen) == len(set(seen))   # no axis used twice


def test_shard_spec_divisibility_synthetic():
    """On a fake big mesh table, non-dividing dims stay unsharded."""
    import dataclasses
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = make_rules(mesh, ParallelConfig())
    # monkey-table: pretend the mesh axes were 16x16 for divisibility math
    big = dataclasses.replace(rules, mesh=rules.mesh)
    spec = rules.shard_spec((15,), ("heads",))   # 15 % 1 == 0 -> sharded ok
    assert spec == P(("model",)) or spec == P(None)


def test_zero_modes_fsdp_axes():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    assert fsdp_axes(mesh, ParallelConfig(zero="none")) == ()
    assert fsdp_axes(mesh, ParallelConfig(zero="zero1")) == ()
    assert fsdp_axes(mesh, ParallelConfig(zero="zero3")) == ("data",)
    mesh3 = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    assert fsdp_axes(mesh3, ParallelConfig(zero="zero3")) == ("pod", "data")
    # hierarchical ZeRO: gather group bounded to the pod-local data axis
    assert fsdp_axes(mesh3, ParallelConfig(zero="zero3_hier")) == ("data",)
    assert data_axes(mesh3) == ("pod", "data")


def test_tree_shardings_cover_params(tiny_cfg):
    from repro.models import Model
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = make_rules(mesh, ParallelConfig())
    model = Model(tiny_cfg)
    sh = tree_shardings(rules, model.specs())
    n_specs = len(jax.tree_util.tree_leaves(
        model.specs(), is_leaf=lambda x: isinstance(x, ParamSpec)))
    n_sh = len(jax.tree_util.tree_leaves(sh))
    assert n_specs == n_sh
