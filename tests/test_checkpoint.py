"""Asynchronous checkpointing: roundtrip, stall behavior, atomicity,
RAM-cache fast restore, elastic (re-sharded) load."""
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ft.checkpoint import CheckpointManager
from repro.utils import tree_allclose


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (64, 32)),
            "opt": {"m": jnp.ones((64, 32)), "step": jnp.int32(7)}}


def test_roundtrip_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    state = _state()
    stall = mgr.save_async(10, state, extra={"data_step": 11})
    mgr.wait()
    assert stall < 5.0
    restored, extra = mgr.restore(10, jax.tree_util.tree_map(
        lambda x: jnp.zeros_like(x), state))
    assert tree_allclose(state, restored)
    assert extra["data_step"] == 11


def test_restore_from_disk_after_cache_eviction(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=8, ram_cache_slots=1)
    states = {s: _state(s) for s in (1, 2, 3)}
    for s, st in states.items():
        mgr.save_async(s, st)
    mgr.wait()
    assert list(mgr.ram_cache) == [3]          # evicted down to 1 slot
    template = jax.tree_util.tree_map(jnp.zeros_like, states[1])
    restored, _ = mgr.restore(1, template)     # must come from disk
    assert tree_allclose(states[1], restored)


def test_keep_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in range(5):
        mgr.save_sync(s, _state(s))
    assert mgr.available_steps() == [3, 4]


def test_latest_restorable_prefers_ram(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3,
                            storage_bandwidth_gbps=0.01)  # slow persist
    mgr.save_async(5, _state())
    # persist is still in flight; RAM cache must already expose step 5
    assert mgr.latest_restorable() == 5
    mgr.wait(timeout=60)
    assert mgr.latest_step() == 5


def test_async_stall_much_smaller_than_sync(tmp_path):
    """The paper's §6.1 claim in miniature: async checkpointing blocks for
    the host snapshot only, not the (throttled) storage write."""
    big = {"w": jnp.ones((512, 1024))}          # 2 MiB
    mgr = CheckpointManager(str(tmp_path), keep=2,
                            storage_bandwidth_gbps=0.05)   # ~0.3s write
    t_sync = mgr.save_sync(1, big)
    t_async = mgr.save_async(2, big)
    mgr.wait(timeout=60)
    assert t_async < t_sync / 3, (t_sync, t_async)


def test_atomic_commit_no_partial_dirs(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=4)
    for s in range(3):
        mgr.save_async(s, _state(s))
    mgr.wait()
    for name in os.listdir(tmp_path):
        assert not name.endswith(".tmp")
        assert os.path.exists(os.path.join(tmp_path, name, "manifest.json"))


def test_elastic_restore_resharded(tmp_path):
    """Save under one sharding, restore under another (mesh-agnostic)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = _state()
    mgr.save_sync(1, state)
    mesh = jax.make_mesh((1,), ("data",))
    shardings = jax.tree_util.tree_map(
        lambda x: NamedSharding(mesh, P("data") if getattr(x, "ndim", 0) > 0
                                else P()), state)
    restored, _ = mgr.restore(1, jax.tree_util.tree_map(jnp.zeros_like, state),
                              shardings=shardings)
    assert tree_allclose(state, restored)
    leaf = restored["w"]
    assert leaf.sharding.spec == P("data")


def test_available_steps_skips_malformed_entries(tmp_path):
    """Stray step_* litter (editor backups, aborted copies, human notes)
    must not poison the directory scan with a ValueError."""
    mgr = CheckpointManager(str(tmp_path), keep=8)
    mgr.save_sync(5, _state())
    mgr.save_sync(12, _state())
    for junk in ("step_final", "step_12_copy", "step_", "step_abc"):
        d = os.path.join(str(tmp_path), junk)
        os.makedirs(d)
        with open(os.path.join(d, "manifest.json"), "w") as f:
            f.write("{}")
    # a plain *file* named step_<int> (no manifest inside) is skipped too
    with open(os.path.join(str(tmp_path), "step_99"), "w") as f:
        f.write("not a checkpoint")
    assert mgr.available_steps() == [5, 12]
    assert mgr.latest_step() == 12
    mgr.close()
