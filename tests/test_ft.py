"""Fault tolerance: diagnosis accuracy over the Table-3 taxonomy, two-round
detection (property-based), spike policy, supervisor end-to-end."""
import random

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.ft.detection import (SimulatedFleet, StragglerMonitor,
                                     two_round_detection)
from repro.core.ft.diagnosis import FailureDiagnosisSystem, LogCompressor
from repro.core.ft.events import BY_NAME, TABLE3, generate_log
from repro.core.ft.spike import SpikeDetector
from repro.core.ft.supervisor import JobFailure, Supervisor


# --- detection ---------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(n=st.integers(2, 64), data=st.data())
def test_two_round_detection_exact(n, data):
    """Property: the sweep finds exactly the faulty set for any fleet."""
    faulty = data.draw(st.sets(st.integers(0, n - 1),
                               max_size=max(n // 3, 1)))
    fleet = SimulatedFleet(n, faulty=set(faulty))
    res = two_round_detection(fleet.healthy_nodes(), fleet)
    assert set(res.faulty) == faulty
    # ~n/2 round-1 pairs + <=n round-2 probes (tiny fleets hit the ceiling)
    assert res.probes <= (n + 1) // 2 + n


def test_two_round_probe_count():
    fleet = SimulatedFleet(64, faulty={5})
    res = two_round_detection(fleet.healthy_nodes(), fleet)
    assert res.probes == 32 + 2        # one failed pair -> 2 suspects


def test_straggler_monitor_flags_slow_host():
    mon = StragglerMonitor(range(8), min_samples=8)
    rng = np.random.default_rng(0)
    for _ in range(12):
        for h in range(8):
            mon.record(h, 1.0 + (0.6 if h == 3 else 0.0) + 0.01 * rng.random())
    assert mon.stragglers() == [3]


# --- diagnosis ---------------------------------------------------------------

def test_diagnosis_accuracy_over_taxonomy():
    """Every Table-3 failure type, buried in cascades, is diagnosed right
    >= 90% of the time (the paper: ~90% less manual intervention)."""
    sys_ = FailureDiagnosisSystem()
    total, correct = 0, 0
    for ft in TABLE3:
        for seed in range(3):
            log = generate_log(ft, seed=seed, n_normal=120)
            diag = sys_.diagnose(log)
            total += 1
            correct += diag.failure == ft.name
    assert correct / total >= 0.9, f"{correct}/{total}"


def test_diagnosis_root_cause_beats_symptoms():
    """NVLink fault w/ NCCL-timeout cascade must resolve to NVLinkError."""
    sys_ = FailureDiagnosisSystem()
    log = generate_log(BY_NAME["NVLinkError"], seed=1, cascade=True)
    assert sys_.diagnose(log).failure == "NVLinkError"


def test_diagnosis_learns_rules():
    import dataclasses
    # pin a single log template so the learned regex must generalize only
    # over the randomized fields, not across alternative phrasings
    ft = dataclasses.replace(BY_NAME["ECCError"],
                             templates=BY_NAME["ECCError"].templates[:1])
    sys_ = FailureDiagnosisSystem(seed_rules=[])
    first = sys_.diagnose(generate_log(ft, seed=0))
    assert first.source == "agent"
    second = sys_.diagnose(generate_log(ft, seed=5))
    assert second.failure == "ECCError"
    assert second.source == "rule"       # continuous learning kicked in


def test_log_compression_ratio():
    comp = LogCompressor()
    log = generate_log(BY_NAME["CUDAError"], seed=0, n_normal=2000)
    kept = comp.compress(log)
    assert comp.compression_ratio > 20
    assert any("CUDA" in l for l in kept)     # error lines survive


# --- spike -------------------------------------------------------------------

def test_spike_detector_fires_and_names_rollback():
    det = SpikeDetector(min_history=8, patience=3)
    ev = None
    for s in range(200):
        loss = 2.0 - 0.002 * s + (4.0 if s >= 120 else 0.0)
        ev = det.update(s, loss, available_ckpts=[0, 40, 80, 110])
        if ev:
            break
    assert ev is not None
    assert ev.onset_step == 120 and ev.rollback_step == 110
    assert ev.skip_range[0] <= 120 < ev.skip_range[1]


def test_spike_detector_ignores_transients():
    det = SpikeDetector(min_history=8, patience=4)
    rng = np.random.default_rng(0)
    for s in range(300):
        loss = 2.0 + 0.05 * rng.standard_normal()
        if s % 50 == 10:
            loss += 5.0        # single-step blip: recovers immediately
        assert det.update(s, loss, available_ckpts=[0]) is None


def test_spike_detector_handles_nan():
    det = SpikeDetector(min_history=8, patience=2)
    ev = None
    for s in range(40):
        loss = float("nan") if s >= 30 else 2.0 + 0.01 * (s % 3)
        ev = det.update(s, loss, available_ckpts=[0, 20])
        if ev:
            break
    assert ev is not None and ev.rollback_step == 20


# --- supervisor --------------------------------------------------------------

def test_supervisor_end_to_end(tmp_path):
    from repro.core.ft.checkpoint import CheckpointManager
    ckpt = CheckpointManager(str(tmp_path), keep=5)
    fleet = SimulatedFleet(16)
    sup = Supervisor(ckpt, FailureDiagnosisSystem(), fleet)
    fired = set()
    schedule = [(25, "NVLinkError"), (57, "ConnectionError")]

    def job(ctx):
        for step in range(ctx.start_step, 80):
            if step % 10 == 0:
                ckpt.save_async(step, {"step": np.int64(step)})
            for fs, fname in schedule:
                if step == fs and fs not in fired:
                    fired.add(fs)
                    if BY_NAME[fname].needs_node_cordon:
                        fleet.fail({3})
                    raise JobFailure(step, generate_log(BY_NAME[fname],
                                                        seed=step),
                                     truth=fname)
        return 80

    rep = sup.run(job)
    ckpt.wait()
    assert rep.completed and rep.final_step == 80
    assert rep.auto_recoveries == 2 and rep.manual_interventions == 0
    assert rep.diagnosis_accuracy == 1.0
    assert 3 in fleet.cordoned                 # NVLink node cordoned
    assert rep.lost_steps <= 12                # resumed from fresh snapshots


def test_supervisor_surfaces_script_errors(tmp_path):
    from repro.core.ft.checkpoint import CheckpointManager
    ckpt = CheckpointManager(str(tmp_path), keep=2)
    sup = Supervisor(ckpt, FailureDiagnosisSystem(), SimulatedFleet(4))
    seen = []
    sup.on_manual = seen.append
    fired = []

    def job(ctx):
        if not fired:
            fired.append(1)
            raise JobFailure(3, generate_log(BY_NAME["SyntaxError"], seed=0),
                             truth="SyntaxError")
        return 10

    rep = sup.run(job)
    assert rep.completed
    assert rep.manual_interventions == 1       # script bugs page a human
    assert seen and not seen[0].auto_recoverable


def test_spike_rollback_refreshes_resume_extra(tmp_path):
    """Regression: after a SpikeInterrupt rollback, the next attempt's
    resume_extra must come from the *rollback* checkpoint, not linger from
    the attempt that spiked."""
    from repro.core.ft.checkpoint import CheckpointManager
    from repro.core.ft.spike import SpikeEvent
    from repro.core.ft.supervisor import SpikeInterrupt, Supervisor

    ckpt = CheckpointManager(str(tmp_path), keep=8, ram_cache_slots=8)
    sup = Supervisor(ckpt, FailureDiagnosisSystem(), SimulatedFleet(4))
    seen_extra = []
    spiked = []

    def job(ctx):
        seen_extra.append(dict(ctx.resume_extra))
        for step in range(ctx.start_step, 60):
            if step % 10 == 0:
                ckpt.save_async(step, {"step": np.int64(step)},
                                extra={"data_step": step})
            if step == 37 and not spiked:
                spiked.append(step)
                raise SpikeInterrupt(SpikeEvent(
                    onset_step=35, detect_step=37, rollback_step=20,
                    skip_range=(30, 40), baseline=2.0, peak=9.0))
        return 60

    rep = sup.run(job)
    ckpt.wait()
    ckpt.close()
    assert rep.completed and rep.final_step == 60
    # attempt 0 starts fresh; attempt 1 must resume with step-20 extras
    assert seen_extra[0] == {}
    assert seen_extra[1] == {"data_step": 20}
