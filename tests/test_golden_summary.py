"""Golden-summary equivalence for the replay engine's hot-path rewrite.

The perf work on ``repro.cluster.replay`` (incremental ``NodeLedger``
bucket indices, dirty-flag reconcile triggers, vectorized ``analysis``
aggregation) carries a hard contract: **bit-exact output**. Every field of
``ReplayResult.summary()`` — queue-delay quantiles, restart counts, lost
GPU hours, recovery/pool/placement/head-delay breakdowns — must be
unchanged relative to the pre-optimization engine.

These tests enforce it by replaying fixed 50k/20k-job traces through the
heaviest configurations the engine supports and comparing the full
``summary()`` tree against committed golden fixtures that were generated
by the pre-optimization engine (PR 4). Any divergence — a different node
picked by the placement ledger, a skipped borrower reconcile that should
have run, a re-associated float sum in the aggregation — shows up as a
field-level diff.

Regenerating (only legitimate when the *semantics* deliberately change,
never as part of a perf PR):

    REPRO_REGOLD=1 PYTHONPATH=src python -m pytest tests/test_golden_summary.py

Fixtures live in ``tests/golden/``. Floats survive the JSON round-trip
exactly (``float(repr(x)) == x``), so the comparison is bit-exact; the
fresh summary is normalized through ``json.dumps``/``loads`` so int dict
keys compare against their JSON string form.
"""
from __future__ import annotations

import json
import os

import pytest

from repro.cluster import (KALOS, FailureInjector, ReplayConfig,
                           generate_jobs, replay_trace)
from repro.core.evalsched import STORAGE_SPEC, TrialBorrower

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
REGOLD = os.environ.get("REPRO_REGOLD") == "1"


def _full_feature_summary() -> dict:
    """The tentpole configuration: placement + best-effort revocable
    leases + elastic shrink/regrow + trial borrowing + diagnosis, 50k
    jobs on a saturated Kalos spare pool."""
    jobs = generate_jobs(KALOS, seed=0, n_jobs=50_000, best_effort_frac=0.3)
    borrower = TrialBorrower.from_suite(63, repeat=100, spec=STORAGE_SPEC)
    cfg = ReplayConfig(injector=FailureInjector(seed=1, rate_scale=2.0),
                       diagnose=True, elastic=True, placement=True,
                       reshard_cost_min=1.0, borrower=borrower)
    res = replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.97, config=cfg)
    return res.summary()


def _easy_pool_summary() -> dict:
    """EASY backfill + the full pool: the shadow-time machinery (head
    episodes, sampled estimates, regrow admission) on top of placement
    and best-effort leases, 20k jobs."""
    jobs = generate_jobs(KALOS, seed=3, n_jobs=20_000, best_effort_frac=0.3)
    borrower = TrialBorrower.from_suite(63, repeat=50, spec=STORAGE_SPEC)
    cfg = ReplayConfig(injector=FailureInjector(seed=1, rate_scale=2.0),
                       diagnose=True, elastic=True, placement=True,
                       reshard_cost_min=1.0, borrower=borrower,
                       backfill="easy")
    res = replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.97, config=cfg)
    return res.summary()


def _noinject_summary() -> dict:
    """Pure queue replay (simulate_queue semantics) with greedy backfill:
    the dispatch core with every pool feature off."""
    jobs = generate_jobs(KALOS, seed=7, n_jobs=50_000)
    res = replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.97,
                       config=ReplayConfig(injector=None, backfill="greedy"))
    return res.summary()


def _roofline_summary() -> dict:
    """``runtime_model="roofline"``: arch-tagged pretraining jobs reprice
    elastic shrink/regrow through the cost model's width curves. Pinned
    with the hermetic *analytic* model (no dryrun artifacts read), so the
    fixture is reproducible on a bare checkout; the same trace replayed
    nominally is covered by the existing goldens staying untouched."""
    from repro.cluster.workload import PRETRAIN_ARCHS
    from repro.launch.cost_model import CostModel
    jobs = generate_jobs(KALOS, seed=3, n_jobs=20_000, best_effort_frac=0.3,
                         arch_frac=0.8)
    cfg = ReplayConfig(injector=FailureInjector(seed=1, rate_scale=2.0),
                       diagnose=True, elastic=True, placement=True,
                       reshard_cost_min=1.0, backfill="easy",
                       runtime_model="roofline",
                       cost_model=CostModel.analytic(PRETRAIN_ARCHS))
    res = replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.97, config=cfg)
    return res.summary()


def _serve_summary() -> dict:
    """Serving replay: 20k diurnal+bursty requests through the default
    disaggregated fleet, priced with the hermetic *analytic* model (same
    reasoning as the roofline fixture — reproducible on a bare checkout).
    Pins the whole scorecard: TTFT/TPOT tails, SLO attainment, KV
    eviction/recompute accounting, occupancy."""
    from repro.cluster import (ServeReplayConfig, generate_requests,
                               replay_requests)
    from repro.launch.cost_model import CostModel
    reqs = generate_requests(20_000, seed=0, horizon_min=30.0)
    cfg = ServeReplayConfig(cost_model=CostModel.analytic(("internlm-7b",)))
    return replay_requests(reqs, cfg).summary()


def _serve_faults_summary() -> dict:
    """Fault-tolerant serving: the same 20k trace with the §5 taxonomy
    striking the fleet through fixed injector/diagnosis seeds — pins the
    whole recovery pipeline (verdict routing, cordon/respawn vs in-place
    restart, bounded retries, degradation windows, shed accounting) plus
    the ``summary()["faults"]`` attribution tree. The no-injection
    ``serve_20k`` fixture staying untouched is the bit-exactness
    guarantee for the fault machinery's inert path."""
    from repro.cluster import (SERVING_TAXONOMY, DiagnosisLoop,
                               FailureInjector, ServeReplayConfig,
                               generate_requests, replay_requests)
    from repro.launch.cost_model import CostModel
    reqs = generate_requests(20_000, seed=0, horizon_min=30.0)
    cfg = ServeReplayConfig(
        cost_model=CostModel.analytic(("internlm-7b",)),
        injector=FailureInjector(SERVING_TAXONOMY, seed=7, rate_scale=500.0),
        diagnosis=DiagnosisLoop(n_variants=4, flavor="serve"))
    return replay_requests(reqs, cfg).summary()


CASES = {
    "full_feature_50k": _full_feature_summary,
    "easy_pool_20k": _easy_pool_summary,
    "noinject_greedy_50k": _noinject_summary,
    "roofline_20k": _roofline_summary,
    "serve_20k": _serve_summary,
    "serve_faults_20k": _serve_faults_summary,
}


def _diff(path: str, a, b, out: list) -> None:
    """Collect leaf-level differences so a failure names the exact field."""
    if isinstance(a, dict) and isinstance(b, dict):
        for k in sorted(set(a) | set(b)):
            if k not in a:
                out.append(f"{path}.{k}: missing from golden")
            elif k not in b:
                out.append(f"{path}.{k}: missing from fresh")
            else:
                _diff(f"{path}.{k}", a[k], b[k], out)
    elif isinstance(a, list) and isinstance(b, list):
        if len(a) != len(b):
            out.append(f"{path}: length {len(a)} != {len(b)}")
        else:
            for i, (x, y) in enumerate(zip(a, b)):
                _diff(f"{path}[{i}]", x, y, out)
    elif a != b:
        out.append(f"{path}: golden={a!r} fresh={b!r}")


@pytest.mark.parametrize("case", sorted(CASES))
def test_golden_summary(case):
    fixture = os.path.join(GOLDEN_DIR, f"{case}.json")
    # normalize through JSON so int keys / float repr match the fixture
    fresh = json.loads(json.dumps(CASES[case]()))
    if REGOLD or not os.path.exists(fixture):
        os.makedirs(GOLDEN_DIR, exist_ok=True)
        with open(fixture, "w") as f:
            json.dump(fresh, f, indent=1, sort_keys=True)
        pytest.skip(f"golden fixture (re)generated: {fixture}")
    with open(fixture) as f:
        golden = json.load(f)
    diffs: list = []
    _diff("summary", golden, fresh, diffs)
    assert not diffs, (
        f"{case}: summary diverged from the pre-optimization engine in "
        f"{len(diffs)} field(s):\n  " + "\n  ".join(diffs[:40]))
