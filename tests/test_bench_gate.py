"""CI perf-regression gate (benchmarks.check_regression): pass/fail logic
over benchmark artifact JSON, tolerance handling, missing-file rules, and
the consolidated BENCH_replay.json throughput-trajectory artifact."""
import json
import os

import pytest

from benchmarks.check_regression import DEFAULT_TOLERANCE, GATES, check, main
from benchmarks.run import TRAJECTORY_BENCHES, write_trajectory


def _write(dirp, bench, metrics):
    os.makedirs(dirp, exist_ok=True)
    rows = [{"bench": bench, "metric": m, "value": v,
             "target": "", "unit": "", "ok": None}
            for m, v in metrics.items()]
    with open(os.path.join(dirp, f"{bench}.json"), "w") as f:
        json.dump(rows, f)


def _write_all(dirp, scale=1.0, fingerprint=1234.0):
    _write(dirp, "replay", {"events_per_calib": 0.8 * scale,
                            "events_per_calib_full": 0.8 * scale,
                            "events_per_calib_legacy": 1.1 * scale,
                            "events_per_calib_placement": 0.95 * scale,
                            "events_per_calib_best_effort": 1.0 * scale,
                            "events_per_sec": 150e3 * scale})
    _write(dirp, "pool", {"events_per_calib": 0.4 * scale})
    _write(dirp, "evalsched", {"events_per_calib": 2.0 * scale})
    # the serving bench is dryrun-STAMPED but not dryrun-GUARDED: its
    # gated probe is hermetic, so no fingerprint row is needed here
    _write(dirp, "serve", {"events_per_calib": 1.5 * scale,
                           "events_per_calib_serve": 1.5 * scale,
                           "events_per_calib_serve_faults": 1.2 * scale,
                           "slo_joint_attainment": 0.8,
                           "decoded_tok_per_s": 2300.0})
    _write(dirp, "detection", {"n128_probe_savings": 120.0 * scale,
                               "n512_probe_savings": 490.0 * scale})
    _write(dirp, "checkpoint", {"7B-analog_stall_reduction": 10.0 * scale,
                                "123B-analog_stall_reduction": 19.0 * scale})
    # cost-model benches: dryrun-derived rows + the provenance stamp the
    # gate checks before judging them (the fingerprint never scales — a
    # differing one means a different cell set, covered separately below)
    _write(dirp, "roofline", {"n_cells": 4.0 * scale,
                              "worst_roofline_frac": 0.004 * scale,
                              "dryrun_fingerprint": fingerprint})
    _write(dirp, "moe_comm", {"deepseek_over_dense": 6.0 * scale,
                              "mixtral_over_dense": 3.5 * scale,
                              "deepseek_a2a_gib_per_step": 9.75 * scale,
                              "dryrun_fingerprint": fingerprint})
    # the static kernel cost table (deterministic, but gated with the
    # same uniform bands so these synthetic scaling fixtures cover it)
    _write(dirp, "kernel_cost", {"cost_model_agreement": 1.0 * scale,
                                 "n_rows": 6.0 * scale,
                                 "min_intensity": 0.5 * scale,
                                 "max_intensity": 61.0 * scale})


def test_gate_passes_within_tolerance(tmp_path):
    base, fresh = tmp_path / "base", tmp_path / "fresh"
    _write_all(str(base))
    _write_all(str(fresh), scale=0.80)      # -20% < the 25% tolerance
    assert check(str(fresh), str(base)) == []
    assert main(["--fresh", str(fresh), "--baseline", str(base)]) == 0


def test_gate_fails_on_throughput_regression(tmp_path):
    base, fresh = tmp_path / "base", tmp_path / "fresh"
    _write_all(str(base))
    _write_all(str(fresh), scale=0.40)      # -60%: beyond every tolerance
    failures = check(str(fresh), str(base))
    gated = {f"{b}.{m}" for b, ms in GATES.items() for m, _, _ in ms}
    assert len(failures) == len(gated)
    assert main(["--fresh", str(fresh), "--baseline", str(base)]) == 1


def test_checkpoint_has_wider_noise_band(tmp_path):
    """The stall-reduction ratio is noisy by construction; a -30% drop
    fails replay/detection but stays inside checkpoint's 50% band."""
    base, fresh = tmp_path / "base", tmp_path / "fresh"
    _write_all(str(base))
    _write_all(str(fresh), scale=0.70)
    failures = check(str(fresh), str(base))
    assert failures and not any("checkpoint" in f for f in failures)
    _write_all(str(fresh), scale=0.45)      # -55%: outside even 50%
    assert any("checkpoint" in f for f in check(str(fresh), str(base)))


def test_gate_single_metric_regression_is_reported(tmp_path):
    base, fresh = tmp_path / "base", tmp_path / "fresh"
    _write_all(str(base))
    _write_all(str(fresh))
    _write(str(fresh), "replay", {"events_per_calib": 0.5,   # -37.5%
                                  "events_per_calib_full": 0.8,
                                  "events_per_calib_legacy": 1.1,
                                  "events_per_calib_placement": 0.95,
                                  "events_per_calib_best_effort": 1.0,
                                  "events_per_sec": 150e3})
    failures = check(str(fresh), str(base))
    assert len(failures) == 1
    assert "replay.events_per_calib" in failures[0]


def test_gate_covers_replay_full_row(tmp_path):
    """The per-knob replay_full row is gated on its own: the aggregate
    surviving while the full-feature row tanks must still fail."""
    assert ("events_per_calib_full", "higher", None) in GATES["replay"]
    base, fresh = tmp_path / "base", tmp_path / "fresh"
    _write_all(str(base))
    _write_all(str(fresh))
    _write(str(fresh), "replay", {"events_per_calib": 0.8,
                                  "events_per_calib_full": 0.3,  # -62%
                                  "events_per_calib_legacy": 1.1,
                                  "events_per_calib_placement": 0.95,
                                  "events_per_calib_best_effort": 1.0,
                                  "events_per_sec": 150e3})
    failures = check(str(fresh), str(base))
    assert len(failures) == 1
    assert "replay.events_per_calib_full" in failures[0]
    # a baseline *without* the new row (pre-PR-5 artifacts) is skipped,
    # not failed retroactively
    _write(str(base), "replay", {"events_per_calib": 0.8,
                                 "events_per_sec": 150e3})
    assert check(str(fresh), str(base)) == []


def test_missing_baseline_is_skipped_missing_fresh_fails(tmp_path):
    base, fresh = tmp_path / "base", tmp_path / "fresh"
    _write_all(str(fresh))
    # no baseline at all: nothing to compare, gate passes (new benches
    # must not fail retroactively)
    assert check(str(fresh), str(base)) == []
    # a fresh artifact missing is a hard failure: the bench should have
    # produced it
    _write_all(str(base))
    os.remove(os.path.join(str(fresh), "replay.json"))
    failures = check(str(fresh), str(base))
    assert any("replay" in f and "missing" in f for f in failures)


def test_dryrun_fingerprint_guards_cost_model_rows(tmp_path):
    """roofline/moe_comm rows from different dryrun cell sets must never
    be judged against each other: a differing (or missing) fingerprint
    skips their metrics entirely instead of reporting regressions."""
    base, fresh = tmp_path / "base", tmp_path / "fresh"
    _write_all(str(base))
    _write_all(str(fresh), scale=0.40, fingerprint=5678.0)  # other cells
    failures = check(str(fresh), str(base))
    assert not any(f.startswith(("roofline", "moe_comm")) for f in failures)
    assert any(f.startswith("replay") for f in failures)    # still gated
    # unstamped artifacts (either side) are skipped too, not failed
    _write(str(fresh), "roofline", {"n_cells": 1.0})
    _write(str(fresh), "moe_comm", {"deepseek_over_dense": 0.1})
    failures = check(str(fresh), str(base))
    assert not any(f.startswith(("roofline", "moe_comm")) for f in failures)
    # matching fingerprints arm the gate: now the same drop fails
    _write_all(str(fresh), scale=0.40)
    failures = check(str(fresh), str(base))
    assert any(f.startswith("roofline.n_cells") for f in failures)
    assert any(f.startswith("moe_comm.deepseek_over_dense")
               for f in failures)


def test_dirty_stamps_are_refused(tmp_path):
    """Artifacts stamped by a lint-dirty or kernel-resource-dirty tree
    fail the gate outright, before any metric is compared."""
    base, fresh = tmp_path / "base", tmp_path / "fresh"
    _write_all(str(base))
    _write_all(str(fresh))
    assert check(str(fresh), str(base)) == []
    _write(str(fresh), "pool", {"events_per_calib": 0.4,
                                "replint_clean": 0.0,
                                "replint_findings": 3.0})
    failures = check(str(fresh), str(base))
    assert any("replint" in f for f in failures)
    _write(str(fresh), "pool", {"events_per_calib": 0.4,
                                "replint_clean": 1.0,
                                "pallas_cost_clean": 0.0,
                                "pallas_cost_findings": 2.0})
    failures = check(str(fresh), str(base))
    assert any("RPL2xx" in f for f in failures)
    assert not any("replint findings" in f for f in failures)


def test_tolerance_is_configurable(tmp_path):
    base, fresh = tmp_path / "base", tmp_path / "fresh"
    _write_all(str(base))
    _write_all(str(fresh), scale=0.70)
    failures = check(str(fresh), str(base), tolerance=0.5)
    # per-metric overrides are immune to --tolerance: roofline.n_cells
    # keeps its tight 20% band (losing a cell from the 4-cell CI set is
    # a real artifact-pipeline regression, never noise)
    assert [f.split(" ")[0] for f in failures] == ["roofline.n_cells"]
    _write(str(fresh), "roofline", {"n_cells": 4.0,
                                    "worst_roofline_frac": 0.004 * 0.70,
                                    "dryrun_fingerprint": 1234.0})
    assert check(str(fresh), str(base), tolerance=0.5) == []
    assert DEFAULT_TOLERANCE == pytest.approx(0.25)


# --- consolidated BENCH_replay.json trajectory -------------------------------

def test_trajectory_extends_baseline_history(tmp_path):
    """A fresh run's gated events_per_calib values append one labeled
    entry to the committed baseline's history; re-running with the same
    label replaces that entry instead of duplicating it."""
    fresh = tmp_path / "fresh"
    baseline = tmp_path / "base" / "BENCH_replay.json"
    os.makedirs(baseline.parent)
    with open(baseline, "w") as f:
        json.dump({"metric": "events_per_calib",
                   "history": [{"label": "pr3", "replay": 0.7,
                                "pool": 0.3, "evalsched": 1.8}]}, f)
    _write_all(str(fresh))
    doc = write_trajectory(str(fresh), str(baseline), label="pr4")
    assert doc is not None
    assert [e["label"] for e in doc["history"]] == ["pr3", "pr4"]
    assert doc["history"][-1]["replay"] == pytest.approx(0.8)
    assert doc["history"][-1]["pool"] == pytest.approx(0.4)
    assert doc["history"][-1]["evalsched"] == pytest.approx(2.0)
    # the per-knob replay rows ride along when the artifact carries them
    assert doc["history"][-1]["replay_full"] == pytest.approx(0.8)
    assert doc["history"][-1]["replay_legacy"] == pytest.approx(1.1)
    assert doc["history"][-1]["replay_placement"] == pytest.approx(0.95)
    assert doc["history"][-1]["replay_best_effort"] == pytest.approx(1.0)
    # the pre-PR-5 baseline entry simply lacks them — no backfill
    assert "replay_full" not in doc["history"][0]
    out = os.path.join(str(fresh), "BENCH_replay.json")
    assert os.path.exists(out)
    # same label again (a re-run) replaces, never duplicates
    _write(str(fresh), "pool", {"events_per_calib": 0.5})
    doc = write_trajectory(str(fresh), str(baseline), label="pr4")
    assert [e["label"] for e in doc["history"]] == ["pr3", "pr4"]
    assert doc["history"][-1]["pool"] == pytest.approx(0.5)


def test_trajectory_skipped_on_partial_run(tmp_path):
    """--only runs (or a bench failure) must not write a trajectory entry
    with holes: any missing gated artifact skips the consolidation."""
    fresh = tmp_path / "fresh"
    _write_all(str(fresh))
    os.remove(os.path.join(str(fresh), "evalsched.json"))
    assert write_trajectory(str(fresh), str(tmp_path / "none.json"),
                            label="x") is None
    assert not os.path.exists(os.path.join(str(fresh), "BENCH_replay.json"))
    assert set(TRAJECTORY_BENCHES) == {"replay", "pool", "evalsched",
                                       "serve"}
