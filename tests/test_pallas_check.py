"""Tests for ``repro.quality.pallas_check`` — the static BlockSpec/grid
checker must pass every shipped kernel and flag every deliberately broken
fixture in ``tests/fixtures/pallas_broken.py`` with exactly its code.

Everything here runs under the capturing stub: no TPU, no interpret-mode
execution — the kernels are traced, never lowered.
"""
import sys
from pathlib import Path

import pytest

jax = pytest.importorskip("jax")

from jax.experimental import pallas as pl  # noqa: E402

from repro.quality import pallas_check as pc  # noqa: E402

FIXTURES = Path(__file__).resolve().parent / "fixtures"


def _fixtures():
    if str(FIXTURES) not in sys.path:
        sys.path.insert(0, str(FIXTURES))
    import pallas_broken
    return pallas_broken


def _codes(trace) -> list[str]:
    return sorted(f.code for f in pc.check_traced(trace, "fixture.py"))


# ---------------------------------------------------------------------------
# the capturing stub
# ---------------------------------------------------------------------------

def test_capture_restores_pallas_call():
    original = pl.pallas_call
    with pc.capture_pallas_calls() as stub:
        assert pl.pallas_call is stub
    assert pl.pallas_call is original
    # restored even when the traced thunk raises
    with pytest.raises(RuntimeError):
        with pc.capture_pallas_calls():
            raise RuntimeError("boom")
    assert pl.pallas_call is original


def test_capture_records_contract_without_lowering():
    mod = _fixtures()
    with pc.capture_pallas_calls() as stub:
        mod.good_control()
    (call,) = stub.calls
    assert call.grid == (2,)
    assert len(call.in_specs) == 1 and len(call.operands) == 1
    assert tuple(call.operands[0].shape) == mod._X
    assert tuple(call.out_shape[0].shape) == mod._X


# ---------------------------------------------------------------------------
# fixture corpus: each bad_* flags exactly its code
# ---------------------------------------------------------------------------

def test_good_control_is_clean():
    assert _codes(_fixtures().good_control) == []


@pytest.mark.parametrize("name,code", [
    ("bad_index_map_arity", "RPL101"),
    ("bad_index_map_rank", "RPL101"),
    ("bad_block_rank", "RPL102"),
    ("bad_divisibility", "RPL103"),
    ("bad_alignment", "RPL104"),
    ("bad_kernel_arity", "RPL105"),
    ("bad_index_map_corner", "RPL101"),
])
def test_broken_fixture_flags_exactly_its_code(name, code):
    mod = _fixtures()
    assert _codes(getattr(mod, name)) == [code]


def test_corner_finding_names_the_corner():
    # the map is fine at the origin; only the (1,) corner misbehaves
    mod = _fixtures()
    (f,) = pc.check_traced(mod.bad_index_map_corner, "fixture.py")
    assert "corner (1,)" in f.message


def test_grid_corners_dedup():
    assert pc.grid_corners(()) == [()]
    assert pc.grid_corners((1,)) == [(0,)]
    assert pc.grid_corners((3,)) == [(0,), (2,)]
    assert pc.grid_corners((2, 1, 3)) == [(0, 0, 0), (0, 0, 2),
                                          (1, 0, 0), (1, 0, 2)]


# ---------------------------------------------------------------------------
# the grid_spec= calling convention and unknown-kwarg recording
# ---------------------------------------------------------------------------

def test_grid_spec_branch_unpacks_and_is_clean():
    mod = _fixtures()
    with pc.capture_pallas_calls() as stub:
        mod.good_grid_spec()
    (call,) = stub.calls
    assert call.grid == (2,)
    assert len(call.in_specs) == 1 and call.out_specs
    assert pc.check_call(call, "p") == []


def test_extra_kwargs_recorded_not_dropped():
    mod = _fixtures()
    with pc.capture_pallas_calls() as stub:
        mod.good_control()
    (call,) = stub.calls
    # the fixtures pass interpret=True, which the stub does not model
    assert call.extra_kwargs == ["interpret"]


def test_shipped_report_surfaces_kwargs():
    findings, kwargs_seen = pc.shipped_report()
    assert findings == []
    assert "interpret" in kwargs_seen


def test_findings_name_the_offending_spec():
    mod = _fixtures()
    got = pc.check_traced(mod.bad_divisibility, "fixture.py")
    (f,) = got
    assert f.path == "fixture.py"
    assert "in_specs[0]" in f.message and "100" in f.message


# ---------------------------------------------------------------------------
# per-check unit coverage via hand-built captured calls
# ---------------------------------------------------------------------------

def _aval(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jax.numpy.float32)


def _call(kernel, grid, in_specs, out_specs, out_shape, operands,
          scratch=()):
    return pc.CapturedCall(kernel=kernel, grid=tuple(grid),
                           in_specs=list(in_specs), out_specs=list(out_specs),
                           out_shape=list(out_shape),
                           scratch_shapes=list(scratch),
                           operands=list(operands))


def _k2(x_ref, o_ref):
    pass


def test_whole_operand_spec_skipped():
    # a spec without block_shape (whole-operand) has nothing to check
    spec = pl.BlockSpec()
    call = _call(_k2, (2,), [spec], [spec], [_aval((256, 256))],
                 [_aval((256, 256))])
    assert pc.check_call(call, "p") == []


def test_none_block_dim_is_whole_axis():
    spec = pl.BlockSpec((None, 256), lambda i: (i, 0))
    call = _call(_k2, (2,), [spec], [pl.BlockSpec((128, 256),
                                                  lambda i: (i, 0))],
                 [_aval((256, 256))], [_aval((256, 256))])
    codes = [f.code for f in pc.check_call(call, "p")]
    assert "RPL103" not in codes and "RPL104" not in codes


def test_trailing_whole_dim_is_aligned():
    # trailing block dim == operand dim (e.g. ssd's P=64 axis) is exempt
    spec = pl.BlockSpec((32, 64), lambda i: (i, 0))
    call = _call(_k2, (2,), [spec], [spec], [_aval((64, 64))],
                 [_aval((64, 64))])
    assert [f.code for f in pc.check_call(call, "p")] == []


def test_in_spec_operand_count_mismatch():
    spec = pl.BlockSpec((128, 256), lambda i: (i, 0))
    call = _call(_k2, (2,), [spec, spec], [spec], [_aval((256, 256))],
                 [_aval((256, 256))])
    codes = [f.code for f in pc.check_call(call, "p")]
    assert "RPL105" in codes


def test_partial_bound_kernel_arity():
    import functools

    def body(step, x_ref, o_ref, acc_ref):
        pass

    spec = pl.BlockSpec((128, 256), lambda i: (i, 0))
    bound = functools.partial(body, 3)      # one positional bound -> 3 refs
    call = _call(bound, (2,), [spec], [spec], [_aval((256, 256))],
                 [_aval((256, 256))],
                 scratch=[_aval((128, 128))])
    assert pc.check_call(call, "p") == []
    # without the scratch ref wired, the same body is a RPL105
    call2 = _call(bound, (2,), [spec], [spec], [_aval((256, 256))],
                  [_aval((256, 256))])
    assert [f.code for f in pc.check_call(call2, "p")] == ["RPL105"]


def test_varargs_kernel_not_checked():
    def body(*refs):
        pass

    spec = pl.BlockSpec((128, 256), lambda i: (i, 0))
    call = _call(body, (2,), [spec], [spec], [_aval((256, 256))],
                 [_aval((256, 256))])
    assert pc.check_call(call, "p") == []


def test_scratch_nonpositive_dim():
    spec = pl.BlockSpec((128, 256), lambda i: (i, 0))
    call = _call(_k2, (2,), [spec], [spec], [_aval((256, 256))],
                 [_aval((256, 256))], scratch=[_aval((128, 0))])
    codes = [f.code for f in pc.check_call(call, "p")]
    # RPL103 for the degenerate scratch dim, RPL105 for the unwired ref
    assert "RPL103" in codes


# ---------------------------------------------------------------------------
# acceptance: the three shipped kernels pass
# ---------------------------------------------------------------------------

def test_shipped_kernels_are_clean():
    findings = pc.check_shipped()
    assert findings == [], [f"{f.path}: {f.code} {f.message}"
                            for f in findings]


def test_shipped_covers_all_three_kernels():
    assert set(pc.SHIPPED_KERNELS) == {
        "src/repro/kernels/flash_attention/kernel.py",
        "src/repro/kernels/rmsnorm/kernel.py",
        "src/repro/kernels/ssd/kernel.py",
    }
    # every kernel entry actually makes at least one pallas_call
    for path, trace in pc.SHIPPED_KERNELS.items():
        with pc.capture_pallas_calls() as stub:
            trace()
        assert stub.calls, f"{path}: trace captured no pallas_call"
