"""Tests for ``repro.quality`` — the replint rule engine, suppressions,
baseline mechanism, CLI, and the acceptance property that the shipped tree
lints clean against the committed (empty) baseline.

Rule-corpus cases call ``lint_source`` directly with repo-shaped fake
paths, because scoping is part of each rule's contract: RPL003 only fires
in engine modules, RPL004 only in library code, RPL005 only in the two
declared hot modules.
"""
import json
import textwrap
from pathlib import Path

import pytest

from repro.quality import lint as rl
from repro.quality.rules import RULES, Finding, lint_source

REPO = Path(__file__).resolve().parent.parent

ENGINE = "src/repro/cluster/replay.py"        # engine + hot + library
LIB = "src/repro/core/trace.py"               # library, not engine
BENCH = "benchmarks/bench_fake.py"            # neither


def codes(path: str, src: str) -> list[str]:
    return [f.code for f in lint_source(path, textwrap.dedent(src))]


# ---------------------------------------------------------------------------
# rule corpus
# ---------------------------------------------------------------------------

def test_rpl000_syntax_error():
    got = lint_source(LIB, "def broken(:\n")
    assert [f.code for f in got] == ["RPL000"]
    assert got[0].line == 1


@pytest.mark.parametrize("src", [
    "import random\nrandom.random()\n",
    "import random\nrandom.seed(0)\n",           # reseeding the global RNG
    "from random import shuffle\nshuffle(xs)\n",
    "import numpy as np\nnp.random.randint(0, 5)\n",
    "import numpy\nnumpy.random.seed(1)\n",
    "from numpy import random as nr\nnr.normal()\n",
    "import random\nrandom.Random()\n",          # unseeded construction
    "import numpy as np\nnp.random.default_rng()\n",
])
def test_rpl001_fires(src):
    assert codes(LIB, src) == ["RPL001"]


@pytest.mark.parametrize("src", [
    "import random\nrng = random.Random(42)\nrng.random()\n",
    "import numpy as np\nrng = np.random.default_rng(7)\nrng.normal()\n",
    "import random\nrandom.Random(seed)\n",      # positional seed
    "import numpy as np\nnp.random.default_rng(seed=0)\n",
    # method draws on a local generator share names with module draws —
    # the alias map must not resolve local variables
    "def f(rng):\n    return rng.randint(0, 5)\n",
    "class random:\n    pass\n",                  # no import, no alias
])
def test_rpl001_quiet_when_seeded(src):
    assert "RPL001" not in codes(LIB, src)


@pytest.mark.parametrize("src,n", [
    ("for x in {1, 2, 3}:\n    pass\n", 1),
    ("xs = list({1, 2})\n", 1),
    ("xs = tuple(set(ys))\n", 1),
    ("for i, x in enumerate(frozenset(ys)):\n    pass\n", 1),
    ("xs = [x for x in {1, 2}]\n", 1),
    ("g = (x for x in set(ys))\n", 1),
    ("d = {x: 1 for x in {1, 2}}\n", 1),
    ("import heapq\nheapq.heappush(h, (1, {2, 3}))\n", 1),
    ("from heapq import heappush\nheappush(h, (t, set(ys)))\n", 1),
])
def test_rpl002_fires(src, n):
    assert codes(LIB, src).count("RPL002") == n


@pytest.mark.parametrize("src", [
    "for x in sorted({1, 2, 3}):\n    pass\n",
    "xs = list(sorted(set(ys)))\n",
    "s = {x for x in {1, 2}}\n",            # set-in set-out: no order escape
    "s = set(ys)\nfor x in s:\n    pass\n",  # variable: deliberately unflagged
    "import heapq\nheapq.heappush(h, (1, 'a'))\n",
])
def test_rpl002_quiet(src):
    assert "RPL002" not in codes(LIB, src)


@pytest.mark.parametrize("src", [
    "import time\nt = time.time()\n",
    "import time\nt = time.perf_counter()\n",
    "from time import monotonic\nt = monotonic()\n",
    "import datetime\nnow = datetime.datetime.now()\n",
    "key = id(obj)\n",
])
def test_rpl003_fires_in_engine_only(src):
    assert "RPL003" in codes(ENGINE, src)
    assert "RPL003" in codes(
        "src/repro/core/evalsched/coordinator.py", src)
    # identical source outside the engine is fine (benchmarks time things)
    assert "RPL003" not in codes(LIB, src)
    assert "RPL003" not in codes(BENCH, src)
    # runner.py measures real eval wall time on purpose
    assert "RPL003" not in codes("src/repro/core/evalsched/runner.py", src)


def test_rpl003_id_requires_args():
    assert "RPL003" not in codes(ENGINE, "x = id\n")


def test_rpl004_print_scoping():
    src = "print('hello')\n"
    assert codes(LIB, src) == ["RPL004"]
    assert codes(ENGINE, src) == ["RPL004"]
    assert "RPL004" not in codes(BENCH, src)
    assert "RPL004" not in codes("examples/demo.py", src)
    # the linter itself may print
    assert "RPL004" not in codes("src/repro/quality/lint.py", src)


@pytest.mark.parametrize("src,expect", [
    ("class Rec:\n    pass\n", True),
    ("class Rec:\n    __slots__ = ('a',)\n    a: int\n", False),
    ("class Rec:\n    __slots__: tuple = ('a',)\n", False),   # AnnAssign
    ("import dataclasses\n"
     "@dataclasses.dataclass(slots=True)\nclass Rec:\n    a: int\n", False),
    ("import dataclasses\n"
     "@dataclasses.dataclass\nclass Rec:\n    a: int\n", True),
    ("import enum\nclass Kind(enum.Enum):\n    A = 1\n", False),
    ("class Boom(RuntimeError):\n    pass\n", False),
    ("class MyError(SomeBaseError):\n    pass\n", False),
])
def test_rpl005_slots_in_hot_module(src, expect):
    got = "RPL005" in codes(ENGINE, src)
    assert got is expect
    # never applies outside the declared hot modules
    assert "RPL005" not in codes(LIB, src)


def test_findings_sorted_and_rendered():
    src = "import time\nprint(1)\nt = time.time()\n"
    got = lint_source(ENGINE, src)
    assert [f.code for f in got] == ["RPL004", "RPL003"]
    assert [f.line for f in got] == sorted(f.line for f in got)
    r = got[0].render()
    assert r.startswith(f"{ENGINE}:2:") and "RPL004" in r


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------

def _write(tmp_path: Path, name: str, src: str) -> Path:
    p = tmp_path / name
    p.write_text(textwrap.dedent(src))
    return p


def test_suppression_by_code(tmp_path, monkeypatch):
    p = _write(tmp_path, "mod.py", """\
        import random
        a = random.random()  # replint: disable=RPL001
        b = random.random()  # replint: disable=RPL002
        c = random.random()  # replint: disable
        d = random.random()
    """)
    monkeypatch.chdir(tmp_path)
    kept, n_suppressed = rl.lint_file(p.name)
    # line 2 (matching code) and line 4 (bare disable) are suppressed;
    # line 3 disables the wrong code, line 5 has no comment
    assert n_suppressed == 2
    assert sorted(f.line for f in kept) == [3, 5]
    assert all(f.code == "RPL001" for f in kept)


def test_suppression_multiple_codes():
    got = rl._suppressed_codes("x = 1  # replint: disable=RPL001, RPL003")
    assert got == frozenset({"RPL001", "RPL003"})
    assert rl._suppressed_codes("x = 1  # replint: disable") == frozenset()
    assert rl._suppressed_codes("x = 1  # unrelated comment") is None


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------

def _finding(path="a.py", code="RPL001", line=3, snippet="x = rnd()"):
    return Finding(code=code, path=path, line=line, col=1,
                   message="m", snippet=snippet)


def test_baseline_round_trip(tmp_path):
    base = tmp_path / "baseline.json"
    f1, f2 = _finding(line=3), _finding(line=9, code="RPL002", snippet="s")
    rl.write_baseline(str(base), [f1, f2])
    loaded = rl.load_baseline(str(base))
    assert loaded[f1.fingerprint()] == 1 and loaded[f2.fingerprint()] == 1

    # same fingerprints at drifted lines still match; one extra instance of
    # f1's fingerprint is new; f2 fixed -> its entry is stale
    now = [_finding(line=30), _finding(line=31), _finding(line=99)]
    new, n_baselined, n_stale = rl.apply_baseline(now, loaded)
    assert n_baselined == 1 and n_stale == 1
    assert len(new) == 2


def test_baseline_missing_file_is_empty(tmp_path):
    assert not rl.load_baseline(str(tmp_path / "nope.json"))


def test_baseline_invalidated_by_edit(tmp_path):
    base = tmp_path / "baseline.json"
    rl.write_baseline(str(base), [_finding(snippet="old = rnd()")])
    new, n_baselined, n_stale = rl.apply_baseline(
        [_finding(snippet="new = rnd()")], rl.load_baseline(str(base)))
    assert len(new) == 1 and n_baselined == 0 and n_stale == 1


# ---------------------------------------------------------------------------
# CLI / report
# ---------------------------------------------------------------------------

def test_cli_exit_codes_and_report(tmp_path, monkeypatch, capsys):
    _write(tmp_path, "clean.py", "x = 1\n")
    _write(tmp_path, "dirty.py", "import random\nrandom.random()\n")
    monkeypatch.chdir(tmp_path)
    empty = tmp_path / "empty_baseline.json"
    empty.write_text("[]\n")

    report = tmp_path / "replint.json"
    rc = rl.main(["dirty.py", "clean.py", "--baseline", str(empty),
                  "--report", str(report)])
    assert rc == 1
    doc = json.loads(report.read_text())
    assert doc["tool"] == "replint" and not doc["clean"]
    assert doc["n_files"] == 2 and doc["n_findings"] == 1
    assert doc["findings"][0]["code"] == "RPL001"
    assert set(doc["rules"]) == set(RULES)
    assert "RPL001" in capsys.readouterr().out

    assert rl.main(["clean.py", "--baseline", str(empty)]) == 0
    assert rl.main(["no_such_dir", "--baseline", str(empty)]) == 2


def test_cli_write_baseline_then_clean(tmp_path, monkeypatch):
    _write(tmp_path, "dirty.py", "import random\nrandom.random()\n")
    monkeypatch.chdir(tmp_path)
    base = tmp_path / "base.json"
    assert rl.main(["dirty.py", "--baseline", str(base),
                    "--write-baseline"]) == 0
    # grandfathered: same tree now lints clean against its baseline
    assert rl.main(["dirty.py", "--baseline", str(base)]) == 0
    # reports the stale entry once the violation is fixed
    _write(tmp_path, "dirty.py", "x = 1\n")
    assert rl.main(["dirty.py", "--baseline", str(base)]) == 0


def test_iter_py_files_sorted_and_skips(tmp_path, monkeypatch):
    (tmp_path / "pkg" / "__pycache__").mkdir(parents=True)
    _write(tmp_path / "pkg", "b.py", "")
    _write(tmp_path / "pkg", "a.py", "")
    _write(tmp_path / "pkg" / "__pycache__", "x.py", "")
    (tmp_path / "pkg" / "note.txt").write_text("")
    monkeypatch.chdir(tmp_path)
    assert rl.iter_py_files(["pkg"]) == ["pkg/a.py", "pkg/b.py"]
    with pytest.raises(FileNotFoundError):
        rl.iter_py_files(["missing"])


def test_verdict_shape(tmp_path, monkeypatch):
    _write(tmp_path, "dirty.py", "import random\nrandom.random()\n")
    monkeypatch.chdir(tmp_path)
    v = rl.verdict(["dirty.py"])
    assert v == {"clean": False, "findings": 1, "baselined": 0}


# ---------------------------------------------------------------------------
# the committed known-bad corpus
# ---------------------------------------------------------------------------

BAD_CORPUS = REPO / "tests" / "fixtures" / "replint_bad.py"


def _expected_corpus_codes() -> list[str]:
    out = []
    for line in BAD_CORPUS.read_text().splitlines():
        if "# EXPECT " in line:
            out.append(line.split("# EXPECT ")[1].strip())
    return sorted(out)


def test_bad_corpus_findings_match_expect_comments():
    # linted under an engine+hot+library path so every rule family
    # applies; lint_source is pre-suppression, so drop the one finding
    # whose line carries the disable comment (the CLI test below checks
    # it is counted as suppressed)
    src = BAD_CORPUS.read_text()
    lines = src.splitlines()
    got = [f for f in lint_source("src/repro/cluster/replay.py", src)
           if "replint: disable" not in lines[f.line - 1]]
    assert sorted(f.code for f in got) == _expected_corpus_codes()


def test_bad_corpus_fails_cli(tmp_path, monkeypatch):
    # the acceptance criterion: the CLI exits non-zero on the corpus (laid
    # out at a repo-shaped path so scoped rules fire), 1 suppression noted
    dst = tmp_path / "src" / "repro" / "cluster" / "replay.py"
    dst.parent.mkdir(parents=True)
    dst.write_text(BAD_CORPUS.read_text())
    empty = tmp_path / "empty.json"
    empty.write_text("[]\n")
    monkeypatch.chdir(tmp_path)
    report = tmp_path / "report.json"
    assert rl.main(["src", "--baseline", str(empty),
                    "--report", str(report)]) == 1
    doc = json.loads(report.read_text())
    assert doc["n_findings"] == len(_expected_corpus_codes())
    assert doc["n_suppressed"] == 1


# ---------------------------------------------------------------------------
# acceptance: the shipped tree is clean with the committed empty baseline
# ---------------------------------------------------------------------------

def test_shipped_baseline_is_empty():
    assert json.loads(Path(rl.DEFAULT_BASELINE).read_text()) == []


def test_repo_lints_clean(monkeypatch):
    monkeypatch.chdir(REPO)
    report = rl.run_lint(["src/repro", "benchmarks", "examples"])
    assert report["clean"], report["findings"]
    assert report["n_stale_baseline"] == 0
    assert report["n_files"] > 40       # really walked the tree
