"""Trace generator + scheduler: the §3 characterization claims hold on the
synthetic Acme trace, the queue simulation conserves resources, and the
cordon/elastic accounting round-trips exactly."""
import random

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.cluster import (KALOS, SEREN, ReservationScheduler, generate_jobs,
                           simulate_queue, trace_summary)
from repro.cluster.workload import JobRecord

HORIZON = 6 * 30 * 24 * 60.0


@pytest.fixture(scope="module")
def kalos_jobs():
    jobs = generate_jobs(KALOS, seed=0)
    return simulate_queue(jobs, KALOS.n_gpus, reserved_frac=0.97)


def test_kalos_type_shares(kalos_jobs):
    """Fig. 4: eval 92.9% of jobs / ~0.8% of GPU time; pretraining 3.2% /
    ~94% (Kalos)."""
    s = trace_summary(kalos_jobs, KALOS.n_gpus, HORIZON)["type_shares"]
    assert abs(s["evaluation"]["count_frac"] - 0.929) < 0.01
    assert s["evaluation"]["gputime_frac"] < 0.02
    assert abs(s["pretrain"]["count_frac"] - 0.032) < 0.005
    assert s["pretrain"]["gputime_frac"] > 0.90


def test_kalos_duration_median(kalos_jobs):
    """Fig. 2a: median GPU job duration ~2 minutes."""
    med = trace_summary(kalos_jobs, KALOS.n_gpus, HORIZON)["duration"]["median_min"]
    assert 0.8 <= med <= 3.5


def test_kalos_demand_skew(kalos_jobs):
    """Fig. 3b: jobs >=256 GPUs take >90% of GPU time; single-GPU <2%."""
    d = trace_summary(kalos_jobs, KALOS.n_gpus, HORIZON)["demand"]
    assert d["gputime_frac_ge256"] > 0.9
    assert d["gputime_frac_single_gpu"] < 0.02
    assert d["frac_jobs_ge8"] < 0.10       # most jobs are small


def test_queue_delay_inversion(kalos_jobs):
    """Fig. 6: evaluation has the LONGEST median queueing delay despite the
    smallest demand — the paper's reservation-policy inversion."""
    q = trace_summary(kalos_jobs, KALOS.n_gpus, HORIZON)["queue"]
    ev = q["evaluation"]["median_min"]
    assert ev > 1.0
    for t, v in q.items():
        if t != "evaluation":
            assert v["median_min"] < ev


def test_final_status_mix(kalos_jobs):
    """Fig. 17: ~40% of jobs fail using ~10% of time; canceled ~7% of jobs
    but the majority of GPU time."""
    s = trace_summary(kalos_jobs, KALOS.n_gpus, HORIZON)["status"]
    assert abs(s["failed"]["count_frac"] - 0.40) < 0.04
    assert s["failed"]["gputime_frac"] < 0.2
    assert s["canceled"]["count_frac"] < 0.12
    assert s["canceled"]["gputime_frac"] > 0.5


def test_seren_pretrain_share():
    jobs = generate_jobs(SEREN, seed=1, n_jobs=60_000)
    s = trace_summary(jobs, SEREN.n_gpus, HORIZON)["type_shares"]
    assert s["pretrain"]["gputime_frac"] > 0.6
    assert s["evaluation"]["gputime_frac"] < 0.05


# --- cordon / elastic accounting ---------------------------------------------

def test_cordon_uncordon_with_zero_free_gpus_is_noop():
    """Regression: cordoning a fully-allocated cluster must take nothing
    and the round-trip must leave the pool accounting untouched — repeated
    cycles included."""
    sched = ReservationScheduler(64, 0.75)
    hog = JobRecord(0, "pretrain", 64, 0.0, 10.0, "completed")
    assert sched.can_start(hog)
    sched.start(hog)
    assert (sched.free_reserved, sched.free_spare) == (0, 0)
    for _ in range(50):
        take = sched.cordon(8)
        assert take == (0, 0)
        sched.uncordon(*take)
        assert (sched.free_reserved, sched.free_spare) == (0, 0)
        assert sched.free_reserved >= 0 and sched.free_spare >= 0
    sched.finish(hog)
    assert (sched.free_reserved, sched.free_spare) == (48, 16)


@settings(max_examples=25, deadline=None)
@given(gpus=st.integers(8, 96), frac=st.floats(0.0, 1.0),
       seed=st.integers(0, 1000))
def test_repeated_cordon_cycles_conserve_accounting(gpus, frac, seed):
    """Random interleavings of job start/finish and cordon/uncordon: free
    counts never go negative and every cordon hands back exactly what it
    took, so the final state equals the initial one."""
    rng = random.Random(seed)
    sched = ReservationScheduler(gpus, frac)
    init = (sched.free_reserved, sched.free_spare)
    running, cordons = [], []
    for step in range(200):
        op = rng.randrange(4)
        if op == 0:
            j = JobRecord(step, rng.choice(["pretrain", "evaluation"]),
                          rng.randint(1, gpus), 0.0, 1.0, "completed")
            if sched.can_start(j):
                sched.start(j)
                running.append(j)
        elif op == 1 and running:
            sched.finish(running.pop(rng.randrange(len(running))))
        elif op == 2:
            cordons.append(sched.cordon(rng.randint(1, gpus)))
        elif op == 3 and cordons:
            sched.uncordon(*cordons.pop(rng.randrange(len(cordons))))
        assert sched.free_reserved >= 0, "reserved pool went negative"
        assert sched.free_spare >= 0, "spare pool went negative"
        allocated = sum(r + s for _, r, s in (j._alloc for j in running))
        outstanding = sum(r + s for r, s in cordons)
        assert sched.free_reserved + sched.free_spare \
            + allocated + outstanding == gpus
    for j in running:
        sched.finish(j)
    for take in cordons:
        sched.uncordon(*take)
    assert (sched.free_reserved, sched.free_spare) == init


def test_release_partial_and_reacquire_round_trip():
    """Elastic shrink accounting: partial release detaches GPUs from the
    job without freeing them; reacquire restores the allocation so finish
    frees exactly the original amount."""
    sched = ReservationScheduler(32, 0.5)
    job = JobRecord(0, "pretrain", 24, 0.0, 10.0, "completed")
    sched.start(job)
    free0 = (sched.free_reserved, sched.free_spare)
    take = sched.release_partial(job, 8)
    assert sum(take) == 8
    # the pools saw nothing: the GPUs left with the cordoned node
    assert (sched.free_reserved, sched.free_spare) == free0
    kind, r, s = job._alloc
    assert r + s == 16
    sched.reacquire(job, *take)
    _, r, s = job._alloc
    assert r + s == 24
    sched.finish(job)
    assert (sched.free_reserved, sched.free_spare) == (16, 16)


# --- scheduler invariants ----------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(n=st.integers(5, 60), gpus=st.integers(8, 64),
       frac=st.floats(0.3, 0.9), seed=st.integers(0, 100))
def test_queue_sim_conserves_capacity(n, gpus, frac, seed):
    rng = np.random.default_rng(seed)
    jobs = [JobRecord(i, rng.choice(["evaluation", "pretrain", "debug"]),
                      int(rng.integers(1, gpus + 1)),
                      float(rng.uniform(0, 100)),
                      float(rng.uniform(0.1, 20)), "completed")
            for i in range(n)]
    out = simulate_queue(list(jobs), gpus, reserved_frac=frac)
    # every job started (queue_min finite) and no negative waits.
    # Times are bucketed to 1e-4 min with frees applied before same-bucket
    # starts: back-to-back start-at-finish events reconstruct with ~1 ULP
    # skew, which is scheduling latency zero, not an overlap.
    events = []
    for j in out:
        assert j.queue_min >= 0
        start = j.submit_min + j.queue_min
        events.append((round(start, 4), 0, j.gpus))
        events.append((round(start + j.duration_min, 4), -1, -j.gpus))
    events.sort()
    used = 0
    for _, _, delta in events:
        used += delta
        assert used <= gpus + 1e-9       # capacity never exceeded
    assert used == 0                      # everything finished
