"""The §Perf optimization variants must be math-preserving: same loss and
same updated params as the baseline on a tiny model (single-device mesh —
shardings degenerate but every code path still executes)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro.config import ModelConfig, ParallelConfig, TrainConfig
from repro.models import Model
from repro.sharding import make_rules
from repro.train.optimizer import adamw_init
from repro.train.train_step import compile_train_step


def _step_result(cfg: ModelConfig, parallel: ParallelConfig):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    model = Model(cfg, parallel, make_rules(mesh, parallel))
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 16)
    fn, p_sh, o_sh, b_sh = compile_train_step(
        model, TrainConfig(global_batch=2, seq_len=16), mesh, parallel,
        donate=False)
    with mesh:
        p2, o2, metrics = fn(params, adamw_init(params), batch)
    return p2, float(metrics["loss"])


@pytest.mark.parametrize("variant", [
    dict(shard_model_axes=False, sequence_parallel=False),   # fsdp2d
    dict(grad_dtype="bfloat16"),                             # bf16 grads
    dict(zero="zero1"),
    dict(remat="full"),
])
def test_variant_preserves_math(tiny_cfg, variant):
    cfg = dataclasses.replace(tiny_cfg, dtype="float32")
    base = ParallelConfig(remat="none", moe_impl="dense")
    p_base, l_base = _step_result(cfg, base)
    p_var, l_var = _step_result(cfg, dataclasses.replace(base, **variant))
    # bf16 grads evaluate the forward on the bf16 view of the params, so a
    # float32-dtype model sees bf16-rounding-level shifts
    tol = 2e-2 if variant.get("grad_dtype") == "bfloat16" else 1e-5
    assert abs(l_var - l_base) < tol
    for a, b in zip(jax.tree_util.tree_leaves(p_base),
                    jax.tree_util.tree_leaves(p_var)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=tol, atol=tol)
