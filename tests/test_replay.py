"""Failure-aware trace replay: exact parity with simulate_queue, capacity
conservation under injected failures, rollback accounting, the two-round
cordon path, backfill, and the never-started sentinel."""
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.cluster import (DEFAULT_TAXONOMY, KALOS, NEVER_STARTED,
                           FailureInjector, ReplayConfig, ReplayFailureClass,
                           generate_jobs, replay_trace, simulate_queue)
from repro.cluster.failures import HARDWARE, INFRA, PREEMPTION
from repro.cluster.workload import JobRecord


class ScriptedInjector:
    """Deterministic injector: pops pre-scripted (ttf, cls) draws."""

    def __init__(self, script):
        self.script = list(script)

    def draw(self, jtype, gpus, remaining_min):
        if not self.script:
            return None
        hit = self.script.pop(0)
        if hit is None:
            return None
        ttf, cls = hit
        return (ttf, cls) if ttf < remaining_min else None


def _random_jobs(rng, n, gpus_max, jtypes=("evaluation", "pretrain", "debug")):
    return [JobRecord(i, str(rng.choice(list(jtypes))),
                      int(rng.integers(1, gpus_max + 1)),
                      float(rng.uniform(0, 200)),
                      float(rng.uniform(0.1, 30)), "completed")
            for i in range(n)]


def _assert_capacity_conserved(segments, total_gpus):
    events = []
    for _, gpus, t0, t1, _ in segments:
        assert t1 >= t0
        events.append((round(t0, 6), 1, gpus))
        events.append((round(t1, 6), 0, -gpus))   # frees before same-t starts
    events.sort()
    used = 0
    for _, _, d in events:
        used += d
        assert used <= total_gpus
    assert used == 0


# --- parity ------------------------------------------------------------------

def test_disabled_injection_matches_simulate_queue():
    """replay_trace(injector=None) IS simulate_queue — bit-exact delays."""
    jobs = generate_jobs(KALOS, seed=3, n_jobs=4000)
    simulate_queue(jobs, KALOS.n_gpus, reserved_frac=0.9)
    base = [j.queue_min for j in jobs]
    # a failure-injected replay in between must not perturb a later clean one
    replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.9,
                 config=ReplayConfig(injector=FailureInjector(seed=7)))
    replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.9, config=ReplayConfig())
    assert [j.queue_min for j in jobs] == base
    assert all(j.restarts == 0 and j.lost_gpu_min == 0.0 for j in jobs)


# --- conservation under failures (property) ----------------------------------

@settings(max_examples=12, deadline=None)
@given(n=st.integers(20, 120), gpus=st.integers(8, 48),
       seed=st.integers(0, 50), rate=st.floats(0.0, 0.5))
def test_injected_replay_conserves_capacity(n, gpus, seed, rate):
    """For ANY small trace and failure rate: GPU usage never exceeds the
    cluster, waits are non-negative, and accounting fields stay sane."""
    rng = np.random.default_rng(seed)
    jobs = _random_jobs(rng, n, gpus)
    inj = FailureInjector(seed=seed, rate_scale=rate * 5e3)
    res = replay_trace(jobs, gpus, reserved_frac=0.6,
                       config=ReplayConfig(injector=inj, node_gpus=4,
                                           record_segments=True, seed=seed))
    _assert_capacity_conserved(res.segments, gpus)
    killed = set(res.killed_job_ids)
    finished = {s[0] for s in res.segments if s[4] == "finish"}
    for j in jobs:
        assert j.queue_min >= 0 and j.requeue_wait_min >= 0
        assert j.lost_gpu_min >= 0
        assert j.restarts <= 1 + ReplayConfig.max_restarts
        # every job either finishes or exhausts its restart budget
        assert (j.job_id in finished) != (j.job_id in killed)
        if j.job_id in killed:
            assert j.restarts == 1 + ReplayConfig.max_restarts
    # every injected failure is accounted as exactly one restart attempt
    assert sum(s.failures for s in res.by_class.values()) \
        == res.total_restarts


# --- rollback accounting -----------------------------------------------------

def test_checkpoint_rollback_accounting_exact():
    """A pretrain job failing at minute 50 with a 30-min checkpoint cadence
    loses exactly 20 minutes of work and resumes from minute 30."""
    infra = next(c for c in DEFAULT_TAXONOMY if c.name == INFRA)
    job = JobRecord(0, "pretrain", 8, 0.0, 100.0, "completed")
    inj = ScriptedInjector([(50.0, infra), None])
    res = replay_trace([job], 16, reserved_frac=0.5,
                       config=ReplayConfig(injector=inj,
                                           checkpoint_interval_min=30.0,
                                           record_segments=True))
    assert job.restarts == 1
    assert job.lost_gpu_min == pytest.approx(20.0 * 8)
    assert res.by_class[INFRA].failures == 1
    # run 0..50 (fail), requeue after overhead, run the remaining 70 min
    (id0, _, s0, e0, k0), (id1, _, s1, e1, k1) = res.segments
    assert (k0, k1) == ("fail", "finish")
    assert (s0, e0) == (0.0, 50.0)
    assert s1 == pytest.approx(50.0 + infra.restart_overhead_min)
    assert e1 - s1 == pytest.approx(100.0 - 30.0)


def test_uncheckpointed_type_restarts_from_scratch():
    infra = next(c for c in DEFAULT_TAXONOMY if c.name == INFRA)
    job = JobRecord(0, "debug", 2, 0.0, 40.0, "completed")
    inj = ScriptedInjector([(25.0, infra), None])
    res = replay_trace([job], 8,
                       config=ReplayConfig(injector=inj,
                                           record_segments=True))
    assert job.lost_gpu_min == pytest.approx(25.0 * 2)   # all progress lost
    assert res.segments[-1][3] - res.segments[-1][2] == pytest.approx(40.0)


def test_max_restarts_kills_job():
    infra = next(c for c in DEFAULT_TAXONOMY if c.name == INFRA)
    job = JobRecord(0, "debug", 1, 0.0, 50.0, "completed")
    inj = ScriptedInjector([(10.0, infra)] * 3)
    res = replay_trace([job], 8,
                       config=ReplayConfig(injector=inj, max_restarts=2,
                                           record_segments=True))
    assert res.killed_job_ids == [0]
    assert job.restarts == 3
    assert not any(s[4] == "finish" for s in res.segments)


# --- cordon path -------------------------------------------------------------

def test_hardware_failure_triggers_two_round_cordon():
    hw = next(c for c in DEFAULT_TAXONOMY if c.name == HARDWARE)
    cls = ReplayFailureClass(HARDWARE, rate_per_gpu_hour=hw.rate_per_gpu_hour,
                             jtype_mult={}, needs_cordon=True,
                             restart_overhead_min=5.0, repair_min=60.0)
    job = JobRecord(0, "pretrain", 16, 0.0, 120.0, "completed")
    inj = ScriptedInjector([(30.0, cls), None])
    res = replay_trace([job], 32,
                       config=ReplayConfig(injector=inj, node_gpus=8,
                                           record_segments=True))
    assert res.cordon_events == 1
    assert res.detection_probes > 0        # the §6.1 sweep actually ran
    assert any(s[4] == "finish" for s in res.segments)   # job still completes


def test_cordon_shrinks_then_repair_restores_capacity():
    """While a node is cordoned, a full-cluster job cannot start; after the
    repair event it can."""
    cls = ReplayFailureClass(HARDWARE, 1.0, {}, needs_cordon=True,
                             restart_overhead_min=1.0, repair_min=500.0)
    first = JobRecord(0, "pretrain", 8, 0.0, 50.0, "completed")
    full = JobRecord(1, "pretrain", 32, 60.0, 10.0, "completed")
    inj = ScriptedInjector([(20.0, cls), None, None])
    res = replay_trace([first, full], 32,
                       config=ReplayConfig(injector=inj, node_gpus=8,
                                           max_cordon_frac=0.5,
                                           record_segments=True))
    assert res.cordon_events == 1
    # the 32-GPU job must wait for the repair at t = 20 + 500
    start_full = next(s[2] for s in res.segments if s[0] == 1)
    assert start_full >= 520.0
    assert full.queue_min == pytest.approx(start_full - 60.0)


def test_preemption_never_hits_reserved_types():
    pre = next(c for c in DEFAULT_TAXONOMY if c.name == PREEMPTION)
    assert pre.rate_for("pretrain") == 0.0
    assert pre.rate_for("sft") == 0.0
    assert pre.rate_for("evaluation") > 0.0


# --- failure impact on the paper's metrics -----------------------------------

def test_failures_cost_gpu_hours_and_restarts():
    jobs = generate_jobs(KALOS, seed=0, n_jobs=20_000)
    res = replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.97,
                       config=ReplayConfig(
                           injector=FailureInjector(seed=1, rate_scale=4.0)))
    s = res.summary()
    assert s["total_restarts"] > 0
    assert s["total_lost_gpu_hours"] > 0
    # pretraining dominates lost GPU time (paper §5.1)
    lost = s["lost_gpu_hours_by_jtype"]
    assert lost["pretrain"]["gpu_hours"] >= max(
        v["gpu_hours"] for t, v in lost.items() if t != "pretrain")
    # and the injected classes all appear in the JSON-ready breakdown
    assert set(s["lost_gpu_hours_by_class"]) >= {HARDWARE, INFRA}


# --- backfill ----------------------------------------------------------------

def test_backfill_never_worse_for_eval_and_conserves():
    jobs = generate_jobs(KALOS, seed=2, n_jobs=8000)
    simulate_queue(jobs, KALOS.n_gpus, reserved_frac=0.97)
    fifo_eval = np.median([j.queue_min for j in jobs
                           if j.jtype == "evaluation"])
    res = replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.97,
                       config=ReplayConfig(backfill=True,
                                           record_segments=True))
    _assert_capacity_conserved(res.segments, KALOS.n_gpus)
    bf_eval = np.median([j.queue_min for j in jobs
                         if j.jtype == "evaluation"])
    assert bf_eval <= fifo_eval
    assert all(j.started for j in jobs)


# --- never-started sentinel --------------------------------------------------

def test_impossible_job_rejected_with_warning(caplog):
    jobs = [JobRecord(0, "pretrain", 128, 0.0, 10.0, "completed"),
            JobRecord(1, "pretrain", 16, 1.0, 10.0, "completed")]
    with caplog.at_level("WARNING", logger="repro"):
        res = replay_trace(jobs, 64, config=ReplayConfig())
    assert any("rejected" in r.message for r in caplog.records)
    assert res.rejected_job_ids == [0]
    assert jobs[0].queue_min == NEVER_STARTED
    assert not jobs[0].started
    assert jobs[1].started and jobs[1].queue_min == pytest.approx(0.0)


def test_wedged_head_marks_blocked_jobs_never_started():
    """Legacy mode (no rejection): an impossible FIFO head wedges its class;
    everything stuck behind it surfaces as NEVER_STARTED, not 0.0."""
    jobs = [JobRecord(0, "pretrain", 128, 0.0, 10.0, "completed"),
            JobRecord(1, "pretrain", 16, 1.0, 10.0, "completed"),
            JobRecord(2, "evaluation", 2, 2.0, 5.0, "completed")]
    replay_trace(jobs, 64,
                 config=ReplayConfig(reject_impossible=False))
    assert jobs[0].queue_min == NEVER_STARTED
    assert jobs[1].queue_min == NEVER_STARTED   # stuck behind the wedge
    assert jobs[2].started                       # other class unaffected


def test_queue_stats_excludes_never_started():
    from repro.cluster.analysis import queue_stats
    jobs = [JobRecord(0, "evaluation", 2, 0.0, 5.0, "completed",
                      queue_min=4.0),
            JobRecord(1, "evaluation", 2, 0.0, 5.0, "completed",
                      queue_min=NEVER_STARTED)]
    q = queue_stats(jobs)
    assert q["evaluation"]["median_min"] == 4.0    # inf filtered out
    assert q["evaluation"]["n_never_started"] == 1
