"""Failure-aware trace replay: exact parity with simulate_queue, capacity
conservation under injected failures, rollback accounting, the two-round
cordon path, diagnosis-driven recovery (elastic shrink / in-place restart),
greedy vs EASY backfill, and the never-started sentinel."""
import collections
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.cluster import (DEFAULT_TAXONOMY, KALOS, NEVER_STARTED,
                           FailureInjector, ReplayConfig, ReplayFailureClass,
                           generate_jobs, recovery_stats, replay_trace,
                           simulate_queue, synthesize_failure_log)
from repro.cluster.failures import HARDWARE, INFRA, PREEMPTION
from repro.cluster.workload import JobRecord


class ScriptedInjector:
    """Deterministic injector: pops pre-scripted (ttf, cls) draws."""

    def __init__(self, script):
        self.script = list(script)

    def draw(self, jtype, gpus, remaining_min):
        if not self.script:
            return None
        hit = self.script.pop(0)
        if hit is None:
            return None
        ttf, cls = hit
        return (ttf, cls) if ttf < remaining_min else None


def _random_jobs(rng, n, gpus_max, jtypes=("evaluation", "pretrain", "debug")):
    return [JobRecord(i, str(rng.choice(list(jtypes))),
                      int(rng.integers(1, gpus_max + 1)),
                      float(rng.uniform(0, 200)),
                      float(rng.uniform(0.1, 30)), "completed")
            for i in range(n)]


def _assert_capacity_conserved(segments, total_gpus):
    events = []
    for _, gpus, t0, t1, _ in segments:
        assert t1 >= t0
        events.append((round(t0, 6), 1, gpus))
        events.append((round(t1, 6), 0, -gpus))   # frees before same-t starts
    events.sort()
    used = 0
    for _, _, d in events:
        used += d
        assert used <= total_gpus
    assert used == 0


# --- parity ------------------------------------------------------------------

def test_disabled_injection_matches_simulate_queue():
    """replay_trace(injector=None) IS simulate_queue — bit-exact delays."""
    jobs = generate_jobs(KALOS, seed=3, n_jobs=4000)
    simulate_queue(jobs, KALOS.n_gpus, reserved_frac=0.9)
    base = [j.queue_min for j in jobs]
    # a failure-injected replay in between must not perturb a later clean one
    replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.9,
                 config=ReplayConfig(injector=FailureInjector(seed=7)))
    replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.9, config=ReplayConfig())
    assert [j.queue_min for j in jobs] == base
    assert all(j.restarts == 0 and j.lost_gpu_min == 0.0 for j in jobs)


# --- conservation under failures (property) ----------------------------------

@settings(max_examples=12, deadline=None)
@given(n=st.integers(20, 120), gpus=st.integers(8, 48),
       seed=st.integers(0, 50), rate=st.floats(0.0, 0.5))
def test_injected_replay_conserves_capacity(n, gpus, seed, rate):
    """For ANY small trace and failure rate: GPU usage never exceeds the
    cluster, waits are non-negative, and accounting fields stay sane."""
    rng = np.random.default_rng(seed)
    jobs = _random_jobs(rng, n, gpus)
    inj = FailureInjector(seed=seed, rate_scale=rate * 5e3)
    cfg = ReplayConfig(injector=inj, node_gpus=4,
                       record_segments=True, seed=seed)
    res = replay_trace(jobs, gpus, reserved_frac=0.6, config=cfg)
    _assert_capacity_conserved(res.segments, gpus)
    killed = set(res.killed_job_ids)
    finished = {s[0] for s in res.segments if s[4] == "finish"}
    for j in jobs:
        assert j.queue_min >= 0 and j.requeue_wait_min >= 0
        assert j.lost_gpu_min >= 0
        assert j.restarts <= 1 + cfg.max_restarts
        # every job either finishes or exhausts its restart budget
        assert (j.job_id in finished) != (j.job_id in killed)
        if j.job_id in killed:
            assert j.restarts == 1 + cfg.max_restarts
    # every injected failure is accounted as exactly one restart attempt
    assert sum(s.failures for s in res.by_class.values()) \
        == res.total_restarts


# --- rollback accounting -----------------------------------------------------

def test_checkpoint_rollback_accounting_exact():
    """A pretrain job failing at minute 50 with a 30-min checkpoint cadence
    loses exactly 20 minutes of work and resumes from minute 30."""
    infra = next(c for c in DEFAULT_TAXONOMY if c.name == INFRA)
    job = JobRecord(0, "pretrain", 8, 0.0, 100.0, "completed")
    inj = ScriptedInjector([(50.0, infra), None])
    res = replay_trace([job], 16, reserved_frac=0.5,
                       config=ReplayConfig(injector=inj,
                                           checkpoint_interval_min=30.0,
                                           record_segments=True))
    assert job.restarts == 1
    assert job.lost_gpu_min == pytest.approx(20.0 * 8)
    assert res.by_class[INFRA].failures == 1
    # run 0..50 (fail), requeue after overhead, run the remaining 70 min
    (id0, _, s0, e0, k0), (id1, _, s1, e1, k1) = res.segments
    assert (k0, k1) == ("fail", "finish")
    assert (s0, e0) == (0.0, 50.0)
    assert s1 == pytest.approx(50.0 + infra.restart_overhead_min)
    assert e1 - s1 == pytest.approx(100.0 - 30.0)


def test_uncheckpointed_type_restarts_from_scratch():
    infra = next(c for c in DEFAULT_TAXONOMY if c.name == INFRA)
    job = JobRecord(0, "debug", 2, 0.0, 40.0, "completed")
    inj = ScriptedInjector([(25.0, infra), None])
    res = replay_trace([job], 8,
                       config=ReplayConfig(injector=inj,
                                           record_segments=True))
    assert job.lost_gpu_min == pytest.approx(25.0 * 2)   # all progress lost
    assert res.segments[-1][3] - res.segments[-1][2] == pytest.approx(40.0)


def test_max_restarts_kills_job():
    infra = next(c for c in DEFAULT_TAXONOMY if c.name == INFRA)
    job = JobRecord(0, "debug", 1, 0.0, 50.0, "completed")
    inj = ScriptedInjector([(10.0, infra)] * 3)
    res = replay_trace([job], 8,
                       config=ReplayConfig(injector=inj, max_restarts=2,
                                           record_segments=True))
    assert res.killed_job_ids == [0]
    assert job.restarts == 3
    assert not any(s[4] == "finish" for s in res.segments)


# --- cordon path -------------------------------------------------------------

def test_hardware_failure_triggers_two_round_cordon():
    hw = next(c for c in DEFAULT_TAXONOMY if c.name == HARDWARE)
    cls = ReplayFailureClass(HARDWARE, rate_per_gpu_hour=hw.rate_per_gpu_hour,
                             jtype_mult={}, needs_cordon=True,
                             restart_overhead_min=5.0, repair_min=60.0)
    job = JobRecord(0, "pretrain", 16, 0.0, 120.0, "completed")
    inj = ScriptedInjector([(30.0, cls), None])
    res = replay_trace([job], 32,
                       config=ReplayConfig(injector=inj, node_gpus=8,
                                           record_segments=True))
    assert res.cordon_events == 1
    assert res.detection_probes > 0        # the §6.1 sweep actually ran
    assert any(s[4] == "finish" for s in res.segments)   # job still completes


def test_cordon_shrinks_then_repair_restores_capacity():
    """While a node is cordoned, a full-cluster job cannot start; after the
    repair event it can."""
    cls = ReplayFailureClass(HARDWARE, 1.0, {}, needs_cordon=True,
                             restart_overhead_min=1.0, repair_min=500.0)
    first = JobRecord(0, "pretrain", 8, 0.0, 50.0, "completed")
    full = JobRecord(1, "pretrain", 32, 60.0, 10.0, "completed")
    inj = ScriptedInjector([(20.0, cls), None, None])
    res = replay_trace([first, full], 32,
                       config=ReplayConfig(injector=inj, node_gpus=8,
                                           max_cordon_frac=0.5,
                                           record_segments=True))
    assert res.cordon_events == 1
    # the 32-GPU job must wait for the repair at t = 20 + 500
    start_full = next(s[2] for s in res.segments if s[0] == 1)
    assert start_full >= 520.0
    assert full.queue_min == pytest.approx(start_full - 60.0)


def test_cordon_drain_spares_colocated_jobs():
    """Node-less cordon drain is clamped to the failing job's own GPUs:
    the rest of the node is held by co-located jobs that keep running to
    their own completion, so draining the nominal node width would
    double-count their GPUs and starve later arrivals. Pins the
    co-located job's undisturbed end time AND the clamped free pool via a
    later job's queueing delay (an over-drain of the full 8-GPU node
    width would push its start past the co-located job's completion)."""
    cls = ReplayFailureClass(HARDWARE, 1.0, {}, needs_cordon=True,
                             restart_overhead_min=5.0, repair_min=500.0)
    fail = JobRecord(0, "pretrain", 2, 0.0, 100.0, "completed")
    colo = JobRecord(1, "pretrain", 6, 0.0, 50.0, "completed")
    late = JobRecord(2, "pretrain", 8, 20.0, 10.0, "completed")
    inj = ScriptedInjector([(10.0, cls), None, None])
    res = replay_trace([fail, colo, late], 16, reserved_frac=0.5,
                       config=ReplayConfig(injector=inj, node_gpus=8,
                                           max_cordon_frac=0.5,
                                           record_segments=True))
    assert res.cordon_events == 1
    assert fail.restarts == 1
    # the co-located job never noticed the neighbor's node fault
    assert colo.restarts == 0
    colo_end = next(s[3] for s in res.segments
                    if s[0] == 1 and s[4] == "finish")
    assert colo_end == pytest.approx(50.0)
    # drain clamped to the failing job's 2 GPUs: free capacity after the
    # cordon is 16 - 6 (colo) - 2 (drained) - 2 (fail's restart) = 6, so
    # the late 8-GPU job starts the moment colo's GPUs return at t = 50
    late_start = next(s[2] for s in res.segments if s[0] == 2)
    assert late_start == pytest.approx(50.0)
    assert late.queue_min == pytest.approx(30.0)


def test_preemption_never_hits_reserved_types():
    pre = next(c for c in DEFAULT_TAXONOMY if c.name == PREEMPTION)
    assert pre.rate_for("pretrain") == 0.0
    assert pre.rate_for("sft") == 0.0
    assert pre.rate_for("evaluation") > 0.0


# --- failure impact on the paper's metrics -----------------------------------

def test_failures_cost_gpu_hours_and_restarts():
    jobs = generate_jobs(KALOS, seed=0, n_jobs=20_000)
    res = replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.97,
                       config=ReplayConfig(
                           injector=FailureInjector(seed=1, rate_scale=4.0)))
    s = res.summary()
    assert s["total_restarts"] > 0
    assert s["total_lost_gpu_hours"] > 0
    # pretraining dominates lost GPU time (paper §5.1)
    lost = s["lost_gpu_hours_by_jtype"]
    assert lost["pretrain"]["gpu_hours"] >= max(
        v["gpu_hours"] for t, v in lost.items() if t != "pretrain")
    # and the injected classes all appear in the JSON-ready breakdown
    assert set(s["lost_gpu_hours_by_class"]) >= {HARDWARE, INFRA}


# --- backfill ----------------------------------------------------------------

def test_backfill_never_worse_for_eval_and_conserves():
    jobs = generate_jobs(KALOS, seed=2, n_jobs=8000)
    simulate_queue(jobs, KALOS.n_gpus, reserved_frac=0.97)
    fifo_eval = np.median([j.queue_min for j in jobs
                           if j.jtype == "evaluation"])
    res = replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.97,
                       config=ReplayConfig(backfill=True,
                                           record_segments=True))
    _assert_capacity_conserved(res.segments, KALOS.n_gpus)
    bf_eval = np.median([j.queue_min for j in jobs
                         if j.jtype == "evaluation"])
    assert bf_eval <= fifo_eval
    assert all(j.started for j in jobs)


# --- diagnosis-driven recovery -----------------------------------------------

def _assert_work_identity(jobs, res):
    """Executed GPU-minutes (from the run segments) must equal useful work
    plus rolled-back (lost) work for every job, under any recovery policy:
    elastic width changes redistribute work over time but never create or
    destroy it."""
    executed = collections.defaultdict(float)
    for jid, w, t0, t1, _ in res.segments:
        executed[jid] += w * (t1 - t0)
    finished = {s[0] for s in res.segments if s[4] == "finish"}
    for j in jobs:
        useful = j.gpus * (j.duration_min if j.job_id in finished
                           else j._done)
        assert executed[j.job_id] == pytest.approx(
            useful + j.lost_gpu_min, rel=1e-6, abs=1e-5)


def test_synthesized_logs_match_their_class():
    """failures.synthesize_failure_log draws hardware logs from cordon-type
    templates and labels them with the ground truth."""
    from repro.core.ft.events import BY_NAME, CORDON_TYPES
    hw = next(c for c in DEFAULT_TAXONOMY if c.name == HARDWARE)
    pre = next(c for c in DEFAULT_TAXONOMY if c.name == PREEMPTION)
    for seed in range(10):
        lines, truth = synthesize_failure_log(hw, seed=seed)
        assert truth in CORDON_TYPES and BY_NAME[truth].needs_node_cordon
        assert any("ERROR" in l for l in lines)
    lines, truth = synthesize_failure_log(pre, seed=0)
    assert truth is None
    assert any("PREEMPTION" in l for l in lines)


def test_diagnosis_verdicts_reported_with_hardware_recall():
    """Acceptance: per-failure-class verdicts appear in summary() and >=95%
    of synthesized hardware logs are classified hardware by core/ft."""
    jobs = generate_jobs(KALOS, seed=0, n_jobs=20_000)
    res = replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.97,
                       config=ReplayConfig(
                           injector=FailureInjector(seed=1, rate_scale=4.0),
                           diagnose=True, elastic=True))
    rec = res.summary()["recovery"]
    hw = rec["diagnosis_verdicts"].get("hardware", {})
    assert sum(hw.values()) > 0
    assert hw.get("hardware", 0) / sum(hw.values()) >= 0.95
    # transient infra verdicts restarted in place, hardware ones shrank
    assert rec["policies"].get("inplace", 0) > 0
    assert res.elastic_shrinks > 0
    # the variant cache bounds pipeline cost no matter the incident count
    assert 0 < res.diagnosis_pipeline_runs <= 3 * 32
    assert res.diagnosis_incidents == sum(
        sum(v.values()) for v in res.verdicts.values())
    stats = recovery_stats(res)
    assert stats["hardware_verdict_recall"] >= 0.95
    # preemptions must requeue no matter what the diagnosis says
    assert rec["policies"].get("inplace", 0) + rec["policies"].get(
        "elastic", 0) + rec["policies"].get("requeue", 0) \
        + rec["policies"].get("killed", 0) == sum(rec["policies"].values())


def test_elastic_shrink_stretches_then_repair_regrows():
    """A 16-GPU job losing one 8-GPU node at t=50 rolls back to the t=30
    checkpoint, continues at width 8 (stretched 2x), and regrows to 16 when
    the node is repaired — all hand-checkable timestamps."""
    cls = ReplayFailureClass(HARDWARE, 1.0, {}, needs_cordon=True,
                             restart_overhead_min=5.0, repair_min=40.0)
    job = JobRecord(0, "pretrain", 16, 0.0, 60.0, "completed")
    inj = ScriptedInjector([(50.0, cls), None, None])
    res = replay_trace([job], 32, reserved_frac=0.5,
                       config=ReplayConfig(injector=inj, node_gpus=8,
                                           recovery_policy="elastic",
                                           max_cordon_frac=0.5,
                                           checkpoint_interval_min=30.0,
                                           record_segments=True))
    assert res.elastic_shrinks == 1 and res.elastic_regrows == 1
    assert res.cordon_events == 1 and res.detection_probes > 0
    assert job.restarts == 1
    assert job.lost_gpu_min == pytest.approx(20.0 * 16)   # 50 -> ckpt 30
    # run 0..50 at 16; resume at 55 width 8 (prog 30); repair at 90 folds
    # (90-55)*8/16 = 17.5 nominal -> prog 47.5, width 16 again; finish at
    # 90 + (60-47.5) = 102.5
    (f0, f1, f2) = res.segments
    assert f0 == (0, 16, 0.0, 50.0, "fail")
    assert f1[:2] == (0, 8) and f1[2] == pytest.approx(55.0) \
        and f1[3] == pytest.approx(90.0) and f1[4] == "resize"
    assert f2[:2] == (0, 16) and f2[3] == pytest.approx(102.5) \
        and f2[4] == "finish"
    assert res.stale_events == 1          # the voided width-8 end event
    _assert_work_identity([job], res)


def test_elastic_too_narrow_falls_back_to_cordon_requeue():
    """A job no wider than one node cannot shed it: the node is still
    cordoned (from the pool) and the job requeues."""
    cls = ReplayFailureClass(HARDWARE, 1.0, {}, needs_cordon=True,
                             restart_overhead_min=5.0, repair_min=500.0)
    job = JobRecord(0, "pretrain", 8, 0.0, 40.0, "completed")
    inj = ScriptedInjector([(10.0, cls), None])
    res = replay_trace([job], 32, reserved_frac=0.5,
                       config=ReplayConfig(injector=inj, node_gpus=8,
                                           recovery_policy="elastic",
                                           max_cordon_frac=0.5,
                                           record_segments=True))
    assert res.elastic_shrinks == 0
    assert res.cordon_events == 1                  # fallback still cordons
    assert res.policies["requeue"] == 1
    assert any(s[4] == "finish" for s in res.segments)
    _assert_work_identity([job], res)


def test_inplace_restart_keeps_allocation():
    """A transient failure restarts in place: the allocation is never
    released, so a same-size job arriving during the restart overhead must
    wait for the *full* run, not the overhead window."""
    infra = next(c for c in DEFAULT_TAXONOMY if c.name == INFRA)
    a = JobRecord(0, "pretrain", 8, 0.0, 100.0, "completed")
    b = JobRecord(1, "pretrain", 8, 55.0, 10.0, "completed")
    inj = ScriptedInjector([(50.0, infra), None, None])
    res = replay_trace([a, b], 8, reserved_frac=1.0,
                       config=ReplayConfig(injector=inj,
                                           recovery_policy="inplace",
                                           checkpoint_interval_min=30.0,
                                           record_segments=True))
    assert res.policies["inplace"] == 1
    # a: fail at 50 (ckpt 30), resume 50+10 overhead, remaining 70 -> 130
    a_end = max(s[3] for s in res.segments if s[0] == 0)
    assert a_end == pytest.approx(130.0)
    assert a.lost_gpu_min == pytest.approx(20.0 * 8)
    # b arrived at 55 while a held the cluster through its restart
    assert b.queue_min == pytest.approx(130.0 - 55.0)
    _assert_work_identity([a, b], res)


@pytest.mark.parametrize("policy", ["requeue", "inplace", "elastic"])
def test_total_work_invariant_across_recovery_policies(policy):
    """Same failure point in all three worlds: completed + lost GPU-time is
    policy-invariant (policies move work in time, never in amount)."""
    cls = ReplayFailureClass(HARDWARE, 1.0, {}, needs_cordon=True,
                             restart_overhead_min=7.0, repair_min=200.0)
    job = JobRecord(0, "pretrain", 16, 0.0, 100.0, "completed")
    inj = ScriptedInjector([(50.0, cls), None, None])
    res = replay_trace([job], 32, reserved_frac=0.5,
                       config=ReplayConfig(injector=inj, node_gpus=8,
                                           max_cordon_frac=0.5,
                                           recovery_policy=policy,
                                           checkpoint_interval_min=30.0,
                                           record_segments=True))
    executed = sum(w * (t1 - t0) for _, w, t0, t1, _ in res.segments)
    assert job.lost_gpu_min == pytest.approx(20.0 * 16)
    assert executed == pytest.approx(100.0 * 16 + job.lost_gpu_min)
    assert any(s[4] == "finish" for s in res.segments)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(20, 120), gpus=st.integers(8, 48),
       seed=st.integers(0, 50), rate=st.floats(0.0, 0.5))
def test_elastic_replay_conserves_capacity_and_work(n, gpus, seed, rate):
    """For ANY small trace and failure rate under the elastic policy: GPU
    usage never exceeds the cluster at any event timestamp, and executed
    GPU-time equals useful + lost work for every job."""
    rng = np.random.default_rng(seed)
    jobs = _random_jobs(rng, n, gpus)
    inj = FailureInjector(seed=seed, rate_scale=rate * 5e3)
    res = replay_trace(jobs, gpus, reserved_frac=0.6,
                       config=ReplayConfig(injector=inj, node_gpus=4,
                                           recovery_policy="elastic",
                                           record_segments=True, seed=seed))
    _assert_capacity_conserved(res.segments, gpus)
    _assert_work_identity(jobs, res)
    for j in jobs:
        assert j.queue_min >= 0 and j.requeue_wait_min >= 0
        assert j.lost_gpu_min >= 0


# --- EASY vs greedy backfill -------------------------------------------------

def _backfill_trace():
    return [JobRecord(0, "evaluation", 4, 0.0, 10.0, "completed"),
            JobRecord(1, "evaluation", 2, 0.0, 5.0, "completed"),
            JobRecord(2, "evaluation", 8, 1.0, 5.0, "completed"),   # head
            JobRecord(3, "evaluation", 4, 2.0, 20.0, "completed"),
            JobRecord(4, "evaluation", 2, 2.0, 3.0, "completed")]


def test_easy_backfill_never_delays_head_greedy_does():
    """On a crafted trace the greedy policy backfills a long job in front
    of the blocked head (delaying it 10 -> 22), while EASY only admits the
    short job whose completion lands before the head's shadow time."""
    jobs = _backfill_trace()
    replay_trace(jobs, 10, reserved_frac=0.0, config=ReplayConfig())
    assert jobs[2].queue_min == pytest.approx(9.0)       # FIFO head start

    replay_trace(jobs, 10, reserved_frac=0.0,
                 config=ReplayConfig(backfill="greedy"))
    assert jobs[2].queue_min == pytest.approx(21.0)      # head delayed
    assert jobs[3].queue_min == pytest.approx(0.0)       # long job jumped

    replay_trace(jobs, 10, reserved_frac=0.0,
                 config=ReplayConfig(backfill="easy"))
    assert jobs[2].queue_min == pytest.approx(9.0)       # head protected
    assert jobs[4].queue_min == pytest.approx(0.0)       # short: on arrival
    assert jobs[3].queue_min == pytest.approx(13.0)      # long one waited


def test_easy_admits_fitting_arrival_immediately():
    """An EASY candidate whose completion lands before the head's shadow
    must start at *arrival*, not wait for the next capacity event."""
    a = JobRecord(0, "evaluation", 8, 0.0, 100.0, "completed")
    h = JobRecord(1, "evaluation", 4, 1.0, 5.0, "completed")    # blocked
    c = JobRecord(2, "evaluation", 2, 2.0, 5.0, "completed")
    replay_trace([a, h, c], 10, reserved_frac=0.0,
                 config=ReplayConfig(backfill="easy"))
    assert c.queue_min == pytest.approx(0.0)     # ends t=7 << shadow t=100
    assert h.queue_min == pytest.approx(99.0)    # head start unharmed


def test_shared_diagnosis_loop_reports_per_run_deltas():
    """Reusing one DiagnosisLoop across replays keeps the verdict cache
    warm, but each result must report its own run's incident counts."""
    from repro.cluster import DiagnosisLoop
    loop = DiagnosisLoop()
    jobs = generate_jobs(KALOS, seed=0, n_jobs=5000)
    results = [replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.97,
                            config=ReplayConfig(
                                injector=FailureInjector(seed=1,
                                                         rate_scale=4.0),
                                diagnosis=loop))
               for _ in range(2)]
    for r in results:
        assert r.diagnosis_incidents == sum(
            sum(v.values()) for v in r.verdicts.values())
    assert loop.incidents == sum(r.diagnosis_incidents for r in results)
    assert results[1].diagnosis_pipeline_runs <= \
        results[0].diagnosis_pipeline_runs   # cache stayed warm


def test_shared_diagnosis_loop_deltas_across_interleaved_worlds():
    """The bench_pool pattern: ONE DiagnosisLoop shared across interleaved
    multi-world replays with different configs (plain / elastic / EASY +
    pool). Every result must report exactly its own run's incidents and
    newly-paid pipeline runs — the snapshot scoping must not bleed counts
    between worlds, and the per-run deltas must sum to the loop totals."""
    from repro.cluster import DiagnosisLoop
    loop = DiagnosisLoop()
    jobs = generate_jobs(KALOS, seed=0, n_jobs=4000)
    configs = [
        ReplayConfig(injector=FailureInjector(seed=1, rate_scale=4.0),
                     diagnosis=loop),
        ReplayConfig(injector=FailureInjector(seed=2, rate_scale=4.0),
                     diagnosis=loop, elastic=True),
        ReplayConfig(injector=FailureInjector(seed=3, rate_scale=4.0),
                     diagnosis=loop, elastic=True, backfill="easy"),
    ]
    results = []
    marks = []
    for cfg in configs:
        before = (loop.incidents, loop.pipeline_runs)
        results.append(replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.97,
                                    config=cfg))
        marks.append((loop.incidents - before[0],
                      loop.pipeline_runs - before[1]))
    for r, (d_inc, d_runs) in zip(results, marks):
        assert r.diagnosis_incidents == d_inc == sum(
            sum(v.values()) for v in r.verdicts.values())
        assert r.diagnosis_pipeline_runs == d_runs >= 0
        assert r.diagnosis_incidents > 0
    assert loop.incidents == sum(r.diagnosis_incidents for r in results)
    assert loop.pipeline_runs == sum(r.diagnosis_pipeline_runs
                                     for r in results)


def test_head_episode_survives_fail_and_requeue():
    """Fail-while-head audit: a job that served a blocked head episode,
    started, *failed* and requeued must open a fresh, correctly-timed
    episode when it becomes a blocked head again — no stale
    ``_head_since``/``_shadow_est`` may leak across the requeue into
    ``analysis.head_delay_stats``.

    Timeline (8-GPU cluster, all jobs 8-wide so nothing overlaps):
      X runs 0..50; H arrives at 5, heads 5..50 (episode 1: 45), fails at
      60 (infra, overhead 10); Y arrives at 55, heads 55..60 (episode 2:
      5); H re-arrives at 70, heads 70..90 behind Y (episode 3: 20). Under
      EASY every episode carries a shadow estimate, and all three are
      exact — a stale pre-fail estimate would surface as a wild error."""
    infra = ReplayFailureClass(INFRA, 1.0, {}, restart_overhead_min=10.0)
    x = JobRecord(0, "evaluation", 8, 0.0, 50.0, "completed")
    h = JobRecord(1, "evaluation", 8, 5.0, 20.0, "completed")
    y = JobRecord(2, "evaluation", 8, 55.0, 30.0, "completed")
    inj = ScriptedInjector([None, (10.0, infra), None, None])
    res = replay_trace([x, h, y], 8, reserved_frac=0.0,
                       config=ReplayConfig(injector=inj, backfill="easy"))
    assert res.head_delays == pytest.approx([45.0, 5.0, 20.0])
    assert res.shadow_errors == pytest.approx([0.0, 0.0, 0.0])
    assert h.queue_min == pytest.approx(45.0)
    assert h.requeue_wait_min == pytest.approx(20.0)
    # the same trace under plain FIFO with sampling on every head agrees
    inj = ScriptedInjector([None, (10.0, infra), None, None])
    res = replay_trace([x, h, y], 8, reserved_frac=0.0,
                       config=ReplayConfig(injector=inj,
                                           head_delay_sample=1))
    assert res.head_delays == pytest.approx([45.0, 5.0, 20.0])


def test_killed_job_charges_no_restart_overhead():
    """A failure that kills the job restarts nothing: by_class and
    by_policy overhead totals must reconcile exactly."""
    infra = next(c for c in DEFAULT_TAXONOMY if c.name == INFRA)
    job = JobRecord(0, "debug", 1, 0.0, 50.0, "completed")
    inj = ScriptedInjector([(10.0, infra)] * 3)
    res = replay_trace([job], 8,
                       config=ReplayConfig(injector=inj, max_restarts=2))
    # two requeues paid overhead; the third (killing) failure did not
    assert res.by_class[INFRA].overhead_min == \
        pytest.approx(2 * infra.restart_overhead_min)
    assert sum(s.overhead_min for s in res.by_class.values()) == \
        pytest.approx(sum(s.overhead_min for s in res.by_policy.values()))


def test_easy_backfill_conserves_and_helps_eval():
    jobs = generate_jobs(KALOS, seed=2, n_jobs=8000)
    simulate_queue(jobs, KALOS.n_gpus, reserved_frac=0.97)
    fifo_eval = np.median([j.queue_min for j in jobs
                           if j.jtype == "evaluation"])
    res = replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.97,
                       config=ReplayConfig(backfill="easy",
                                           record_segments=True))
    _assert_capacity_conserved(res.segments, KALOS.n_gpus)
    easy_eval = np.median([j.queue_min for j in jobs
                           if j.jtype == "evaluation"])
    assert easy_eval <= fifo_eval
    assert all(j.started for j in jobs)


# --- never-started sentinel --------------------------------------------------

def test_impossible_job_rejected_with_warning(caplog):
    jobs = [JobRecord(0, "pretrain", 128, 0.0, 10.0, "completed"),
            JobRecord(1, "pretrain", 16, 1.0, 10.0, "completed")]
    with caplog.at_level("WARNING", logger="repro"):
        res = replay_trace(jobs, 64, config=ReplayConfig())
    assert any("rejected" in r.message for r in caplog.records)
    assert res.rejected_job_ids == [0]
    assert jobs[0].queue_min == NEVER_STARTED
    assert not jobs[0].started
    assert jobs[1].started and jobs[1].queue_min == pytest.approx(0.0)


def test_wedged_head_marks_blocked_jobs_never_started():
    """Legacy mode (no rejection): an impossible FIFO head wedges its class;
    everything stuck behind it surfaces as NEVER_STARTED, not 0.0."""
    jobs = [JobRecord(0, "pretrain", 128, 0.0, 10.0, "completed"),
            JobRecord(1, "pretrain", 16, 1.0, 10.0, "completed"),
            JobRecord(2, "evaluation", 2, 2.0, 5.0, "completed")]
    replay_trace(jobs, 64,
                 config=ReplayConfig(reject_impossible=False))
    assert jobs[0].queue_min == NEVER_STARTED
    assert jobs[1].queue_min == NEVER_STARTED   # stuck behind the wedge
    assert jobs[2].started                       # other class unaffected


def test_queue_stats_excludes_never_started():
    from repro.cluster.analysis import queue_stats
    jobs = [JobRecord(0, "evaluation", 2, 0.0, 5.0, "completed",
                      queue_min=4.0),
            JobRecord(1, "evaluation", 2, 0.0, 5.0, "completed",
                      queue_min=NEVER_STARTED)]
    q = queue_stats(jobs)
    assert q["evaluation"]["median_min"] == 4.0    # inf filtered out
    assert q["evaluation"]["n_never_started"] == 1
