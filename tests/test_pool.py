"""Elastic capacity pool: opportunistic free-pool regrowth, evalsched trial
borrowing, the EASY head-protection priority rule, and conservation of GPU
capacity + total work across arbitrary shrink -> borrow -> preempt-return ->
regrow cycles."""
import collections

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.cluster import (KALOS, FailureInjector, ReplayConfig,
                           ReplayFailureClass, ReservationScheduler,
                           generate_jobs, replay_trace)
from repro.cluster.failures import HARDWARE
from repro.cluster.workload import JobRecord
from repro.core.evalsched import BorrowItem, TrialBorrower


class ScriptedInjector:
    """Deterministic injector: pops pre-scripted (ttf, cls) draws."""

    def __init__(self, script):
        self.script = list(script)

    def draw(self, jtype, gpus, remaining_min):
        if not self.script:
            return None
        hit = self.script.pop(0)
        if hit is None:
            return None
        ttf, cls = hit
        return (ttf, cls) if ttf < remaining_min else None


def _hw(overhead=2.0, repair=1000.0):
    return ReplayFailureClass(HARDWARE, 1.0, {}, needs_cordon=True,
                              restart_overhead_min=overhead,
                              repair_min=repair)


def _assert_capacity_conserved(spans, total_gpus):
    """spans: (id, gpus, t0, t1, kind) job segments and/or 1-GPU leases."""
    events = []
    for _, gpus, t0, t1, _ in spans:
        assert t1 >= t0
        events.append((round(t0, 6), 1, gpus))
        events.append((round(t1, 6), 0, -gpus))   # frees before same-t starts
    events.sort()
    used = 0
    for _, _, d in events:
        used += d
        assert used <= total_gpus
    assert used == 0


def _assert_work_identity(jobs, res):
    executed = collections.defaultdict(float)
    for jid, w, t0, t1, _ in res.segments:
        executed[jid] += w * (t1 - t0)
    finished = {s[0] for s in res.segments if s[4] == "finish"}
    for j in jobs:
        useful = j.gpus * (j.duration_min if j.job_id in finished
                           else j._done)
        assert executed[j.job_id] == pytest.approx(
            useful + j.lost_gpu_min, rel=1e-6, abs=1e-5)


# --- scheduler primitive -----------------------------------------------------

def test_grow_draws_pools_by_allocation_kind():
    """grow() respects the reservation policy: hi allocations draw
    reserved-then-spare, best-effort allocations spare only, takes clamp at
    the free pools, and everything round-trips through finish/uncordon."""
    sched = ReservationScheduler(32, 0.5)              # 16 r / 16 s
    hi = JobRecord(0, "pretrain", 8, 0.0, 10.0, "completed")
    lo = JobRecord(1, "evaluation", 4, 0.0, 10.0, "completed")
    sched.start(hi)                                    # alloc (r8, s0)
    sched.start(lo)                                    # alloc (r0, s4)
    assert (sched.free_reserved, sched.free_spare) == (8, 12)
    take = sched.release_partial(hi, 4)                # node leaves with r4
    assert take == (4, 0)
    assert sched.grow(lo, 20) == (0, 12)               # spare only, clamped
    assert sched.grow(hi, 6) == (6, 0)                 # reserved first
    assert (sched.free_reserved, sched.free_spare) == (2, 0)
    sched.finish(lo)
    sched.finish(hi)
    sched.uncordon(*take)
    assert (sched.free_reserved, sched.free_spare) == (16, 16)


# --- opportunistic regrowth --------------------------------------------------

def test_shrunken_job_regrows_from_pool_at_completion_event():
    """A 16-GPU job that shed a node regrows from the free pool the moment
    another job's completion frees capacity — long before the node's repair
    (which then simply returns the node's GPUs to the pool). Timeline is
    hand-checkable end to end."""
    cls = _hw(overhead=5.0, repair=500.0)
    a = JobRecord(0, "pretrain", 16, 0.0, 60.0, "completed")
    b = JobRecord(1, "pretrain", 8, 0.0, 20.0, "completed")
    inj = ScriptedInjector([(10.0, cls), None, None, None])
    res = replay_trace([a, b], 32, reserved_frac=0.5,
                       config=ReplayConfig(injector=inj, node_gpus=8,
                                           recovery_policy="elastic",
                                           max_cordon_frac=0.5,
                                           checkpoint_interval_min=30.0,
                                           record_segments=True))
    assert res.elastic_shrinks == 1
    assert res.pool_regrows == 1 and res.pool_regrown_gpus == 8
    assert res.elastic_regrows == 0        # repair found the job full-width
    # a: runs 0..10 at 16 (fail, ckpt 0 -> all 10 nominal min lost);
    # resumes at 15 width 8; b ends at 20 -> regrow to 16 with progress
    # (20-15)*8/16 = 2.5; finish at 20 + (60-2.5) = 77.5
    segs_a = [s for s in res.segments if s[0] == 0]
    assert segs_a[0] == (0, 16, 0.0, 10.0, "fail")
    assert segs_a[1][1] == 8 and segs_a[1][2] == pytest.approx(15.0) \
        and segs_a[1][3] == pytest.approx(20.0) and segs_a[1][4] == "resize"
    assert segs_a[2][1] == 16 and segs_a[2][3] == pytest.approx(77.5) \
        and segs_a[2][4] == "finish"
    assert a.lost_gpu_min == pytest.approx(10.0 * 16)
    _assert_capacity_conserved(res.segments, 32)
    _assert_work_identity([a, b], res)
    s = res.summary()["pool"]
    assert s["regrowth"]["pool_regrows"] == 1
    assert s["regrowth"]["events"] == 1


def test_regrow_disabled_restores_repair_only_semantics():
    """opportunistic_regrow=False is exactly the PR-2 world: width comes
    back only at the lender node's REPAIR event."""
    cls = _hw(overhead=5.0, repair=40.0)
    a = JobRecord(0, "pretrain", 16, 0.0, 60.0, "completed")
    b = JobRecord(1, "pretrain", 8, 0.0, 20.0, "completed")
    inj = ScriptedInjector([(50.0, cls), None, None, None])
    res = replay_trace([a, b], 32, reserved_frac=0.5,
                       config=ReplayConfig(injector=inj, node_gpus=8,
                                           recovery_policy="elastic",
                                           max_cordon_frac=0.5,
                                           opportunistic_regrow=False,
                                           checkpoint_interval_min=30.0,
                                           record_segments=True))
    assert res.pool_regrows == 0
    assert res.elastic_shrinks == 1 and res.elastic_regrows == 1


# --- the priority rule: regrowth never starves the EASY head -----------------

def _easy_head_trace():
    # 16-GPU spare-only cluster; A shrinks 8->4 (one 4-GPU node cordoned),
    # B and C end at 31 and 50; H (8 GPUs) arrives at 5 and must wait
    cls = _hw(overhead=2.0, repair=10_000.0)
    a = JobRecord(0, "evaluation", 8, 0.0, 200.0, "completed")
    b = JobRecord(1, "evaluation", 4, 0.0, 31.0, "completed")
    c = JobRecord(2, "evaluation", 4, 0.0, 50.0, "completed")
    h = JobRecord(3, "evaluation", 8, 5.0, 10.0, "completed")
    inj = ScriptedInjector([(4.0, cls)] + [None] * 6)
    return [a, b, c, h], inj


def test_regrowth_never_starves_easy_head():
    """Regression for the pool priority rule. At B's completion (t=31)
    there are 4 free GPUs and the shrunken job wants exactly 4 — but
    regrowing would push its completion (t~218) past the waiting head's
    shadow time (t=50, when C also ends), so under EASY the regrow is
    deferred and the head starts exactly at its shadow estimate."""
    jobs, inj = _easy_head_trace()
    h = jobs[3]
    res = replay_trace(jobs, 16, reserved_frac=0.0,
                       config=ReplayConfig(injector=inj, node_gpus=4,
                                           recovery_policy="elastic",
                                           max_cordon_frac=0.5,
                                           backfill="easy",
                                           record_segments=True))
    assert res.elastic_shrinks == 1
    assert h.queue_min == pytest.approx(45.0)      # started at shadow t=50
    # the shadow estimate for H was exact (error 0), recorded under EASY
    assert any(abs(e) < 1e-9 for e in res.shadow_errors)
    # the deferred regrow fires later, once H is running and no head waits
    assert res.pool_regrows == 1
    seg_a_final = max(s for s in res.segments if s[0] == 0)
    assert seg_a_final[1] == 8                     # A did reach full width
    _assert_capacity_conserved(res.segments, 16)
    _assert_work_identity(jobs, res)


def test_fifo_regrowth_may_delay_head_easy_protects():
    """Contrast: without EASY the same trace regrows at t=31, consuming the
    free GPUs the head was waiting for — the head then waits for the
    regrown job itself. The EASY world's head starts 4x earlier."""
    jobs, inj = _easy_head_trace()
    h = jobs[3]
    replay_trace(jobs, 16, reserved_frac=0.0,
                 config=ReplayConfig(injector=inj, node_gpus=4,
                                     recovery_policy="elastic",
                                     max_cordon_frac=0.5))
    assert h.queue_min > 200.0                     # starved by the regrow


# --- borrowing bridge --------------------------------------------------------

def test_borrower_lease_complete_and_accounting():
    """A single shard leases an idle GPU at the first event, completes
    mid-window, and the lease record closes at the exact completion time;
    borrowed time = work + one restart cost."""
    j0 = JobRecord(0, "evaluation", 1, 0.0, 1.0, "completed")
    a = JobRecord(1, "evaluation", 8, 20.0, 10.0, "completed")
    bor = TrialBorrower([BorrowItem("x", 10.0)], restart_cost_min=1.0,
                        record_leases=True)
    replay_trace([j0, a], 8, reserved_frac=0.0,
                 config=ReplayConfig(borrower=bor))
    assert bor.completed == ["x"]
    assert bor.lease_count == 1 and bor.preemptions == 0
    assert bor.borrowed_gpu_min == pytest.approx(11.0)   # 10 work + 1 setup
    assert bor.lease_records == [(0.0, pytest.approx(11.0))]


def test_borrower_preempted_by_dispatch_and_returns():
    """Full shrink-free borrow/preempt/return cycle: leases are revoked the
    instant a queued job needs the GPUs (the job's own start is NOT
    delayed), shards keep their progress, pay the restart cost again on
    re-lease, and finish once capacity returns."""
    j0 = JobRecord(0, "evaluation", 1, 0.0, 1.0, "completed")
    a = JobRecord(1, "evaluation", 8, 5.0, 10.0, "completed")
    j1 = JobRecord(2, "evaluation", 1, 50.0, 1.0, "completed")
    bor = TrialBorrower([BorrowItem("x", 10.0), BorrowItem("y", 30.0)],
                        restart_cost_min=1.0, record_leases=True)
    res = replay_trace([j0, a, j1], 8, reserved_frac=0.0,
                       config=ReplayConfig(borrower=bor,
                                           record_segments=True))
    # borrowing is a virtual overlay on free capacity: A starts on arrival
    assert a.queue_min == pytest.approx(0.0)
    assert bor.preemptions == 2                  # both leases revoked at t=5
    assert sorted(bor.completed) == ["x", "y"]
    # each shard leased twice (initial + post-preemption re-lease)
    assert bor.lease_count == 4
    assert bor.overhead_min == pytest.approx(4.0)
    # 40 min of work + 4 restart charges, all executed on leased GPUs
    assert bor.borrowed_gpu_min == pytest.approx(44.0)
    spans = res.segments + [(-1, 1, t0, t1, "lease")
                            for t0, t1 in bor.lease_records]
    _assert_capacity_conserved(spans, 8)


def test_borrower_alone_accumulates_and_completes():
    b = TrialBorrower([BorrowItem("a", 2.0)], restart_cost_min=0.25)
    assert b.reconcile(0.0, 3) == 1
    assert b.reconcile(1.0, 3) == 1
    assert b.reconcile(5.0, 3) == 0              # finished at t=2.25
    assert b.completed == ["a"]
    assert b.borrowed_gpu_min == pytest.approx(2.25)
    assert b.stats()["shards_pending"] == 0


def test_pool_summary_present_without_borrower():
    jobs = generate_jobs(KALOS, seed=0, n_jobs=2000)
    res = replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.97,
                       config=ReplayConfig())
    pool = res.summary()["pool"]
    assert pool["borrow"] == {} and pool["borrowed_gpu_min"] == 0.0
    assert pool["free_gpu_hours"] > 0.0
    assert pool["horizon_min"] > 0.0


# --- head-delay characterization ---------------------------------------------

def test_head_delay_tail_reported_under_easy_and_fifo():
    jobs = generate_jobs(KALOS, seed=0, n_jobs=20_000)
    easy = replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.97,
                        config=ReplayConfig(
                            injector=FailureInjector(seed=1, rate_scale=4.0),
                            diagnose=True, elastic=True, backfill="easy"))
    hd = easy.summary()["head_delay"]
    assert hd["n"] > 0
    assert 0.0 <= hd["p50_min"] <= hd["p95_min"] <= hd["p99_min"]
    # under EASY (nearly) every head episode carries a shadow estimate —
    # the rare exception is a head whose shadow was infinite at marking
    # time (its demand outstrips the cluster minus cordoned capacity)
    assert hd["shadow_error"]["n"] >= 0.99 * hd["n"]
    fifo = replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.97,
                        config=ReplayConfig(
                            injector=FailureInjector(seed=1, rate_scale=4.0),
                            diagnose=True, elastic=True))
    fd = fifo.summary()["head_delay"]
    assert fd["n"] > 0
    assert fd["shadow_error"]["n"] <= fd["n"]    # sampled cadence
    # sampling off disables the machinery entirely
    off = replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.97,
                       config=ReplayConfig(head_delay_sample=0))
    assert off.summary()["head_delay"]["n"] == 0


# --- conservation across arbitrary pool cycles (property) --------------------

def _random_jobs(rng, n, gpus_max):
    jtypes = ("evaluation", "pretrain", "debug")
    return [JobRecord(i, str(rng.choice(list(jtypes))),
                      int(rng.integers(1, gpus_max + 1)),
                      float(rng.uniform(0, 200)),
                      float(rng.uniform(0.1, 30)), "completed")
            for i in range(n)]


@settings(max_examples=10, deadline=None)
@given(n=st.integers(20, 100), gpus=st.integers(8, 48),
       seed=st.integers(0, 40), rate=st.floats(0.0, 0.5))
def test_pool_cycles_conserve_capacity_and_work(n, gpus, seed, rate):
    """For ANY small trace and failure rate with the whole pool active
    (elastic shrink + opportunistic regrowth + trial borrowing): job
    segments plus 1-GPU lease spans never exceed the cluster at any
    instant, executed GPU-time equals useful + lost work for every job,
    and the borrower's ledger balances to the per-shard consumption."""
    rng = np.random.default_rng(seed)
    jobs = _random_jobs(rng, n, gpus)
    items = [BorrowItem(f"i{k}", float(rng.uniform(0.5, 20.0)))
             for k in range(int(rng.integers(1, 12)))]
    bor = TrialBorrower(items, restart_cost_min=0.3, max_leases=gpus,
                        record_leases=True)
    inj = FailureInjector(seed=seed, rate_scale=rate * 5e3)
    res = replay_trace(jobs, gpus, reserved_frac=0.6,
                       config=ReplayConfig(injector=inj, node_gpus=4,
                                           recovery_policy="elastic",
                                           borrower=bor,
                                           record_segments=True, seed=seed))
    spans = res.segments + [(-1, 1, t0, t1, "lease")
                            for t0, t1 in bor.lease_records]
    _assert_capacity_conserved(spans, gpus)
    _assert_work_identity(jobs, res)
    # borrower ledger: borrowed time == total consumption across shards
    consumed = sum(it.work_min + it.overhead_min - it.remaining_min
                   for it in bor.items)
    assert bor.borrowed_gpu_min == pytest.approx(consumed, abs=1e-6)
    assert bor.borrowed_gpu_min >= 0.0
    done = set(bor.completed)
    for it in bor.items:
        assert it.remaining_min >= -1e-9
        if it.name in done:
            assert it.remaining_min == pytest.approx(0.0, abs=1e-9)
    for j in jobs:
        assert j.queue_min >= 0 and j.requeue_wait_min >= 0
        assert j.lost_gpu_min >= 0
