"""Elastic capacity pool: opportunistic free-pool regrowth, evalsched trial
borrowing, the EASY head-protection priority rule, node-local placement
(NodeLedger + Fig. 16 NIC-contended borrowed loads), the best-effort
revocable-lease tier (§3.2 quota reclamation as policy), and conservation of
GPU capacity + total work + checkpoint accounting across arbitrary
shrink -> borrow -> preempt-return -> regrow and best-effort
start -> revoke -> rollback -> requeue -> re-lease cycles."""
import collections
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.cluster import (KALOS, QUOTA_RECLAIM, FailureInjector,
                           NodeLedger, ReplayConfig, ReplayFailureClass,
                           ReservationScheduler, generate_jobs, replay_trace)
from repro.cluster.failures import HARDWARE, PREEMPTION
from repro.cluster.workload import JobRecord
from repro.core.evalsched import BorrowItem, ClusterSpec, TrialBorrower


class ScriptedInjector:
    """Deterministic injector: pops pre-scripted (ttf, cls) draws."""

    def __init__(self, script):
        self.script = list(script)

    def draw(self, jtype, gpus, remaining_min):
        if not self.script:
            return None
        hit = self.script.pop(0)
        if hit is None:
            return None
        ttf, cls = hit
        return (ttf, cls) if ttf < remaining_min else None


def _hw(overhead=2.0, repair=1000.0):
    return ReplayFailureClass(HARDWARE, 1.0, {}, needs_cordon=True,
                              restart_overhead_min=overhead,
                              repair_min=repair)


def _assert_capacity_conserved(spans, total_gpus):
    """spans: (id, gpus, t0, t1, kind) job segments and/or 1-GPU leases."""
    events = []
    for _, gpus, t0, t1, _ in spans:
        assert t1 >= t0
        events.append((round(t0, 6), 1, gpus))
        events.append((round(t1, 6), 0, -gpus))   # frees before same-t starts
    events.sort()
    used = 0
    for _, _, d in events:
        used += d
        assert used <= total_gpus
    assert used == 0


def _assert_work_identity(jobs, res):
    executed = collections.defaultdict(float)
    for jid, w, t0, t1, _ in res.segments:
        executed[jid] += w * (t1 - t0)
    finished = {s[0] for s in res.segments if s[4] == "finish"}
    for j in jobs:
        useful = j.gpus * (j.duration_min if j.job_id in finished
                           else j._done)
        assert executed[j.job_id] == pytest.approx(
            useful + j.lost_gpu_min, rel=1e-6, abs=1e-5)


# --- scheduler primitive -----------------------------------------------------

def test_grow_draws_pools_by_allocation_kind():
    """grow() respects the reservation policy: hi allocations draw
    reserved-then-spare, best-effort allocations spare only, takes clamp at
    the free pools, and everything round-trips through finish/uncordon."""
    sched = ReservationScheduler(32, 0.5)              # 16 r / 16 s
    hi = JobRecord(0, "pretrain", 8, 0.0, 10.0, "completed")
    lo = JobRecord(1, "evaluation", 4, 0.0, 10.0, "completed")
    sched.start(hi)                                    # alloc (r8, s0)
    sched.start(lo)                                    # alloc (r0, s4)
    assert (sched.free_reserved, sched.free_spare) == (8, 12)
    take = sched.release_partial(hi, 4)                # node leaves with r4
    assert take == (4, 0)
    assert sched.grow(lo, 20) == (0, 12)               # spare only, clamped
    assert sched.grow(hi, 6) == (6, 0)                 # reserved first
    assert (sched.free_reserved, sched.free_spare) == (2, 0)
    sched.finish(lo)
    sched.finish(hi)
    sched.uncordon(*take)
    assert (sched.free_reserved, sched.free_spare) == (16, 16)


# --- opportunistic regrowth --------------------------------------------------

def test_shrunken_job_regrows_from_pool_at_completion_event():
    """A 16-GPU job that shed a node regrows from the free pool the moment
    another job's completion frees capacity — long before the node's repair
    (which then simply returns the node's GPUs to the pool). Timeline is
    hand-checkable end to end."""
    cls = _hw(overhead=5.0, repair=500.0)
    a = JobRecord(0, "pretrain", 16, 0.0, 60.0, "completed")
    b = JobRecord(1, "pretrain", 8, 0.0, 20.0, "completed")
    inj = ScriptedInjector([(10.0, cls), None, None, None])
    res = replay_trace([a, b], 32, reserved_frac=0.5,
                       config=ReplayConfig(injector=inj, node_gpus=8,
                                           recovery_policy="elastic",
                                           max_cordon_frac=0.5,
                                           checkpoint_interval_min=30.0,
                                           record_segments=True))
    assert res.elastic_shrinks == 1
    assert res.pool_regrows == 1 and res.pool_regrown_gpus == 8
    assert res.elastic_regrows == 0        # repair found the job full-width
    # a: runs 0..10 at 16 (fail, ckpt 0 -> all 10 nominal min lost);
    # resumes at 15 width 8; b ends at 20 -> regrow to 16 with progress
    # (20-15)*8/16 = 2.5; finish at 20 + (60-2.5) = 77.5
    segs_a = [s for s in res.segments if s[0] == 0]
    assert segs_a[0] == (0, 16, 0.0, 10.0, "fail")
    assert segs_a[1][1] == 8 and segs_a[1][2] == pytest.approx(15.0) \
        and segs_a[1][3] == pytest.approx(20.0) and segs_a[1][4] == "resize"
    assert segs_a[2][1] == 16 and segs_a[2][3] == pytest.approx(77.5) \
        and segs_a[2][4] == "finish"
    assert a.lost_gpu_min == pytest.approx(10.0 * 16)
    _assert_capacity_conserved(res.segments, 32)
    _assert_work_identity([a, b], res)
    s = res.summary()["pool"]
    assert s["regrowth"]["pool_regrows"] == 1
    assert s["regrowth"]["events"] == 1


def test_regrow_disabled_restores_repair_only_semantics():
    """opportunistic_regrow=False is exactly the PR-2 world: width comes
    back only at the lender node's REPAIR event."""
    cls = _hw(overhead=5.0, repair=40.0)
    a = JobRecord(0, "pretrain", 16, 0.0, 60.0, "completed")
    b = JobRecord(1, "pretrain", 8, 0.0, 20.0, "completed")
    inj = ScriptedInjector([(50.0, cls), None, None, None])
    res = replay_trace([a, b], 32, reserved_frac=0.5,
                       config=ReplayConfig(injector=inj, node_gpus=8,
                                           recovery_policy="elastic",
                                           max_cordon_frac=0.5,
                                           opportunistic_regrow=False,
                                           checkpoint_interval_min=30.0,
                                           record_segments=True))
    assert res.pool_regrows == 0
    assert res.elastic_shrinks == 1 and res.elastic_regrows == 1


# --- the priority rule: regrowth never starves the EASY head -----------------

def _easy_head_trace():
    # 16-GPU spare-only cluster; A shrinks 8->4 (one 4-GPU node cordoned),
    # B and C end at 31 and 50; H (8 GPUs) arrives at 5 and must wait
    cls = _hw(overhead=2.0, repair=10_000.0)
    a = JobRecord(0, "evaluation", 8, 0.0, 200.0, "completed")
    b = JobRecord(1, "evaluation", 4, 0.0, 31.0, "completed")
    c = JobRecord(2, "evaluation", 4, 0.0, 50.0, "completed")
    h = JobRecord(3, "evaluation", 8, 5.0, 10.0, "completed")
    inj = ScriptedInjector([(4.0, cls)] + [None] * 6)
    return [a, b, c, h], inj


def test_regrowth_never_starves_easy_head():
    """Regression for the pool priority rule. At B's completion (t=31)
    there are 4 free GPUs and the shrunken job wants exactly 4 — but
    regrowing would push its completion (t~218) past the waiting head's
    shadow time (t=50, when C also ends), so under EASY the regrow is
    deferred and the head starts exactly at its shadow estimate."""
    jobs, inj = _easy_head_trace()
    h = jobs[3]
    res = replay_trace(jobs, 16, reserved_frac=0.0,
                       config=ReplayConfig(injector=inj, node_gpus=4,
                                           recovery_policy="elastic",
                                           max_cordon_frac=0.5,
                                           backfill="easy",
                                           record_segments=True))
    assert res.elastic_shrinks == 1
    assert h.queue_min == pytest.approx(45.0)      # started at shadow t=50
    # the shadow estimate for H was exact (error 0), recorded under EASY
    assert any(abs(e) < 1e-9 for e in res.shadow_errors)
    # the deferred regrow fires later, once H is running and no head waits
    assert res.pool_regrows == 1
    seg_a_final = max(s for s in res.segments if s[0] == 0)
    assert seg_a_final[1] == 8                     # A did reach full width
    _assert_capacity_conserved(res.segments, 16)
    _assert_work_identity(jobs, res)


def test_fifo_regrowth_may_delay_head_easy_protects():
    """Contrast: without EASY the same trace regrows at t=31, consuming the
    free GPUs the head was waiting for — the head then waits for the
    regrown job itself. The EASY world's head starts 4x earlier."""
    jobs, inj = _easy_head_trace()
    h = jobs[3]
    replay_trace(jobs, 16, reserved_frac=0.0,
                 config=ReplayConfig(injector=inj, node_gpus=4,
                                     recovery_policy="elastic",
                                     max_cordon_frac=0.5))
    assert h.queue_min > 200.0                     # starved by the regrow


# --- borrowing bridge --------------------------------------------------------

def test_borrower_lease_complete_and_accounting():
    """A single shard leases an idle GPU at the first event, completes
    mid-window, and the lease record closes at the exact completion time;
    borrowed time = work + one restart cost."""
    j0 = JobRecord(0, "evaluation", 1, 0.0, 1.0, "completed")
    a = JobRecord(1, "evaluation", 8, 20.0, 10.0, "completed")
    bor = TrialBorrower([BorrowItem("x", 10.0)], restart_cost_min=1.0,
                        record_leases=True)
    replay_trace([j0, a], 8, reserved_frac=0.0,
                 config=ReplayConfig(borrower=bor))
    assert bor.completed == ["x"]
    assert bor.lease_count == 1 and bor.preemptions == 0
    assert bor.borrowed_gpu_min == pytest.approx(11.0)   # 10 work + 1 setup
    assert bor.lease_records == [(0.0, pytest.approx(11.0))]


def test_borrower_preempted_by_dispatch_and_returns():
    """Full shrink-free borrow/preempt/return cycle: leases are revoked the
    instant a queued job needs the GPUs (the job's own start is NOT
    delayed), shards keep their progress, pay the restart cost again on
    re-lease, and finish once capacity returns."""
    j0 = JobRecord(0, "evaluation", 1, 0.0, 1.0, "completed")
    a = JobRecord(1, "evaluation", 8, 5.0, 10.0, "completed")
    j1 = JobRecord(2, "evaluation", 1, 50.0, 1.0, "completed")
    bor = TrialBorrower([BorrowItem("x", 10.0), BorrowItem("y", 30.0)],
                        restart_cost_min=1.0, record_leases=True)
    res = replay_trace([j0, a, j1], 8, reserved_frac=0.0,
                       config=ReplayConfig(borrower=bor,
                                           record_segments=True))
    # borrowing is a virtual overlay on free capacity: A starts on arrival
    assert a.queue_min == pytest.approx(0.0)
    assert bor.preemptions == 2                  # both leases revoked at t=5
    assert sorted(bor.completed) == ["x", "y"]
    # each shard leased twice (initial + post-preemption re-lease)
    assert bor.lease_count == 4
    assert bor.overhead_min == pytest.approx(4.0)
    # 40 min of work + 4 restart charges, all executed on leased GPUs
    assert bor.borrowed_gpu_min == pytest.approx(44.0)
    spans = res.segments + [(-1, 1, t0, t1, "lease")
                            for t0, t1 in bor.lease_records]
    _assert_capacity_conserved(spans, 8)


def test_borrower_alone_accumulates_and_completes():
    b = TrialBorrower([BorrowItem("a", 2.0)], restart_cost_min=0.25)
    assert b.reconcile(0.0, 3) == 1
    assert b.reconcile(1.0, 3) == 1
    assert b.reconcile(5.0, 3) == 0              # finished at t=2.25
    assert b.completed == ["a"]
    assert b.borrowed_gpu_min == pytest.approx(2.25)
    assert b.stats()["shards_pending"] == 0


def test_pool_summary_present_without_borrower():
    jobs = generate_jobs(KALOS, seed=0, n_jobs=2000)
    res = replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.97,
                       config=ReplayConfig())
    pool = res.summary()["pool"]
    assert pool["borrow"] == {} and pool["borrowed_gpu_min"] == 0.0
    assert pool["free_gpu_hours"] > 0.0
    assert pool["horizon_min"] > 0.0


# --- head-delay characterization ---------------------------------------------

def test_head_delay_tail_reported_under_easy_and_fifo():
    jobs = generate_jobs(KALOS, seed=0, n_jobs=20_000)
    easy = replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.97,
                        config=ReplayConfig(
                            injector=FailureInjector(seed=1, rate_scale=4.0),
                            diagnose=True, elastic=True, backfill="easy"))
    hd = easy.summary()["head_delay"]
    assert hd["n"] > 0
    assert 0.0 <= hd["p50_min"] <= hd["p95_min"] <= hd["p99_min"]
    # under EASY (nearly) every head episode carries a shadow estimate —
    # the rare exception is a head whose shadow was infinite at marking
    # time (its demand outstrips the cluster minus cordoned capacity)
    assert hd["shadow_error"]["n"] >= 0.99 * hd["n"]
    fifo = replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.97,
                        config=ReplayConfig(
                            injector=FailureInjector(seed=1, rate_scale=4.0),
                            diagnose=True, elastic=True))
    fd = fifo.summary()["head_delay"]
    assert fd["n"] > 0
    assert fd["shadow_error"]["n"] <= fd["n"]    # sampled cadence
    # sampling off disables the machinery entirely
    off = replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.97,
                       config=ReplayConfig(head_delay_sample=0))
    assert off.summary()["head_delay"]["n"] == 0


# --- conservation across arbitrary pool cycles (property) --------------------

def _random_jobs(rng, n, gpus_max):
    jtypes = ("evaluation", "pretrain", "debug")
    return [JobRecord(i, str(rng.choice(list(jtypes))),
                      int(rng.integers(1, gpus_max + 1)),
                      float(rng.uniform(0, 200)),
                      float(rng.uniform(0.1, 30)), "completed")
            for i in range(n)]


@settings(max_examples=10, deadline=None)
@given(n=st.integers(20, 100), gpus=st.integers(8, 48),
       seed=st.integers(0, 40), rate=st.floats(0.0, 0.5))
def test_pool_cycles_conserve_capacity_and_work(n, gpus, seed, rate):
    """For ANY small trace and failure rate with the whole pool active
    (elastic shrink + opportunistic regrowth + trial borrowing): job
    segments plus 1-GPU lease spans never exceed the cluster at any
    instant, executed GPU-time equals useful + lost work for every job,
    and the borrower's ledger balances to the per-shard consumption."""
    rng = np.random.default_rng(seed)
    jobs = _random_jobs(rng, n, gpus)
    items = [BorrowItem(f"i{k}", float(rng.uniform(0.5, 20.0)))
             for k in range(int(rng.integers(1, 12)))]
    bor = TrialBorrower(items, restart_cost_min=0.3, max_leases=gpus,
                        record_leases=True)
    inj = FailureInjector(seed=seed, rate_scale=rate * 5e3)
    res = replay_trace(jobs, gpus, reserved_frac=0.6,
                       config=ReplayConfig(injector=inj, node_gpus=4,
                                           recovery_policy="elastic",
                                           borrower=bor,
                                           record_segments=True, seed=seed))
    spans = res.segments + [(-1, 1, t0, t1, "lease")
                            for t0, t1 in bor.lease_records]
    _assert_capacity_conserved(spans, gpus)
    _assert_work_identity(jobs, res)
    # borrower ledger: borrowed time == total consumption across shards
    consumed = sum(it.work_min + it.overhead_min - it.remaining_min
                   for it in bor.items)
    assert bor.borrowed_gpu_min == pytest.approx(consumed, abs=1e-6)
    assert bor.borrowed_gpu_min >= 0.0
    done = set(bor.completed)
    for it in bor.items:
        assert it.remaining_min >= -1e-9
        if it.name in done:
            assert it.remaining_min == pytest.approx(0.0, abs=1e-9)
    for j in jobs:
        assert j.queue_min >= 0 and j.requeue_wait_min >= 0
        assert j.lost_gpu_min >= 0


# --- scheduler primitive: revocable leases -----------------------------------

def test_lease_draws_spare_then_reserved_and_round_trips():
    sched = ReservationScheduler(32, 0.5)              # 16 r / 16 s
    be = JobRecord(0, "debug", 20, 0.0, 10.0, "completed", best_effort=True)
    assert sched.can_lease(be)
    sched.lease(be)
    # spare first, then idle reserved quota — the §3.2 reclamation target
    assert be._alloc == ("be", 4, 16)
    assert (sched.free_reserved, sched.free_spare) == (12, 0)
    # a "be" allocation regrows spare-first too (and may draw reserved)
    assert sched.grow(be, 6) == (6, 0)
    sched.finish(be)
    assert (sched.free_reserved, sched.free_spare) == (16, 16)


# --- the best-effort revocable-lease tier ------------------------------------

def test_best_effort_leases_reserved_quota_and_dispatch_revokes():
    """A checkpointed best-effort job runs on the pretraining reservation's
    idle quota; the moment a pretrain job wants the GPUs the lease is
    revoked — the pretrain job starts undelayed, the best-effort job rolls
    back to its last 30-min checkpoint, requeues, and finishes later.
    Every number in the timeline is hand-checkable."""
    be = JobRecord(0, "debug", 16, 0.0, 100.0, "completed", best_effort=True)
    hi = JobRecord(1, "pretrain", 16, 47.0, 10.0, "completed")
    res = replay_trace([be, hi], 16, reserved_frac=1.0,
                       config=ReplayConfig(record_segments=True))
    # the lease started instantly on reserved quota
    assert be.queue_min == pytest.approx(0.0)
    assert res.be_lease_starts == 2            # initial lease + re-lease
    # quota reclaimed at t=47: rollback to ckpt at 30, 17 min x 16 GPUs lost
    assert hi.queue_min == pytest.approx(0.0)  # dispatch never delayed
    assert be.restarts == 1
    assert be.lost_gpu_min == pytest.approx(17.0 * 16)
    assert be._done == pytest.approx(30.0)
    reclaim = res.by_class[QUOTA_RECLAIM]
    assert reclaim.failures == 1
    assert reclaim.lost_gpu_min == pytest.approx(17.0 * 16)
    assert reclaim.overhead_min == pytest.approx(2.0)
    # requeued at 49, pretrain ends 57, re-leases then runs 70 more min
    assert be.requeue_wait_min == pytest.approx(57.0 - 49.0)
    segs_be = [s for s in res.segments if s[0] == 0]
    assert segs_be[0] == (0, 16, 0.0, 47.0, "revoke")
    assert segs_be[-1][3] == pytest.approx(57.0 + 70.0)
    assert segs_be[-1][4] == "finish"
    _assert_capacity_conserved(res.segments, 16)
    _assert_work_identity([be, hi], res)
    s = res.summary()["pool"]["best_effort"]
    assert s == {"jobs": 1, "lease_starts": 2, "revocations": 1,
                 "lost_gpu_hours": pytest.approx(17.0 * 16 / 60.0),
                 "revoke_overhead_min": pytest.approx(2.0),
                 "never_started": 0}


def test_revocation_accounting_matches_injected_preemption():
    """The emergent quota-reclamation preemption must charge exactly what
    the injected ``preemption`` failure class charges: same rollback, same
    lost GPU-time, same restart overhead and requeue timing."""
    def preempt_cls():
        return ReplayFailureClass(PREEMPTION, 1.0, {},
                                  restart_overhead_min=2.0)

    # world A: best-effort job revoked by an arriving pretrain job at t=47
    be = JobRecord(0, "sft", 4, 0.0, 100.0, "completed", best_effort=True)
    blocker_a = JobRecord(1, "pretrain", 8, 47.0, 500.0, "completed")
    replay_trace([be, blocker_a], 8, reserved_frac=1.0,
                 config=ReplayConfig())
    # world B: identical job hit by an injected preemption at t=47
    inj = JobRecord(0, "sft", 4, 0.0, 100.0, "completed")
    blocker_b = JobRecord(1, "pretrain", 8, 47.0, 500.0, "completed")
    res_b = replay_trace([inj, blocker_b], 8, reserved_frac=1.0,
                         config=ReplayConfig(injector=ScriptedInjector(
                             [(47.0, preempt_cls()), None, None])))
    assert be.lost_gpu_min == pytest.approx(inj.lost_gpu_min)
    assert be._done == pytest.approx(inj._done) == pytest.approx(30.0)
    assert be.restarts == inj.restarts == 1
    # both re-arrive at t=49 behind the 8-GPU blocker
    assert be.requeue_wait_min == pytest.approx(inj.requeue_wait_min)
    assert res_b.by_class[PREEMPTION].overhead_min == pytest.approx(2.0)


def test_best_effort_killed_after_max_restarts():
    be = JobRecord(0, "debug", 8, 0.0, 500.0, "completed", best_effort=True)
    blockers = [JobRecord(i, "pretrain", 8, 40.0 * i, 5.0, "completed")
                for i in range(1, 4)]
    res = replay_trace([be] + blockers, 8, reserved_frac=1.0,
                       config=ReplayConfig(max_restarts=2))
    assert be.restarts == 3
    assert res.killed_job_ids == [0]
    reclaim = res.by_class[QUOTA_RECLAIM]
    assert reclaim.failures == 3
    # the killing revocation charges no restart overhead (nothing restarts)
    assert reclaim.overhead_min == pytest.approx(2 * 2.0)


# --- the lease/regrow capacity-event ordering audit --------------------------

def test_regrow_revocation_lands_before_grow_reads_free_count():
    """Ordering regression (the double-count audit): at B's completion the
    shrunken job A wants 8 GPUs back but only 4 are free — the other 2 sit
    under a best-effort lease. The regrow admission counts the revocable
    capacity, the revocation *lands first*, and the grow then reads the
    post-revocation pools: A regrows by exactly 6 (4 free + 2 revoked),
    with no instant where allocations exceed the cluster."""
    cls = _hw(overhead=5.0, repair=10_000.0)
    a = JobRecord(0, "evaluation", 10, 0.0, 300.0, "completed")
    b = JobRecord(1, "evaluation", 4, 0.0, 31.0, "completed")
    d = JobRecord(2, "debug", 2, 1.0, 100.0, "completed", best_effort=True)
    inj = ScriptedInjector([(4.0, cls)] + [None] * 8)
    res = replay_trace([a, b, d], 16, reserved_frac=0.0,
                       config=ReplayConfig(injector=inj, node_gpus=8,
                                           recovery_policy="elastic",
                                           max_cordon_frac=0.5,
                                           record_segments=True))
    assert res.elastic_shrinks == 1            # A: 10 -> 2 at t=4
    assert d.queue_min == pytest.approx(0.0)   # lease started on idle GPUs
    # at t=31: free=4, lease holds 2, A's deficit is 8 -> regrow admits 6
    assert res.pool_regrows == 1
    assert res.pool_regrown_gpus == 6
    reclaim = res.by_class[QUOTA_RECLAIM]
    assert reclaim.failures == 1               # D revoked by the regrow
    # D ran 1..31 and checkpoints every 30: rollback to 30, zero loss
    assert d.restarts == 1
    assert d.lost_gpu_min == pytest.approx(0.0)
    assert d._done == pytest.approx(30.0)
    _assert_capacity_conserved(res.segments, 16)
    _assert_work_identity([a, b, d], res)


def test_dispatch_revocation_preserves_easy_head_start():
    """The EASY-head variant of the ordering audit: a best-effort lease
    takes the 4 GPUs freed at t=31 (the regrow was deferred to protect the
    head), and at t=50 the head needs them back — the lease is revoked in
    the same event and the head still starts exactly at its shadow time."""
    jobs, inj = _easy_head_trace()
    h = jobs[3]
    d = JobRecord(4, "debug", 4, 32.0, 200.0, "completed", best_effort=True)
    res = replay_trace(jobs + [d], 16, reserved_frac=0.0,
                       config=ReplayConfig(injector=inj, node_gpus=4,
                                           recovery_policy="elastic",
                                           max_cordon_frac=0.5,
                                           backfill="easy",
                                           record_segments=True))
    assert d.queue_min == pytest.approx(0.0)       # leased the deferred GPUs
    assert h.queue_min == pytest.approx(45.0)      # head start unharmed
    assert res.by_class[QUOTA_RECLAIM].failures >= 1
    _assert_capacity_conserved(res.segments, 16)


# --- explicit regrow re-shard penalty ----------------------------------------

def test_regrow_charges_explicit_reshard_stall():
    """Same hand-checked timeline as
    test_shrunken_job_regrows_from_pool_at_completion_event, now with a
    2-minute re-shard stall: the regrown segment starts 2 minutes later
    and the completion shifts by exactly the stall."""
    cls = _hw(overhead=5.0, repair=500.0)
    a = JobRecord(0, "pretrain", 16, 0.0, 60.0, "completed")
    b = JobRecord(1, "pretrain", 8, 0.0, 20.0, "completed")
    inj = ScriptedInjector([(10.0, cls), None, None, None])
    res = replay_trace([a, b], 32, reserved_frac=0.5,
                       config=ReplayConfig(injector=inj, node_gpus=8,
                                           recovery_policy="elastic",
                                           max_cordon_frac=0.5,
                                           reshard_cost_min=2.0,
                                           checkpoint_interval_min=30.0,
                                           record_segments=True))
    assert res.pool_regrows == 1
    assert res.pool_reshard_events == 1
    assert res.pool_reshard_min == pytest.approx(2.0)
    # without the stall A finishes at 77.5; the explicit penalty adds 2
    seg_final = max(s for s in res.segments if s[0] == 0)
    assert seg_final[3] == pytest.approx(79.5)
    assert seg_final[4] == "finish"
    s = res.summary()["pool"]["regrowth"]
    assert s["reshard_events"] == 1
    assert s["reshard_stall_min"] == pytest.approx(2.0)


# --- node-local placement (NodeLedger + Fig. 16 borrowed-load collapse) ------

def test_node_ledger_conserves_and_round_trips():
    led = NodeLedger(4, 8, 32)
    assert led.free_total() == 32
    a = led.alloc(20)                  # 2 whole nodes + best-fit remainder
    assert sum(a.values()) == 20 and led.free_total() == 12
    assert sorted(a.values(), reverse=True)[:2] == [8, 8]
    b = led.alloc(3)                   # packs into the existing fragment
    assert sum(b.values()) == 3
    assert set(b) & set(a)             # shares the partially-used node
    # cordon a fully-free node: its GPUs drain
    free_node = next(n for n in range(4) if led.free[n] == 8)
    assert led.cordon_node(free_node) == 8
    assert led.free_total() == 32 - 23 - 8
    led.release(b)
    led.release(a)
    led.repair_nodes([free_node])
    led.add_free(8, prefer=[free_node])
    assert led.free_total() == 32
    assert led.free == [8, 8, 8, 8]
    assert not led.cordoned


def test_node_ledger_detach_attach_cycle():
    led = NodeLedger(2, 8, 16)
    nodes = led.alloc(12)
    donor = max(nodes, key=nodes.get)          # the fully-used node
    k = nodes[donor]
    assert led.detach(nodes, donor) == k       # GPUs leave with the cordon
    assert led.cordon_node(donor) == 0         # nothing free on it
    assert led.free_total() == 4
    led.repair_nodes([donor])
    led.attach(nodes, [donor], k)
    assert nodes[donor] == k
    led.release(nodes)
    assert led.free_total() == 16


def test_borrowed_loads_collapse_on_shared_node_nic():
    """Deterministic Fig. 16 reproduction inside the replay: 8 shards
    lease the 8 GPUs of one node nearly at once, so the k-th lease's model
    load sees k-1 loads already sharing the 25 Gb/s storage NIC and pays
    exactly ``load_minutes_shared(k)`` — the paper's load collapse."""
    spec = ClusterSpec(n_nodes=1)
    j0 = JobRecord(0, "evaluation", 1, 0.0, 0.05, "completed")
    bor = TrialBorrower([BorrowItem(f"s{i}", 30.0) for i in range(8)],
                        restart_cost_min=0.5, spec=spec)
    res = replay_trace([j0], 8, reserved_frac=0.0,
                       config=ReplayConfig(placement=True))
    p = res.summary()["placement"]
    assert p["n_nodes"] == 1             # ledger view, no load bins yet
    assert "load_by_concurrency" not in p

    res = replay_trace([j0], 8, reserved_frac=0.0,
                       config=ReplayConfig(placement=True, borrower=bor))
    p = res.summary()["placement"]
    assert p["n_nodes"] == 1 and p["node_gpus"] == 8
    bins = p["load_by_concurrency"]
    assert [bins[str(k)]["n"] for k in range(1, 9)] == [1] * 8
    for k in range(1, 9):
        assert bins[str(k)]["mean_load_min"] == pytest.approx(
            spec.load_minutes_shared(k))
    assert p["max_load_concurrency"] == 8
    # 25/8 Gb/s shared vs the 12 Gb/s single-stream ceiling: ~3.8x slower
    assert p["load_collapse_x"] == pytest.approx(
        spec.load_minutes_shared(8) / spec.load_minutes_shared(1))
    assert p["load_collapse_x"] > 3.0
    # the NIC-contended load is charged to the shard as lease overhead
    assert bor.overhead_min == pytest.approx(
        8 * 0.5 + sum(spec.load_minutes_shared(k) for k in range(1, 9)))


def test_placement_revokes_node_local_leases_on_allocation():
    """A lease on a node whose free GPUs a starting job consumed must be
    revoked even when total free capacity still covers the lease count:
    leases are node-local, not abstract."""
    spec = ClusterSpec(n_nodes=2)
    j0 = JobRecord(0, "evaluation", 1, 0.0, 0.05, "completed")
    big = JobRecord(1, "evaluation", 8, 10.0, 5.0, "completed")
    bor = TrialBorrower([BorrowItem("x", 100.0)], restart_cost_min=0.5,
                        spec=spec, max_leases=1, record_leases=True)
    replay_trace([j0, big], 16, reserved_frac=0.0,
                 config=ReplayConfig(placement=True, borrower=bor))
    # the shard leased a whole-free node at t=0; the 8-GPU job at t=10
    # takes a whole node — whichever node it lands on, the ledger keeps
    # the lease and the job on disjoint GPUs or revokes the lease
    assert big.queue_min == pytest.approx(0.0)
    assert bor.lease_count >= 1
    for t0, t1 in bor.lease_records:
        assert t1 >= t0


# --- best-effort cycles: capacity + work + checkpoint conservation -----------

@settings(max_examples=8, deadline=None)
@given(n=st.integers(20, 80), gpus=st.integers(8, 48),
       seed=st.integers(0, 40), rate=st.floats(0.0, 0.5),
       be_frac=st.floats(0.2, 0.9))
def test_best_effort_cycles_conserve_capacity_work_and_checkpoints(
        n, gpus, seed, rate, be_frac):
    """For ANY small trace with the whole machinery on (elastic shrink,
    regrowth with re-shard stalls, node-local placement, best-effort
    leases, trial borrowing): job segments plus lease spans never exceed
    the cluster, executed GPU-time equals useful + lost work per job,
    every best-effort rollback lands on a checkpoint multiple, and the
    quota-reclaim ledger reconciles exactly with the revoke segments."""
    rng = np.random.default_rng(seed)
    jobs = _random_jobs(rng, n, gpus)
    for j in jobs:
        if j.jtype != "pretrain" and rng.random() < be_frac:
            j.best_effort = True
    items = [BorrowItem(f"i{k}", float(rng.uniform(0.5, 20.0)))
             for k in range(int(rng.integers(1, 10)))]
    placement = bool(seed % 2)
    bor = TrialBorrower(items, restart_cost_min=0.3, max_leases=gpus,
                        record_leases=True,
                        spec=ClusterSpec(n_nodes=max(gpus // 4, 1))
                        if placement else None)
    inj = FailureInjector(seed=seed, rate_scale=rate * 5e3)
    interval = 10.0
    res = replay_trace(jobs, gpus, reserved_frac=0.6,
                       config=ReplayConfig(injector=inj, node_gpus=4,
                                           recovery_policy="elastic",
                                           checkpoint_interval_min=interval,
                                           placement=placement,
                                           reshard_cost_min=0.25,
                                           borrower=bor,
                                           record_segments=True, seed=seed))
    spans = res.segments + [(-1, 1, t0, t1, "lease")
                            for t0, t1 in bor.lease_records]
    _assert_capacity_conserved(spans, gpus)
    _assert_work_identity(jobs, res)
    # checkpoint accounting: a revoked/preempted best-effort job always
    # resumes from an exact checkpoint multiple, never loses checkpointed
    # work, and its loss ledger reconciles with the revoke segments
    revokes = collections.Counter(s[0] for s in res.segments
                                  if s[4] == "revoke")
    reclaim = res.by_class.get(QUOTA_RECLAIM)
    assert sum(revokes.values()) == (reclaim.failures if reclaim else 0)
    for j in jobs:
        if j.best_effort:
            assert j._done == pytest.approx(
                math.floor(j._done / interval + 1e-9) * interval, abs=1e-6) \
                or j._done == pytest.approx(j.duration_min)
            assert revokes[j.job_id] <= j.restarts
    # borrower ledger: borrowed time == total consumption across shards
    consumed = sum(it.work_min + it.overhead_min - it.remaining_min
                   for it in bor.items)
    assert bor.borrowed_gpu_min == pytest.approx(consumed, abs=1e-6)
