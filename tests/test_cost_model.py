"""Cost model (repro.launch.cost_model): width-curve physics, analytic
fallback ordering, artifact-tree hardening, workload arch tagging, dryrun
provenance fingerprints, and the replay engine's nominal-parity contract
for ``runtime_model="roofline"``."""
import json

import pytest

from repro.cluster import (KALOS, FailureInjector, ReplayConfig,
                           generate_jobs, replay_trace)
from repro.cluster.workload import PRETRAIN_ARCHS
from repro.launch.cost_model import (NOMINAL_DEVICES, CostModel, WidthCurve,
                                     dryrun_provenance)
from repro.launch.roofline import cell_roofline, full_table, load_cells

WIDTHS = (1, 2, 8, 32, 64, 128, 256, 512, 1024)


@pytest.fixture(scope="module")
def model() -> CostModel:
    return CostModel.analytic(PRETRAIN_ARCHS)


# ---------------------------------------------------------------- curves

@pytest.mark.parametrize("arch", PRETRAIN_ARCHS)
def test_efficiency_invariants(model, arch):
    """efficiency(1) == 1, <= 1 everywhere, monotone non-increasing."""
    c = model.curve(arch)
    assert c is not None
    assert c.efficiency(1) == 1.0
    effs = [c.efficiency(w) for w in WIDTHS]
    assert all(e <= 1.0 for e in effs)
    assert all(a >= b for a, b in zip(effs, effs[1:]))


@pytest.mark.parametrize("arch", PRETRAIN_ARCHS)
def test_rate_nominal_is_exactly_one(model, arch):
    """The bit-exactness anchor: at the curve's own width the progress
    rate is *exactly* 1.0 (same float expression divided by itself)."""
    c = model.curve(arch)
    assert c.rate(c.n_devices) == 1.0
    assert c.n_devices == NOMINAL_DEVICES


@pytest.mark.parametrize("arch", PRETRAIN_ARCHS)
def test_shrink_sublinear_grow_superlinear_cost(model, arch):
    """Shrinking hurts less than linearly (the collective term does not
    grow), regrowing gains less than linearly — the MegaScale-flavored
    behavior the replay's repricing relies on."""
    c = model.curve(arch)
    w0 = c.n_devices
    for w in WIDTHS:
        if w < w0:
            assert c.rate(w) > w / w0
        elif w > w0:
            assert 1.0 < c.rate(w) < w / w0
    rates = [c.rate(w) for w in WIDTHS]
    assert all(a < b for a, b in zip(rates, rates[1:]))  # monotone in w


@pytest.mark.parametrize("gpus", (8, 32, 96, 512, 1024))
def test_job_curve_reanchored_at_job_width(model, gpus):
    """job_curve anchors rate()==1.0 at the *job's* width, with the same
    curve shape (step times identical to the nominal-width curve)."""
    jc = model.job_curve("internlm-7b", gpus)
    assert jc.rate(gpus) == 1.0
    nom = model.curve("internlm-7b")
    for w in WIDTHS:
        assert jc.step_time(w) == nom.step_time(w)
    assert model.job_curve("internlm-7b", gpus) is jc      # cached


def test_curve_unknown_arch_is_none(model):
    assert model.curve("no-such-arch") is None
    assert model.job_curve("no-such-arch", 256) is None


def test_widthcurve_repr_and_step_time():
    c = WidthCurve("x", 4, work_s=8.0, coll_s=2.0)
    assert c.step_time(4) == 4.0 and c.step_time(1) == 10.0
    assert c.t_nom == 4.0
    assert "x" in repr(c)


# ------------------------------------------------------ analytic fallback

def test_analytic_moe_heavier_than_dense(model):
    """The fallback's one hard promise: MoE archs cost several times more
    collective bytes per useful FLOP than dense, and carry a2a traffic."""
    def per_flop(arch):
        cell = model.cell(arch)
        return cell.collective_bytes / cell.model_flops
    dense = per_flop("nemotron-4-15b")
    for moe in ("deepseek-v2-lite-16b", "mixtral-8x22b"):
        assert per_flop(moe) > 1.5 * dense
        assert model.cell(moe).a2a_bytes > 0
    assert model.cell("nemotron-4-15b").a2a_bytes == 0


def test_analytic_deterministic(model):
    again = CostModel.analytic(PRETRAIN_ARCHS)
    assert again.cells == model.cells


def test_analytic_unknown_arch_counted():
    m = CostModel.analytic(("internlm-7b", "definitely-not-an-arch"))
    assert m.skipped == {"unknown_arch": 1}
    assert m.archs() == ["internlm-7b"]


def test_load_empty_tree_falls_back(tmp_path):
    m = CostModel.load(str(tmp_path / "nothing"), archs=("internlm-7b",))
    assert m.skipped.get("analytic_fallback") == 1
    assert m.cell("internlm-7b").source == "analytic"
    bare = CostModel.load(str(tmp_path / "nothing"), archs=("internlm-7b",),
                          analytic_fallback=False)
    assert bare.cells == {} and bare.curve("internlm-7b") is None


# ------------------------------------------------- artifact-tree hardening

def _record(arch="smollm-360m", shape="train_4k", **over) -> dict:
    rec = {"arch": arch, "shape": shape, "kind": "train", "seq_len": 4096,
           "global_batch": 256, "n_devices": 256, "status": "ok",
           "cost": {"flops": 1.4e12, "bytes_accessed": 6.1e10},
           "memory": {"argument_size_in_bytes": 7.3e7,
                      "temp_size_in_bytes": 8.9e9},
           "collectives": {"total_bytes_per_device": 2.8e9},
           "calibrated": {"flops": 1.0e13, "bytes_accessed": 8.1e11,
                          "coll_total": 2.1e10,
                          "coll_all-to-all": 5.0e8}}
    rec.update(over)
    return rec


def _tree(tmp_path, files: dict) -> str:
    """files: {"arch/name.json": record-or-raw-string}."""
    root = tmp_path / "dryrun"
    for rel, content in files.items():
        p = root / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        if isinstance(content, str):
            p.write_text(content)
        else:
            p.write_text(json.dumps(content))
    return str(root)


def test_load_cells_skips_garbage_keeps_good(tmp_path):
    art = _tree(tmp_path, {
        "smollm-360m/train_4k.json": _record(),
        "smollm-360m/truncated.json": '{"arch": "smollm-360m", "cost"',
        "smollm-360m/list.json": "[1, 2, 3]",
    })
    skipped: dict = {}
    recs = load_cells(art, skipped=skipped)
    assert len(recs) == 1 and recs[0]["arch"] == "smollm-360m"
    assert skipped == {"unreadable_json": 1, "not_a_record": 1}


def test_cell_roofline_counts_each_reason():
    skipped: dict = {}
    assert cell_roofline("nope", skipped=skipped) is None
    assert cell_roofline(_record(status="failed"), skipped=skipped) is None
    assert cell_roofline({"status": "ok"}, skipped=skipped) is None
    assert cell_roofline(_record(seq_len="huh"), skipped=skipped) is None
    assert cell_roofline(_record(arch="not-an-arch"),
                         skipped=skipped) is None
    assert skipped == {"not_a_record": 1, "status_failed": 1,
                       "malformed_record": 2, "unknown_arch": 1}
    # non-dict calibrated/cost/collectives blobs degrade, not raise
    r = cell_roofline(_record(calibrated=None, collectives="x"))
    assert r is not None and r.calibrated is False
    assert r.collective_bytes == 0.0


def test_full_table_and_model_survive_mixed_tree(tmp_path):
    art = _tree(tmp_path, {
        "smollm-360m/train_4k.json": _record(),
        "smollm-360m/failed.json": _record(shape="prefill_32k",
                                           status="compile_error"),
        "weird/bad.json": '["not", "a", "dict"]',
        "weird/mystery.json": _record(arch="mystery", shape="train_4k"),
    })
    skipped: dict = {}
    rows = full_table(art, skipped=skipped)
    assert [r.arch for r in rows] == ["smollm-360m"]
    assert skipped == {"status_compile_error": 1, "not_a_record": 1,
                       "unknown_arch": 1}
    m = CostModel.load(art, archs=("internlm-7b",))
    cell = m.cell("smollm-360m")
    assert cell.source == "calibrated" and cell.a2a_bytes == 5.0e8
    assert m.cell("internlm-7b").source == "analytic"
    assert m.skipped["analytic_fallback"] == 1


# ----------------------------------------------------- dryrun provenance

def test_provenance_identity_and_sensitivity(tmp_path):
    art = _tree(tmp_path, {
        "smollm-360m/train_4k.json": _record(),
        "smollm-360m/failed.json": _record(shape="prefill_32k",
                                           status="oom"),
    })
    prov = dryrun_provenance(art)
    assert prov["archs"] == ["smollm-360m"]
    assert prov["n_cells"] == 1 and prov["n_calibrated"] == 1
    assert prov == dryrun_provenance(art)          # deterministic
    # identity is the cell *set*, not the measured numbers
    bumped = _record()
    bumped["calibrated"]["flops"] *= 1.01
    art2 = _tree(tmp_path / "b", {"smollm-360m/train_4k.json": bumped,
                                  "smollm-360m/failed.json":
                                  _record(shape="prefill_32k",
                                          status="oom")})
    assert dryrun_provenance(art2)["fingerprint"] == prov["fingerprint"]
    # ... but a new cell, or losing calibration, changes it
    art3 = _tree(tmp_path / "c", {
        "smollm-360m/train_4k.json": _record(),
        "internlm-7b/train_4k.json": _record(arch="internlm-7b")})
    assert dryrun_provenance(art3)["fingerprint"] != prov["fingerprint"]
    art4 = _tree(tmp_path / "d",
                 {"smollm-360m/train_4k.json": _record(calibrated={})})
    assert dryrun_provenance(art4)["fingerprint"] != prov["fingerprint"]
    empty = dryrun_provenance(str(tmp_path / "missing"))
    assert empty["n_cells"] == 0 and len(empty["fingerprint"]) == 8


# ----------------------------------------------------- workload tagging

def test_arch_tagging_leaves_population_bit_identical():
    plain = generate_jobs(KALOS, seed=11, n_jobs=3000, best_effort_frac=0.3)
    tagged = generate_jobs(KALOS, seed=11, n_jobs=3000,
                           best_effort_frac=0.3, arch_frac=0.6)
    assert len(plain) == len(tagged)
    n_tagged = 0
    for a, b in zip(plain, tagged):
        assert a.arch is None
        if b.arch is not None:
            n_tagged += 1
            assert b.jtype == "pretrain"
            assert b.arch in PRETRAIN_ARCHS
        for f in ("job_id", "jtype", "gpus", "submit_min", "duration_min",
                  "best_effort"):
            assert getattr(a, f) == getattr(b, f)
    assert n_tagged > 0
    again = generate_jobs(KALOS, seed=11, n_jobs=3000,
                          best_effort_frac=0.3, arch_frac=0.6)
    assert [j.arch for j in again] == [j.arch for j in tagged]


def test_arch_pool_override():
    jobs = generate_jobs(KALOS, seed=5, n_jobs=2000, arch_frac=1.0,
                         arch_pool=("internlm-7b",))
    archs = {j.arch for j in jobs if j.jtype == "pretrain"}
    assert archs == {"internlm-7b"}
    assert all(j.arch is None for j in jobs if j.jtype != "pretrain")


# ------------------------------------------------- replay integration

def _cfg(**over) -> ReplayConfig:
    kw = dict(injector=FailureInjector(seed=1, rate_scale=2.0),
              diagnose=True, elastic=True, placement=True,
              reshard_cost_min=1.0, backfill="easy")
    kw.update(over)
    return ReplayConfig(**kw)


def _replay(jobs, **cfg_over) -> dict:
    return replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.97,
                        config=_cfg(**cfg_over)).summary()


def test_unknown_runtime_model_raises():
    jobs = generate_jobs(KALOS, seed=0, n_jobs=10)
    with pytest.raises(ValueError, match="runtime_model"):
        _replay(jobs, runtime_model="quadratic")


def test_nominal_mode_ignores_arch_tags():
    """runtime_model="nominal" (the default) must be bit-exact whether or
    not the population carries arch tags — tagging alone changes nothing."""
    plain = _replay(generate_jobs(KALOS, seed=11, n_jobs=5000,
                                  best_effort_frac=0.3))
    tagged = _replay(generate_jobs(KALOS, seed=11, n_jobs=5000,
                                   best_effort_frac=0.3, arch_frac=0.8))
    assert "runtime_model" not in plain
    assert plain == tagged


def test_roofline_mode_untagged_is_exact_nominal_parity():
    """With no arch tags every job prices nominally, so roofline mode is
    bit-exact against nominal — minus only the runtime_model stats key."""
    jobs = lambda: generate_jobs(KALOS, seed=11, n_jobs=5000,  # noqa: E731
                                 best_effort_frac=0.3)
    nominal = _replay(jobs())
    roof = _replay(jobs(), runtime_model="roofline",
                   cost_model=CostModel.analytic(PRETRAIN_ARCHS))
    stats = roof.pop("runtime_model")
    assert stats["jobs_tagged"] == 0 and stats["jobs_modeled"] == 0
    assert roof == nominal


def test_roofline_mode_reprices_tagged_jobs():
    jobs = lambda: generate_jobs(KALOS, seed=11, n_jobs=5000,  # noqa: E731
                                 best_effort_frac=0.3, arch_frac=0.8)
    nominal = _replay(jobs())
    roof = _replay(jobs(), runtime_model="roofline",
                   cost_model=CostModel.analytic(PRETRAIN_ARCHS))
    stats = roof.pop("runtime_model")
    assert stats["model"] == "roofline"
    assert stats["jobs_modeled"] > 0
    assert stats["jobs_modeled"] <= stats["jobs_tagged"]
    assert set(stats["archs"]) <= set(PRETRAIN_ARCHS)
    assert roof != nominal           # the width curves actually repriced
