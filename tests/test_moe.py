"""MoE execution paths: dense (exact) vs gshard / tp (capacity-based) vs
gather-decode, plus router invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import MoEConfig, ParallelConfig
from repro.models import moe as moe_lib
from repro.models.spec import init_params
from repro.sharding import make_rules


def _setup(E=4, top_k=2, d=32, eff=64, capacity_factor=8.0):
    cfg = MoEConfig(num_experts=E, top_k=top_k, expert_ff=eff,
                    capacity_factor=capacity_factor)
    specs = moe_lib.moe_specs(d, cfg, "silu_glu")
    params = init_params(specs, jax.random.PRNGKey(0))
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 16, d))
    return cfg, params, x


def test_gshard_matches_dense_with_ample_capacity():
    """With capacity >> tokens, the capacity-dispatch path is exact."""
    cfg, params, x = _setup()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = make_rules(mesh, ParallelConfig())
    y_dense, aux_d = moe_lib.moe_dense(params, cfg, x, act="silu_glu",
                                       dtype=jnp.float32)
    with mesh:
        y_g, aux_g = moe_lib.moe_gshard(params, cfg, x, rules=rules,
                                        act="silu_glu", dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_g),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(float(aux_d), float(aux_g), rtol=1e-5)


def test_tp_matches_dense_with_ample_capacity():
    cfg, params, x = _setup()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = make_rules(mesh, ParallelConfig())
    y_dense, _ = moe_lib.moe_dense(params, cfg, x, act="silu_glu",
                                   dtype=jnp.float32)
    with mesh:
        y_tp, _ = moe_lib.moe_tp(params, cfg, x, rules=rules,
                                 act="silu_glu", dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_tp),
                               rtol=2e-5, atol=2e-5)


def test_gather_decode_matches_dense():
    cfg, params, _ = _setup(E=8, top_k=2)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(2), (1, 1, 32))
    y_dense, _ = moe_lib.moe_dense(params, cfg, x, act="silu_glu",
                                   dtype=jnp.float32)
    y_gather, _ = moe_lib.moe_gather_decode(params, cfg, x, act="silu_glu",
                                            dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_gather),
                               rtol=2e-5, atol=2e-5)


def test_capacity_drops_fall_through_to_residual():
    """Tokens beyond capacity produce zero output (residual passthrough),
    never garbage."""
    cfg, params, x = _setup(capacity_factor=0.05)   # almost everything drops
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = make_rules(mesh, ParallelConfig())
    with mesh:
        y, _ = moe_lib.moe_gshard(params, cfg, x, rules=rules,
                                  act="silu_glu", dtype=jnp.float32)
    assert bool(jnp.isfinite(y).all())
    # most rows zero
    norms = jnp.linalg.norm(y.reshape(-1, y.shape[-1]), axis=-1)
    assert float((norms == 0).mean()) > 0.5


def test_router_gates_normalized():
    cfg, params, x = _setup()
    gates, idx, probs = moe_lib._route(params["router"],
                                       x.reshape(-1, x.shape[-1]), cfg)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)
    assert int(idx.max()) < cfg.num_experts
    # aux loss is minimal (==1 scaled) for a perfectly uniform router
    E = cfg.num_experts
    uniform = jnp.full((64, E), 1.0 / E)
    idx_u = jnp.tile(jnp.arange(cfg.top_k), (64, 1))
    aux = moe_lib._aux_loss(uniform, idx_u, E)
    assert float(aux) >= 0.99
