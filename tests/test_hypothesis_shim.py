"""The hypothesis shim is test infrastructure for every property test in
the tier-1 suite — so it gets its own tests (ROADMAP standing note: extend
the shim instead of skipping tests; this module covers what the shim
promises so extensions cannot silently break draw determinism).

Tested directly against ``repro.utils.hypothesis_shim`` (not through the
installed ``hypothesis`` module name), so the suite behaves identically
whether or not real hypothesis is present.
"""
import random
import sys

import pytest

from repro.utils import hypothesis_shim as shim

st = shim


# --- draw determinism --------------------------------------------------------

def _run_tagged(tag: str, n_examples: int) -> list:
    """All values a @given test body would see. The ``tag`` names the
    capture function *before* decoration (the qualname participates in the
    derived seed at decoration time), so distinct tags get distinct
    streams — the property that makes shim failures reproducible
    run-to-run and machine-to-machine."""
    seen: list = []

    def body(n, x, tup):
        seen.append((n, x, tup))

    body.__qualname__ = f"capture_{tag}"
    body.__name__ = f"capture_{tag}"
    wrapped = shim.settings(max_examples=n_examples)(shim.given(
        n=shim.integers(0, 10 ** 9), x=shim.floats(-1.0, 1.0),
        tup=shim.tuples(shim.booleans(), shim.integers(0, 3)))(body))
    wrapped()
    return seen


def test_draws_deterministic_across_runs():
    """Same test name => identical example sequence, run after run."""
    a = _run_tagged("alpha", 12)
    b = _run_tagged("alpha", 12)
    assert a == b
    assert len(a) == 12


def test_distinct_tests_get_distinct_streams():
    """The per-test derived seed must differ between test names, or every
    property test in the suite would explore the same corner."""
    assert _run_tagged("alpha", 12) != _run_tagged("beta", 12)


def test_draws_independent_of_global_random_state():
    """Shim draws come from a private seeded Random — reseeding the global
    RNG between runs must not change them (replint RPL001's contract)."""
    random.seed(0)
    a = _run_tagged("gamma", 8)
    random.seed(12345)
    b = _run_tagged("gamma", 8)
    assert a == b


# --- settings / assume -------------------------------------------------------

@pytest.mark.parametrize("order", ["settings_over_given",
                                   "given_over_settings"])
def test_settings_max_examples_both_orders(order):
    calls = []

    def body(n):
        calls.append(n)

    deco_given = shim.given(n=shim.integers(0, 5))
    deco_settings = shim.settings(max_examples=7)
    if order == "settings_over_given":
        wrapped = deco_settings(deco_given(body))
    else:
        wrapped = deco_given(deco_settings(body))
    wrapped()
    assert len(calls) == 7


def test_assume_skips_examples():
    calls = []

    @shim.settings(max_examples=20)
    @shim.given(n=shim.integers(0, 9))
    def body(n):
        shim.assume(n % 2 == 0)
        calls.append(n)

    body()
    assert calls and all(n % 2 == 0 for n in calls)
    assert len(calls) < 20          # some examples were skipped


def test_falsifying_example_reraises():
    @shim.given(n=shim.integers(0, 5))
    def body(n):
        raise AssertionError("boom")

    with pytest.raises(AssertionError, match="boom"):
        body()


def test_given_rejects_positional_and_unknown_kwargs():
    with pytest.raises(TypeError):
        shim.given(shim.integers(0, 1))
    with pytest.raises(TypeError):
        shim.given(zzz=shim.integers(0, 1))(lambda n: None)


# --- strategy coverage -------------------------------------------------------

def _rng():
    return random.Random(1234)


def test_integers_floats_bounds():
    rng = _rng()
    for _ in range(200):
        assert 3 <= shim.integers(3, 9).do_draw(rng) <= 9
        assert -2.5 <= shim.floats(-2.5, 0.5).do_draw(rng) <= 0.5


def test_booleans_sampled_from_just():
    rng = _rng()
    drawn = {shim.booleans().do_draw(rng) for _ in range(50)}
    assert drawn == {True, False}
    opts = ["a", "b", "c"]
    assert all(shim.sampled_from(opts).do_draw(rng) in opts
               for _ in range(50))
    with pytest.raises(ValueError):
        shim.sampled_from([])
    assert shim.just(42).do_draw(rng) == 42


def test_lists_sets_size_bounds():
    rng = _rng()
    els = shim.integers(0, 100)
    for _ in range(50):
        xs = shim.lists(els, min_size=2, max_size=5).do_draw(rng)
        assert 2 <= len(xs) <= 5
        s = shim.sets(shim.integers(0, 3), min_size=1,
                      max_size=4).do_draw(rng)
        # the element domain has only 4 values; sizes stay in range anyway
        assert 1 <= len(s) <= 4 and s <= {0, 1, 2, 3}


def test_data_draws_interactively():
    seen = []

    @shim.settings(max_examples=5)
    @shim.given(data=shim.data())
    def body(data):
        n = data.draw(shim.integers(0, 3))
        xs = data.draw(shim.lists(shim.integers(0, 9), min_size=n,
                                  max_size=n))
        seen.append((n, xs))
        assert len(xs) == n

    body()
    assert len(seen) == 5


# --- the PR's extensions: one_of / text / dictionaries -----------------------

def test_one_of_covers_every_branch():
    rng = _rng()
    strat = shim.one_of(shim.just("L"), shim.just("R"))
    drawn = {strat.do_draw(rng) for _ in range(100)}
    assert drawn == {"L", "R"}
    with pytest.raises(ValueError):
        shim.one_of()


def test_text_alphabet_and_bounds():
    rng = _rng()
    strat = shim.text("ab", min_size=1, max_size=6)
    for _ in range(100):
        s = strat.do_draw(rng)
        assert 1 <= len(s) <= 6 and set(s) <= {"a", "b"}
    assert shim.text("", max_size=5).do_draw(rng) == ""
    # character strategies work as alphabets too
    s = shim.text(shim.sampled_from("xy"), min_size=3,
                  max_size=3).do_draw(rng)
    assert len(s) == 3 and set(s) <= {"x", "y"}


def test_dictionaries_sizes_and_domains():
    rng = _rng()
    strat = shim.dictionaries(shim.integers(0, 3),
                              shim.text("k", min_size=1, max_size=1),
                              min_size=1, max_size=4)
    for _ in range(50):
        d = strat.do_draw(rng)
        assert 1 <= len(d) <= 4
        assert set(d) <= {0, 1, 2, 3} and set(d.values()) <= {"k"}


def test_extensions_deterministic():
    """New combinators obey the same seeded-draw contract as the rest."""
    def run():
        rng = random.Random(7)
        strat = shim.tuples(
            shim.one_of(shim.integers(0, 9), shim.text("abc", max_size=4)),
            shim.dictionaries(shim.text("xy", min_size=1, max_size=2),
                              shim.floats(0.0, 1.0), max_size=3))
        return [strat.do_draw(rng) for _ in range(20)]

    assert run() == run()


# --- install() ---------------------------------------------------------------

def test_install_registers_module_and_is_idempotent():
    saved = {k: sys.modules.get(k)
             for k in ("hypothesis", "hypothesis.strategies")}
    try:
        assert shim.install(force=True)
        import hypothesis
        import hypothesis.strategies as hst
        assert hypothesis.__shim__
        assert hst.one_of is shim.one_of
        assert hst.text is shim.text
        assert hst.dictionaries is shim.dictionaries
        # idempotent: installing again over the shim stays installed
        assert shim.install()
    finally:
        for k, v in saved.items():
            if v is None:
                sys.modules.pop(k, None)
            else:
                sys.modules[k] = v
