"""Trainer + Supervisor integration on a real (tiny) JAX training loop:
failure recovery, spike rollback with data skipping, straggler cordoning,
and resumption exactness."""
import numpy as np
import pytest

from repro.config import ParallelConfig, TrainConfig, get_smoke
from repro.core.ft.checkpoint import CheckpointManager
from repro.core.ft.detection import SimulatedFleet
from repro.core.ft.diagnosis import FailureDiagnosisSystem
from repro.core.ft.events import BY_NAME
from repro.core.ft.supervisor import Supervisor
from repro.launch.mesh import make_host_mesh
from repro.launch.train import Trainer
from repro.models import Model
from repro.sharding import make_rules


def _trainer(tmp_path, steps=50, **kw):
    cfg = get_smoke("smollm-360m")
    mesh = make_host_mesh()
    parallel = ParallelConfig(remat="none", moe_impl="dense")
    tcfg = TrainConfig(global_batch=2, seq_len=32, total_steps=steps,
                       warmup_steps=5, learning_rate=1e-3)
    model = Model(cfg, parallel, make_rules(mesh, parallel))
    ckpt = CheckpointManager(str(tmp_path), keep=4)
    return Trainer(model, tcfg, mesh, parallel, ckpt, total_steps=steps,
                   ckpt_every=10, log_every=10 ** 9, **kw), ckpt


def test_trainer_recovers_and_skips_spike_data(tmp_path):
    trainer, ckpt = _trainer(
        tmp_path, steps=50,
        fault_schedule={17: BY_NAME["ECCError"]},
        spike_schedule={30 + i: 8.0 for i in range(5)})
    fleet = SimulatedFleet(8)
    sup = Supervisor(ckpt, FailureDiagnosisSystem(), fleet)
    report = sup.run(trainer.job)
    ckpt.wait()
    assert report.completed and report.final_step == 50
    kinds = [e.kind for e in report.events]
    assert "failure" in kinds and "spike" in kinds
    spike = next(e for e in report.events if e.kind == "spike")
    assert spike.resumed_from <= 30          # pre-onset checkpoint
    losses = [l for _, l in trainer.history]
    assert np.isfinite(losses[-1]) and losses[-1] < losses[0]


def test_trainer_cordons_stragglers(tmp_path):
    fleet = SimulatedFleet(8)
    times = lambda step: {h: 1.0 + (0.8 if h == 5 else 0.0) + 0.001 * step
                          for h in range(8)}
    trainer, ckpt = _trainer(tmp_path, steps=15, fleet=fleet,
                             host_time_fn=times)
    sup = Supervisor(ckpt, FailureDiagnosisSystem(), fleet)
    report = sup.run(trainer.job)
    ckpt.wait()
    assert report.completed
    assert 5 in fleet.cordoned               # persistent straggler removed
    assert len(fleet.cordoned) == 1          # and only it
