"""§6.1 asynchronous checkpointing: blocking time sync vs async.

Paper claim: "The checkpoint time and overhead percentage of 7B and 123B
size models are reduced by 3.6 ~ 58.7x (interval = 30 mins)". We measure the
actual blocking time of save_sync (snapshot + serialize + throttled write,
modelling the contended remote PFS) vs save_async (snapshot only) across
host-RAM-sized model states standing in for the 7B/123B per-host shards.
"""
from __future__ import annotations

import tempfile

import jax
import numpy as np

from benchmarks.common import Row, emit
from repro.core.ft.checkpoint import CheckpointManager

# per-host state sizes: a 7B model on 64 hosts ~ 1.6 GiB/host of fp32 state
# (params+opt /64); scaled to container RAM. bandwidth = paper's 25 Gb/s
# storage NIC shared by ~8 writers -> ~0.4 GB/s effective.
SIZES_MB = {"7B-analog": 48, "123B-analog": 384}
BW_GBPS = 3.2 / 8       # effective per-writer Gb/s under contention


def _state(mb: int):
    n = mb * 1024 * 1024 // 4
    return {"w": jax.numpy.asarray(np.random.default_rng(0)
                                   .standard_normal(n, dtype=np.float32))}


def run(fast: bool = False) -> list[Row]:
    rows = []
    for name, mb in SIZES_MB.items():
        if fast and mb > 100:
            mb = 96
        state = _state(mb)
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep=1,
                                    storage_bandwidth_gbps=BW_GBPS)
            # min-of-3: the stall-reduction ratio feeds the CI perf gate
            # (benchmarks.check_regression), so scheduler jitter in the
            # small async numbers must not masquerade as a regression
            t_sync = min(mgr.save_sync(1, state) for _ in range(3))
            t_async = min(mgr.save_async(s, state) for s in (2, 3, 4))
            mgr.wait(timeout=600)
            mgr.close()
        ratio = t_sync / max(t_async, 1e-9)
        rows += [
            Row("checkpoint", f"{name}_sync_block_s", t_sync, "", "s"),
            Row("checkpoint", f"{name}_async_block_s", t_async, "", "s"),
            Row("checkpoint", f"{name}_stall_reduction", ratio,
                "3.6~58.7x (§6.1)", "x", 3.0 <= ratio),
        ]
    return rows


def main(fast: bool = False) -> None:
    emit(run(fast), "checkpoint")


if __name__ == "__main__":
    main()
