"""§6.1 fast fault detection: two-round pairwise allgather localization.

Reports probe counts vs fleet size and correctness under multi-fault
scenarios; baseline comparison = exhaustive pairwise screening (n*(n-1)/2
probes), which the two-round scheme beats by orders of magnitude.
"""
from __future__ import annotations

import random

from benchmarks.common import Row, emit
from repro.core.ft.detection import SimulatedFleet, two_round_detection


def run(fast: bool = False) -> list[Row]:
    rows = []
    rng = random.Random(0)
    sizes = [128, 512] if fast else [128, 512, 2048]
    for n in sizes:
        trials = 10 if fast else 25
        probes = []
        exact = 0
        for t in range(trials):
            k = rng.randint(1, max(n // 64, 1))
            faulty = set(rng.sample(range(n), k))
            fleet = SimulatedFleet(n, faulty=set(faulty))
            res = two_round_detection(fleet.healthy_nodes(), fleet)
            probes.append(res.probes)
            exact += set(res.faulty) == faulty
        avg = sum(probes) / len(probes)
        naive = n * (n - 1) // 2
        rows += [
            Row("detection", f"n{n}_exact_frac", exact / trials,
                "pinpoints faulty nodes", "", exact == trials),
            Row("detection", f"n{n}_avg_probes", avg,
                f"vs naive {naive} pairwise", "probes", avg < n),
            Row("detection", f"n{n}_probe_savings", naive / avg, "", "x"),
        ]
    return rows


def main(fast: bool = False) -> None:
    emit(run(fast), "detection")


if __name__ == "__main__":
    main()
