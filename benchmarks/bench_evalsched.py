"""§6.2 decoupled evaluation scheduling (Fig. 16 + makespan claims).

Paper: trial coordinator reduces the 63-dataset / 7B evaluation makespan by
1.3x (1 node) and 1.8x (4 nodes); the loading-speed stress test collapses
from 1 to 8 concurrent trials per node and stabilizes 8..256.
"""
from __future__ import annotations

from benchmarks.common import Row, calibrated_probe, emit
from repro.core.evalsched import (ClusterSpec, schedule_baseline,
                                  schedule_decoupled, standard_suite)
from repro.core.evalsched.coordinator import loading_speed_curve


def run(fast: bool = False) -> list[Row]:
    suite = standard_suite(63)
    rows = []
    for nodes, target, lo, hi in ((1, "1.3x (§6.2)", 1.1, 1.6),
                                  (4, "1.8x (§6.2)", 1.5, 2.3)):
        spec = ClusterSpec(n_nodes=nodes)
        b = schedule_baseline(suite, spec)
        d = schedule_decoupled(suite, spec)
        ratio = b.makespan / d.makespan
        rows += [
            Row("evalsched", f"{nodes}node_baseline_makespan_min",
                b.makespan, "", "min"),
            Row("evalsched", f"{nodes}node_decoupled_makespan_min",
                d.makespan, "", "min"),
            Row("evalsched", f"{nodes}node_speedup", ratio, target, "x",
                lo <= ratio <= hi),
            Row("evalsched", f"{nodes}node_decoupled_gpu_util",
                d.gpu_utilization, "GPU idle eliminated (Fig.13)", "",
                d.gpu_utilization > 0.9),
        ]
    # calibrated decoupled-scheduler throughput for the CI regression gate:
    # repeated full decoupled schedules, engine task completions per
    # calibrated op (methodology in benchmarks.common.calibrated_probe)
    probe_spec = ClusterSpec(n_nodes=4)
    rows.append(Row("evalsched", "events_per_calib",
                    calibrated_probe(
                        lambda: float(sum(
                            schedule_decoupled(suite, probe_spec).n_events
                            for _ in range(50))),
                        rounds=4),
                    "CI regression gate (calibrated)", ""))
    curve = dict(loading_speed_curve(ClusterSpec(n_nodes=4),
                                     [1, 2, 4, 8, 64, 256]))
    rows += [
        Row("evalsched", "load_GBps_1trial", curve[1],
            "fast when alone (Fig.16 left)", "GB/s"),
        Row("evalsched", "load_GBps_8trials", curve[8],
            "NIC-bound at 8/node", "GB/s", curve[1] / curve[8] >= 2),
        Row("evalsched", "load_GBps_256trials", curve[256],
            "stable 8..256", "GB/s", curve[256] == curve[8]),
    ]
    if not fast:
        # the real threaded mini-run (actual JAX inference + CPU metrics)
        import jax
        from repro.config import AttentionConfig, ModelConfig
        from repro.core.evalsched.runner import (RemoteStore, make_suite,
                                                 run_baseline, run_decoupled)
        from repro.models import Model
        cfg = ModelConfig(
            name="t", num_layers=2, d_model=64, d_ff=128, vocab_size=256,
            max_seq_len=64, vocab_pad_multiple=64,
            attention=AttentionConfig(num_heads=4, num_kv_heads=2,
                                      head_dim=16))
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        store = RemoteStore(params, bandwidth_mbps=4.0)
        mini = make_suite(model, n_datasets=10, heavy_tail=0.6)
        try:
            base = run_baseline(model, store, mini, n_workers=2,
                                warm_params=params)
            dec = run_decoupled(model, store, mini, n_workers=2,
                                warm_params=params)
        finally:
            store.close()
        r = base.makespan_s / dec.makespan_s
        rows.append(Row("evalsched", "real_threaded_speedup", r,
                        "decoupled wins on real execution", "x", r > 1.25))
    return rows


def main(fast: bool = False) -> None:
    emit(run(fast), "evalsched")


if __name__ == "__main__":
    main()
