"""Benchmark plumbing: result rows + artifact output."""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Optional

ARTIFACTS = os.environ.get("REPRO_BENCH_DIR", "artifacts/bench")


@dataclasses.dataclass
class Row:
    bench: str
    metric: str
    value: float
    target: Optional[str] = None       # the paper's figure/claim, as text
    unit: str = ""
    ok: Optional[bool] = None          # within-band verdict when checkable

    def line(self) -> str:
        tgt = self.target or ""
        oks = "" if self.ok is None else ("PASS" if self.ok else "MISS")
        return (f"{self.bench},{self.metric},{self.value:.6g},{self.unit},"
                f"{tgt},{oks}")


def emit(rows: list[Row], name: str) -> None:
    os.makedirs(ARTIFACTS, exist_ok=True)
    print(f"# --- {name} " + "-" * max(0, 60 - len(name)))
    print("bench,metric,value,unit,paper_target,verdict")
    for r in rows:
        print(r.line())
    with open(os.path.join(ARTIFACTS, f"{name}.json"), "w") as f:
        json.dump([dataclasses.asdict(r) for r in rows], f, indent=1)
