"""Benchmark plumbing: result rows, artifact output, CPU calibration, and
the parallel multi-world runner."""
from __future__ import annotations

import concurrent.futures
import dataclasses
import gc
import heapq
import json
import os
import random
import time
from typing import Any, Callable, Optional

ARTIFACTS = os.environ.get("REPRO_BENCH_DIR", "artifacts/bench")

# sequential fallback for the multi-world runner: debugging, or boxes where
# process spawn is more expensive than the parallelism buys back
SEQUENTIAL = os.environ.get("REPRO_BENCH_SEQUENTIAL") == "1"


def _run_world(entry: tuple) -> Any:
    fn, args, kwargs = entry
    return fn(*args, **kwargs)


def run_worlds(worlds: "dict[str, tuple]",
               max_workers: Optional[int] = None) -> dict[str, Any]:
    """Run independent benchmark *worlds* in parallel, one process each.

    ``worlds`` maps a name to ``(fn, args)`` or ``(fn, args, kwargs)`` where
    ``fn`` is a module-level (picklable) callable that builds its own inputs
    from deterministic seeds and returns a picklable result. Returns
    ``{name: result}``.

    The bench suites replay the same trace through several configurations
    (repair-only vs pool vs EASY worlds, baseline vs injected vs parity
    runs); those replays are independent by construction — each world
    regenerates its jobs from a fixed seed — so they can overlap instead of
    dominating CI wall time sequentially. ``events_per_calib`` probe
    worlds may run in here too: each probe interleaves its own calibration
    chunks (see :func:`calibrated_probe`), which is what makes the gated
    ratio robust to contention from sibling worlds — the same property
    that lets it survive noisy shared CI runners. Wall-clock rows, by
    contrast, should be measured *outside* any parallel phase (see
    ``bench_replay``'s headline run).

    Falls back to in-process sequential execution when
    ``REPRO_BENCH_SEQUENTIAL=1`` or the pool cannot be spawned; if the
    pool breaks mid-run (a worker crashed or was OOM-killed), only the
    worlds that did not complete are re-run inline, so finished results
    are kept and the crash site is visible in the output.
    """
    norm = {name: (w[0], w[1] if len(w) > 1 else (),
                   w[2] if len(w) > 2 else {})
            for name, w in worlds.items()}
    if SEQUENTIAL or len(norm) <= 1:
        return {name: _run_world(w) for name, w in norm.items()}
    workers = max_workers or min(len(norm), os.cpu_count() or 2)
    try:
        with concurrent.futures.ProcessPoolExecutor(workers) as pool:
            futs = {name: pool.submit(_run_world, w)
                    for name, w in norm.items()}
            out: dict[str, Any] = {}
            failed: list[str] = []
            for name, f in futs.items():
                try:
                    out[name] = f.result()
                except concurrent.futures.process.BrokenProcessPool:
                    failed.append(name)
    except OSError:
        # constrained sandbox (no fork/spawn): run the worlds inline
        return {name: _run_world(w) for name, w in norm.items()}
    if failed:
        print(f"# run_worlds: process pool broke; rerunning {failed} "
              "inline (completed worlds kept)")
        for name in failed:
            out[name] = _run_world(norm[name])
    return out


def calibration_chunk(n: int = 300_000) -> tuple[int, float]:
    """One fixed seeded heap-push/pop burst (the replay engine's inner-loop
    shape); returns ``(ops, seconds)``. Callers interleave these chunks
    with the workload they are measuring and ratio the *windowed* rates:
    throughput divided by the same-window calibration is roughly
    machine-invariant AND robust to bursty CPU contention, which is what
    lets ``check_regression`` compare a fresh CI run against baselines
    recorded on a different runner class."""
    rng = random.Random(0)
    rand = rng.random
    heappush, heappop = heapq.heappush, heapq.heappop
    h: list = []
    t0 = time.perf_counter()
    for i in range(n):
        heappush(h, (rand(), i))
        if len(h) > 512:
            heappop(h)
    return n, time.perf_counter() - t0


def calibrated_probe(workload: Callable[[], float], rounds: int = 4) -> float:
    """The CI-gate measurement methodology, shared by every
    ``events_per_calib`` metric: run ``workload`` (returns its event/op
    count) ``rounds`` times interleaved with calibration chunks, GC paused
    across the window, and ratio the *windowed* rates — workload events/s
    over same-window calibration ops/s — so runner class and bursty CPU
    contention cancel. Keep all gated benches on this one helper: gates are
    only comparable if their sensitivity to noise is identical."""
    c_ops = c_sec = w_ev = w_sec = 0.0
    gc.collect()
    gc.disable()
    try:
        for _ in range(rounds):
            ops, sec = calibration_chunk()
            c_ops += ops
            c_sec += sec
            t0 = time.perf_counter()
            w_ev += workload()
            w_sec += time.perf_counter() - t0
    finally:
        gc.enable()
    return (w_ev / max(w_sec, 1e-9)) / (c_ops / max(c_sec, 1e-9))


# replint verdict rows stamped into every artifact this process emits (set
# once by benchmarks.run before any bench executes; None = unstamped, e.g.
# a bench module run directly). check_regression refuses fresh artifacts
# whose stamp says the tree had non-baseline lint findings — numbers from
# a dirty tree must never become comparison baselines.
_REPLINT_STAMP: "Optional[dict]" = None


def set_replint_stamp(verdict: dict) -> None:
    global _REPLINT_STAMP
    _REPLINT_STAMP = dict(verdict)


# pallas_cost verdict rows (repro.quality.pallas_cost.verdict) stamped
# alongside the replint stamp: bench numbers recorded while a kernel
# carried RPL2xx resource findings (or while the static cost table
# disagreed with the analytic cost model) must never become baselines.
_PALLAS_COST_STAMP: "Optional[dict]" = None


def set_pallas_cost_stamp(verdict: dict) -> None:
    global _PALLAS_COST_STAMP
    _PALLAS_COST_STAMP = dict(verdict)


# dryrun-artifact provenance (launch.cost_model.dryrun_provenance) stamped
# into the benches that consume artifacts/dryrun/** — check_regression
# compares the fingerprint before comparing any of their metrics, so a
# roofline row is never judged against a baseline built from a different
# cell set (different archs, or calibrated vs raw-HLO records).
_DRYRUN_STAMP: "Optional[dict]" = None
DRYRUN_STAMPED_BENCHES = ("roofline", "moe_comm", "serve")


def set_dryrun_stamp(provenance: dict) -> None:
    global _DRYRUN_STAMP
    _DRYRUN_STAMP = dict(provenance)


@dataclasses.dataclass
class Row:
    bench: str
    metric: str
    value: float
    target: Optional[str] = None       # the paper's figure/claim, as text
    unit: str = ""
    ok: Optional[bool] = None          # within-band verdict when checkable

    def line(self) -> str:
        tgt = self.target or ""
        oks = "" if self.ok is None else ("PASS" if self.ok else "MISS")
        return (f"{self.bench},{self.metric},{self.value:.6g},{self.unit},"
                f"{tgt},{oks}")


def emit(rows: list[Row], name: str) -> None:
    os.makedirs(ARTIFACTS, exist_ok=True)
    if _REPLINT_STAMP is not None:
        rows = rows + [
            Row(name, "replint_clean",
                1.0 if _REPLINT_STAMP.get("clean") else 0.0,
                target="no non-baseline lint findings", unit="bool"),
            Row(name, "replint_findings",
                float(_REPLINT_STAMP.get("findings", 0)), unit="count"),
        ]
    if _PALLAS_COST_STAMP is not None:
        rows = rows + [
            Row(name, "pallas_cost_clean",
                1.0 if _PALLAS_COST_STAMP.get("clean") else 0.0,
                target="no RPL2xx findings + cost-model check holds",
                unit="bool"),
            Row(name, "pallas_cost_findings",
                float(_PALLAS_COST_STAMP.get("n_findings", 0)),
                unit="count"),
        ]
    if _DRYRUN_STAMP is not None and name in DRYRUN_STAMPED_BENCHES:
        # the 32-bit crc fingerprint is exactly representable as a float,
        # so it survives the Row value field and the JSON round-trip
        rows = rows + [
            Row(name, "dryrun_cells",
                float(_DRYRUN_STAMP.get("n_cells", 0)), unit="count"),
            Row(name, "dryrun_calibrated",
                float(_DRYRUN_STAMP.get("n_calibrated", 0)), unit="count"),
            Row(name, "dryrun_fingerprint",
                float(int(_DRYRUN_STAMP.get("fingerprint", "0"), 16)),
                target="cell-set identity for check_regression"),
        ]
    print(f"# --- {name} " + "-" * max(0, 60 - len(name)))
    print("bench,metric,value,unit,paper_target,verdict")
    for r in rows:
        print(r.line())
    with open(os.path.join(ARTIFACTS, f"{name}.json"), "w") as f:
        json.dump([dataclasses.asdict(r) for r in rows], f, indent=1)
