"""Benchmark plumbing: result rows, artifact output, CPU calibration."""
from __future__ import annotations

import dataclasses
import gc
import heapq
import json
import os
import random
import time
from typing import Any, Callable, Optional

ARTIFACTS = os.environ.get("REPRO_BENCH_DIR", "artifacts/bench")


def calibration_chunk(n: int = 300_000) -> tuple[int, float]:
    """One fixed seeded heap-push/pop burst (the replay engine's inner-loop
    shape); returns ``(ops, seconds)``. Callers interleave these chunks
    with the workload they are measuring and ratio the *windowed* rates:
    throughput divided by the same-window calibration is roughly
    machine-invariant AND robust to bursty CPU contention, which is what
    lets ``check_regression`` compare a fresh CI run against baselines
    recorded on a different runner class."""
    rng = random.Random(0)
    rand = rng.random
    heappush, heappop = heapq.heappush, heapq.heappop
    h: list = []
    t0 = time.perf_counter()
    for i in range(n):
        heappush(h, (rand(), i))
        if len(h) > 512:
            heappop(h)
    return n, time.perf_counter() - t0


def calibrated_probe(workload: Callable[[], float], rounds: int = 4) -> float:
    """The CI-gate measurement methodology, shared by every
    ``events_per_calib`` metric: run ``workload`` (returns its event/op
    count) ``rounds`` times interleaved with calibration chunks, GC paused
    across the window, and ratio the *windowed* rates — workload events/s
    over same-window calibration ops/s — so runner class and bursty CPU
    contention cancel. Keep all gated benches on this one helper: gates are
    only comparable if their sensitivity to noise is identical."""
    c_ops = c_sec = w_ev = w_sec = 0.0
    gc.collect()
    gc.disable()
    try:
        for _ in range(rounds):
            ops, sec = calibration_chunk()
            c_ops += ops
            c_sec += sec
            t0 = time.perf_counter()
            w_ev += workload()
            w_sec += time.perf_counter() - t0
    finally:
        gc.enable()
    return (w_ev / max(w_sec, 1e-9)) / (c_ops / max(c_sec, 1e-9))


@dataclasses.dataclass
class Row:
    bench: str
    metric: str
    value: float
    target: Optional[str] = None       # the paper's figure/claim, as text
    unit: str = ""
    ok: Optional[bool] = None          # within-band verdict when checkable

    def line(self) -> str:
        tgt = self.target or ""
        oks = "" if self.ok is None else ("PASS" if self.ok else "MISS")
        return (f"{self.bench},{self.metric},{self.value:.6g},{self.unit},"
                f"{tgt},{oks}")


def emit(rows: list[Row], name: str) -> None:
    os.makedirs(ARTIFACTS, exist_ok=True)
    print(f"# --- {name} " + "-" * max(0, 60 - len(name)))
    print("bench,metric,value,unit,paper_target,verdict")
    for r in rows:
        print(r.line())
    with open(os.path.join(ARTIFACTS, f"{name}.json"), "w") as f:
        json.dump([dataclasses.asdict(r) for r in rows], f, indent=1)
