"""§6.1 failure diagnosis (Fig. 15 + Table 3): accuracy of the rule+agent
pipeline over the Table-3 failure mix with cascaded symptom logs, the
learning curve (agent -> rules), and log-compression ratio.

Paper claim: the system "reduces manual intervention by around 90%"; our
proxy: >=90% of failures are auto-diagnosed correctly, and every
infrastructure failure (auto-recoverable) is routed away from a human.
"""
from __future__ import annotations

import random

from benchmarks.common import Row, emit
from repro.core.ft.diagnosis import FailureDiagnosisSystem
from repro.core.ft.events import TABLE3, generate_log, sample_failure


def run(fast: bool = False) -> list[Row]:
    n = 60 if fast else 150
    rng = random.Random(0)
    sys_ = FailureDiagnosisSystem()
    correct = 0
    infra_auto = 0
    infra_total = 0
    rule_hits_late = 0
    results = []
    for i in range(n):
        ft = sample_failure(rng)
        log = generate_log(ft, seed=i, n_normal=300)
        diag = sys_.diagnose(log)
        ok = diag.failure == ft.name
        correct += ok
        results.append((i, ok, diag.source))
        if ft.category == "Infrastructure":
            # the operational claim: the failure is routed to the right
            # *recovery* (auto-restart, node cordon when needed) without a
            # human — exact-label accuracy is reported separately. The
            # paper itself notes its categories overlap (e.g. ECC <-> CUDA).
            infra_total += 1
            infra_auto += (diag.auto_recoverable
                           and diag.needs_node_cordon == ft.needs_node_cordon)
        if i >= n // 2 and diag.source == "rule":
            rule_hits_late += 1
    acc = correct / n
    late_rule_frac = rule_hits_late / (n - n // 2)
    comp = sys_.compressor.compression_ratio
    rows = [
        Row("diagnosis", "accuracy", acc, ">=0.9 (~90% manual reduction)",
            "", acc >= 0.9),
        Row("diagnosis", "infra_auto_recover_frac",
            infra_auto / max(infra_total, 1), "infra failures auto-routed",
            "", infra_auto / max(infra_total, 1) >= 0.9),
        Row("diagnosis", "late_rule_hit_frac", late_rule_frac,
            "rules learned over time (Fig.15 writeback)", "",
            late_rule_frac > 0.5),
        Row("diagnosis", "log_compression_ratio", comp,
            "hundreds-of-MB logs -> error tail", "x", comp > 20),
        Row("diagnosis", "n_failures", float(n), "", ""),
    ]
    return rows


def main(fast: bool = False) -> None:
    emit(run(fast), "diagnosis")


if __name__ == "__main__":
    main()
