"""Fig. 14 / §5.3: training progress under failures — manual on-call
recovery vs the automatic supervisor, on a real (tiny) JAX training run
with injected Table-3 infrastructure faults and a loss spike.

"Manual" recovery models the paper's early-2023 practice: a human notices
and restarts the job after a response latency (the paper's Fig. 14 shows
overnight gaps); the supervisor restarts immediately after diagnosis, uses
the in-RAM snapshot, and skips poisoned batches after spikes.
"""
from __future__ import annotations

import tempfile

from benchmarks.common import Row, emit

MANUAL_RESPONSE_STEPS = 60     # human notice+restart latency, in step units


def _run_supervised(steps: int, ckpt_every: int):
    import jax  # noqa: F401
    from repro.config import ParallelConfig, TrainConfig, get_smoke
    from repro.core.ft.checkpoint import CheckpointManager
    from repro.core.ft.detection import SimulatedFleet
    from repro.core.ft.diagnosis import FailureDiagnosisSystem
    from repro.core.ft.events import BY_NAME
    from repro.core.ft.supervisor import Supervisor
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train import Trainer
    from repro.models import Model
    from repro.sharding import make_rules

    cfg = get_smoke("smollm-360m")
    mesh = make_host_mesh()
    parallel = ParallelConfig(remat="none", moe_impl="dense")
    tcfg = TrainConfig(global_batch=4, seq_len=64, total_steps=steps,
                       warmup_steps=5, learning_rate=1e-3)
    model = Model(cfg, parallel, make_rules(mesh, parallel))
    with tempfile.TemporaryDirectory() as d:
        ckpt = CheckpointManager(d, keep=4)
        trainer = Trainer(
            model, tcfg, mesh, parallel, ckpt, total_steps=steps,
            ckpt_every=ckpt_every, log_every=10 ** 9,
            fault_schedule={steps // 3: BY_NAME["NVLinkError"],
                            2 * steps // 3: BY_NAME["ConnectionError"]},
            spike_schedule={steps // 2 + i: 6.0 for i in range(6)})
        fleet = SimulatedFleet(8)
        sup = Supervisor(ckpt, FailureDiagnosisSystem(), fleet)
        report = sup.run(trainer.job)
        ckpt.wait()
    losses = [l for _, l in trainer.history]
    return report, losses


def run(fast: bool = False) -> list[Row]:
    steps = 60 if fast else 90
    report, losses = _run_supervised(steps, ckpt_every=10)
    n_failures = sum(1 for e in report.events if e.kind == "failure")
    n_spikes = sum(1 for e in report.events if e.kind == "spike")
    # manual baseline cost model: same failures, human latency each time +
    # rollback to the last *persisted* checkpoint
    manual_lost = n_failures * (MANUAL_RESPONSE_STEPS + 10)
    auto_lost = report.lost_steps
    rows = [
        Row("recovery", "completed", float(report.completed),
            "job finishes unattended", "", report.completed),
        Row("recovery", "n_failures_injected", float(n_failures), "", ""),
        Row("recovery", "n_spikes_detected", float(n_spikes),
            "loss spike -> rollback+skip (§5.3)", "", n_spikes >= 1),
        Row("recovery", "auto_lost_steps", float(auto_lost), "", "steps"),
        Row("recovery", "manual_lost_steps_model", float(manual_lost),
            "Fig.14 overnight gaps", "steps"),
        Row("recovery", "recovery_cost_reduction",
            manual_lost / max(auto_lost, 1), "supervisor >> on-call human",
            "x", manual_lost / max(auto_lost, 1) > 2),
        Row("recovery", "diagnosis_accuracy", report.diagnosis_accuracy,
            "", "", report.diagnosis_accuracy >= 0.99),
        Row("recovery", "final_loss_finite_and_training",
            losses[-1], "loss resumes decreasing post-rollback", "",
            losses[-1] < losses[0]),
    ]
    return rows


def main(fast: bool = False) -> None:
    emit(run(fast), "recovery")


if __name__ == "__main__":
    main()
