"""Fig. 10/11 analog: InternEvo V1 (global ZeRO-3 gathers) vs V2
(hierarchical ZeRO bounded to a pod) on the paper's 123B model, multi-pod
mesh — compared via compiled collective traffic and memory (the dry-run
"profile"; the paper reports ~16% step acceleration and lower activation
memory for V2).

The paper's mechanism: bound the parameter-gather group so all-gathers stay
on fast intra-pod links and only gradient reduction crosses pods. In GSPMD
terms: fsdp axes (pod, data) -> (data).
"""
from __future__ import annotations

import dataclasses
import json
import os

from benchmarks.common import Row, emit

CACHE = "artifacts/bench/parallelism_cells.json"


def _measure():
    # run in a subprocess-like late import so the 512-device XLA flag is
    # only forced when this benchmark actually executes
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
    from repro.launch.dryrun import default_parallel, lower_cell
    from repro.launch.hlo_analysis import analyze, classify_collectives
    from repro.launch.mesh import make_production_mesh
    from repro.launch.shapes import SHAPES

    mesh = make_production_mesh(multi_pod=True)
    pod_boundary = mesh.devices.size // mesh.shape["pod"]
    out = {}
    for name, zero in (("v1_global_zero3", "zero3"),
                       ("v2_hier_zero3", "zero3_hier")):
        # bf16 grads for both: the fp32 gradient all-reduce otherwise
        # dominates cross-pod bytes equally on each side and masks the
        # param-gather locality difference (the paper's actual mechanism)
        par = dataclasses.replace(default_parallel("internlm-123b", mesh),
                                  zero=zero, grad_dtype="bfloat16")
        lowered = lower_cell("internlm-123b", SHAPES["train_4k"], mesh,
                             parallel=par)
        compiled = lowered.compile()
        a = analyze(compiled)
        cls = classify_collectives(compiled.as_text(), pod_boundary)
        out[name] = {
            "coll_bytes_per_dev": a["collectives"]["total_bytes_per_device"],
            "bytes_by_op": a["collectives"]["bytes_by_op"],
            "cross_pod_bytes": cls["cross_pod_bytes"],
            "pod_local_bytes": cls["pod_local_bytes"],
            "temp_gib": a["memory"].get("temp_size_in_bytes", 0) / 2 ** 30,
            "args_gib": a["memory"].get("argument_size_in_bytes", 0) / 2 ** 30,
        }
    return out


def run(fast: bool = False) -> list[Row]:
    if fast and os.path.exists(CACHE):
        cells = json.load(open(CACHE))
    else:
        cells = _measure()
        os.makedirs(os.path.dirname(CACHE), exist_ok=True)
        json.dump(cells, open(CACHE, "w"), indent=1)
    v1, v2 = cells["v1_global_zero3"], cells["v2_hier_zero3"]
    # cross-pod DCN is the scarce resource (the paper's single-IB-NIC pain):
    # hierarchical ZeRO bounds the param gathers to a pod, so its win shows
    # up as cross-pod bytes, not total bytes (intra-pod ICI is cheap).
    red = v1["cross_pod_bytes"] / max(v2["cross_pod_bytes"], 1.0)
    # headline: the share of collective traffic that stays on fast intra-pod
    # ICI. V2's parameter gathers are pod-bounded by construction; the
    # residual cross-pod bytes (batch/loss reductions) are identical on both
    # sides, so the SHARE is the clean signal in this scan-once proxy.
    lf1 = v1["pod_local_bytes"] / (v1["pod_local_bytes"]
                                   + v1["cross_pod_bytes"])
    lf2 = v2["pod_local_bytes"] / (v2["pod_local_bytes"]
                                   + v2["cross_pod_bytes"])
    rows = [
        Row("parallelism", "v1_pod_local_traffic_share", lf1, "", ""),
        Row("parallelism", "v2_pod_local_traffic_share", lf2,
            "hierarchical ZeRO keeps gathers on intra-pod links "
            "(Fig.10 V2, ~16% step win)", "", lf2 > lf1 + 0.1),
        Row("parallelism", "v1_cross_pod_gib_per_dev",
            v1["cross_pod_bytes"] / 2 ** 30, "", "GiB"),
        Row("parallelism", "v2_cross_pod_gib_per_dev",
            v2["cross_pod_bytes"] / 2 ** 30,
            "no higher than V1 despite 2x gather redundancy", "GiB",
            v2["cross_pod_bytes"] <= v1["cross_pod_bytes"] * 1.05),
        Row("parallelism", "v1_pod_local_gib", v1["pod_local_bytes"] / 2 ** 30,
            "", "GiB"),
        Row("parallelism", "v2_pod_local_gib", v2["pod_local_bytes"] / 2 ** 30,
            "gathers moved onto intra-pod ICI", "GiB",
            v2["pod_local_bytes"] > v1["pod_local_bytes"]),
        Row("parallelism", "v1_temp_gib", v1["temp_gib"], "", "GiB"),
        Row("parallelism", "v2_temp_gib", v2["temp_gib"],
            "memory/locality trade (Fig.11)", "GiB"),
    ]
    return rows


def main(fast: bool = False) -> None:
    emit(run(fast), "parallelism")


if __name__ == "__main__":
    main()
