"""§3 characterization (Fig. 2/3/4/5/6/17): the synthetic Acme trace must
reproduce the paper's headline statistics."""
from __future__ import annotations

from benchmarks.common import Row, emit
from repro.cluster import KALOS, generate_jobs, simulate_queue, trace_summary

HORIZON = 6 * 30 * 24 * 60.0


def run(fast: bool = False) -> list[Row]:
    jobs = generate_jobs(KALOS, seed=0,
                         n_jobs=8000 if fast else None)
    jobs = simulate_queue(jobs, KALOS.n_gpus, reserved_frac=0.97)
    s = trace_summary(jobs, KALOS.n_gpus, HORIZON)
    ts, d, q, st = (s["type_shares"], s["demand"], s["queue"], s["status"])
    med = s["duration"]["median_min"]
    rows = [
        Row("trace", "median_job_duration_min", med, "~2 (Fig.2a)", "min",
            0.8 <= med <= 3.5),
        Row("trace", "eval_count_frac", ts["evaluation"]["count_frac"],
            "0.929 (Fig.4c)", "",
            abs(ts["evaluation"]["count_frac"] - 0.929) < 0.01),
        Row("trace", "eval_gputime_frac", ts["evaluation"]["gputime_frac"],
            "0.008 (Fig.4d)", "", ts["evaluation"]["gputime_frac"] < 0.02),
        Row("trace", "pretrain_count_frac", ts["pretrain"]["count_frac"],
            "0.032 (Fig.4c)", "",
            abs(ts["pretrain"]["count_frac"] - 0.032) < 0.006),
        Row("trace", "pretrain_gputime_frac", ts["pretrain"]["gputime_frac"],
            "0.940 (Fig.4d)", "", ts["pretrain"]["gputime_frac"] > 0.90),
        Row("trace", "gputime_frac_ge256gpu", d["gputime_frac_ge256"],
            ">0.96 (Fig.3b)", "", d["gputime_frac_ge256"] > 0.88),
        Row("trace", "gputime_frac_single_gpu", d["gputime_frac_single_gpu"],
            "<0.02 (Fig.3b)", "", d["gputime_frac_single_gpu"] < 0.02),
        Row("trace", "eval_median_queue_min",
            q["evaluation"]["median_min"], "longest of all types (Fig.6d)",
            "min",
            all(q["evaluation"]["median_min"] >= v["median_min"]
                for v in q.values())),
        Row("trace", "pretrain_median_queue_min",
            q["pretrain"]["median_min"], "~0 (reservation)", "min",
            q["pretrain"]["median_min"] < 1.0),
        Row("trace", "failed_count_frac", st["failed"]["count_frac"],
            "~0.40 (Fig.17a)", "",
            abs(st["failed"]["count_frac"] - 0.40) < 0.05),
        Row("trace", "failed_gputime_frac", st["failed"]["gputime_frac"],
            "~0.10 (Fig.17b)", "", st["failed"]["gputime_frac"] < 0.25),
        Row("trace", "canceled_gputime_frac", st["canceled"]["gputime_frac"],
            ">0.60 (Fig.17b)", "", st["canceled"]["gputime_frac"] > 0.5),
    ]
    return rows


def main(fast: bool = False) -> None:
    emit(run(fast), "trace")


if __name__ == "__main__":
    main()
