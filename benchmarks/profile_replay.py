"""Profiling harness for the replay engine's per-event hot path.

Runs cProfile over a 200k-job full-feature replay (placement + best-effort
revocable leases + elastic regrowth + trial borrowing + diagnosis — the
heaviest configuration the engine supports) and writes the top-25
cumulative-time functions to ``artifacts/bench/profile_replay.json``.

This is the instrument behind the PR 5 hot-path rewrite: optimize what the
table shows, not what looks slow. Two caveats the table itself cannot tell
you (both bit us during that work):

  * cProfile charges ~1 us of tracer overhead per function call, so
    call-heavy code looks relatively worse than it is — treat the
    ``ncalls`` column as the reliable signal and confirm wall-clock wins
    with ``time.process_time`` on a quiet machine;
  * results on shared runners swing with CPU throttling; the calibrated
    ``events_per_calib`` probes (``benchmarks.common.calibrated_probe``)
    are the regression-grade numbers, this profile is for *finding* the
    next target.

Usage:

  PYTHONPATH=src python -m benchmarks.profile_replay [--fast] [--top N]
  PYTHONPATH=src python -m benchmarks.run --profile    # same, via runner
"""
from __future__ import annotations

import argparse
import cProfile
import json
import os
import pstats
import time

from benchmarks.common import ARTIFACTS
from repro.cluster import (KALOS, FailureInjector, ReplayConfig,
                           generate_jobs, replay_trace)
from repro.core.evalsched import STORAGE_SPEC, TrialBorrower

N_JOBS = 200_000
N_JOBS_FAST = 20_000
TOP_N = 25


def profile_replay(n_jobs: int = N_JOBS, top_n: int = TOP_N) -> dict:
    """Profile one full-feature replay; returns the JSON-ready document."""
    jobs = generate_jobs(KALOS, seed=0, n_jobs=n_jobs, best_effort_frac=0.3)
    cfg = ReplayConfig(injector=FailureInjector(seed=1, rate_scale=2.0),
                       diagnose=True, elastic=True, placement=True,
                       reshard_cost_min=1.0,
                       borrower=TrialBorrower.from_suite(
                           63, repeat=200, spec=STORAGE_SPEC))
    prof = cProfile.Profile()
    t0 = time.perf_counter()
    prof.enable()
    res = replay_trace(jobs, KALOS.n_gpus, reserved_frac=0.97, config=cfg)
    prof.disable()
    wall = time.perf_counter() - t0

    stats = pstats.Stats(prof)
    stats.sort_stats("cumulative")
    width, funcs = stats.get_print_list([top_n])
    rows = []
    for func in funcs:
        cc, nc, tt, ct, _ = stats.stats[func]
        path, line, name = func
        rows.append({
            "function": f"{os.path.basename(path)}:{line}({name})",
            "ncalls": int(nc),
            "primitive_calls": int(cc),
            "tottime_s": round(tt, 4),
            "cumtime_s": round(ct, 4),
        })
    return {
        "config": "full-feature (placement+best-effort+borrow+elastic"
                  "+diagnosis)",
        "n_jobs": n_jobs,
        "events_processed": res.events_processed,
        "profiled_wall_s": round(wall, 3),
        "events_per_profiled_s": round(res.events_processed / wall, 1),
        "note": "profiled wall includes cProfile tracer overhead "
                "(~1us/call); use events_per_calib for regression-grade "
                "throughput",
        "top_cumulative": rows,
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fast", action="store_true",
                    help=f"profile {N_JOBS_FAST} jobs instead of {N_JOBS}")
    ap.add_argument("--top", type=int, default=TOP_N)
    args = ap.parse_args(argv)
    doc = profile_replay(N_JOBS_FAST if args.fast else N_JOBS, args.top)
    os.makedirs(ARTIFACTS, exist_ok=True)
    out = os.path.join(ARTIFACTS, "profile_replay.json")
    with open(out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"# profile: {doc['events_processed']} events in "
          f"{doc['profiled_wall_s']}s (profiled) -> {out}")
    for r in doc["top_cumulative"][:10]:
        print(f"#   {r['cumtime_s']:8.3f}s cum {r['ncalls']:>9} calls  "
              f"{r['function']}")


if __name__ == "__main__":
    main()
