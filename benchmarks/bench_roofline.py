"""§Roofline: per-(arch x shape) three-term roofline from the dry-run
artifacts; identifies the dominant bottleneck per cell."""
from __future__ import annotations

import os

from benchmarks.common import Row, emit
from repro.launch.roofline import HEADER, full_table


def run(fast: bool = False) -> list[Row]:
    table = full_table()
    if not table:
        return [Row("roofline", "skipped_no_dryrun_artifacts", 0.0,
                    "run repro.launch.dryrun --calibrate first", "", None)]
    os.makedirs("artifacts", exist_ok=True)
    with open("artifacts/roofline.csv", "w") as f:
        f.write(HEADER + "\n")
        for r in table:
            f.write(r.row() + "\n")
    rows = [Row("roofline", "n_cells", float(len(table)), "35 runnable", "",
                len(table) >= 30)]
    by_dom = {}
    for r in table:
        by_dom[r.dominant] = by_dom.get(r.dominant, 0) + 1
    for dom, n in sorted(by_dom.items()):
        rows.append(Row("roofline", f"cells_dominated_by_{dom}", float(n),
                        "", ""))
    worst = min(table, key=lambda r: r.roofline_frac)
    best = max(table, key=lambda r: r.roofline_frac)
    rows += [
        Row("roofline", f"worst_frac[{worst.arch}/{worst.shape}]",
            worst.roofline_frac, "", ""),
        Row("roofline", f"best_frac[{best.arch}/{best.shape}]",
            best.roofline_frac, "", ""),
    ]
    return rows


def main(fast: bool = False) -> None:
    emit(run(fast), "roofline")


if __name__ == "__main__":
    main()
