"""§Roofline: per-(arch x shape) three-term roofline from the dry-run
artifacts; identifies the dominant bottleneck per cell.

Consumes ``artifacts/dryrun/single/**`` (CI's ``dryrun-smoke`` job
produces a small calibrated subset; a full local
``python -m repro.launch.dryrun --calibrate`` run widens the table).
Malformed or partial records are skipped with a counted reason
(``skipped_<reason>`` rows) rather than aborting the bench, and a missing
artifact tree is reported as the explicit ``artifact_cells_missing`` row —
the gated metrics still emit (as zeros) so the regression gate's
missing-metric check stays meaningful, and the dryrun provenance stamp
keeps ``check_regression`` from comparing a table against a baseline
built from a different cell set.
"""
from __future__ import annotations

import os

from benchmarks.common import Row, emit
from repro.launch.roofline import HEADER, full_table


def run(fast: bool = False) -> list[Row]:
    skipped: dict = {}
    table = full_table(skipped=skipped)
    rows = [Row("roofline", "n_cells", float(len(table)),
                "dryrun artifact cells", "count", len(table) > 0)]
    if not table:
        rows.append(Row("roofline", "artifact_cells_missing", 1.0,
                        "run repro.launch.dryrun --calibrate first", "",
                        None))
        rows += [Row("roofline", "n_calibrated_cells", 0.0, "", "count"),
                 Row("roofline", "worst_roofline_frac", 0.0, "", ""),
                 Row("roofline", "best_roofline_frac", 0.0, "", "")]
    else:
        os.makedirs("artifacts", exist_ok=True)
        with open("artifacts/roofline.csv", "w") as f:
            f.write(HEADER + "\n")
            for r in table:
                f.write(r.row() + "\n")
        by_dom: dict = {}
        for r in table:
            by_dom[r.dominant] = by_dom.get(r.dominant, 0) + 1
        for dom, n in sorted(by_dom.items()):
            rows.append(Row("roofline", f"cells_dominated_by_{dom}",
                            float(n), "", ""))
        n_cal = sum(1 for r in table if r.calibrated)
        worst = min(table, key=lambda r: r.roofline_frac)
        best = max(table, key=lambda r: r.roofline_frac)
        rows += [
            Row("roofline", "n_calibrated_cells", float(n_cal),
                "cells with depth-extrapolated totals", "count",
                n_cal == len(table)),
            # stable names for the regression gate; the cell identities
            # ride along as info rows
            Row("roofline", "worst_roofline_frac", worst.roofline_frac,
                "", ""),
            Row("roofline", "best_roofline_frac", best.roofline_frac,
                "", ""),
            Row("roofline", f"worst_cell[{worst.arch}/{worst.shape}]",
                worst.roofline_frac, "", ""),
            Row("roofline", f"best_cell[{best.arch}/{best.shape}]",
                best.roofline_frac, "", ""),
        ]
    for reason, n in sorted(skipped.items()):
        rows.append(Row("roofline", f"skipped_{reason}", float(n),
                        "malformed/partial records tolerated", "count"))
    return rows


def main(fast: bool = False) -> None:
    emit(run(fast), "roofline")


if __name__ == "__main__":
    main()
